"""Property tests for the calibration round trip (tier-2).

For randomized ground-truth ``SoCParams`` (mesh shape x link latency x
burst framing x flops throughput), synthesizing timings through the flit
simulator and fitting from a deliberately wrong starting point must
recover every fitted field:

* ``link_latency`` and ``burst_bytes`` exactly — both are discrete
  hardware choices on the fitter's candidate grids, and the generator and
  the fitter share one forward model, so the residual at the truth is the
  noise floor;
* ``flops_per_cycle`` to the closed-form LS tolerance (exact with zero
  noise, within the jitter scale under seeded noise);
* the residual at the recovered params is ~zero with zero noise, and the
  per-field confidences reflect it.

Runs under real ``hypothesis`` when installed, else under the vendored
deterministic fallback (``tests/_hypothesis_vendor.py``) — keep that
module's strategy surface (``fixed_dictionaries`` included) in sync with
what this file imports.
"""

import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.calib import fit as calib_fit
from repro.calib import measure
from repro.core.noc.perfmodel import SoCParams

pytestmark = pytest.mark.tier2

# Small candidate grids keep each example's coordinate search cheap while
# still forcing the fitter to *choose* (truth always on-grid — the
# documented exact-recovery regime; off-grid truths resolve to the nearest
# candidate and are not property-tested here).
_LINKS = (1, 2, 4)
_BURSTS = (2048, 4096, 8192)
_FPCS = (2048.0, 4096.0, 8192.0)
_MESHES = ((4, 3), (4, 4), (5, 4))

_field_overrides = st.fixed_dictionaries({
    "link_latency": st.sampled_from(_LINKS),
    "burst_bytes": st.sampled_from(_BURSTS),
    "flops_per_cycle": st.sampled_from(_FPCS),
})


def _truth_params(mesh, overrides) -> SoCParams:
    w, h = mesh
    if (w, h) == (4, 3):
        return SoCParams(**overrides)
    return SoCParams.pod(w, h, **overrides)


def _wrong_base(truth: SoCParams) -> SoCParams:
    """A starting point that disagrees with the truth on every fitted
    field — recovery must come from the observations, not the prior."""
    return dataclasses.replace(
        truth,
        link_latency=next(l for l in _LINKS if l != truth.link_latency),
        burst_bytes=next(b for b in _BURSTS if b != truth.burst_bytes),
        flops_per_cycle=next(f for f in _FPCS
                             if f != truth.flops_per_cycle))


@settings(deadline=None, max_examples=15)
@given(mesh=st.sampled_from(_MESHES), overrides=_field_overrides)
def test_fit_round_trips_ground_truth(mesh, overrides):
    truth = _truth_params(mesh, overrides)
    obs = (measure.flit_sim_observations(truth) +
           measure.compute_observations(truth))
    cp = calib_fit.fit_soc_params(
        obs, base=_wrong_base(truth),
        link_candidates=_LINKS, burst_candidates=_BURSTS)
    assert cp.params.link_latency == truth.link_latency
    assert cp.params.burst_bytes == truth.burst_bytes
    assert cp.params.flops_per_cycle == pytest.approx(
        truth.flops_per_cycle, rel=1e-6)
    assert cp.residual <= 1e-9
    for name in calib_fit.FIT_FIELDS:
        assert cp.fields[name].n_obs > 0
        assert cp.fields[name].confidence > 0.99
    # topology is carried, never inferred: the fitted params keep the
    # truth's floorplan
    assert (cp.params.mesh_w, cp.params.mesh_h) == mesh
    assert cp.params.mem_tile == truth.mem_tile


@settings(deadline=None, max_examples=10)
@given(mesh=st.sampled_from(_MESHES), overrides=_field_overrides,
       noise=st.sampled_from((0.01, 0.02)),
       seed=st.integers(min_value=0, max_value=7))
def test_fit_round_trips_under_seeded_noise(mesh, overrides, noise, seed):
    """Seeded multiplicative jitter: the discrete fields still land
    exactly (grid-point residual gaps dwarf the noise floor) and the
    continuous flops fit stays within a few noise scales."""
    truth = _truth_params(mesh, overrides)
    obs = (measure.flit_sim_observations(truth, noise=noise, seed=seed) +
           measure.compute_observations(truth, noise=noise, seed=seed))
    cp = calib_fit.fit_soc_params(
        obs, base=_wrong_base(truth),
        link_candidates=_LINKS, burst_candidates=_BURSTS)
    assert cp.params.link_latency == truth.link_latency
    assert cp.params.burst_bytes == truth.burst_bytes
    assert cp.params.flops_per_cycle == pytest.approx(
        truth.flops_per_cycle, rel=5 * noise)
    assert cp.residual <= 3 * noise
