"""Chaos stage: kill half the hosts mid-run and require the full elastic
recovery — checkpoint-restore, ``shrink_mesh`` onto the survivors,
re-mesh => re-plan (the weights broadcast flips MEM -> MCAST once the
fan-out fits under the pod's multicast capacity), and loss-curve
continuity across the topology change.

Runs in a subprocess with 8 forced host devices (see conftest).  The NoC
model is a 3x3 pod: 9 tiles minus mem/cpu/io leaves 6 accelerators, so
``max_dests`` is 5 — an 8-way data axis prices the weights broadcast
over capacity (MEM), the 4-way survivor axis under it (MCAST).  That
makes the decision flip a *guarantee* of the scenario, not a tuning
accident.

scripts/ci.sh runs this as its own timed stage (-m chaos) so tier-1
stays fast.
"""

import pytest

_CHAOS_CODE = r"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.core import socket as SOCK
from repro.core.noc.perfmodel import SoCParams, SoCPerfModel
from repro.core.planner import resolve_policy
from repro.data import SyntheticTokenStream
from repro.models.transformer import RunFlags
from repro.runtime.fault import (FaultError, FaultTolerantRunner,
                                 replan_for_mesh, shrink_mesh)
from repro.runtime.train import (init_state, make_train_step,
                                 resolved_train_rules)

B, SEQ, STEPS, FAIL_AT = 8, 64, 12, 7
cfg = get_reduced("smollm-135m")
flags = RunFlags(remat="none")
shape = ShapeConfig("chaos", SEQ, B, "train")
model = SoCPerfModel(SoCParams.pod(3, 3))      # max_dests=5: 8 > cap > 4

devices = jax.devices()
assert len(devices) == 8, len(devices)
mesh = jax.sharding.Mesh(np.asarray(devices).reshape(8, 1),
                         ("data", "model"))
plan, _ = resolve_policy("auto", cfg, shape, dict(mesh.shape), model=model)
assert plan.mode("weights").name == "MEM", plan.modes   # 8-way > cap 5

SOCK.reset_issue_log()
step_fn, state_sh, _ = make_train_step(
    cfg, flags, mesh, lr=1e-3, total_steps=STEPS, batch_shape=(B, SEQ),
    comm_plan=plan)
jstep = jax.jit(step_fn, donate_argnums=0)
state = init_state(jax.random.key(0), cfg, flags)
stream = SyntheticTokenStream(cfg.vocab_size, B, SEQ)
batches = lambda s: {k: jnp.asarray(v) for k, v in stream.batch(s).items()}


def remesh_hook(at_step, err):
    # half the pod died: shrink onto the survivors and re-plan there
    survivors = list(mesh.devices.flat)[:4]
    new_mesh = shrink_mesh(survivors, 1)
    new_axes = dict(new_mesh.shape)
    assert new_axes == {"data": 4, "model": 1}, new_axes
    new_plan, _, rules, _, flips = replan_for_mesh(
        plan, cfg, shape, new_axes, resolve=resolved_train_rules,
        model=model)
    assert new_plan.mode("weights").name == "MCAST", new_plan.modes
    sfn, sh, _ = make_train_step(
        cfg, flags, new_mesh, rules=rules, lr=1e-3, total_steps=STEPS,
        batch_shape=(B, SEQ), comm_plan=new_plan)
    return {"step_fn": jax.jit(sfn, donate_argnums=0), "shardings": sh,
            "flips": flips, "mesh_axes": new_axes}


ckpt = tempfile.mkdtemp(prefix="chaos_ckpt_")
runner = FaultTolerantRunner(jstep, ckpt, ckpt_every=3,
                             remesh_hook=remesh_hook)
fails = {FAIL_AT}


def inject(step):
    if step in fails:
        fails.discard(step)
        raise FaultError("hosts 4-7 lost")


runner.inject_failures(inject)
state, hist = runner.run(state, batches, STEPS, shardings=state_sh)

# --- acceptance: checkpoint-restore + re-mesh happened -----------------
assert runner.restarts == 1, runner.restarts
steps = [h["step"] for h in hist]
assert steps == list(range(FAIL_AT)) + list(range(6, STEPS)), steps

# --- acceptance: the re-plan event records the decision flip -----------
assert len(runner.comm_replan_events) == 1, runner.comm_replan_events
ev = runner.comm_replan_events[0]
assert ev["step"] == FAIL_AT and ev["error"] == "hosts 4-7 lost", ev
assert ev["mesh_axes"] == {"data": 4, "model": 1}, ev
assert {"tensor": "weights", "old": "MEM", "new": "MCAST"} in ev["flips"], ev

# --- acceptance: loss-curve continuity across the topology change ------
# step 6 ran twice: on the 8-way mesh pre-fault and on the 4-way
# survivor mesh post-restore, from the same checkpointed state and the
# same counter-mode batch — only the reduction topology differs
by_step = {}
for h in hist:
    by_step.setdefault(h["step"], []).append(h["loss"])
pre, post = by_step[6]
assert abs(pre - post) <= 1e-3 * max(abs(pre), 1.0), (pre, post)
assert all(np.isfinite(l) for ls in by_step.values() for l in ls)

# --- acceptance: every socket downgrade carries a machine-readable why -
recs = SOCK.issued_records()
assert recs, "no socket issue records — the comm spine was bypassed"
for r in recs:
    if r.issued is not r.planned:
        assert r.degraded_reason, (
            f"undocumented downgrade at {r.site}: "
            f"{r.planned} -> {r.issued}")
print("CHAOS_OK restarts=%d flips=%d pre=%.6f post=%.6f"
      % (runner.restarts, len(ev["flips"]), pre, post))
"""


@pytest.mark.chaos
def test_kill_half_the_hosts_mid_run(subproc):
    out = subproc(_CHAOS_CODE, n_devices=8)
    assert "CHAOS_OK" in out
