"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs forward/train/prefill/decode on CPU with shape
and finiteness checks.  (Full configs are exercised only via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced, SHAPES, \
    shape_applicable
from repro.models import transformer as T

FLAGS = T.RunFlags(remat="none")


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch_setup(request):
    cfg = get_reduced(request.param)
    params = T.init_params(jax.random.key(0), cfg)
    return request.param, cfg, params


def test_full_config_matches_assignment():
    expect = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    }
    for name, (L, d, H, K, ff, V) in expect.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, H, K, ff, V), name


def test_moe_configs():
    assert get_config("dbrx-132b").moe.n_experts == 16
    assert get_config("dbrx-132b").moe.top_k == 4
    assert get_config("llama4-maverick-400b-a17b").moe.n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").moe.top_k == 1
    assert get_config("falcon-mamba-7b").ssm.state_dim == 16


def test_param_counts_in_expected_range():
    # sanity: derived parameter counts near the advertised sizes
    ranges = {
        "smollm-135m": (0.1e9, 0.2e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "qwen3-4b": (3e9, 5.5e9),
        "falcon-mamba-7b": (5e9, 9e9),
        "qwen2-vl-72b": (60e9, 85e9),
        "dbrx-132b": (110e9, 150e9),
        "llama4-maverick-400b-a17b": (330e9, 460e9),
    }
    for name, (lo, hi) in ranges.items():
        n = get_config(name).param_count()
        assert lo < n < hi, (name, n)
    # active params for MoE archs are far below total
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.active_param_count() < 0.15 * l4.param_count()


def test_long500k_applicability():
    runnable = {a for a in ARCH_NAMES
                if shape_applicable(get_config(a), SHAPES["long_500k"])}
    assert runnable == {"h2o-danube-3-4b", "recurrentgemma-9b",
                        "falcon-mamba-7b"}


def test_train_step_shapes_and_finite(arch_setup):
    name, cfg, params = arch_setup
    B, S = 2, 64
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    loss = jax.jit(lambda p, b: T.forward_train(p, b, cfg, FLAGS))(
        params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name


def test_train_grads_finite(arch_setup):
    name, cfg, params = arch_setup
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    grads = jax.jit(jax.grad(
        lambda p: T.forward_train(p, batch, cfg, FLAGS)))(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), (name, path)


def test_prefill_and_decode(arch_setup):
    name, cfg, params = arch_setup
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab_size)
    logits, caches = jax.jit(
        lambda p, t: T.prefill(p, t, cfg, FLAGS))(params, toks)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), name

    cache = T.make_cache(cfg, B, S)
    logits2, cache2 = jax.jit(
        lambda p, t, c: T.decode_step(p, t, jnp.int32(S - 1), c, cfg,
                                      FLAGS))(params, toks[:, :1], cache)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), name
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_decode_cache_sizes_respect_window():
    cfg = get_reduced("h2o-danube-3-4b")
    cache = T.make_cache(cfg, 2, 1024)  # window = 32 in the reduced config
    leaves = jax.tree.leaves(cache)
    kv = [l for l in leaves if l.ndim == 5]
    assert kv and all(l.shape[2] == cfg.local_window for l in kv)
