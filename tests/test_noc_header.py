"""Header-flit layout (paper C2): destination capacity and roundtrip."""

import pytest
from hypothesis import given, strategies as st

from repro.core.noc.header import (max_multicast_dests, encode_header,
                                   decode_header, ESP_MAX_DESTS)


def test_paper_capacities():
    # "a 64-bit NoC can encode up to 5 destinations, and a 128-bit NoC can
    #  encode up to 14 destinations"
    assert max_multicast_dests(64) == 5
    assert max_multicast_dests(128) == 14
    # "ESP supports multicasts of up to 16 destinations"
    assert max_multicast_dests(256) == 16
    assert max_multicast_dests(1024) == ESP_MAX_DESTS


def test_capacity_monotone():
    caps = [max_multicast_dests(w) for w in range(32, 512, 8)]
    assert all(a <= b for a, b in zip(caps, caps[1:]))


coord = st.tuples(st.integers(0, 7), st.integers(0, 7))


@given(src=coord, dests=st.lists(coord, max_size=14, unique=True),
       bw=st.sampled_from([128, 256]))
def test_header_roundtrip(src, dests, bw):
    if len(dests) > max_multicast_dests(bw):
        with pytest.raises(ValueError):
            encode_header(src, dests, bw)
        return
    h = encode_header(src, dests, bw, msg_type=3)
    rsrc, mtype, rdests = decode_header(h, bw)
    assert rsrc == src
    assert mtype == 3
    assert rdests == list(dests)


def test_header_fits_bitwidth():
    h = encode_header((7, 7), [(i % 8, i // 8) for i in range(14)], 128)
    assert h < (1 << 128)


def test_coord_range_checked():
    with pytest.raises(ValueError):
        encode_header((8, 0), [(0, 0)], 256)
