"""Socket robustness: bounded retry, the typed degradation ladder, and
the sync-fence stall watchdog.

A socket without a :class:`RetryPolicy` must behave exactly as before the
ladder existed (nothing caught); with one bound, a flaky kernel rung
retries with backoff, downgrades with a machine-readable
``degraded_reason``, and only a fully exhausted ladder raises
:class:`~repro.core.comm.FaultError` — the fault-tolerant runner's
recovery signal, re-exported unchanged from ``runtime.fault``."""

import time

import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import socket as SOCK
from repro.core.comm import CommMode, FaultError, TransferDescriptor
from repro.core.socket import (AcceleratorSocket, IssueRecord, RetryPolicy,
                               DEGRADATION_LADDER)

DESC = TransferDescriptor("weights", site="t.degrade")


def _policy(**kw):
    sleeps = []
    kw.setdefault("backoff_s", 0.01)
    pol = RetryPolicy(sleep=sleeps.append, **kw)
    return pol, sleeps


# ------------------------------------------------------------ RetryPolicy ----

def test_schedule_is_capped_geometric():
    pol = RetryPolicy(max_attempts=4, backoff_s=0.1, multiplier=2.0,
                      max_backoff_s=0.3)
    assert list(pol.schedule()) == pytest.approx([0.1, 0.2, 0.3])
    assert list(RetryPolicy(max_attempts=1).schedule()) == []
    assert RetryPolicy().sleep is time.sleep   # wall clock by default


def test_no_policy_never_catches():
    sock = AcceleratorSocket()
    with pytest.raises(ZeroDivisionError):
        sock._attempt(lambda: 1 // 0)


def test_flaky_rung_retries_then_succeeds():
    pol, sleeps = _policy(max_attempts=3)
    sock = AcceleratorSocket(retry=pol)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("link glitch")
        return 7

    assert sock._attempt(flaky) == (True, 7)
    assert calls["n"] == 3
    assert sleeps == pytest.approx(list(pol.schedule()))


def test_exhausted_rung_reports_attempts_and_error():
    pol, _ = _policy(max_attempts=2)
    sock = AcceleratorSocket(retry=pol)
    ok, (attempts, err) = sock._attempt(lambda: 1 // 0)
    assert not ok and attempts == 2
    assert isinstance(err, ZeroDivisionError)


def test_faulterror_is_never_retried():
    pol, sleeps = _policy(max_attempts=5)
    sock = AcceleratorSocket(retry=pol)

    def fatal():
        raise FaultError("watchdog fired inside the rung")

    with pytest.raises(FaultError):
        sock._attempt(fatal)
    assert sleeps == []   # no retry, no backoff


@pytest.mark.tier2
@settings(deadline=None, max_examples=30)
@given(attempts=st.integers(1, 8),
       backoff=st.floats(0.001, 0.5),
       mult=st.floats(1.0, 4.0),
       cap=st.floats(0.001, 1.0))
def test_schedule_properties(attempts, backoff, mult, cap):
    """len == max_attempts - 1; every delay positive and capped; the
    first delay is the base backoff (capped)."""
    pol = RetryPolicy(max_attempts=attempts, backoff_s=backoff,
                      multiplier=mult, max_backoff_s=cap)
    sched = list(pol.schedule())
    assert len(sched) == attempts - 1
    assert all(0 < d <= cap for d in sched)
    if sched:
        assert sched[0] == pytest.approx(min(backoff, cap))


# ------------------------------------------------------- degradation ladder ----

def _rungs(fail_first_n, results=("kern", "serial", "mem")):
    """Three ladder rungs where the first ``fail_first_n`` always raise."""
    def make(i, val):
        def thunk():
            if i < fail_first_n:
                raise RuntimeError(f"rung {i} down")
            return val
        return thunk
    issued = (CommMode.MCAST, CommMode.MCAST, CommMode.MEM)
    users = (3, 3, 0)
    impls = ("mcast_stream_kernel", "fork_tree", "mem_roundtrip")
    return [(DEGRADATION_LADDER[i], issued[i], users[i], impls[i], i == 0,
             make(i, results[i])) for i in range(3)]


def test_ladder_first_rung_success_logs_fused_undegraded():
    SOCK.reset_issue_log()
    pol, _ = _policy(max_attempts=1)
    sock = AcceleratorSocket(retry=pol)
    out = sock._ladder(DESC, "write", CommMode.MCAST, 128, _rungs(0))
    assert out == "kern"
    rec = SOCK.issued_records()[-1]
    assert rec.fused and rec.impl == "mcast_stream_kernel"
    assert rec.degraded_reason is None


def test_ladder_downgrade_carries_machine_readable_reason():
    SOCK.reset_issue_log()
    pol, _ = _policy(max_attempts=2)
    sock = AcceleratorSocket(retry=pol)
    out = sock._ladder(DESC, "write", CommMode.MCAST, 128, _rungs(1))
    assert out == "serial"
    rec = SOCK.issued_records()[-1]
    assert rec.impl == "fork_tree" and not rec.fused
    assert rec.issued == "MCAST"
    assert "ladder FUSED_RING->P2P" in rec.degraded_reason
    assert "2 attempt(s)" in rec.degraded_reason
    assert "RuntimeError" in rec.degraded_reason


def test_ladder_mem_rung_accumulates_both_hops():
    SOCK.reset_issue_log()
    pol, _ = _policy(max_attempts=1)
    sock = AcceleratorSocket(retry=pol)
    out = sock._ladder(DESC, "write", CommMode.MCAST, 128, _rungs(2))
    assert out == "mem"
    rec = SOCK.issued_records()[-1]
    assert rec.issued == "MEM" and rec.user == 0
    assert "ladder FUSED_RING->P2P" in rec.degraded_reason
    assert "ladder P2P->MEM" in rec.degraded_reason


def test_ladder_exhausted_raises_faulterror():
    SOCK.reset_issue_log()
    pol, _ = _policy(max_attempts=2)
    sock = AcceleratorSocket(retry=pol)
    with pytest.raises(FaultError, match="ladder exhausted at rung MEM"):
        sock._ladder(DESC, "write", CommMode.MCAST, 128, _rungs(3))
    # nothing was logged: the dispatch never completed
    assert SOCK.issued_records() == []


# ----------------------------------------------------- fence stall watchdog ----

def test_fence_watchdog_turns_stall_into_faulterror(monkeypatch):
    monkeypatch.setattr(SOCK.SYNC, "barrier",
                        lambda axis: time.sleep(30))
    sock = AcceleratorSocket(axis_name="x", fence_timeout_s=0.05)
    with pytest.raises(FaultError, match="stalled past"):
        sock._fence(jnp.ones((2,)), CommMode.P2P)


def test_fence_watchdog_passes_through_fast_barriers(monkeypatch):
    flags = []
    monkeypatch.setattr(SOCK.SYNC, "barrier", lambda axis: "FLAG")
    monkeypatch.setattr(SOCK.SYNC, "ordered_after",
                        lambda x, flag: flags.append(flag) or x)
    sock = AcceleratorSocket(axis_name="x", fence_timeout_s=5.0)
    x = jnp.ones((2,))
    assert sock._fence(x, CommMode.P2P) is x
    assert flags == ["FLAG"]


def test_fence_watchdog_propagates_barrier_errors(monkeypatch):
    def bad(axis):
        raise ValueError("unknown axis")

    monkeypatch.setattr(SOCK.SYNC, "barrier", bad)
    sock = AcceleratorSocket(axis_name="x", fence_timeout_s=5.0)
    with pytest.raises(ValueError, match="unknown axis"):
        sock._fence(jnp.ones((2,)), CommMode.P2P)


def test_fence_watchdog_disabled_by_default(monkeypatch):
    seen = []
    monkeypatch.setattr(SOCK.SYNC, "barrier",
                        lambda axis: seen.append(axis) or "F")
    monkeypatch.setattr(SOCK.SYNC, "ordered_after", lambda x, flag: x)
    sock = AcceleratorSocket(axis_name="x")   # fence_timeout_s=0.0
    sock._fence(jnp.ones((2,)), CommMode.P2P)
    assert seen == ["x"]   # direct call, no thread


# --------------------------------------------------- record / error plumbing ----

def test_faulterror_reexported_from_runtime_fault():
    from repro.core.comm import FaultError as core_err
    from repro.runtime.fault import FaultError as runtime_err
    assert runtime_err is core_err


def test_degraded_reason_compat_alias():
    rec = IssueRecord(site="s", name="n", channel="write", planned="MCAST",
                      issued="MEM", user=0, nbytes=4, impl="x",
                      degraded_reason="why")
    assert rec.degraded == "why"
    SOCK.reset_issue_log()
    SOCK.record_implicit_issue("weights", planned=CommMode.MCAST,
                               issued=CommMode.MEM, impl="xla",
                               reason="gate held", site="t.site")
    entry = SOCK.issued_modes()["t.site"]
    assert entry["degraded_reason"] == "gate held"
    assert entry["degraded"] == "gate held"   # legacy artifact key


# -------------------------------------------- end-to-end under shard_map ----

_LADDER_E2E_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.comm import (CommMode, CommPlan, FaultError,
                             TransferDescriptor, register_fusion_target)
from repro.core import socket as SOCK
import repro.kernels.ring_allgather_matmul as RK

mesh = compat.make_mesh((8,), ("x",), axis_types=(compat.AxisType.Auto,))
ip = compat.interpret_params()
plan = CommPlan({"weights": CommMode.P2P})
register_fusion_target("mlp.up_proj")
gdesc = TransferDescriptor("weights", fused_with="mlp.up_proj",
                           site="t.gather")
x = jax.random.normal(jax.random.key(0), (8 * 4, 16), jnp.float32)
w = jax.random.normal(jax.random.key(1), (16, 8), jnp.float32)

calls = {"n": 0}
def flaky(*a, **k):
    calls["n"] += 1
    raise RuntimeError("NoC link down")
RK.ring_allgather_matmul_local = flaky

sleeps = []
pol = SOCK.RetryPolicy(max_attempts=2, backoff_s=0.001, sleep=sleeps.append)

def run():
    def body(xs, ws):
        s = SOCK.socket_for_axis("x", plan, use_kernels=True, interpret=ip,
                                 retry=pol)
        return s.gather_matmul(xs, ws, gdesc)
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("x", None), P(None, None)),
        out_specs=P(None, None), check_vma=False))(x, w)

SOCK.reset_issue_log()
out = run()
# the dead kernel retried once per policy, then the serial rung delivered
# identical numbers under the same P2P verdict, reason attached
assert calls["n"] == 2 and sleeps == [0.001], (calls, sleeps)
np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                           rtol=1e-4, atol=1e-4)
rec = SOCK.issued_records()[-1]
assert rec.impl == "lax_all_gather" and not rec.fused, rec
assert rec.issued == "P2P"
assert rec.degraded_reason and "ladder FUSED_RING->P2P" in rec.degraded_reason
assert SOCK.issued_matches_plan(plan)

# without a policy the same dead kernel crashes the trace (legacy behavior)
calls["n"] = 0
def run_bare():
    def body(xs, ws):
        s = SOCK.socket_for_axis("x", plan, use_kernels=True, interpret=ip)
        return s.gather_matmul(xs, ws, gdesc)
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("x", None), P(None, None)),
        out_specs=P(None, None), check_vma=False))(x, w)
try:
    run_bare()
except RuntimeError as e:
    assert "NoC link down" in str(e)
else:
    raise AssertionError("bare socket should not catch kernel errors")
print("LADDER_E2E_OK", flush=True)
"""


def test_ladder_degrades_inside_shard_map(subproc):
    """A dead FUSED_RING kernel inside a real 8-way shard_map trace
    retries per policy, degrades to the serial lax rung with identical
    numerics and a machine-readable reason — and without a policy the
    error still propagates untouched."""
    out = subproc(_LADDER_E2E_CODE, n_devices=8)
    assert "LADDER_E2E_OK" in out
