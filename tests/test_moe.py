"""MoE routing/dispatch semantics (single-device local path; the
distributed mem-vs-mcast equivalence runs in test_distributed.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models import moe as M


def _setup(arch="dbrx-132b", seed=0):
    cfg = get_reduced(arch)
    params = M.moe_init(jax.random.key(seed), cfg)
    return cfg, params


def _dense_oracle(params, x, cfg):
    """Every token through its top-k experts at unlimited capacity."""
    B, S, d = x.shape
    x_flat = x.reshape(-1, d)
    gates, idx, _ = M._route(params["router"], x_flat, cfg.moe.top_k)
    out = np.zeros((B * S, d), np.float32)
    for e in range(cfg.moe.n_experts):
        toks = np.asarray(x_flat, np.float32)
        g = jnp.einsum("cd,df->cf", x_flat.astype(jnp.bfloat16),
                       params["w_gate"][e].astype(jnp.bfloat16))
        u = jnp.einsum("cd,df->cf", x_flat.astype(jnp.bfloat16),
                       params["w_up"][e].astype(jnp.bfloat16))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(jnp.bfloat16) * u
        y_e = jnp.einsum("cf,fd->cd", h,
                         params["w_down"][e].astype(jnp.bfloat16))
        w_e = np.asarray(jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1))
        out += np.asarray(y_e, np.float32) * w_e[:, None]
    return out.reshape(B, S, d)


def test_moe_local_matches_dense_oracle():
    cfg, params = _setup()
    # capacity_factor high enough that nothing drops
    cfg = cfg.__class__(**{**cfg.__dict__,
                           "moe": cfg.moe.__class__(cfg.moe.n_experts,
                                                    cfg.moe.top_k, 8.0)})
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = M.moe_apply(params, x, cfg, mode="mem", model_axis=None)
    oracle = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), oracle, rtol=5e-2, atol=5e-2)
    assert np.isfinite(float(aux))


def test_router_topk_normalized():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.key(2), (32, cfg.d_model))
    gates, idx, aux = M._route(params["router"], x, cfg.moe.top_k)
    assert gates.shape == (32, cfg.moe.top_k)
    np.testing.assert_allclose(jnp.sum(gates, -1), jnp.ones(32), rtol=1e-5)
    # top-k indices are distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == cfg.moe.top_k


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 100))
def test_capacity_drops_lowest_gates(seed):
    """When an expert is oversubscribed, the kept tokens are the
    highest-gate ones (the documented drop policy)."""
    cfg, params = _setup(seed=seed)
    x = jax.random.normal(jax.random.key(seed), (1, 8, cfg.d_model))
    x_flat = x.reshape(-1, cfg.d_model)
    gates, idx, _ = M._route(params["router"], x_flat, cfg.moe.top_k)
    experts = jnp.arange(cfg.moe.n_experts)
    toks, src, w = M._select_for_experts(x_flat, gates, idx, experts, 2)
    w = np.asarray(w)
    for e in range(cfg.moe.n_experts):
        g = np.asarray(jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1))
        kept = w[e][w[e] > 0]
        expected = np.sort(g[g > 0])[::-1][:2]
        np.testing.assert_allclose(np.sort(kept)[::-1], expected, rtol=1e-5)


def test_top1_is_unicast_top4_is_multicast():
    """The user-field analogy: top-1 routes each token to exactly one
    expert (unicast P2P), top-k to k (multicast)."""
    for arch, k in (("llama4-maverick-400b-a17b", 1), ("dbrx-132b", 2)):
        cfg, params = _setup(arch)
        x = jax.random.normal(jax.random.key(3), (16, cfg.d_model))
        gates, idx, _ = M._route(params["router"], x, cfg.moe.top_k)
        assert idx.shape[-1] == k == cfg.moe.top_k
