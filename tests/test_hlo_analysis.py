"""The roofline instrument itself: trip-count-aware HLO walking."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (analyze_hlo_text, parse_collectives,
                                       parse_computations, comp_multipliers)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_trip_count_multiplies_flops():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=50)
        return out

    comp = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    cost = analyze_hlo_text(comp.as_text())
    expected = 50 * 2 * 64 * 64 * 64
    assert cost.flops == pytest.approx(expected, rel=0.01)


def test_nested_scans_multiply():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    comp = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    cost = analyze_hlo_text(comp.as_text())
    assert cost.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)


def test_unrolled_dot_flops_exact():
    def f(a, b):
        return a @ b

    comp = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                    jax.ShapeDtypeStruct((256, 64), jnp.float32))
    cost = analyze_hlo_text(comp.as_text())
    assert cost.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)
    assert cost.dot_count == 1


def test_peak_estimate_sees_loop_carry():
    def f(x):
        def body(c, _):
            return jnp.tanh(c), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    big = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)  # 4 MB carry
    comp = _compile(f, big)
    cost = analyze_hlo_text(comp.as_text(), argument_bytes=4 * 1024 * 1024)
    assert cost.peak_bytes_est >= 8 * 1024 * 1024  # args + carried tuple


def test_collective_parsing_formats():
    hlo = """
ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[256,64]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[16,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    colls = parse_collectives(hlo)
    assert colls["all-reduce"].count == 1
    ar_bytes = 16 * 64 * 4
    assert colls["all-reduce"].wire_bytes == pytest.approx(
        2 * ar_bytes * 15 / 16)
    ag_bytes = 256 * 64 * 2
    assert colls["all-gather"].wire_bytes == pytest.approx(
        ag_bytes * 3 / 4)
    assert colls["collective-permute"].wire_bytes == pytest.approx(
        16 * 64 * 4)


def test_multiplier_map():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    comp = _compile(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_computations(comp.as_text())
    mult = comp_multipliers(comps)
    assert any(abs(m - 7.0) < 0.5 for m in mult.values()), mult
