import os
import subprocess
import sys
import types

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _install_hypothesis_fallback():
    """Make the property-test modules importable on a network-less box: if
    the real ``hypothesis`` is absent, register the deterministic vendored
    fallback (``_hypothesis_vendor``) under its import names BEFORE pytest
    collects the test modules (conftest imports first)."""
    try:
        import hypothesis  # noqa: F401  (real package wins when present)
        return
    except ImportError:
        pass
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_vendor as vendor

    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = vendor.__doc__
    hyp.__version__ = vendor.__version__
    hyp.given = vendor.given
    hyp.settings = vendor.settings
    hyp.assume = vendor.assume

    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "tuples", "lists", "booleans",
                 "just", "text", "floats", "one_of", "permutations",
                 "fixed_dictionaries"):
        setattr(st, name, getattr(vendor, name))
    hyp.strategies = st

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_fallback()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tier2: slower property-test stage; scripts/ci.sh runs it as its own "
        "timed stage after tier-1 (select with -m tier2)")
    config.addinivalue_line(
        "markers",
        "chaos: end-to-end fault-injection stage (subprocess kill-a-host "
        "chaos test); scripts/ci.sh runs it as its own timed stage "
        "(select with -m chaos)")


@pytest.fixture(autouse=True)
def _reset_planner_state():
    """Planner plan-cache and HLO spec-cache globals otherwise leak across
    tests (cache-stats assertions in one test see another test's entries)."""
    yield
    planner = sys.modules.get("repro.core.planner")
    if planner is not None:
        planner.clear_plan_cache()
    hlo = sys.modules.get("repro.launch.hlo_analysis")
    if hlo is not None:
        hlo._SPEC_CACHE.clear()
    sock = sys.modules.get("repro.core.socket")
    if sock is not None:
        sock.reset_issue_log()
    pm = sys.modules.get("repro.core.noc.perfmodel")
    if pm is not None:
        # a calibrated default-params install changes every
        # default-constructed SoCPerfModel (and the plan-cache key)
        pm.set_default_params(None)


def run_devices_script(code: str, n_devices: int = 8, timeout: int = 560):
    """Run ``code`` in a subprocess with ``n_devices`` forced host devices.

    Multi-device behaviour (shard_map collectives, interpret-mode remote
    DMA, mesh plumbing) needs more than this container's single CPU device,
    but the device count is locked at first jax init — so those tests run in
    a child process.  The main pytest process keeps seeing 1 device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_devices_script
