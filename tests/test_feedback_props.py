"""Property tests for the planner -> sharding feedback loop (tier-2).

For randomized ``SoCParams`` profiles and transfer-spec sets, the loop's
contract holds:

* ``resolve_rules`` is idempotent — resolving an already-resolved table is
  a no-op with an empty overlay;
* it never produces an unshardable rule — the resolved table has exactly
  the original logical axes, and every value is a valid AxisVal over the
  production mesh axes (no duplicates, no invented axis names);
* re-planning under the resolved rules never prices worse than the static
  plan — ``modeled_step_cycles(decisions, resolved) <=
  modeled_step_cycles(decisions, static)`` at every point;
* pricing is deterministic, and the base-archetype aggregate a per-layer
  plan publishes is the dominant (largest-payload) layer's mode;
* the overlap objective is never worse than the serial objective for the
  same decisions (ramp clamp), equals it when nothing declares compute,
  and the hidden-comm fraction stays in [0, 1].

Runs under real ``hypothesis`` when installed, else under the vendored
deterministic fallback (``tests/_hypothesis_vendor.py``) — keep that
module's strategy surface in sync with what this file imports.
"""

import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.comm import CommMode, base_transfer_name
from repro.core.noc.perfmodel import (SoCParams, SoCPerfModel,
                                      overlapped_cycles)
from repro.core.planner import (CommPlanner, TransferSpec, chosen_cycles,
                                comm_overlap_fraction, modeled_step_cycles)
from repro.core.sharding import (DEFAULT_RULES, RULE_OVERLAYS,
                                 logical_to_pspec, resolve_rules)

pytestmark = pytest.mark.tier2

# ------------------------------------------------------------- strategies ----

# The archetypes the HLO mapping emits; grad_reduce arrives reduce-marked.
_ARCHETYPES = ("weights", "moe_dispatch", "stage_activation", "grad_reduce",
               "grad_scatter")

# (profile index, link_latency, burst_bytes) — randomized SoCParams
_PROFILE_BUILDERS = (
    lambda: SoCParams(),
    lambda: SoCParams.pod(8, 8),
    lambda: SoCParams.pod(16, 16),
)

profile_st = st.tuples(st.integers(0, len(_PROFILE_BUILDERS) - 1),
                       st.integers(1, 4),
                       st.sampled_from((1024, 4096, 8192)))

# (archetype, layer, nbytes, fan_out, pull, reduce, compute_kflops)
spec_st = st.tuples(st.sampled_from(_ARCHETYPES),
                    st.integers(0, 7),
                    st.integers(1, 1 << 22),
                    st.integers(0, 40),
                    st.booleans(),
                    st.booleans(),
                    st.sampled_from((0, 0, 1, 512, 1 << 14, 1 << 20)))

specs_st = st.lists(spec_st, min_size=0, max_size=12)


def _mk_model(profile) -> SoCPerfModel:
    idx, link, burst = profile
    p = _PROFILE_BUILDERS[idx]()
    return SoCPerfModel(dataclasses.replace(
        p, link_latency=link, burst_bytes=burst,
        name=f"{p.name}-l{link}-b{burst}"))


def _mk_specs(raw):
    out = []
    for arch, layer, nbytes, fan_out, pull, reduce, kflops in raw:
        out.append(TransferSpec(
            f"{arch}.L{layer}", nbytes=nbytes, fan_out=fan_out,
            pull=pull, reduce=reduce or arch in ("grad_reduce",
                                                 "grad_scatter"),
            layer=layer, compute_flops=1024.0 * kflops))
    return out


# -------------------------------------------------------------- properties ----

@settings(deadline=None, max_examples=30)
@given(profile=profile_st, raw=specs_st)
def test_resolve_rules_idempotent(profile, raw):
    plan, _ = CommPlanner(_mk_model(profile)).plan_with_decisions(
        _mk_specs(raw))
    r1, o1 = resolve_rules(plan, DEFAULT_RULES)
    r2, o2 = resolve_rules(plan, r1)
    assert r2 == r1
    assert o2 == {}
    # the overlay is exactly the delta between input and output
    assert all(r1[k] == v and DEFAULT_RULES[k] != v for k, v in o1.items())


@settings(deadline=None, max_examples=30)
@given(profile=profile_st, raw=specs_st)
def test_resolve_rules_never_unshardable(profile, raw):
    plan, _ = CommPlanner(_mk_model(profile)).plan_with_decisions(
        _mk_specs(raw))
    resolved, overlay = resolve_rules(plan, DEFAULT_RULES)
    # no logical axis appears or disappears, overlays only touch known axes
    assert set(resolved) == set(DEFAULT_RULES)
    assert set(overlay) <= set(DEFAULT_RULES)
    mesh_axes = {"pod", "data", "model"}
    for name, val in resolved.items():
        if val is None:
            continue
        axes = (val,) if isinstance(val, str) else val
        assert isinstance(axes, tuple)
        assert all(isinstance(a, str) for a in axes)
        assert len(set(axes)) == len(axes), (name, val)
        assert set(axes) <= mesh_axes, (name, val)
        # the pspec mapping accepts every rewritten rule
        logical_to_pspec((name,), resolved, mesh=None)


@settings(deadline=None, max_examples=30)
@given(profile=profile_st, raw=specs_st)
def test_resolved_rules_never_price_worse(profile, raw):
    specs = _mk_specs(raw)
    plan, decisions = CommPlanner(_mk_model(profile)).plan_with_decisions(
        specs)
    resolved, overlay = resolve_rules(plan, DEFAULT_RULES)
    static_cost = modeled_step_cycles(decisions, DEFAULT_RULES)
    resolved_cost = modeled_step_cycles(decisions, resolved)
    assert resolved_cost <= static_cost + 1e-9, (overlay, specs)
    # a w_fsdp rewrite unlocks overlap credit for a rule-gated fusible
    # decision, so it must strictly lower the modeled cost.  The
    # moe_dispatch MEM overlay (seq_sp -> None) is a dataflow rewrite —
    # it may legitimately be price-neutral, never worse (asserted above).
    if "w_fsdp" in overlay:
        assert resolved_cost < static_cost, (overlay, specs)


@settings(deadline=None, max_examples=20)
@given(profile=profile_st, raw=specs_st)
def test_pricing_deterministic_and_aggregate_is_dominant(profile, raw):
    specs = _mk_specs(raw)
    planner = CommPlanner(_mk_model(profile))
    plan_a, dec_a = planner.plan_with_decisions(specs)
    plan_b, dec_b = planner.plan_with_decisions(specs)
    assert dict(plan_a.modes) == dict(plan_b.modes)
    assert [d.mode for d in dec_a] == [d.mode for d in dec_b]
    for d in dec_a:
        if d.fused:
            # a fused verdict bounds the OVERLAPPED cost by the serial
            # memory baseline; its raw comm may exceed mem (a ring chain
            # hidden behind a large consumer matmul)
            eff = overlapped_cycles(chosen_cycles(d), d.compute_cycles,
                                    d.ramp_cycles)
            assert eff <= d.cycles["mem"] + d.compute_cycles + 1e-9, d
        else:
            assert chosen_cycles(d) <= d.cycles["mem"] + 1e-9, d
    # the base aggregate a layered plan publishes is the dominant layer's
    # mode (largest payload wins; for duplicate names the last write wins,
    # matching CommPlan.with_mode)
    by_name = {}
    for d in dec_a:
        by_name[d.spec.name] = d
    groups = {}
    for d in by_name.values():
        groups.setdefault(base_transfer_name(d.spec.name), []).append(d)
    for base, ds in groups.items():
        if all(d.spec.name == base for d in ds):
            continue
        dom = max(ds, key=lambda d: d.spec.nbytes)
        assert plan_a.mode(base) in {d.mode for d in ds}
        if len({d.spec.nbytes for d in ds}) == len(ds):
            assert plan_a.mode(base) is dom.mode, (base, dom)


@settings(deadline=None, max_examples=30)
@given(profile=profile_st, raw=specs_st)
def test_overlap_objective_never_worse_than_serial(profile, raw):
    """For ANY decisions and ANY rule table, the overlap objective prices
    no worse than the serial objective (the ramp clamp), collapses to the
    serial objective when nothing declares compute, and the hidden-comm
    fraction is a fraction."""
    specs = _mk_specs(raw)
    plan, decisions = CommPlanner(_mk_model(profile)).plan_with_decisions(
        specs)
    for rules in (None, DEFAULT_RULES, resolve_rules(plan, DEFAULT_RULES)[0]):
        overlap = modeled_step_cycles(decisions, rules)
        serial = modeled_step_cycles(decisions, rules, objective="serial")
        assert overlap <= serial + 1e-9, (rules, specs)
        frac = comm_overlap_fraction(decisions, rules)
        assert 0.0 <= frac <= 1.0 + 1e-12, (frac, specs)
        if all(s.compute_flops == 0 for s in specs):
            assert overlap == serial and frac == 0.0


def test_overlay_table_is_well_formed():
    """Every RULE_OVERLAYS rewrite targets an axis the default table has,
    with a value that is a valid AxisVal — the static guarantee behind the
    'never unshardable' property."""
    for transfer, by_mode in RULE_OVERLAYS.items():
        assert transfer == base_transfer_name(transfer)
        for mode, rewrite in by_mode.items():
            assert isinstance(mode, CommMode)
            for axis, val in rewrite.items():
                assert axis in DEFAULT_RULES, axis
                assert val is None or isinstance(val, (str, tuple))
