"""NoC fault injection: kill a router or link at cycle *t*.

Deterministic scenarios pin the semantics (queued flits die with their
router, the YX escape path routes around a dead link, dead sources cannot
inject); the tier-2 property suite proves the vectorized stepper and the
object reference stay flit-for-flit identical under sampled fault kinds
and fault cycles, and that no flit is ever silently dropped
(delivered + lost == injected, the conservation law the planner's
degraded-topology pricing leans on).
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.noc.reference_sim import ReferenceMeshNoC
from repro.core.noc.router import LOCAL, fault_next_port
from repro.core.noc.simulator import MeshNoC, Message

W, H = 4, 3
NODES = [(x, y) for x in range(W) for y in range(H)]


def _pair():
    return MeshNoC(W, H), ReferenceMeshNoC(W, H)


def _link_from(a, direction):
    """Map (node, 0..3) onto a valid directed mesh link (mirror if the
    neighbor falls off the mesh)."""
    dx, dy = ((1, 0), (-1, 0), (0, 1), (0, -1))[direction]
    b = (a[0] + dx, a[1] + dy)
    if not (0 <= b[0] < W and 0 <= b[1] < H):
        b = (a[0] - dx, a[1] - dy)
    return (a, b)


# ------------------------------------------------------ pinned semantics

def test_dead_router_drops_and_records_queued_flits():
    vec, ref = _pair()
    for noc in (vec, ref):
        noc.inject_fault(router=(1, 0), at_cycle=0)
        noc.inject(Message((0, 0), ((3, 0),), 2))
    # row 0 is unreachable once (1, 0) dies: XY and YX coincide there
    assert vec.drain() == ref.drain()
    want = [(0, s, (3, 0)) for s in range(3)]
    assert sorted(vec.lost) == sorted(ref.lost) == want
    assert vec.received((3, 0), 0) == [] and ref.received((3, 0), 0) == []


def test_dead_link_takes_yx_escape_path():
    vec, ref = _pair()
    for noc in (vec, ref):
        noc.inject_fault(link=((1, 0), (2, 0)), at_cycle=0)
        noc.inject(Message((0, 0), ((3, 1),), 2))
    assert vec.drain() == ref.drain()
    assert vec.lost == [] and ref.lost == []
    assert len(vec.received((3, 1), 0)) == 3 == len(ref.received((3, 1), 0))
    # the escape detour costs hops but loses nothing
    assert vec.total_hops == ref.total_hops


def test_mid_flight_router_kill_is_identical():
    vec, ref = _pair()
    for noc in (vec, ref):
        noc.inject(Message((0, 0), ((3, 0), (3, 2)), 4))
        noc.inject(Message((1, 2), ((3, 0),), 2))
        noc.inject_fault(router=(2, 0), at_cycle=3)
    assert vec.drain() == ref.drain()
    assert vec.total_hops == ref.total_hops
    assert sorted(vec.lost) == sorted(ref.lost)
    assert len(vec.lost) > 0  # the kill really strands flits
    for c in vec.delivered:
        assert [(f.msg_id, f.seq) for f in vec.delivered[c]] == \
            [(f.msg_id, f.seq) for f in ref.delivered[c]], c


def test_dead_source_cannot_inject():
    vec, ref = _pair()
    for noc in (vec, ref):
        noc.inject_fault(router=(0, 0), at_cycle=0)
        noc.inject(Message((0, 0), ((2, 2),), 1, inject_cycle=5))
        noc.inject(Message((3, 2), ((2, 2),), 1))
    assert vec.drain() == ref.drain()
    assert sorted(vec.lost) == sorted(ref.lost) == \
        [(0, 0, (2, 2)), (0, 1, (2, 2))]
    assert len(vec.received((2, 2), 1)) == 2


def test_two_faults_compound():
    vec, ref = _pair()
    for noc in (vec, ref):
        noc.inject_fault(link=((1, 1), (2, 1)), at_cycle=0)
        noc.inject_fault(router=(2, 0), at_cycle=4)
        noc.inject(Message((0, 1), ((3, 1),), 3))
        noc.inject(Message((0, 0), ((3, 0),), 3, inject_cycle=2))
    assert vec.drain() == ref.drain()
    assert sorted(vec.lost) == sorted(ref.lost)
    for c in vec.delivered:
        assert [(f.msg_id, f.seq) for f in vec.delivered[c]] == \
            [(f.msg_id, f.seq) for f in ref.delivered[c]], c


def test_fault_validation():
    vec, ref = _pair()
    for noc in (vec, ref):
        with pytest.raises(ValueError):
            noc.inject_fault(router=(9, 9))
        with pytest.raises(ValueError):
            noc.inject_fault(link=((0, 0), (2, 0)))  # not adjacent
        with pytest.raises(ValueError):
            noc.inject_fault()


def test_fault_route_monotone_progress():
    """Every fault-aware hop strictly decreases the Manhattan distance to
    the destination, so escape routing can neither loop nor livelock."""
    dead_n = frozenset({(2, 1)})
    dead_l = frozenset({((1, 0), (2, 0))})
    deltas = {1: (0, -1), 2: (0, 1), 3: (1, 0), 4: (-1, 0)}
    for src in NODES:
        for dst in NODES:
            if src in dead_n or src == dst:
                continue
            here, hops = src, 0
            while here != dst:
                p = fault_next_port(here, dst, dead_n, dead_l)
                if p is None:
                    break  # surfaced as loss
                if p == LOCAL:
                    break
                dx, dy = deltas[p]
                nxt = (here[0] + dx, here[1] + dy)
                assert abs(nxt[0] - dst[0]) + abs(nxt[1] - dst[1]) < \
                    abs(here[0] - dst[0]) + abs(here[1] - dst[1]), (src, dst)
                here, hops = nxt, hops + 1
                assert hops <= (W + H) * 2, "escape route failed to progress"


# -------------------------------------------------- tier-2 property suite

node_idx = st.integers(0, len(NODES) - 1)
# fault kinds sampled via one_of: a router kill or a directed-link kill
fault_kind = st.one_of(
    st.tuples(st.just("router"), node_idx),
    st.tuples(st.just("link"), st.tuples(node_idx, st.integers(0, 3))))


def _apply_fault(noc, kind, at_cycle):
    tag, payload = kind
    if tag == "router":
        noc.inject_fault(router=NODES[payload], at_cycle=at_cycle)
    else:
        a_idx, direction = payload
        noc.inject_fault(link=_link_from(NODES[a_idx], direction),
                         at_cycle=at_cycle)


@pytest.mark.tier2
@settings(deadline=None, max_examples=25)
@given(raw=st.lists(st.tuples(node_idx, node_idx, node_idx,
                              st.integers(1, 4), st.integers(0, 12)),
                    min_size=1, max_size=8),
       kind=fault_kind,
       fault_cycle=st.integers(0, 30))
def test_faulted_run_matches_reference(raw, kind, fault_cycle):
    """Flit-for-flit identity under fault injection: same drain cycle, same
    hop count, same per-tile delivery log, same loss set."""
    vec, ref = _pair()
    for noc in (vec, ref):
        _apply_fault(noc, kind, fault_cycle)
        for (a, b, c, n, at) in raw:
            dests = tuple({NODES[b], NODES[c]})
            noc.inject(Message(NODES[a], dests, n, inject_cycle=at))
    assert vec.drain() == ref.drain()
    assert vec.total_hops == ref.total_hops
    assert sorted(vec.lost) == sorted(ref.lost)
    for coord in vec.delivered:
        assert [(f.msg_id, f.seq) for f in vec.delivered[coord]] == \
            [(f.msg_id, f.seq) for f in ref.delivered[coord]], coord


@pytest.mark.tier2
@settings(deadline=None, max_examples=25)
@given(raw=st.lists(st.tuples(node_idx, node_idx, node_idx,
                              st.integers(1, 4)),
                    min_size=1, max_size=8),
       kind=fault_kind,
       fault_cycle=st.integers(0, 30))
def test_fault_conserves_flits(raw, kind, fault_cycle):
    """No silent drops: every injected (msg, seq, dest) flit copy is either
    delivered or recorded as lost — on both simulators."""
    vec, ref = _pair()
    expect = 0
    for noc in (vec, ref):
        _apply_fault(noc, kind, fault_cycle)
    for (a, b, c, n) in raw:
        dests = tuple({NODES[b], NODES[c]})
        expect += (n + 1) * len(dests)
        for noc in (vec, ref):
            noc.inject(Message(NODES[a], dests, n))
    vec.drain(), ref.drain()
    for noc in (vec, ref):
        got = sum(len(v) for v in noc.delivered.values())
        assert got + len(noc.lost) == expect, (got, len(noc.lost), expect)
