"""End-to-end behaviour: train -> checkpoint -> restart -> identical
continuation; serve pipeline; dry-run plumbing on a small mesh (subprocess)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import SyntheticTokenStream
from repro.models.transformer import RunFlags
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.runtime.train import make_train_step, init_state


def test_checkpoint_restart_bitwise_continuation(tmp_path):
    """Train 6 steps straight vs. 3 steps -> checkpoint -> restore -> 3
    steps: identical final loss (determinism end to end)."""
    cfg = get_reduced("smollm-135m")
    flags = RunFlags(remat="none")
    step_fn, _, _ = make_train_step(cfg, flags)
    jstep = jax.jit(step_fn)
    stream = SyntheticTokenStream(cfg.vocab_size, 4, 64)
    batches = [
        {k: jnp.asarray(v) for k, v in stream.batch(s).items()}
        for s in range(6)]

    state = init_state(jax.random.key(0), cfg, flags)
    for b in batches:
        state, metrics = jstep(state, b)
    loss_straight = float(metrics["loss"])

    state2 = init_state(jax.random.key(0), cfg, flags)
    for b in batches[:3]:
        state2, _ = jstep(state2, b)
    save_checkpoint(str(tmp_path), 3, state2)
    assert latest_step(str(tmp_path)) == 3

    state3 = restore_checkpoint(str(tmp_path), 3, state2)
    for b in batches[3:]:
        state3, metrics3 = jstep(state3, b)
    assert float(metrics3["loss"]) == pytest.approx(loss_straight, rel=1e-5)


def test_moe_arch_trains(tmp_path):
    cfg = get_reduced("dbrx-132b")
    flags = RunFlags(remat="none")
    step_fn, _, _ = make_train_step(cfg, flags, lr=1e-3)
    jstep = jax.jit(step_fn, donate_argnums=0)
    state = init_state(jax.random.key(0), cfg, flags)
    stream = SyntheticTokenStream(cfg.vocab_size, 4, 32)
    losses = []
    for s in range(8):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
        state, metrics = jstep(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


_DRYRUN_SMALL = r"""
import jax
from repro import compat
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import lower_cell, make_flags
from repro.launch import hlo_analysis

mesh = compat.make_mesh((4, 4), ("data", "model"),
                        axis_types=(compat.AxisType.Auto,) * 2)

# one family of each kind x (train, decode)
for arch in ("smollm-135m", "dbrx-132b", "falcon-mamba-7b",
             "recurrentgemma-9b"):
    cfg = get_reduced(arch)
    for shape in (ShapeConfig("t", 128, 16, "train"),
                  ShapeConfig("d", 128, 16, "decode")):
        flags = make_flags(cfg, shape)
        lowered, _ = lower_cell(cfg, shape, mesh, flags)
        compiled = lowered.compile()
        roof = hlo_analysis.analyze(compiled, model_flops_total=1e9,
                                    n_chips=16)
        assert roof.flops_per_dev > 0
        assert roof.bound_time() > 0
        print(f"{arch} {shape.kind} OK "
              f"dom={roof.dominant}", flush=True)
print("DRYRUN_SMALL_OK", flush=True)
"""


def test_dryrun_plumbing_small_mesh(subproc):
    out = subproc(_DRYRUN_SMALL, n_devices=16)
    assert "DRYRUN_SMALL_OK" in out
    assert out.count("OK") >= 9
