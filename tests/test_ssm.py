"""SSM / RG-LRU recurrences vs. naive sequential oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models.ssm import (chunked_linear_scan, causal_conv1d,
                              mamba_apply, mamba_decode_step, mamba_init)
from repro.models.griffin import rglru_apply, rglru_decode_step, rglru_init


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 1000), chunk=st.sampled_from([1, 4, 8, 16]))
def test_chunked_linear_scan_matches_sequential(seed, chunk):
    key = jax.random.key(seed)
    B, S, W = 2, 16, 5
    a = jax.random.uniform(key, (B, S, W), minval=0.1, maxval=0.99)
    b = jax.random.normal(jax.random.key(seed + 1), (B, S, W))
    h0 = jax.random.normal(jax.random.key(seed + 2), (B, W))
    h_all, h_last = chunked_linear_scan(a, b, h0, chunk)
    # sequential oracle
    h = np.asarray(h0)
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        np.testing.assert_allclose(h_all[:, t], h, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_last, h, rtol=1e-5, atol=1e-5)


def test_causal_conv_matches_numpy():
    B, S, C, K = 2, 12, 3, 4
    x = jax.random.normal(jax.random.key(0), (B, S, C))
    w = jax.random.normal(jax.random.key(1), (C, K))
    state = jnp.zeros((B, K - 1, C))
    y, new_state = causal_conv1d(x, w, None, state)
    xp = np.concatenate([np.zeros((B, K - 1, C)), np.asarray(x)], axis=1)
    for t in range(S):
        expect = sum(xp[:, t + j] * np.asarray(w)[:, j] for j in range(K))
        np.testing.assert_allclose(y[:, t], expect, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(new_state, xp[:, -K + 1:], rtol=1e-6)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mamba_chunked_consistency(chunk):
    """The chunked scan must be invariant to chunk size."""
    cfg = get_reduced("falcon-mamba-7b")
    params = mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model),
                          jnp.float32)
    y_ref, st_ref = mamba_apply(params, x, cfg, chunk=32)
    y, st = mamba_apply(params, x, cfg, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(st["h"], st_ref["h"], rtol=2e-2, atol=2e-2)


def test_mamba_decode_matches_full():
    """Running tokens one at a time through decode must equal the full
    sequence pass (state-space consistency)."""
    cfg = get_reduced("falcon-mamba-7b")
    params = mamba_init(jax.random.key(0), cfg)
    B, S = 1, 8
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                          jnp.float32)
    y_full, _ = mamba_apply(params, x, cfg, chunk=8)
    state = None
    outs = []
    di = cfg.ssm.expand * cfg.d_model
    state = {"h": jnp.zeros((B, di, cfg.ssm.state_dim), jnp.float32),
             "conv": jnp.zeros((B, cfg.ssm.conv_dim - 1, di), jnp.float32)}
    for t in range(S):
        y_t, state = mamba_decode_step(params, x[:, t:t + 1], cfg, state)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(y_dec, y_full, rtol=3e-2, atol=3e-2)


def test_rglru_decode_matches_full():
    cfg = get_reduced("recurrentgemma-9b")
    params = rglru_init(jax.random.key(0), cfg)
    B, S = 1, 8
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                          jnp.float32)
    y_full, _ = rglru_apply(params, x, cfg, chunk=8)
    w = cfg.rglru.lru_width or cfg.d_model
    state = {"h": jnp.zeros((B, w), jnp.float32),
             "conv": jnp.zeros((B, cfg.rglru.conv_dim - 1, w), jnp.float32)}
    outs = []
    for t in range(S):
        y_t, state = rglru_decode_step(params, x[:, t:t + 1], cfg, state)
        outs.append(y_t)
    np.testing.assert_allclose(jnp.concatenate(outs, axis=1), y_full,
                               rtol=3e-2, atol=3e-2)


def test_rglru_decay_in_unit_interval():
    """RG-LRU stability: the decay a_t must stay in (0, 1)."""
    cfg = get_reduced("recurrentgemma-9b")
    params = rglru_init(jax.random.key(0), cfg)
    from repro.models.griffin import _gates_and_decay
    u = jax.random.normal(jax.random.key(2), (2, 16, cfg.rglru.lru_width))
    a, _ = _gates_and_decay(params, u, jnp.bfloat16)
    assert float(jnp.min(a)) > 0.0
    assert float(jnp.max(a)) < 1.0
