"""Calibration subsystem (repro.calib): observation records, the
SoCParams fitter, the measurement-driven re-plan, and the design-space
sweep — plus the plan-cache regression the subsystem exposed (the cache
key must fingerprint the *effective* default params, or installing
calibrated params would alias stale plans)."""

import dataclasses
import json
import math
import os

import pytest

from repro.calib import fit as calib_fit
from repro.calib import measure
from repro.calib import sweep as calib_sweep
from repro.calib.measure import Observation
from repro.core import socket as socket_mod
from repro.core.comm import CommMode
from repro.core.noc.perfmodel import (SoCParams, default_params,
                                      default_params_override)
from repro.core.planner import (CommPlanner, TransferSpec, plan_cache_stats,
                                refine_plan_from_measurements, resolve_policy)


# ------------------------------------------------------------ measure ----

def test_observation_round_trip():
    o = Observation(kind="flit_sim", name="weights.L3", measured_cycles=123.5,
                    fan_out=4, nbytes=8192, mode="mcast", weight=0.5,
                    source="unit")
    assert Observation.from_dict(o.to_dict()) == o
    # unknown keys from older/newer artifacts are dropped, not fatal
    d = dict(o.to_dict(), someday_field=1)
    assert Observation.from_dict(d) == o


def test_observations_json_round_trip(tmp_path):
    obs = measure.flit_sim_observations(SoCParams(), grid=((2, 4096),))
    path = str(tmp_path / "obs.json")
    measure.observations_to_json(obs, path)
    back = measure.observations_from_json(path)
    assert back == obs


def test_flit_sim_deterministic_and_link_scaling():
    p1 = SoCParams(link_latency=1)
    a = measure.flit_sim_cycles(p1, fan_out=4, nbytes=8192)
    b = measure.flit_sim_cycles(p1, fan_out=4, nbytes=8192)
    assert a == b > 0
    # the flit-sim forward model scales linearly in the per-hop latency
    # (same flit schedule, deeper pipeline) — the lever the fitter pulls
    p2 = SoCParams(link_latency=2)
    assert measure.flit_sim_cycles(p2, 4, 8192) == pytest.approx(2 * a)
    # more payload never gets cheaper
    assert measure.flit_sim_cycles(p1, 4, 32768) > a


def test_flit_sim_observations_noise_seeded():
    p = SoCParams()
    clean = measure.flit_sim_observations(p)
    noisy1 = measure.flit_sim_observations(p, noise=0.05, seed=3)
    noisy2 = measure.flit_sim_observations(p, noise=0.05, seed=3)
    assert noisy1 == noisy2            # deterministic: seeded jitter
    assert noisy1 != clean
    for c, n in zip(clean, noisy1):
        assert abs(n.measured_cycles - c.measured_cycles) \
            <= 0.05 * c.measured_cycles + 1e-9


# ---------------------------------------------------------------- fit ----

def test_fit_exact_recovery():
    """Ground truth on the candidate grids, zero noise: the fit recovers
    every field exactly (generator == forward model) with ~zero residual."""
    truth = SoCParams(link_latency=3, burst_bytes=2048,
                      flops_per_cycle=2048.0)
    obs = (measure.flit_sim_observations(truth) +
           measure.compute_observations(truth))
    base = dataclasses.replace(truth, link_latency=1, burst_bytes=4096,
                               flops_per_cycle=8192.0)
    cp = calib_fit.fit_soc_params(obs, base=base)
    assert cp.params.link_latency == 3
    assert cp.params.burst_bytes == 2048
    assert cp.params.flops_per_cycle == pytest.approx(2048.0)
    assert cp.residual < 1e-9
    assert cp.n_obs == len(obs)
    assert cp.params.name == f"{truth.name}-cal"
    for name in calib_fit.FIT_FIELDS:
        f = cp.fields[name]
        assert f.n_obs > 0 and f.confidence > 0.99


def test_fit_noisy_recovery_bounded():
    """Seeded 2% jitter: discrete grid fields still land exactly (the
    residual gap between grid points dwarfs the noise floor); the
    continuous flops fit lands within the noise scale."""
    truth = SoCParams(link_latency=2, burst_bytes=8192,
                      flops_per_cycle=4096.0)
    obs = (measure.flit_sim_observations(truth, noise=0.02, seed=7) +
           measure.compute_observations(truth, noise=0.02, seed=7))
    cp = calib_fit.fit_soc_params(obs, base=SoCParams())
    assert cp.params.link_latency == 2
    assert cp.params.burst_bytes == 8192
    assert cp.params.flops_per_cycle == pytest.approx(4096.0, rel=0.05)
    assert cp.residual < 0.1


def test_fit_uninformed_fields_keep_base():
    """Fields with no informing observations keep the base value with
    confidence 0 — a compute-only fit must not invent network params."""
    truth = SoCParams(flops_per_cycle=1024.0)
    obs = measure.compute_observations(truth)
    base = SoCParams(link_latency=4, burst_bytes=2048)
    cp = calib_fit.fit_soc_params(obs, base=base)
    assert cp.params.link_latency == 4
    assert cp.params.burst_bytes == 2048
    for name in ("link_latency", "burst_bytes"):
        assert cp.fields[name].confidence == 0.0
        assert cp.fields[name].n_obs == 0
    assert cp.params.flops_per_cycle == pytest.approx(1024.0)


def test_calibrated_params_artifact_round_trip(tmp_path):
    truth = SoCParams(link_latency=2, burst_bytes=8192)
    obs = measure.flit_sim_observations(truth)
    cp = calib_fit.fit_soc_params(obs, base=SoCParams())
    path = str(tmp_path / "cal.json")
    cp.to_json(path)
    back = calib_fit.CalibratedParams.from_json(path)
    assert back.params == cp.params       # tuple coords survive JSON
    assert back.residual == cp.residual
    assert back.fields.keys() == cp.fields.keys()
    # summary() is the dryrun artifact payload: JSON-able as-is
    json.dumps(cp.summary())
    assert "calibrate" not in calib_fit.fit_report(cp, truth=truth) or True


def test_fit_installs_as_default_params():
    """The loop closes: installing the fitted params changes what a
    default-constructed SoCPerfModel prices with, and the override is
    scoped."""
    truth = SoCParams(link_latency=2, burst_bytes=8192)
    cp = calib_fit.fit_soc_params(
        measure.flit_sim_observations(truth), base=SoCParams())
    with default_params_override(cp.params):
        assert default_params().burst_bytes == 8192
        assert CommPlanner().model.p.link_latency == 2
    assert default_params().burst_bytes == 4096


# ------------------------------------- measurement-driven re-planning ----

def _plan_one(name="kv_prefix", nbytes=262144, fan_out=8):
    planner = CommPlanner()
    specs = [TransferSpec(name, nbytes=nbytes, fan_out=fan_out)]
    plan, decisions = planner.plan_with_decisions(specs)
    return plan, decisions


def test_refine_measured_divergence_flips_decision():
    """Injected divergence: the chosen path measures far worse than
    modeled, an alternative is now cheaper -> the plan flips and the flip
    lands in the comm_replan_events schema with its cause."""
    plan, decisions = _plan_one()
    (d,) = decisions
    assert d.mode is CommMode.MCAST       # the regime the paper targets
    measured = 10.0 * d.cycles["mem"]     # fabric says: mcast path is sick
    obs = [Observation(kind="flit_sim", name="kv_prefix",
                       measured_cycles=measured, mode="mcast",
                       fan_out=8, nbytes=262144)]
    new_plan, flips = refine_plan_from_measurements(plan, obs,
                                                    decisions=decisions)
    assert new_plan.mode("kv_prefix") is CommMode.MEM
    assert len(flips) == 1
    f = flips[0]
    assert f["tensor"] == "kv_prefix"
    assert f["old"] == "MCAST" and f["new"] == "MEM"
    assert f["cause"] == "measured_divergence"
    assert f["divergence"] > 0.25
    # the original plan object is untouched (re-plan, not mutation)
    assert plan.mode("kv_prefix") is CommMode.MCAST


def test_refine_divergence_below_threshold_holds():
    plan, decisions = _plan_one()
    (d,) = decisions
    modeled = d.cycles["mcast"]
    obs = [Observation(kind="flit_sim", name="kv_prefix",
                       measured_cycles=1.1 * modeled, mode="mcast")]
    new_plan, flips = refine_plan_from_measurements(plan, obs,
                                                    decisions=decisions)
    assert flips == []
    assert new_plan.mode("kv_prefix") is CommMode.MCAST
    # ... and a custom threshold makes the same observation flip
    _, flips = refine_plan_from_measurements(plan, obs, decisions=decisions,
                                             divergence_threshold=0.05)
    assert [f["cause"] for f in flips] == ["measured_divergence"] or \
        flips == []   # only flips if an alternative actually wins


def test_refine_ignores_unchosen_path_divergence():
    """Only the *chosen* path's divergence re-opens a decision: a noisy
    measurement of a path the plan doesn't use is not a mis-model."""
    plan, decisions = _plan_one()
    (d,) = decisions
    obs = [Observation(kind="flit_sim", name="kv_prefix",
                       measured_cycles=100.0 * d.cycles["mem"], mode="mem")]
    _, flips = refine_plan_from_measurements(plan, obs, decisions=decisions)
    assert flips == []


def test_refine_issued_mismatch_flips_to_issued():
    """A silent issued != planned mismatch re-prices the tensor to the
    issued mode — the fabric already voted."""
    plan, decisions = _plan_one()
    obs = [{"kind": "issue", "name": "kv_prefix.L0", "site": "layer0",
            "planned": "MCAST", "issued": "MEM", "degraded_reason": None}]
    new_plan, flips = refine_plan_from_measurements(plan, obs,
                                                    decisions=decisions)
    assert new_plan.mode("kv_prefix") is CommMode.MEM
    assert flips == [{"tensor": "kv_prefix", "old": "MCAST", "new": "MEM",
                      "cause": "issued_mismatch", "site": "layer0"}]


def test_refine_degraded_issue_conforms():
    """An explicit degradation (machine-readable reason) conforms by
    definition — same convention as socket.mismatched_sites."""
    plan, decisions = _plan_one()
    obs = [{"kind": "issue", "name": "kv_prefix.L0", "site": "layer0",
            "planned": "MCAST", "issued": "MEM",
            "degraded_reason": "no stage axis: degraded to MEM"}]
    _, flips = refine_plan_from_measurements(plan, obs, decisions=decisions)
    assert flips == []


def test_refine_fused_ring_issue_is_p2p():
    """FUSED_RING is the overlapped dispatch of a P2P verdict, not a plan
    mode: a FUSED_RING issue against a P2P plan entry conforms, and
    against any other plan entry it re-prices to P2P (never to a mode the
    plan cannot express)."""
    from repro.core.comm import CommPlan
    plan = CommPlan({"stage_act": CommMode.P2P})
    obs = [{"kind": "issue", "name": "stage_act", "site": "s0",
            "planned": "P2P", "issued": "FUSED_RING",
            "degraded_reason": None}]
    _, flips = refine_plan_from_measurements(plan, obs)
    assert flips == []
    plan2 = CommPlan({"stage_act": CommMode.MEM})
    obs2 = [{"kind": "issue", "name": "stage_act", "site": "s0",
             "planned": "MEM", "issued": "FUSED_RING",
             "degraded_reason": None}]
    new_plan, flips2 = refine_plan_from_measurements(plan2, obs2)
    assert new_plan.mode("stage_act") is CommMode.P2P
    assert [f["new"] for f in flips2] == ["P2P"]


def test_refine_none_plan_is_noop():
    assert refine_plan_from_measurements(None, []) == (None, [])


def test_socket_issue_observations_export():
    """The socket's calibration export: plain dicts (core never imports
    calib), planned re-read from the plan in force, and measure lifts
    them into typed Observations."""
    from repro.core.comm import CommPlan
    socket_mod.reset_issue_log()
    socket_mod.record_implicit_issue(
        "weights.L0", planned=CommMode.MCAST, issued=CommMode.MCAST,
        nbytes=4096)
    socket_mod.record_implicit_issue(
        "grad_reduce", planned=CommMode.MCAST, issued=CommMode.MEM,
        nbytes=8192, reason="reduction: NoC cannot combine in flight")
    plan = CommPlan({"weights": CommMode.MEM})
    out = socket_mod.issue_observations(plan)
    assert [o["kind"] for o in out] == ["issue", "issue"]
    # planned re-read from the plan in force, not the traced hint
    assert out[0]["planned"] == "MEM" and out[0]["issued"] == "MCAST"
    assert out[1]["degraded_reason"] is not None
    lifted = measure.observations_from_issue_log(out)
    assert all(isinstance(o, Observation) for o in lifted)
    assert lifted[0].planned == "MEM" and lifted[0].issued == "MCAST"
    # end to end: the silent mismatch flips, the degraded one conforms
    _, flips = refine_plan_from_measurements(plan, lifted)
    assert [f["cause"] for f in flips] == ["issued_mismatch"]
    assert flips[0]["tensor"] == "weights"


# ------------------------------------------- plan-cache params keying ----

def test_plan_cache_keys_on_effective_default_params():
    """Regression (the bug this PR fixes): with ``model=None`` the cache
    key used ``profile=None`` instead of the effective default params, so
    installing calibrated params via ``set_default_params`` would serve a
    stale plan priced under the old constants.  Two resolutions under
    different effective defaults must be two cache entries."""
    from repro.configs import SHAPES, get_config
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    axes = {"data": 16, "model": 16}
    assert plan_cache_stats()["size"] == 0
    resolve_policy("auto", cfg, shape, axes)
    with default_params_override(SoCParams.pod(8, 8)):
        resolve_policy("auto", cfg, shape, axes)
    stats = plan_cache_stats()
    # old behavior: 1 miss + 1 stale HIT (key blind to the install)
    assert stats["misses"] == 2 and stats["hits"] == 0
    # same params again -> a genuine hit
    resolve_policy("auto", cfg, shape, axes)
    assert plan_cache_stats()["hits"] == 1


# -------------------------------------------------------------- sweep ----

def _small_grid():
    return calib_sweep.design_grid(
        meshes=((4, 3), (8, 8)), link_latencies=(1, 2),
        profiles=(("burst4k", 4096),))


def test_sweep_pareto_front():
    points = calib_sweep.sweep_design_space(candidates=_small_grid())
    assert len(points) == 4
    front = calib_sweep.pareto_front(points)
    assert front                                    # never empty
    names = {p["name"] for p in front}
    for p in points:
        dominated = any(calib_sweep._dominates(q, p) for q in points)
        assert p["pareto"] == (not dominated)
        assert (p["name"] in names) == p["pareto"]
        assert p["cycles"] > 0 and p["cost_um2"] > 0
        assert sum(p["mode_mix"].values()) > 0
    # front is sorted cheapest-fabric first
    costs = [p["cost_um2"] for p in front]
    assert costs == sorted(costs)


def test_sweep_cost_proxy_monotone():
    """The cost proxy must rank sanely: more tiles cost more; a deeper
    link pipeline (longer repeated wire) costs more at fixed mesh."""
    small = SoCParams.pod(4, 3, link_latency=1)
    big = SoCParams.pod(8, 8, link_latency=1)
    deep = SoCParams.pod(4, 3, link_latency=4)
    assert calib_sweep.fabric_cost_um2(big, 8) > \
        calib_sweep.fabric_cost_um2(small, 8)
    assert calib_sweep.fabric_cost_um2(deep, 8) > \
        calib_sweep.fabric_cost_um2(small, 8)


def test_write_frontier_artifact(tmp_path):
    points = calib_sweep.sweep_design_space(candidates=_small_grid())
    path = str(tmp_path / "frontier.json")
    calib_sweep.write_frontier(points, path, arch="dbrx-132b",
                               shape_name="train_4k")
    art = json.load(open(path))
    assert art["arch"] == "dbrx-132b" and art["shape"] == "train_4k"
    assert art["objectives"] == ["cycles", "cost_um2"]
    assert len(art["points"]) == 4 and art["pareto"]
    assert all(p["pareto"] for p in art["pareto"])


# ---------------------------------------------------------------- CLI ----

def test_cli_fit_smoke(capsys):
    from repro.calib.__main__ import main
    rc = main(["fit", "--noise", "0.02", "--seed", "7"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "# fit OK" in out


def test_cli_fit_fails_on_impossible_residual(capsys):
    from repro.calib.__main__ import main
    rc = main(["fit", "--noise", "0.3", "--seed", "1",
               "--max-residual", "0.0001"])
    assert rc == 1
    assert "# fit FAIL" in capsys.readouterr().out


def test_cli_sweep_smoke(tmp_path, capsys):
    from repro.calib.__main__ import main
    out_path = str(tmp_path / "sweep.json")
    rc = main(["sweep", "--arch", "dbrx-132b", "--shape", "train_4k",
               "--meshes", "4x3,8x8", "--link-latencies", "1,2",
               "--bursts", "4096", "--out", out_path])
    assert rc == 0
    assert os.path.exists(out_path)
    assert "Pareto" in capsys.readouterr().out
