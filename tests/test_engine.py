"""Tier-1 tests for the continuous-batching serving engine: admission /
eviction accounting over a scripted arrival trace, the one-trace-per-
function contract across admissions / remaps, epoch-scoped issue-log
keys, the recorded serve-path downgrades, and token equality against a
per-request contiguous reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import socket as SOCK
from repro.core.comm import CommMode, CommPlan
from repro.models import transformer as T
from repro.runtime import serve as RS
from repro.runtime.engine import ServeEngine, ServeMetrics, poisson_trace


def _engine(arch="qwen3-4b", **kw):
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_new_tokens", 4)
    kw.setdefault("n_slots", 2)
    kw.setdefault("block_size", 4)
    return ServeEngine(get_reduced(arch), **kw)


def _prompts(cfg, n, S=8, seed=11):
    return np.asarray(jax.random.randint(jax.random.key(seed), (n, S), 0,
                                         cfg.vocab_size), np.int32)


# ------------------------------------------------- admission / eviction ----

def test_scripted_trace_admission_and_eviction():
    SOCK.reset_issue_log()
    eng = _engine()
    prompts = _prompts(eng.cfg, 4)
    for i, arr in enumerate((0, 0, 0, 3)):
        eng.submit(prompts[i], arrival_step=arr, rid=i)

    seen_active = []
    while eng.pending or eng.n_active:
        stats = eng.step()
        seen_active.append(stats["active"])
        # slots and blocks stay within provisioning at every step
        assert eng.n_active <= 2
        owned = sum(len(r.blocks) for r in eng._slot_req if r is not None)
        assert eng.allocator.n_used == owned

    # two slots, three day-0 arrivals: the third waited for an eviction
    assert max(seen_active) == 2
    assert len(eng.completed) == 4 and not eng.pending
    assert all(len(r.generated) == 4 and r.done for r in eng.completed)
    assert eng.allocator.n_used == 0          # every block came back
    assert sorted(eng._free_slots) == [0, 1]
    # one trace per jitted function for the whole serve
    assert eng.trace_counts == {"prefill": 1, "decode": 1, "admit": 1}


def test_admission_gate_defers_when_no_slot():
    eng = _engine(n_slots=1)
    prompts = _prompts(eng.cfg, 2)
    eng.submit(prompts[0], arrival_step=0, rid=0)
    eng.submit(prompts[1], arrival_step=0, rid=1)
    stats = eng.step()
    assert stats["admitted"] == 1 and len(eng.pending) == 1
    while eng.pending or eng.n_active:
        eng.step()
    assert [r.rid for r in eng.completed] == [0, 1]


def test_submit_validates_against_the_layout():
    eng = _engine()
    with pytest.raises(ValueError):
        eng.submit(np.zeros(5, np.int32))                 # wrong prompt len
    with pytest.raises(ValueError):
        eng.submit(np.zeros(8, np.int32), max_new_tokens=99)


def test_max_new_tokens_one_is_served_by_the_prefill_token():
    eng = _engine()
    eng.submit(_prompts(eng.cfg, 1)[0], max_new_tokens=1)
    while eng.pending or eng.n_active:
        eng.step()
    (req,) = eng.completed
    assert len(req.generated) == 1 and eng.allocator.n_used == 0


def test_run_metrics_sanity():
    eng = _engine()
    trace = poisson_trace(5, rate=0.7, prompt_len=8, vocab=eng.cfg.vocab_size,
                          max_new_tokens=4, seed=5)
    metrics = eng.run(trace)
    assert isinstance(metrics, ServeMetrics)
    assert metrics.n_requests == 5
    assert metrics.total_new_tokens == sum(len(r.generated)
                                           for r in eng.completed) == 20
    assert metrics.tokens_per_s > 0
    assert 0 <= metrics.p50_latency_s <= metrics.p99_latency_s
    s = metrics.summary()
    assert s["n_requests"] == 5 and s["total_new_tokens"] == 20


def test_poisson_trace_is_deterministic():
    a = poisson_trace(4, rate=0.5, prompt_len=8, vocab=64, max_new_tokens=2,
                      seed=9)
    b = poisson_trace(4, rate=0.5, prompt_len=8, vocab=64, max_new_tokens=2,
                      seed=9)
    assert [r.arrival_step for r in a] == [r.arrival_step for r in b]
    assert all((x.prompt == y.prompt).all() for x, y in zip(a, b))


# ------------------------------------------ tokens vs contiguous decode ----

def test_engine_tokens_match_contiguous_reference():
    eng = _engine()
    prompts = _prompts(eng.cfg, 3, seed=21)
    for i, arr in enumerate((0, 1, 2)):      # staggered: batching overlaps
        eng.submit(prompts[i], arrival_step=arr, rid=i)
    while eng.pending or eng.n_active:
        eng.step()
    got = {r.rid: list(r.generated) for r in eng.completed}

    # per-request reference: contiguous prefill -> grow -> batched decode
    prefill = jax.jit(RS.make_prefill_step(eng.cfg, eng.flags))
    decode = jax.jit(RS.make_batched_decode_step(eng.cfg, eng.flags))
    for i in range(3):
        logits, caches = prefill(eng.params, prompts[i][None, :])
        caches = RS.grow_caches(eng.cfg, caches, 8, 4)
        toks = [int(np.asarray(jnp.argmax(logits[0, -1])))]
        for j in range(3):
            logits, caches = decode(eng.params,
                                    jnp.asarray([[toks[-1]]], jnp.int32),
                                    jnp.asarray([8 + j], jnp.int32), caches)
            toks.append(int(np.asarray(jnp.argmax(logits[0, -1]))))
        assert got[i] == toks, f"request {i} diverged from reference"


# ----------------------------------- issue-log epochs + recorded modes ----

def test_issue_log_is_epoch_scoped():
    SOCK.reset_issue_log()
    eng = _engine()
    eng.submit(_prompts(eng.cfg, 1)[0])
    while eng.pending or eng.n_active:
        eng.step()
    modes = SOCK.issued_modes()
    # regression (satellite 3): the admission burst and the steady decode
    # are distinct audit keys — an unscoped log would collapse each site
    # to last-write-wins and the prefill-phase record would vanish
    assert "engine.kv_prefix@prefill" in modes
    assert "prefill.weights_gather@prefill" in modes
    assert "decode.weights_gather@decode" in modes
    kv = modes["engine.kv_prefix@prefill"]
    # no live stage axis inside the engine's jit domain: the multicast
    # degrades to the recorded MEM path, reason attached
    assert kv["issued"] == "MEM" and kv["degraded_reason"]


def test_decode_downgrade_is_recorded_not_mutating():
    cfg = get_reduced("dbrx-132b")
    flags = T.RunFlags(remat="none", moe_mode="mcast")
    plan = CommPlan({"moe_dispatch": CommMode.MCAST,
                     "weights": CommMode.MEM})
    SOCK.reset_issue_log()
    new_flags, new_plan = RS._decode_downgrades(cfg, flags, plan)
    # regression (satellite 1): dataclasses.replace semantics — the caller's
    # flags object is untouched and every other field carries over
    assert flags.moe_mode == "mcast"
    assert new_flags.moe_mode == "mem"
    assert dataclasses.asdict(new_flags) == {
        **dataclasses.asdict(flags), "moe_mode": "mem"}
    assert plan.mode("moe_dispatch") is CommMode.MCAST   # plan not mutated
    assert new_plan.mode("moe_dispatch") is CommMode.MEM
    # the downgrade lands at the descriptor's canonical site so the
    # coverage gate resolves it through the fused chain's declaration
    rec = [r for r in SOCK.issued_records()
           if r.site == "moe.dispatch"][-1]
    assert rec.issued == "MEM" and rec.degraded_reason == "decode_no_seq_dim"
    assert rec.impl == "decode_downgrade"


# -------------------------------------------------- remap / re-plan -------

def test_remap_consumer_mid_serve_never_retraces():
    eng = _engine(consumers=("decode1", "decode2"))
    prompts = _prompts(eng.cfg, 3, seed=31)
    eng.submit(prompts[0], rid=0)
    eng.step()
    counts_before = dict(eng.trace_counts)
    eng.remap_consumer("decode2", 5)
    assert eng.registry.rank_of("decode2") == 5
    assert [int(r) for r in np.asarray(eng.consumer_ranks())][-1] == 5
    # later admissions and decodes reuse the existing traces
    eng.submit(prompts[1], rid=1)
    eng.submit(prompts[2], rid=2)
    while eng.pending or eng.n_active:
        eng.step()
    assert eng.trace_counts == counts_before == \
        {"prefill": 1, "decode": 1, "admit": 1}


def test_replan_for_mesh_rebinds_and_keeps_serving():
    eng = _engine()
    prompts = _prompts(eng.cfg, 2, seed=41)
    eng.submit(prompts[0], rid=0)
    eng.step()
    old_plan = eng.plan
    flips = eng.replan_for_mesh({"x": 4, "stage": 2})
    assert isinstance(flips, list)
    assert eng.plan is not old_plan
    # re-mesh is a re-plan: the rebound step may trace once more, and
    # serving continues over the same pools / tables / scheduler state
    eng.submit(prompts[1], rid=1)
    while eng.pending or eng.n_active:
        eng.step()
    assert len(eng.completed) == 2
    assert all(len(r.generated) == 4 for r in eng.completed)
    assert eng.allocator.n_used == 0
