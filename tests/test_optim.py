"""Optimizer + gradient-compression properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         clip_by_global_norm, ef_int8_compress,
                         ef_int8_decompress)
from repro.optim.adamw import AdamWState


def test_adamw_first_step_matches_reference():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = adamw_init(params)
    lr, b1, b2, eps, wd = 0.01, 0.9, 0.95, 1e-8, 0.1
    new_p, new_s, metrics = adamw_update(params, grads, state, lr,
                                         b1=b1, b2=b2, eps=eps,
                                         weight_decay=wd,
                                         max_grad_norm=1e9)
    g = np.asarray(grads["w"])
    m = (1 - b1) * g
    v = (1 - b2) * g * g
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    expect = np.asarray(params["w"]) - lr * (
        mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(params["w"]))
    np.testing.assert_allclose(new_p["w"], expect, rtol=1e-5)
    assert int(new_s.step) == 1


def test_grad_clip():
    g = {"a": jnp.full((4,), 3.0)}  # norm 6
    clipped, norm = clip_by_global_norm(g, 1.5)
    assert float(norm) == pytest.approx(6.0)
    np.testing.assert_allclose(clipped["a"], 3.0 * 1.5 / 6.0, rtol=1e-5)
    # under the cap: untouched
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(clipped2["a"], 3.0, rtol=1e-6)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(5)) == pytest.approx(0.5e-3, rel=1e-6)


def test_training_reduces_loss():
    """A few hundred params, a few steps: loss must go down."""
    from repro.configs import get_reduced
    from repro.models.transformer import RunFlags
    from repro.runtime.train import make_train_step, init_state
    from repro.data import SyntheticTokenStream

    cfg = get_reduced("smollm-135m")
    flags = RunFlags(remat="none")
    step_fn, _, _ = make_train_step(cfg, flags, lr=1e-3)
    jstep = jax.jit(step_fn, donate_argnums=0)
    state = init_state(jax.random.key(0), cfg, flags)
    stream = SyntheticTokenStream(cfg.vocab_size, 4, 64)
    losses = []
    for s in range(12):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
        state, metrics = jstep(state, batch)  # same batch: must memorize
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


# ---------------------------------------------------------- compression ----

@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 1000))
def test_ef_int8_roundtrip_bounded(seed):
    g = jax.random.normal(jax.random.key(seed), (64,)) * 10
    q, scale, res = ef_int8_compress(g)
    rec = ef_int8_decompress(q, scale)
    # quantization error bounded by scale/2 per element (+ residual carries it)
    np.testing.assert_allclose(np.asarray(rec + res),
                               np.asarray(g, np.float32), rtol=1e-5,
                               atol=1e-4)
    assert np.max(np.abs(np.asarray(rec - g))) <= float(scale) * 0.5 + 1e-5


def test_error_feedback_accumulates_to_truth():
    """Over repeated steps with the SAME gradient, error feedback makes the
    long-run mean of decompressed gradients converge to the truth."""
    g = jax.random.normal(jax.random.key(7), (32,))
    res = None
    total = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, scale, res = ef_int8_compress(g, res)
        total = total + ef_int8_decompress(q, scale)
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g),
                               rtol=5e-2, atol=5e-3)
