"""Multi-device behaviour (8-device subprocess): P2P, multicast, sync,
socket virtualization, MoE mem-vs-mcast equivalence, gradient compression.
These are the framework-level reproductions of the paper's C1-C4."""

_CODE = r"""
import jax, jax.numpy as jnp, numpy as np, functools
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import p2p as P2P
from repro.core import multicast as MC
from repro.core import sync as SYNC
from repro.core import socket as SOCK
from repro.core.comm import CommMode, CommPlan, TransferDescriptor
from repro.core.socket import StageRegistry, AcceleratorSocket
from repro.optim.compression import compressed_psum

mesh = compat.make_mesh((8,), ("s",), axis_types=(compat.AxisType.Auto,))
smap = functools.partial(compat.shard_map, mesh=mesh, check_vma=False)

# ---- C1: pull-based P2P ring shift --------------------------------------
x = jnp.arange(8.0)[:, None] * jnp.ones((1, 4))
shifted = jax.jit(smap(lambda v: P2P.p2p_shift(v, "s", 1),
                       in_specs=P("s", None), out_specs=P("s", None)))(x)
np.testing.assert_allclose(shifted[:, 0], np.roll(np.arange(8.0), 1))
print("P2P_SHIFT_OK", flush=True)

# ---- C1: flexible burst re-blocking across a P2P transfer ---------------
x8 = jnp.arange(8.0)[:, None] * jnp.ones((1, 8))   # 8 words per shard
y = jax.jit(smap(lambda v: P2P.p2p_reblocked(v, "s", src=2, dst=5,
                                             producer_burst=4,
                                             consumer_burst=8),
                 in_specs=P("s", None), out_specs=P("s", None)))(x8)
got = np.asarray(y).reshape(8, -1)[5]          # consumer rank 5's words
np.testing.assert_allclose(got, np.full(8, 2.0))
print("P2P_REBLOCK_OK", flush=True)

# ---- C2: multicast broadcast + subset ------------------------------------
b = jax.jit(smap(lambda v: MC.multicast_bcast(v, "s", src=3),
                 in_specs=P("s", None), out_specs=P("s", None)))(x)
np.testing.assert_allclose(np.asarray(b), 3.0)
sub = jax.jit(smap(lambda v: MC.multicast_subset(v, "s", 1, [2, 5, 6]),
                   in_specs=P("s", None), out_specs=P("s", None)))(x)
sub = np.asarray(sub)
for r in (2, 5, 6):
    np.testing.assert_allclose(sub[r], 1.0)
for r in (0, 3, 4, 7):
    np.testing.assert_allclose(sub[r], 0.0)
np.testing.assert_allclose(sub[1], 1.0)        # source keeps its data
print("MCAST_OK", flush=True)

# ---- C3: sync region ------------------------------------------------------
flags = jax.jit(smap(lambda v: SYNC.barrier("s") * jnp.ones_like(v),
                     in_specs=P("s", None), out_specs=P("s", None)))(x)
np.testing.assert_allclose(np.asarray(flags), 8.0)
ready = jax.jit(smap(
    lambda v: SYNC.ready_check(jnp.ones((), jnp.int32), "s")[None],
    in_specs=P("s", None), out_specs=P("s")))(x)
assert bool(np.all(ready))
print("SYNC_OK", flush=True)

# ---- C4: socket with virtualized peers, descriptor-based API --------------
reg = StageRegistry("s", {"producer": 1, "consumer": 6})
sock = AcceleratorSocket(reg)
desc = TransferDescriptor("stage_activation", axes=("batch", None),
                          source="producer", consumer="consumer", pull=True)
out = jax.jit(smap(lambda v: sock.read(v, desc),
                   in_specs=P("s", None), out_specs=P("s", None)))(x)
np.testing.assert_allclose(np.asarray(out).reshape(8, -1)[6], 1.0)
# retarget the producer through the LUT — no code change (static path:
# the perm is baked, so a fresh jit re-resolves the LUT)
reg.remap("producer", 4)
out2 = jax.jit(smap(lambda v: sock.read(v, desc),
                    in_specs=P("s", None), out_specs=P("s", None)))(x)
np.testing.assert_allclose(np.asarray(out2).reshape(8, -1)[6], 4.0)
rec = [r for r in SOCK.issued_records() if r.name == "stage_activation"][-1]
assert rec.issued == "P2P" and rec.user == 1, rec   # virtual LUT index
print("SOCKET_OK", flush=True)

# ---- C4/C5: remap followed WITHOUT retracing (dynamic LUT path) -----------
reg2 = StageRegistry("s", {"producer": 1, "consumer": 6})
sock2 = AcceleratorSocket(reg2)
traces = []

def stage(v, src):
    traces.append(1)
    return sock2.read(v, desc, source=src, consumer=6)

fn = jax.jit(smap(stage, in_specs=(P("s", None), P()),
                  out_specs=P("s", None)))
o1 = fn(x, sock2.peer_rank("producer"))
np.testing.assert_allclose(np.asarray(o1).reshape(8, -1)[6], 1.0)
reg2.remap("producer", 4)
o2 = fn(x, sock2.peer_rank("producer"))
np.testing.assert_allclose(np.asarray(o2).reshape(8, -1)[6], 4.0)
assert len(traces) == 1, f"stage fn retraced {len(traces)}x after remap"
print("SOCKET_REMAP_NO_RETRACE_OK", flush=True)

# ---- C2/C4: plan-driven descriptor write (multicast + sync fence) ---------
reg3 = StageRegistry("s", {"p": 3, "c1": 2, "c2": 5, "c3": 6})
plan = CommPlan({"kv_prefix": CommMode.MCAST})
sock3 = AcceleratorSocket(reg3, plan)
wdesc = TransferDescriptor("kv_prefix", source="p", dests=("c1", "c2", "c3"),
                           sync=True)
wout = jax.jit(smap(lambda v: sock3.write(v, wdesc),
                    in_specs=P("s", None), out_specs=P("s", None)))(x)
wout = np.asarray(wout)
for r in (2, 5, 6):
    np.testing.assert_allclose(wout[r], 3.0)   # src rank 3's payload
for r in (0, 1, 4, 7):
    np.testing.assert_allclose(wout[r], 0.0)   # non-members get zeros
np.testing.assert_allclose(wout[3], 3.0)       # source keeps its data
rec = [r for r in SOCK.issued_records() if r.name == "kv_prefix"][-1]
assert rec.issued == "MCAST" and rec.user == 3 and rec.sync, rec
print("SOCKET_WRITE_OK", flush=True)

# ---- C4/C5: serve-engine kv-prefix hand-off, consumer migration without ---
# ---- retracing (mirrors ServeEngine.make_stage_kv_writer)                ---
reg4 = StageRegistry("s", {"prefill": 0, "d1": 1, "d2": 2, "d3": 3})
sock4 = AcceleratorSocket(reg4, CommPlan({"kv_prefix": CommMode.MCAST}))
kvdesc = TransferDescriptor("kv_prefix", source="prefill",
                            dests=("d1", "d2", "d3"), sync=True)
xp = (jnp.arange(8.0)[:, None] + 1.0) * jnp.ones((1, 4))  # rank r holds r+1
ktraces = []

def kv_burst(v, ranks):
    ktraces.append(1)
    # traced dests vector = the engine's consumer_ranks(): the dynamic-LUT
    # multicast follows a later remap without retracing
    return sock4.write(v, kvdesc, producer=0, dests=list(ranks))

kv_fn = jax.jit(smap(kv_burst, in_specs=(P("s", None), P()),
                     out_specs=P("s", None)))
cranks = lambda: jnp.asarray(
    [reg4.rank_of(n) for n in ("d1", "d2", "d3")], jnp.int32)
k1 = np.asarray(kv_fn(xp, cranks()))
for r in (1, 2, 3):
    np.testing.assert_allclose(k1[r], 1.0)     # prefill rank 0's payload
for r in (4, 5, 6, 7):
    np.testing.assert_allclose(k1[r], 0.0)
reg4.remap("d3", 6)                            # migrate a decode consumer
k2 = np.asarray(kv_fn(xp, cranks()))
for r in (1, 2, 6):
    np.testing.assert_allclose(k2[r], 1.0)
np.testing.assert_allclose(k2[3], 0.0)         # the old rank dropped out
assert len(ktraces) == 1, f"kv writer retraced {len(ktraces)}x after remap"
rec = [r for r in SOCK.issued_records() if r.name == "kv_prefix"][-1]
assert rec.issued == "MCAST" and rec.user == 3 and \
    rec.impl == "dynamic_lut", rec
print("ENGINE_KV_REMAP_OK", flush=True)

# ---- C4: a MEM verdict is an accounting choice, not a dropped transfer ----
SOCK.reset_issue_log()   # judge only this section's records against memplan
memplan = CommPlan({"stage_activation": CommMode.MEM,
                    "moe_dispatch": CommMode.MEM})
sockm = AcceleratorSocket(None, memplan, axis_name="s")
fwd = jax.jit(smap(lambda v: sockm.forward_to_next(v),
                   in_specs=P("s", None), out_specs=P("s", None)))(x)
np.testing.assert_allclose(np.asarray(fwd)[:, 0],
                           np.roll(np.arange(8.0), 1))   # still shifts
rec = [r for r in SOCK.issued_records() if r.name == "stage_activation"][-1]
assert rec.issued == "MEM" and rec.user == 0 and \
    rec.impl == "mem_roundtrip", rec
xe = jnp.arange(64.0).reshape(8, 8)
ex = jax.jit(smap(lambda v: sockm.exchange(
    v.reshape(8, 1), TransferDescriptor("moe_dispatch"), split_axis=0,
    concat_axis=0).reshape(1, 8),
    in_specs=P("s", None), out_specs=P("s", None)))(xe)
np.testing.assert_allclose(np.asarray(ex), np.asarray(xe).T)  # delivered
rec = [r for r in SOCK.issued_records() if r.name == "moe_dispatch"][-1]
assert rec.issued == "MEM" and rec.user == 0, rec
assert SOCK.issued_matches_plan(memplan)
print("SOCKET_MEM_VERDICT_OK", flush=True)

# ---- C2/C5: Pallas multicast-stream fast path through the socket ----------
from repro.kernels import ops
regk = StageRegistry("s", {"p": 3, **{f"c{i}": i for i in range(8) if i != 3}})
sockk = AcceleratorSocket(regk, use_kernels=True,
                          interpret=ops.interpret_params())
kdesc = TransferDescriptor("kv_prefix", source="p",
                           dests=tuple(f"c{i}" for i in range(8) if i != 3))
xm = jax.random.normal(jax.random.key(7), (16, 32), jnp.float32)
kout = jax.jit(smap(lambda v: sockk.write(v, kdesc),
                    in_specs=P(None, None), out_specs=P("s", None)))(xm)
np.testing.assert_allclose(np.asarray(kout), np.tile(np.asarray(xm), (8, 1)),
                           rtol=1e-6, atol=1e-6)
rec = [r for r in SOCK.issued_records() if r.name == "kv_prefix"][-1]
assert rec.impl == "mcast_stream_kernel", rec
print("SOCKET_KERNEL_OK", flush=True)

# ---- C2/C4: MoE mem (shared-memory) == mcast (multicast) ------------------
from repro.configs import get_reduced
from repro.models import moe as M
import dataclasses
cfg = get_reduced("dbrx-132b")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, n_experts=8, capacity_factor=16.0))  # no drops => equal
params = M.moe_init(jax.random.key(0), cfg)
B, S, d = 2, 16, cfg.d_model
xx = jax.random.normal(jax.random.key(1), (B, S, d), jnp.float32)

# expert weights are sharded over the axis (the socket's expert placement);
# the router is replicated
pspec = {"router": P(), "w_gate": P("s", None, None),
         "w_up": P("s", None, None), "w_down": P("s", None, None)}
mem_fn = jax.jit(smap(
    lambda p, v: M.moe_apply(p, v, cfg, mode="mem", model_axis="s")[0],
    in_specs=(pspec, P(None, None, None)), out_specs=P(None, None, None)))
mc_fn = jax.jit(smap(
    lambda p, v: M.moe_apply(p, v, cfg, mode="mcast", model_axis="s")[0],
    in_specs=(pspec, P(None, "s", None)), out_specs=P(None, "s", None)))
y_mem = mem_fn(params, xx)
y_mc = mc_fn(params, xx)
np.testing.assert_allclose(np.asarray(y_mem), np.asarray(y_mc),
                           rtol=5e-2, atol=5e-2)
# both dispatch paths issued through the socket: the mcast trace recorded
# the two all_to_all exchanges, the mem trace the pinned-MEM combine psum
moe_sites = {r.site: r.issued for r in SOCK.issued_records()}
assert moe_sites.get("moe.dispatch") == "MCAST", moe_sites
assert moe_sites.get("moe.combine") == "MCAST", moe_sites
assert moe_sites.get("moe.combine_psum") == "MEM", moe_sites
print("MOE_MODES_OK", flush=True)

# ---- compression: int8 EF psum ≈ f32 psum ---------------------------------
SOCK.reset_issue_log()
g = jax.random.normal(jax.random.key(2), (8, 64))
mean_true = np.mean(np.asarray(g), axis=0)
comp_fn = jax.jit(smap(
    lambda v: compressed_psum(v[0], "s")[0][None],
    in_specs=P("s", None), out_specs=P(None, None)))
mean_q = np.asarray(comp_fn(g))[0]
err = np.max(np.abs(mean_q - mean_true))
scale = np.max(np.abs(np.asarray(g))) / 127.0
assert err <= scale + 1e-6, (err, scale)
# the int32 combine is a real socket issue priced at the int8 wire bytes
# (one byte per element of the per-shard payload), not the widened sum
crec = [r for r in SOCK.issued_records()
        if r.site == "compression.grad_reduce_compressed"][-1]
assert crec.channel == "reduce" and crec.issued == "MEM", crec
assert crec.nbytes == 64, crec.nbytes   # (1, 64) shard -> 64 wire bytes
print("COMPRESSION_OK", flush=True)
"""


def test_distributed_battery(subproc):
    out = subproc(_CODE, n_devices=8)
    for marker in ("P2P_SHIFT_OK", "P2P_REBLOCK_OK", "MCAST_OK", "SYNC_OK",
                   "SOCKET_OK", "SOCKET_REMAP_NO_RETRACE_OK",
                   "SOCKET_WRITE_OK", "ENGINE_KV_REMAP_OK",
                   "SOCKET_MEM_VERDICT_OK",
                   "SOCKET_KERNEL_OK", "MOE_MODES_OK", "COMPRESSION_OK"):
        assert marker in out, out
