"""Attention equivalences: flash == blockwise == full (values and grads),
RoPE/M-RoPE, and the prefill->decode == forward integration contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.models.attention import (flash_attention, blockwise_attention,
                                    full_attention, apply_rope)


def _qkv(key, B=2, S=128, K=2, G=2, hd=16, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, K, G, hd), dtype)
    k = jax.random.normal(k2, (B, S, K, hd), dtype)
    v = jax.random.normal(k3, (B, S, K, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("chunk", [32, 64])
def test_blockwise_matches_full(window, chunk):
    q, k, v = _qkv(jax.random.key(0))
    out_b = blockwise_attention(q, k, v, chunk=chunk, window=window)
    out_f = full_attention(q, k, v, window=window)
    np.testing.assert_allclose(out_b, out_f, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("window", [0, 32])
def test_flash_grads_match_full(window):
    q, k, v = _qkv(jax.random.key(1))
    g = jax.random.normal(jax.random.key(2), q.shape, q.dtype)
    f = lambda *a: jnp.sum(flash_attention(*a, 32, window) * g)
    r = lambda *a: jnp.sum(full_attention(*a, window=window) * g)
    np.testing.assert_allclose(flash_attention(q, k, v, 32, window),
                               full_attention(q, k, v, window=window),
                               rtol=2e-2, atol=2e-2)
    for a, b in zip(jax.grad(f, (0, 1, 2))(q, k, v),
                    jax.grad(r, (0, 1, 2))(q, k, v)):
        np.testing.assert_allclose(a, b, rtol=6e-2, atol=6e-2)


@pytest.mark.parametrize("shape", [(2, 64, 1, 4, 16),   # MQA
                                   (1, 64, 4, 1, 8),    # MHA
                                   (2, 128, 3, 3, 16)]) # GQA, odd heads
def test_attention_shape_sweep(shape):
    B, S, K, G, hd = shape
    k1, k2, k3 = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(k1, (B, S, K, G, hd))
    k = jax.random.normal(k2, (B, S, K, hd))
    v = jax.random.normal(k3, (B, S, K, hd))
    out = blockwise_attention(q, k, v, chunk=32)
    np.testing.assert_allclose(out, full_attention(q, k, v),
                               rtol=2e-2, atol=2e-2)


def test_causality():
    """Changing future tokens must not affect past outputs."""
    q, k, v = _qkv(jax.random.key(4), S=64)
    out1 = full_attention(q, k, v)
    k2 = k.at[:, 48:].set(9.0)
    v2 = v.at[:, 48:].set(-9.0)
    out2 = full_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :48], out2[:, :48], rtol=1e-5,
                               atol=1e-5)


def test_sliding_window_locality():
    """With window w, tokens further than w in the past are invisible."""
    q, k, v = _qkv(jax.random.key(5), S=128)
    w = 16
    out1 = full_attention(q, k, v, window=w)
    # perturb tokens 0..63; outputs at positions >= 64+w must not change
    k2 = k.at[:, :64].set(5.0)
    v2 = v.at[:, :64].set(5.0)
    out2 = full_attention(q, k2, v2, window=w)
    np.testing.assert_allclose(out1[:, 64 + w:], out2[:, 64 + w:],
                               rtol=1e-5, atol=1e-5)


def test_rope_orthogonal_and_relative():
    x = jax.random.normal(jax.random.key(6), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos, 10000.0)
    # norm preserving per pair
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(7), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(8), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([[i]]), 10000.0)
        kj = apply_rope(k, jnp.asarray([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)


def test_mrope_sections_differ_from_plain_rope():
    x = jax.random.normal(jax.random.key(9), (1, 8, 2, 16))
    pos3 = jnp.stack([jnp.arange(8), jnp.arange(8) * 2,
                      jnp.arange(8) * 3], axis=-1)[None]
    y_plain = apply_rope(x, jnp.arange(8)[None], 10000.0)
    y_m = apply_rope(x, pos3, 10000.0, mrope_sections=(4, 2, 2))
    assert not np.allclose(y_plain, y_m)
    # with identical components M-RoPE degrades to plain RoPE
    pos_same = jnp.broadcast_to(jnp.arange(8)[None, :, None], (1, 8, 3))
    y_same = apply_rope(x, pos_same, 10000.0, mrope_sections=(4, 2, 2))
    np.testing.assert_allclose(y_same, y_plain, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("arch", ["qwen3-4b", "h2o-danube-3-4b", "olmo-1b",
                                  "recurrentgemma-9b", "falcon-mamba-7b"])
def test_prefill_then_decode_matches_forward(arch):
    """Integration contract: prefill(tokens[:-1]) + decode(tokens[-1])
    produces the same next-token logits as prefill(tokens)."""
    cfg = get_reduced(arch)
    flags = T.RunFlags(remat="none", attn_impl="full",
                       cache_dtype=jnp.float32)
    params = T.init_params(jax.random.key(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    logits_full, _ = T.prefill(params, toks, cfg, flags)

    _, caches = T.prefill(params, toks[:, :-1], cfg, flags)
    # grow attention caches from S-1 to S slots (decode appends in place)
    def grow(leaf):
        if leaf.ndim >= 3 and leaf.shape[-3] == S - 1:  # (.., B, skv, K, hd)
            pad = [(0, 0)] * leaf.ndim
            pad[-3] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf
    caches = jax.tree.map(grow, caches)
    logits_dec, _ = T.decode_step(params, toks[:, -1:],
                                  jnp.int32(S - 1), caches, cfg, flags)
    np.testing.assert_allclose(logits_dec, logits_full, rtol=3e-2, atol=3e-2)
