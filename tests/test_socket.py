"""Descriptor-based AcceleratorSocket semantics (paper C4/C5), single
device: plan-driven mode resolution, MEM-path axes from the descriptor
(not an activation-shaped guess), the trace-time issue log, and the ISA
round trip for every descriptor the migrated call sites produce."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import isa
from repro.core import socket as SOCK
from repro.core.comm import (CommMode, CommPlan, CommRequest,
                             TransferDescriptor)
from repro.core.sharding import rule_gated_issued_mode, use_rules
from repro.core.socket import AcceleratorSocket, StageRegistry


# ------------------------------------------------------- mode resolution ----

def test_plan_drives_mode_at_issue_site():
    plan = CommPlan({"moe_dispatch": CommMode.MCAST,
                     "weights.L3": CommMode.P2P,
                     "weights": CommMode.MEM})
    sock = AcceleratorSocket(None, plan)
    assert sock.resolve_mode(TransferDescriptor("moe_dispatch")) is \
        CommMode.MCAST
    # exact per-layer entry wins over the base archetype
    assert sock.resolve_mode(TransferDescriptor("weights.L3")) is CommMode.P2P
    # a per-layer name falls back to its base archetype
    assert sock.resolve_mode(TransferDescriptor("weights.L7")) is CommMode.MEM
    # unplanned transfer: the caller's hint (manual/flag-driven behaviour)
    assert sock.resolve_mode(TransferDescriptor("kv_prefix"),
                             CommMode.MCAST) is CommMode.MCAST
    # unplanned, no hint: the plan default
    assert sock.resolve_mode(TransferDescriptor("kv_prefix")) is CommMode.MEM


def test_ambient_plan_from_rules_context():
    plan = CommPlan({"moe_dispatch": CommMode.MCAST})
    sock = AcceleratorSocket()   # no bound plan: reads the ambient context
    assert sock.resolve_mode(TransferDescriptor("moe_dispatch")) is \
        CommMode.MEM
    with use_rules({}, comm_plan=plan):
        assert sock.resolve_mode(TransferDescriptor("moe_dispatch")) is \
            CommMode.MCAST


# ----------------------------------------------- MEM path axes (satellite) ----

def test_mem_path_axes_come_from_descriptor(monkeypatch):
    """The old socket hardcoded ("batch", "seq", "embed")[:ndim] — wrong
    for weights/KV tensors.  The descriptor's own axes must reach the
    resharding constraint."""
    seen = []

    def fake_constraint(x, names):
        seen.append(tuple(names))
        return x

    monkeypatch.setattr(SOCK, "logical_constraint", fake_constraint)
    sock = AcceleratorSocket()
    kv = jnp.zeros((2, 16, 4, 8))
    desc = TransferDescriptor("kv_prefix",
                              axes=("batch", "kv_seq", "kv_heads",
                                    "head_dim"))
    sock.write(kv, desc)
    assert seen == [("batch", "kv_seq", "kv_heads", "head_dim")]
    # a shorter tensor takes the leading axes of ITS descriptor
    w = jnp.zeros((8, 4))
    sock.write(w, TransferDescriptor("weights", axes=("w_fsdp", "mlp")))
    assert seen[-1] == ("w_fsdp", "mlp")
    # no axes -> placement no-op, no constraint issued
    sock.write(w, TransferDescriptor("weights"))
    assert len(seen) == 2


# -------------------------------------------------------------- issue log ----

def test_issue_log_records_planned_vs_issued():
    SOCK.reset_issue_log()
    plan = CommPlan({"stage_activation": CommMode.P2P})
    sock = AcceleratorSocket(None, plan)   # no stage axis on this topology
    x = jnp.ones((4, 4))
    sock.read(x, TransferDescriptor("stage_activation", axes=("batch", None),
                                    pull=True))
    rec = SOCK.issued_records()[-1]
    assert rec.planned == "P2P" and rec.issued == "MEM"
    assert rec.degraded is not None          # explicit degradation reason
    assert rec.user == 0                     # MEM encodes as user field 0
    # degradation to MEM is the paper's own rule: it conforms to the plan
    assert SOCK.issued_matches_plan(plan)


def test_issue_log_site_labels_and_summary():
    SOCK.reset_issue_log()
    SOCK.mem_write(jnp.ones((2, 2)), "block_activation", ("batch", "seq"),
                   site="blk.tail")
    modes = SOCK.issued_modes()
    assert modes["blk.tail"]["issued"] == "MEM"
    assert modes["blk.tail"]["tensor"] == "block_activation"
    SOCK.reset_issue_log()
    assert SOCK.issued_modes() == {}


def test_implicit_issue_and_match_rules():
    SOCK.reset_issue_log()
    plan = CommPlan({"weights": CommMode.MCAST})
    SOCK.record_implicit_issue("weights", planned=CommMode.MCAST,
                               issued=CommMode.MCAST, impl="xla_all_gather",
                               site="train.weights_gather")
    assert SOCK.issued_matches_plan(plan)
    SOCK.reset_issue_log()
    SOCK.record_implicit_issue("weights", planned=CommMode.MCAST,
                               issued=CommMode.MEM, impl="xla_all_gather",
                               reason="w_fsdp gate not cleared")
    # explicitly-degraded still conforms; a silent mismatch would not
    assert SOCK.issued_matches_plan(plan)


def test_rule_gated_issued_mode():
    plan = CommPlan({"weights": CommMode.MCAST})
    # static rules keep the FSDP gather: the MCAST verdict is not real
    assert rule_gated_issued_mode("weights", plan,
                                  {"w_fsdp": ("pod", "data")}) is CommMode.MEM
    # resolved rules drop w_fsdp: the broadcast is real
    assert rule_gated_issued_mode("weights", plan,
                                  {"w_fsdp": None}) is CommMode.MCAST
    # per-layer names vote as their archetype
    assert rule_gated_issued_mode("weights.L3", plan,
                                  {"w_fsdp": None}) is CommMode.MCAST
    assert rule_gated_issued_mode("weights", None,
                                  {"w_fsdp": None}) is CommMode.MEM


def test_mismatched_sites_lists_offenders():
    """A silent planned-vs-issued disagreement is named (site, tensor,
    modes) — the CLIs print these instead of just recording the flag;
    degraded and degeneracy-paired issues stay conforming."""
    SOCK.reset_issue_log()
    plan = CommPlan({"weights": CommMode.MCAST,
                     "moe_dispatch": CommMode.MCAST})
    # silent mismatch: planned MCAST, issued MEM, no degradation reason
    SOCK.record_implicit_issue("weights", planned=CommMode.MCAST,
                               issued=CommMode.MEM, impl="xla_all_gather",
                               site="train.weights_gather")
    # conforming: explicit degradation
    SOCK.record_implicit_issue("moe_dispatch", planned=CommMode.MCAST,
                               issued=CommMode.MEM, impl="xla",
                               reason="no peers", site="moe.dispatch")
    mm = SOCK.mismatched_sites(plan)
    assert [m["site"] for m in mm] == ["train.weights_gather"]
    assert mm[0]["planned"] == "MCAST" and mm[0]["issued"] == "MEM"
    assert not SOCK.issued_matches_plan(plan)
    assert SOCK.mismatched_sites(None) == []


def test_issue_log_records_fused_flag():
    """IssueRecords distinguish a FUSED_RING (or stream-overlapped) issue
    from a serial one; the per-site summary carries the flag."""
    SOCK.reset_issue_log()
    SOCK.mem_write(jnp.ones((2, 2)), "block_activation", ("batch", "seq"))
    rec = SOCK.issued_records()[-1]
    assert rec.fused is False
    assert SOCK.issued_modes()["block_activation"]["fused"] is False


def test_fused_descriptor_field_defaults():
    d = TransferDescriptor("weights")
    assert d.fused_with is None
    f = TransferDescriptor("grad_scatter", fused_with="mlp.down_proj",
                           site="mlp.down_proj")
    assert f.fused_with == "mlp.down_proj"


def test_named_peers_without_registry_degrade_to_mem():
    """An axis-bound socket with no LUT cannot resolve peer *names*: the
    transfer degrades to the MEM path instead of crashing."""
    SOCK.reset_issue_log()
    from repro.core.socket import socket_for_axis
    sock = socket_for_axis("model")
    x = jnp.ones((4, 4))
    out = sock.write(x, TransferDescriptor("kv_prefix", source="prefill",
                                           dests=("decode1",)))
    assert out.shape == x.shape
    rec = SOCK.issued_records()[-1]
    assert rec.issued == "MEM" and rec.degraded is not None


# ------------------------------------------------------------ registry LUT ----

def test_virtual_index_stable_under_remap():
    reg = StageRegistry("stage")
    assert reg.register("prefill", 0) == 1
    assert reg.register("decode1", 1) == 2
    assert reg.virtual_of("decode1") == 2
    reg.remap("decode1", 5)              # elastic re-mesh moves the stage
    assert reg.virtual_of("decode1") == 2  # the user field does not change
    assert reg.rank_of("decode1") == 5     # only the LUT entry does
    with pytest.raises(KeyError):
        reg.remap("unknown", 3)


# ------------------------------------- ISA round trip for migrated sites ----

def _migrated_site_requests():
    """The (descriptor, channel) pairs the migrated call sites produce,
    resolved into control-channel requests exactly as the socket does."""
    reg = StageRegistry("stage")
    reg.register("prefill", 0)
    for i in (1, 2, 3):
        reg.register(f"decode{i}", i)
    plan = CommPlan({"kv_prefix": CommMode.MCAST,
                     "stage_activation": CommMode.P2P,
                     "moe_dispatch": CommMode.MCAST})
    sock = AcceleratorSocket(reg, plan)
    cases = [
        # examples/serve_pipeline.py: KV prefix multicast (write, user=3)
        (TransferDescriptor("kv_prefix", source="prefill",
                            dests=("decode1", "decode2", "decode3"),
                            sync=True), isa.CH_WRITE, 1 << 16),
        # pipeline stage hand-off (read-channel pull, user = LUT index)
        (TransferDescriptor("stage_activation", source="prefill",
                            consumer="decode1", pull=True),
         isa.CH_READ, 4096),
        # models/*: block-output MEM writes (user=0)
        (TransferDescriptor("block_activation",
                            axes=("batch", "seq", "embed")),
         isa.CH_WRITE, 8192),
        (TransferDescriptor("attn_output", axes=("batch", "seq", "embed")),
         isa.CH_WRITE, 8192),
        # unicast degeneracy: a single-destination write encodes user=1
        (TransferDescriptor("kv_prefix", source="prefill",
                            dests=("decode2",)), isa.CH_WRITE, 256),
    ]
    return [(desc, ch, sock.resolve(desc, nbytes, ch)[1])
            for desc, ch, nbytes in cases]


def test_isa_roundtrip_exact_for_migrated_descriptors():
    for desc, channel, req in _migrated_site_requests():
        assert isa.roundtrip_exact(req, channel), (desc, req)
        instr = isa.encode(req, channel)
        back = isa.decode(instr)
        assert back.length == req.length
        assert back.word_bytes == req.word_bytes
        if channel == isa.CH_WRITE:
            assert back.dests == (req.dests if instr.user else ())
            if len(req.dests) == 1:
                # the paper's degeneracy: user=1 decodes as the unicast
                # P2P write a 1-destination multicast is on the wire
                assert back.mode is CommMode.P2P
        else:
            assert back.source == req.source


def test_isa_decode_rejects_malformed_header():
    with pytest.raises(ValueError):
        isa.decode(isa.DmaInstruction(isa.CH_WRITE, user=3, length=4,
                                      word_bytes=4, dests=(1,)))
    with pytest.raises(ValueError):
        isa.decode(isa.DmaInstruction("bogus", user=0, length=4,
                                      word_bytes=4))


def test_exchange_request_user_field_is_peer_count():
    """The MoE all_to_all dispatch encodes fan-out = axis size - 1 on the
    write channel (destination list in the header)."""
    req = CommRequest(64, 4, CommMode.MCAST, dests=tuple(range(1, 8)))
    instr = isa.encode(req, isa.CH_WRITE)
    assert instr.user == 7
    assert isa.decode(instr).mode is CommMode.MCAST
