"""Router area model vs. every number quoted in the paper (Fig. 4)."""

import pytest

from repro.core.noc.router import (base_router_area, router_area,
                                   AREA_PER_DEST_UM2)


def test_baseline_areas_exact():
    assert base_router_area(64) == 3620.0
    assert base_router_area(128) == 6230.0
    assert base_router_area(256) == 11520.0


def test_area_per_destination():
    # "Supporting additional multicast destinations comes at a cost of
    #  200 um^2, on average"
    assert AREA_PER_DEST_UM2 == 200.0
    for w in (64, 128, 256):
        assert router_area(w, 5) - router_area(w, 4) == pytest.approx(200.0)


def test_percent_of_baseline():
    # "... which is 5.5%, 3.2%, and 1.7% of the 64-bit, 128-bit, and
    #  256-bit baseline routers"
    assert 200 / base_router_area(64) == pytest.approx(0.055, abs=0.001)
    assert 200 / base_router_area(128) == pytest.approx(0.032, abs=0.001)
    assert 200 / base_router_area(256) == pytest.approx(0.017, abs=0.001)


def test_thirty_percent_rule():
    # "The 64-bit, 128-bit, and 256-bit NoC routers can support 4, 8, and 16
    #  destinations, respectively, with less than a 30% increase of area."
    for w, d in ((64, 4), (128, 8), (256, 16)):
        assert router_area(w, d) / base_router_area(w) < 1.30


def test_area_roughly_proportional_to_bitwidth():
    # "Increasing the bitwidth of the NoC shows a roughly proportional
    #  increase in the area of the router"
    a64, a128, a256 = (base_router_area(w) for w in (64, 128, 256))
    assert 1.5 < a128 / a64 < 2.0
    assert 1.7 < a256 / a128 < 2.0
