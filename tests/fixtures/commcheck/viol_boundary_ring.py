"""Fixture: trips ``boundary-ring`` (and nothing else).

User-zone code importing a fused ring kernel directly instead of going
through the socket's FUSED_RING dispatch.
"""

from repro.kernels import ring_allgather_matmul
