"""Fixture: trips ``degraded-without-reason`` (and nothing else).

A ``record_implicit_issue`` with no ``reason=`` at all: if the planned
and issued modes ever diverge at this site, the downgrade is recorded
with an empty ``degraded_reason`` — undocumented, and invisible to the
chaos stage's audit.
"""

from repro.core.comm import CommMode
from repro.core.socket import record_implicit_issue


def log_my_collective(plan):
    record_implicit_issue(
        "lab_gather", planned=plan.mode("lab_gather"),
        issued=CommMode.MEM, impl="xla_all_gather", site="lab.gather")
