"""Fixture: trips ``boundary-p2p`` (and nothing else).

A plain aliased import of a guarded collective module in user-zone code.
"""

import repro.core.p2p as _raw
