"""Fixture: trips ``fence-double-write`` (and nothing else).

Two writes to the same descriptor label in one body with no fence
between them: the second burst can overtake the first's consumption.
"""

from repro.core.comm import TransferDescriptor

ACT_DESC = TransferDescriptor("block_activation", site="lab.stream")


def stream_two_chunks(sock, first, second):
    a = sock.write(first, ACT_DESC)
    b = sock.write(second, ACT_DESC)
    return a, b
