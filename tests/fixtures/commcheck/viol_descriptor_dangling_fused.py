"""Fixture: trips ``descriptor-dangling-fused`` (and nothing else).

The ``fused_with`` target is a typo — no descriptor site and no
``register_fusion_target`` registration resolves it, so the transfer
would silently never fuse.
"""

from repro.core.comm import TransferDescriptor

GATHER_DESC = TransferDescriptor("weights", site="lab.up_gather",
                                 fused_with="lab.up_proj ")
