"""Fixture: trips ``fused-target-unregistered`` (and nothing else).

The ``fused_with`` target resolves — it is another descriptor's site
label, so the runtime would fuse and ``descriptor-dangling-fused`` stays
quiet — but no ``register_fusion_target`` call declares it, so the chain
contract lives only in an incidental site-label collision: rename the
consumer site and the transfer silently stops fusing.
"""

from repro.core.comm import TransferDescriptor

GATHER_DESC = TransferDescriptor("weights", site="lab.w_gather",
                                 fused_with="lab.down_proj")
DOWN_DESC = TransferDescriptor("grad_scatter", site="lab.down_proj")
