"""Fixture: trips ``fence-fused-cycle`` (and nothing else).

Each transfer claims to hide behind the other's consumer matmul — a
circular overlap no schedule can realize.  Both targets are registered,
so ``descriptor-dangling-fused`` and ``fused-target-unregistered`` stay
quiet — the cycle is the only defect.
"""

from repro.core.comm import TransferDescriptor, register_fusion_target

register_fusion_target("cyc.scatter")
register_fusion_target("cyc.gather")
UP_DESC = TransferDescriptor("weights", site="cyc.gather",
                             fused_with="cyc.scatter")
DOWN_DESC = TransferDescriptor("grad_scatter", site="cyc.scatter",
                               fused_with="cyc.gather")
