"""Fixture: trips ``fence-fused-cycle`` (and nothing else).

Each transfer claims to hide behind the other's consumer matmul — a
circular overlap no schedule can realize.  Both targets resolve (they
are each other's sites), so ``descriptor-dangling-fused`` stays quiet.
"""

from repro.core.comm import TransferDescriptor

UP_DESC = TransferDescriptor("weights", site="cyc.gather",
                             fused_with="cyc.scatter")
DOWN_DESC = TransferDescriptor("grad_scatter", site="cyc.scatter",
                               fused_with="cyc.gather")
