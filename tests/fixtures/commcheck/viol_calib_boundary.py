"""Fixture: trips ``boundary-p2p`` (and nothing else).

The calibration subsystem (``src/repro/calib/``) lives *outside*
``core/`` — it is user-zone code like any other consumer of the
communication spine, so reaching for a guarded collective module
directly (instead of going through ``AcceleratorSocket``) is the same
boundary violation it is anywhere else.  This file mirrors what a
measurement collector that "just needs the raw primitive" would write.
"""

import repro.core.p2p as _raw
