"""Fixture: trips ``descriptor-dup-site`` (and nothing else).

Two descriptors sharing one issue-log label in the same module: their
per-site ``comm_issued`` entries would silently overwrite each other.
"""

from repro.core.comm import TransferDescriptor

KV_DESC = TransferDescriptor("kv_prefix", site="decode.kv")
W_DESC = TransferDescriptor("weights", site="decode.kv")
