"""Fixture: trips ``boundary-p2p`` (and nothing else).

The attribute-chain vector the old grep gate could not see: the string
``repro.core.p2p`` never appears in this file — the reference only
exists after resolving ``core`` through the import alias map.
"""

from repro import core


def send_around_the_socket(x):
    return core.p2p.p2p_send(x, peer=1)
