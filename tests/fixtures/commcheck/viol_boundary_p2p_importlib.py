"""Fixture: trips ``boundary-p2p`` (and nothing else).

The dynamic-load vector: a literal ``importlib.import_module`` of a
guarded collective module resolves like any other import.
"""

import importlib

_mcast = importlib.import_module("repro.core.multicast")
