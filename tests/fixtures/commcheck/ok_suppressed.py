"""Fixture: zero findings — a real ``boundary-p2p`` violation silenced
by the inline suppression comment (the suppression round-trip the
analyzer tests assert on)."""

import repro.core.p2p as _raw  # commcheck: allow(boundary-p2p)
