"""Fixture: trips ``descriptor-literal-flags`` (and nothing else).

``sync=`` computed at runtime: the planner and the fence pass cannot
reason about a dynamic flag.
"""

import os

from repro.core.comm import TransferDescriptor

_WANT_FENCE = bool(os.environ.get("LAB_FENCE"))

ACT_DESC = TransferDescriptor("block_activation", site="lab.act",
                              sync=_WANT_FENCE)
