"""Fixture: zero findings — the serve-path decode downgrade, recorded.

The continuous-batching decode has no sequence dimension for the MoE
mcast dispatch to shard, so the step factory pins the transfer to the
MEM path.  The downgrade is *audit-visible*: ``record_implicit_issue``
carries a literal machine-readable ``reason=`` (the
``degraded-without-reason`` rule's requirement) and a literal ``site=``
so the ``--against-artifact`` coverage universe admits it.  Mirrors
``repro.runtime.serve._decode_downgrades``.
"""

from repro.core.comm import CommMode
from repro.core.socket import record_implicit_issue


def downgrade_decode_dispatch(plan):
    planned = plan.mode("moe_dispatch")
    plan = plan.with_mode("moe_dispatch", CommMode.MEM)
    record_implicit_issue(
        "moe_dispatch", planned=planned, issued=CommMode.MEM,
        impl="decode_downgrade", reason="decode_no_seq_dim",
        site="lab.decode_moe_dispatch")
    return plan
