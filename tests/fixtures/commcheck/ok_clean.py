"""Fixture: zero findings — idiomatic spine usage.

Descriptors with distinct sites, a registered self-loop ``fused_with``,
and a double write correctly ordered by a ``sync=True`` fence issue.
"""

from repro.core.comm import TransferDescriptor, register_fusion_target

register_fusion_target("lab.o_proj")
PROJ_DESC = TransferDescriptor("grad_scatter", site="lab.o_proj",
                               fused_with="lab.o_proj")
ACT_DESC = TransferDescriptor("block_activation", site="lab.act")
FENCED_DESC = TransferDescriptor("block_activation", site="lab.act_fenced",
                                 sync=True)


def stream_fenced(sock, first, second):
    a = sock.write(first, ACT_DESC)
    sock.write(first, FENCED_DESC)       # C3 fence orders the stream
    b = sock.write(second, ACT_DESC)
    return a, b
