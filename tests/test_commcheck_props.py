"""Property tests (tier-2): commcheck's parsing layers round-trip.

Runs under real ``hypothesis`` when installed, else the deterministic
vendored fallback (``tests/_hypothesis_vendor.py``) — strategies used
here (text / lists / sampled_from / integers) are part of the vendored
surface; extend the vendor AND conftest's registration list in lockstep
if new ones appear."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import (check_rule_ids, default_rules,
                            format_allowlist, format_suppression,
                            parse_allowlist, parse_suppression_comment,
                            parse_suppressions)
from repro.analysis.engine import AllowEntry, Finding
from repro.core.isa import (UserFieldRangeError, encode, user_field_capacity,
                            CH_READ, CH_WRITE)
from repro.core.comm import CommMode, CommRequest

pytestmark = pytest.mark.tier2

# rule-id-shaped and glob-shaped tokens: no whitespace, no "#", no ")" —
# the vocabularies the suppression/allowlist grammars actually carry
_RULE_ID = st.text(alphabet="abcdefghijklmnopqrstuvwxyz-", min_size=1,
                   max_size=24)
_GLOB = st.text(alphabet="abcdefghijklmnopqrstuvwxyz0123456789_./*-",
                min_size=1, max_size=24)


@settings(deadline=None, max_examples=60)
@given(rules=st.lists(_RULE_ID, min_size=1, max_size=5, unique=True))
def test_suppression_roundtrip(rules):
    """format_suppression -> parse_suppression_comment is the identity on
    any rule-id list, including with surrounding code text."""
    comment = format_suppression(rules)
    assert parse_suppression_comment(comment) == rules
    assert parse_suppression_comment(f"x = f(1, 2)  {comment}") == rules
    # and through the per-line parser: the code line carries exactly them
    per_line = parse_suppressions(f"x = 1\ny = 2  {comment}\n")
    assert per_line.get(2) == set(rules)
    assert 1 not in per_line


@settings(deadline=None, max_examples=60)
@given(entries=st.lists(st.tuples(_RULE_ID, _GLOB), min_size=0, max_size=6))
def test_allowlist_roundtrip(entries):
    """format_allowlist -> parse_allowlist is the identity, and each
    entry covers exactly the findings its glob matches."""
    objs = [AllowEntry(r, g) for r, g in entries]
    assert parse_allowlist(format_allowlist(objs)) == objs
    for e in objs:
        hit = Finding(e.rule, e.glob.replace("*", "x"), 1, "m")
        if "*" not in e.glob:
            assert e.covers(hit)
        assert not e.covers(Finding(e.rule + "x", e.glob, 1, "m"))


@settings(deadline=None, max_examples=30)
@given(junk=st.text(alphabet="abcdefghijklmnopqrstuvwxyz ", min_size=1,
                    max_size=30))
def test_allowlist_rejects_malformed(junk):
    """Any non-comment line that is not exactly two tokens is a loud
    parse error, never a silently ignored exemption."""
    tokens = junk.split()
    if len(tokens) == 2:
        assert parse_allowlist(junk) == [AllowEntry(*tokens)]
    elif not tokens:
        assert parse_allowlist(junk) == []
    else:
        with pytest.raises(ValueError):
            parse_allowlist(junk)


def test_rule_id_uniqueness_is_stable():
    """The shipped catalog stays collision-free (the suppression and
    allowlist vocabulary depends on it)."""
    check_rule_ids(default_rules())
    ids = [r.id for r in default_rules()]
    assert len(ids) == len(set(ids)) == 9


@settings(deadline=None, max_examples=60)
@given(coord_bits=st.integers(1, 8), over=st.integers(1, 1000))
def test_user_field_capacity_is_the_exact_boundary(coord_bits, over):
    """encode() accepts every value up to user_field_capacity(coord_bits)
    and rejects every value past it, on both channels."""
    cap = user_field_capacity(coord_bits)
    assert cap == (1 << (2 * coord_bits)) - 1
    ok = CommRequest(4, 4, CommMode.P2P, source=cap)
    assert encode(ok, CH_READ, coord_bits=coord_bits).user == cap
    with pytest.raises(UserFieldRangeError):
        encode(CommRequest(4, 4, CommMode.P2P, source=cap + over),
               CH_READ, coord_bits=coord_bits)
    with pytest.raises(UserFieldRangeError):
        encode(CommRequest(4, 4, CommMode.MCAST, dests=(1, cap + over)),
               CH_WRITE, coord_bits=coord_bits)
