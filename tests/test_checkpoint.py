"""Checkpoint store: roundtrip, atomicity, GC, corruption, async saver."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, AsyncCheckpointer)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 6)),
                   "b": jnp.zeros((6,), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.ones((4, 6)), "b": jnp.zeros((6,))},
                "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    restored = restore_checkpoint(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


@settings(deadline=None, max_examples=10)
@given(shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                       min_size=1, max_size=4))
def test_roundtrip_property(tmp_path_factory, shapes):
    d = str(tmp_path_factory.mktemp("ckpt"))
    tree = {f"leaf{i}": jnp.arange(a * b, dtype=jnp.float32).reshape(a, b)
            for i, (a, b) in enumerate(shapes)}
    save_checkpoint(d, 1, tree)
    restored = restore_checkpoint(d, 1, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(restored[k]))


def test_keep_last_k(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_corruption_detected(tmp_path):
    tree = _tree()
    path = save_checkpoint(str(tmp_path), 1, tree)
    # flip a byte in one leaf
    victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    fp = os.path.join(path, victim)
    data = bytearray(open(fp, "rb").read())
    data[-1] ^= 0xFF
    open(fp, "wb").write(bytes(data))
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), 1, tree)


def test_shape_mismatch_rejected(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    bad = jax.tree.map(lambda x: jnp.zeros((9,) + x.shape, x.dtype), tree)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_tmp_dirs_do_not_count(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree)
    os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
    assert latest_step(str(tmp_path)) == 3


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = _tree()
    ck.save(1, tree)
    ck.save(2, tree)   # waits for save 1 first (double buffering)
    ck.wait()
    assert latest_step(str(tmp_path)) == 2
    restored = restore_checkpoint(str(tmp_path), 2, tree)
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  np.asarray(restored["params"]["w"]))


def test_elastic_restore_respects_target_structure(tmp_path):
    """Restore works from a structurally identical tree of different
    (host) array types — the elastic re-mesh path."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored = restore_checkpoint(str(tmp_path), 1, template)
    np.testing.assert_array_equal(np.asarray(tree["opt"]["mu"]["w"]),
                                  np.asarray(restored["opt"]["mu"]["w"]))
