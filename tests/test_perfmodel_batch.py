"""Closed-form batch path vs the scalar DES: bit-exact, cap-free.

The vectorized ``batch_cycles`` exists to make planning cheap, not
approximate: every (fan-out, bursts) point must agree *exactly* with the
scalar discrete-event recurrences — including bursts beyond the old
``BATCH_BURST_CAP`` of 4096, where the seed implementation switched to
linear extrapolation — and on pod-scale ``SoCParams`` profiles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.noc.perfmodel import (PAPER_MILESTONES, SoCParams,
                                      SoCPerfModel)
from repro.configs.espsoc_trafficgen import CONSUMER_SWEEP, SIZE_SWEEP


@pytest.fixture(scope="module")
def model():
    return SoCPerfModel()


def _assert_batch_matches_scalar(m, points):
    ns = np.array([p[0] for p in points])
    ds = np.array([p[1] for p in points])
    batch = m.batch_cycles(ns, ds)
    for i, (n, s) in enumerate(points):
        assert batch["mem"][i] == m.shared_memory_cycles(n, s), (n, s)
        if n <= m.max_dests:
            assert batch["mcast"][i] == m.multicast_cycles(n, s), (n, s)
        else:
            assert np.isnan(batch["mcast"][i]), (n, s)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(1, 16), bursts=st.integers(1, 96))
def test_batch_bit_exact_random_points(model, n, bursts):
    """Random fan-outs (crossing the co-tenant boundary at 10+) and burst
    counts: the closed form equals the scalar DES to the bit."""
    _assert_batch_matches_scalar(model, [(n, bursts * 4096)])


def test_batch_bit_exact_beyond_old_cap(model):
    """The seed extrapolated past 4096 bursts; the closed form stays exact
    (the steady-state period is derived, not fitted)."""
    _assert_batch_matches_scalar(model, [(16, 4200 * 4096), (3, 5000 * 4096)])


def test_batch_bit_exact_fig6_grid(model):
    _assert_batch_matches_scalar(
        model, [(n, s) for n in CONSUMER_SWEEP for s in SIZE_SWEEP])


def test_sweep_is_scalar_speedup(model):
    sweep = model.sweep(CONSUMER_SWEEP, SIZE_SWEEP)
    for (n, s), v in sweep.items():
        assert v == model.speedup(n, s), (n, s)
    for (n, s), target in PAPER_MILESTONES.items():
        assert sweep[(n, s)] == pytest.approx(target, rel=0.10)


@settings(deadline=None, max_examples=8)
@given(n=st.integers(1, 16), bursts=st.integers(1, 48),
       mesh=st.sampled_from([(8, 8), (16, 16)]))
def test_pod_profiles_bit_exact(n, bursts, mesh):
    """Pod-scale profiles (parametric mesh, placement, 2-cycle links) run
    through the same closed form and still match their scalar DES."""
    m = SoCPerfModel(SoCParams.pod(*mesh))
    _assert_batch_matches_scalar(m, [(n, bursts * m.p.burst_bytes)])


def test_pod_profile_topology():
    p = SoCParams.pod(16, 16)
    assert p.coord_bits == 4
    assert p.accel_per_tile == 1 and p.n_accel is None
    assert len(p.accel_tiles()) == 16 * 16 - 3   # cpu + mem + io reserved
    m = SoCPerfModel(p)
    # ESP's 16-destination cap still binds at pod scale
    assert m.max_dests == 16
    # fan-out above the tile budget is clamped, not an error, on the batch
    # path (the planner degrades those transfers to MEM)
    out = m.batch_cycles(np.array([500]), np.array([65536]))
    assert np.isfinite(out["mem"][0]) and np.isnan(out["mcast"][0])


def test_default_profile_unchanged_by_generalization():
    """The parametric SoCParams defaults reproduce the calibrated 3x4 FPGA
    SoC exactly: placement, generator packing, and the milestone fits."""
    p = SoCParams()
    assert p.mem_tile == (0, 1) and p.cpu_tile == (0, 0)
    assert p.link_latency == 1 and p.coord_bits == 3
    tiles = p.accel_tiles()
    assert len(tiles) == 17
    assert len(set(tiles)) == 9          # 2 generators per tile, one single
    m = SoCPerfModel(p)
    for (n, s), target in PAPER_MILESTONES.items():
        assert m.speedup(n, s) == pytest.approx(target, rel=0.10)
