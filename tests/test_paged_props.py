"""Property tests (tier-2): the paged block-cache decode is *bit-identical*
to the contiguous continuously-batched decode.

For randomized generation depths, block sizes, and physical block
permutations, running ``make_paged_decode_step`` over pools + block
tables produces exactly the same logits and greedy tokens, step for
step, as ``make_batched_decode_step`` over the grown contiguous caches —
the block indirection is pure data movement, never arithmetic.  Ring
(windowed) and recurrent-state leaves take the slot-state path; a
deterministic ring case (prompt longer than the window) and a pure
recurrent case (mamba) pin those down.

Runs under real ``hypothesis`` when installed, else under the vendored
deterministic fallback (``tests/_hypothesis_vendor.py``) — keep that
module's strategy surface in sync with what this file imports.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.runtime import kv_blocks as KB
from repro.runtime import serve as RS

pytestmark = pytest.mark.tier2

S = 12
N_SLOTS = 2
FLAGS = T.RunFlags(remat="none")

# (gen, block_size) with block_size | (S + gen) — the layout's contract
_GEOMS = ((4, 2), (4, 4), (4, 8), (4, 16), (2, 2), (2, 7), (2, 14),
          (6, 2), (6, 3), (6, 6), (6, 9), (6, 18))

# worst case needs n_slots * max_blocks = 2 * (18 // 2) = 18 distinct
# physical blocks; a 24-wide permutation covers every geometry
_PERM_WIDTH = 24

geom_st = st.sampled_from(_GEOMS)
arch_st = st.sampled_from(("qwen3-4b", "h2o-danube-3-4b"))
perm_st = st.permutations(list(range(_PERM_WIDTH)))

_CACHE = {}


def _setup(arch, prompt_len):
    """One prefill per (arch, prompt_len): params, last-token logits and
    the contiguous prefix caches for N_SLOTS requests."""
    key = (arch, prompt_len)
    if key not in _CACHE:
        cfg = get_reduced(arch)
        params = T.init_params(jax.random.key(0), cfg, FLAGS.param_dtype)
        prompts = jax.random.randint(jax.random.key(1),
                                     (N_SLOTS, prompt_len), 0,
                                     cfg.vocab_size)
        logits, caches = RS.make_prefill_step(cfg, FLAGS)(params, prompts)
        _CACHE[key] = (cfg, params, logits, caches)
    return _CACHE[key]


def _paged_state(cfg, caches, prompt_len, gen, bs, perm):
    """Write the prefill caches into block pools under a permuted
    physical block assignment; returns (layout, pools, tables)."""
    lay = KB.paged_layout(cfg, n_slots=N_SLOTS, prompt_len=prompt_len,
                          max_new_tokens=gen, block_size=bs,
                          dtype=FLAGS.cache_dtype)
    pools = KB.make_pools(lay)
    mb = lay.max_blocks
    # restrict the fixed-width permutation to the blocks this geometry
    # needs (order preserved => still a permutation), skip the null block
    order = [v for v in perm if v < N_SLOTS * mb]
    tables = KB.null_table(lay)
    n_prefix = -(-prompt_len // bs)
    for slot in range(N_SLOTS):
        blocks = [1 + v for v in order[slot * mb:(slot + 1) * mb]]
        tables[slot, :] = blocks
        pre = jax.tree.map(
            lambda sp, c: jnp.take(c, jnp.asarray([slot]), axis=sp.batch_ax),
            lay.specs, caches, is_leaf=KB._spec_is_leaf)
        pools = KB.write_prefix(lay, pools, pre, jnp.int32(slot),
                                jnp.asarray(blocks[:n_prefix], jnp.int32))
    return lay, pools, tables


def _assert_paged_equals_contiguous(arch, prompt_len, gen, bs, perm):
    cfg, params, logits0, caches = _setup(arch, prompt_len)
    lay, pools, tables = _paged_state(cfg, caches, prompt_len, gen, bs, perm)
    paged_step = RS.make_paged_decode_step(cfg, FLAGS, lay)
    ref_step = RS.make_batched_decode_step(cfg, FLAGS)
    ref_caches = RS.grow_caches(cfg, caches, prompt_len, gen)

    tok = jnp.argmax(logits0[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_ref = t_pg = tok
    pos = jnp.full((N_SLOTS,), prompt_len, jnp.int32)
    for j in range(gen - 1):
        l_ref, ref_caches = ref_step(params, t_ref, pos, ref_caches)
        l_pg, pools = paged_step(params, t_pg, pos, pools,
                                 jnp.asarray(tables))
        np.testing.assert_array_equal(
            np.asarray(l_pg), np.asarray(l_ref),
            err_msg=f"step {j}: paged logits diverged "
                    f"(gen={gen} bs={bs} arch={arch})")
        t_ref = jnp.argmax(l_ref[:, -1], axis=-1).astype(jnp.int32)[:, None]
        t_pg = jnp.argmax(l_pg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(np.asarray(t_pg), np.asarray(t_ref))
        pos = pos + 1


@settings(max_examples=5, deadline=None)
@given(arch=arch_st, geom=geom_st, perm=perm_st)
def test_paged_decode_is_bit_identical(arch, geom, perm):
    gen, bs = geom
    _assert_paged_equals_contiguous(arch, S, gen, bs, perm)


def test_ring_case_prompt_longer_than_window():
    # h2o-danube reduced window = 32 < prompt 36: the attention leaves are
    # rings, classified slot-state — the paged path must wrap identically
    _assert_paged_equals_contiguous("h2o-danube-3-4b", 36, 4, 8,
                                    list(range(_PERM_WIDTH)))


def test_recurrent_state_case_mamba():
    # no full-sequence history at all: everything rides the slot-state
    # gather/scatter (including the pool-dtype coercion)
    _assert_paged_equals_contiguous("falcon-mamba-7b", S, 4, 8,
                                    list(reversed(range(_PERM_WIDTH))))
