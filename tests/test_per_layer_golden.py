"""Per-layer transfer-spec golden test.

A 4-layer MoE transformer's compiled prefill step must emit ONE spec per
layer per collective archetype with stable ``.L<i>`` names — the scanned
stack's trip count is the layer count, same-kind ops within one layer
aggregate, and the unscanned epilogue collectives (embedding/final-norm
gathers, last-position permute) land as one trailing pseudo-layer each.
The full (name, fan_out, layer) list is pinned against the checked-in
``golden_per_layer_specs.json``: any change to the HLO -> TransferSpec
mapping that renames, reorders, or re-counts per-layer transfers must
update the golden deliberately.
"""

import json
import os

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden_per_layer_specs.json")

_CODE = r"""
import dataclasses, json
import jax
from repro import compat
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import build_comm_plan, lower_cell, make_flags
from repro.launch.hlo_analysis import transfer_specs_from_hlo

cfg = dataclasses.replace(get_reduced("dbrx-132b"), name="dbrx-4l",
                          n_layers=4)
mesh = compat.make_mesh((4, 4), ("data", "model"),
                        axis_types=(compat.AxisType.Auto,) * 2)
shape = ShapeConfig("g", 128, 16, "prefill")
flags = make_flags(cfg, shape, moe_mode="mcast")
plan, _ = build_comm_plan("auto", cfg, shape, mesh)
lowered, _ = lower_cell(cfg, shape, mesh, flags, comm_plan=plan)
specs = transfer_specs_from_hlo(lowered.compile().as_text())
print("SPECS_JSON=" + json.dumps(
    [[s.name, s.fan_out, s.layer] for s in specs]))
"""


def test_per_layer_specs_golden(subproc):
    out = subproc(_CODE, n_devices=16)
    got = json.loads(out.split("SPECS_JSON=", 1)[1].splitlines()[0])
    with open(GOLDEN) as f:
        want = json.load(f)
    assert got == want, (
        "per-layer transfer specs diverged from the golden list — if the "
        "HLO mapping changed deliberately, regenerate "
        "tests/golden_per_layer_specs.json")
    # structural invariant behind the golden: the 4 scanned layers appear
    # as .L0-.L3 for every archetype the step exhibits
    names = {n for n, _, _ in got}
    for arch in ("weights", "moe_dispatch", "stage_activation",
                 "grad_reduce"):
        assert {f"{arch}.L{i}" for i in range(4)} <= names, arch
