"""Fault tolerance: restart-on-failure, straggler detection, elastic mesh,
and re-mesh => re-plan (survivor-topology re-pricing + LUT remap)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.core.noc.perfmodel import SoCParams, SoCPerfModel
from repro.core.planner import plan_decision_flips, resolve_policy
from repro.core.socket import StageRegistry
from repro.data import SyntheticTokenStream
from repro.models.transformer import RunFlags
from repro.runtime.fault import (FaultError, FaultTolerantRunner,
                                 StragglerStats, remap_registry_for_mesh,
                                 replan_for_mesh, shrink_mesh)
from repro.runtime.train import make_train_step, init_state


def _make(tmp_path, ckpt_every=3):
    cfg = get_reduced("smollm-135m")
    flags = RunFlags(remat="none")
    step_fn, _, _ = make_train_step(cfg, flags)
    jstep = jax.jit(step_fn, donate_argnums=0)
    state = init_state(jax.random.key(0), cfg, flags)
    stream = SyntheticTokenStream(cfg.vocab_size, 4, 64)
    batches = lambda s: {k: jnp.asarray(v) for k, v in stream.batch(s).items()}
    runner = FaultTolerantRunner(jstep, str(tmp_path), ckpt_every=ckpt_every)
    return runner, state, batches


def test_restart_replays_deterministically(tmp_path):
    runner, state, batches = _make(tmp_path)
    fails = {5}

    def inject(step):
        if step in fails:
            fails.discard(step)
            raise FaultError("injected node failure")

    runner.inject_failures(inject)
    state, hist = runner.run(state, batches, 8)
    assert runner.restarts == 1
    steps = [h["step"] for h in hist]
    assert steps == [0, 1, 2, 3, 4, 3, 4, 5, 6, 7]
    # deterministic replay: the re-run of steps 3-4 reproduces the losses
    by_step = {}
    for h in hist:
        by_step.setdefault(h["step"], []).append(h["loss"])
    for s in (3, 4):
        assert by_step[s][0] == pytest.approx(by_step[s][1], rel=1e-6)


def test_failure_before_first_checkpoint_raises(tmp_path):
    runner, state, batches = _make(tmp_path, ckpt_every=100)

    def inject(step):
        if step == 1:
            raise FaultError("early failure")

    runner.inject_failures(inject)
    with pytest.raises(FaultError):
        runner.run(state, batches, 4)


def test_straggler_stats():
    st = StragglerStats()
    for _ in range(10):
        assert not st.update(1.0, factor=3.0)
    assert st.update(10.0, factor=3.0)     # 10x the EMA: flagged
    assert st.events == 1
    # EMA not polluted by the straggler sample
    assert st.ema == pytest.approx(1.0, rel=0.05)


def test_shrink_mesh_keeps_tp_groups():
    devs = list(range(12))  # stand-ins; Mesh accepts any array-like of devices
    with pytest.raises(Exception):
        shrink_mesh([], 4)
    mesh_like = shrink_mesh(np.asarray(jax.devices() * 12)[:12], 1)
    assert mesh_like.shape["data"] == 12
    assert mesh_like.shape["model"] == 1


def test_shrink_mesh_survivors_below_model_parallel():
    # 3 survivors cannot host a TP group of 4: a FaultError, not a
    # silently-wrong 0-wide mesh
    devs = np.asarray(jax.devices() * 3)[:3]
    with pytest.raises(FaultError, match="model_parallel=4"):
        shrink_mesh(devs, 4)


def test_shrink_mesh_drops_remainder_hosts():
    # 7 survivors with model_parallel=2: only 6 fit whole TP groups, the
    # 7th is dropped rather than shearing a group
    devs = np.asarray(jax.devices() * 7)[:7]
    mesh = shrink_mesh(devs, 2)
    assert mesh.shape["data"] == 3 and mesh.shape["model"] == 2
    assert mesh.size == 6


def test_shrink_mesh_to_one_host():
    devs = np.asarray(jax.devices() * 1)[:1]
    mesh = shrink_mesh(devs, 1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_nan_loss_triggers_restart(tmp_path):
    runner, state, batches = _make(tmp_path)
    calls = {"n": 0}
    orig = runner.step_fn

    def poisoned(state, batch):
        new_state, metrics = orig(state, batch)
        calls["n"] += 1
        if calls["n"] == 5:
            metrics = dict(metrics)
            metrics["loss"] = jnp.float32(jnp.nan)
        return new_state, metrics

    runner.step_fn = poisoned
    state, hist = runner.run(state, batches, 6)
    assert runner.restarts == 1
    assert hist[-1]["step"] == 5


def test_straggler_first_step_seeds_ema():
    st = StragglerStats()
    assert not st.update(7.0)          # first sample seeds the EMA ...
    assert st.ema == pytest.approx(7.0)
    assert not st.update(70.0)          # ... and warmup (count <= 2) never
    assert not st.update(70.0)          # flags, however slow
    assert st.events == 0


def test_straggler_reset_rebaselines_but_keeps_events():
    st = StragglerStats()
    for _ in range(5):
        st.update(0.1)
    assert st.update(10.0)              # flagged against the 0.1s EMA
    st.reset()
    assert st.count == 0 and st.ema == 0.0
    assert st.events == 1               # cumulative tally survives the reset
    # post-re-mesh the survivor topology is 10x slower per step; without
    # the reset every step would be a straggler — with it, none are
    for _ in range(5):
        assert not st.update(1.0)
    assert st.events == 1


# ------------------------------------------------ re-mesh => re-plan ----

_POD33 = SoCPerfModel(SoCParams.pod(3, 3))   # max_dests=5: 8 ranks > cap > 4


def test_replan_for_mesh_flips_weights_to_mcast():
    cfg = get_reduced("smollm-135m")
    shape = ShapeConfig("remesh", 128, 8, "train")
    plan8, _ = resolve_policy("auto", cfg, shape, {"data": 8, "model": 1},
                              model=_POD33)
    assert plan8.mode("weights").name == "MEM"   # fan-out 8 over cap 5
    plan4, _, rules, overlay, flips = replan_for_mesh(
        plan8, cfg, shape, {"data": 4, "model": 1}, model=_POD33)
    assert plan4.mode("weights").name == "MCAST"
    assert {"tensor": "weights", "old": "MEM", "new": "MCAST"} in flips
    assert rules is None and overlay is None     # no resolve callable given


def test_plan_cache_keys_on_mesh_shape():
    # same policy/specs, different survivor topology: the cache must not
    # alias the pre-fault entry (same mesh -> same cached object)
    cfg = get_reduced("smollm-135m")
    shape = ShapeConfig("remesh", 128, 8, "train")
    a1, _ = resolve_policy("auto", cfg, shape, {"data": 8, "model": 1},
                           model=_POD33)
    a2, _ = resolve_policy("auto", cfg, shape, {"data": 8, "model": 1},
                           model=_POD33)
    b, _ = resolve_policy("auto", cfg, shape, {"data": 4, "model": 1},
                          model=_POD33)
    assert a1 is a2
    assert b is not a1
    assert b.mode("weights") is not a1.mode("weights")


def test_plan_decision_flips_handles_missing_plans():
    assert plan_decision_flips(None, None) == []
    cfg = get_reduced("smollm-135m")
    shape = ShapeConfig("remesh", 128, 8, "train")
    p, _ = resolve_policy("auto", cfg, shape, {"data": 8, "model": 1},
                          model=_POD33)
    assert plan_decision_flips(None, p) == []
    assert plan_decision_flips(p, p) == []


def test_remap_registry_folds_dropped_ranks():
    reg = StageRegistry("stage")
    for i in range(8):
        reg.register(f"stage{i}", i)
    virt_before = {n: reg.virtual_of(n) for n in reg.table}
    moved = remap_registry_for_mesh(reg, 4)
    assert [(n, o, nw) for n, o, nw in moved] == [
        ("stage4", 4, 0), ("stage5", 5, 1), ("stage6", 6, 2),
        ("stage7", 7, 3)]
    assert all(r < 4 for r in reg.table.values())
    # the no-retrace property: virtual indices (what the encoded user
    # field carries) are untouched by the remap
    assert {n: reg.virtual_of(n) for n in reg.table} == virt_before
    assert remap_registry_for_mesh(reg, 4) == []   # idempotent


def test_remesh_hook_swaps_step_and_records_event(tmp_path):
    runner, state, batches = _make(tmp_path)       # ckpt_every=3
    orig = runner.step_fn
    swapped_calls = {"n": 0}

    def swapped(state, batch):
        swapped_calls["n"] += 1
        return orig(state, batch)

    flips = [{"tensor": "weights", "old": "MEM", "new": "MCAST"}]

    def hook(step, err):
        assert step == 5 and isinstance(err, FaultError)
        return {"step_fn": swapped, "flips": flips,
                "mesh_axes": {"data": 4, "model": 1}}

    runner.remesh_hook = hook
    runner.straggler.update(100.0)                 # pre-fault EMA to reset
    fails = {5}

    def inject(step):
        if step in fails:
            fails.discard(step)
            raise FaultError("host lost")

    runner.inject_failures(inject)
    state, hist = runner.run(state, batches, 8)
    assert runner.restarts == 1
    # restored to step 3 (last checkpoint) and replayed 3..7 on the new fn
    assert swapped_calls["n"] == 5
    assert [h["step"] for h in hist][-5:] == [3, 4, 5, 6, 7]
    assert runner.comm_replan_events == [{
        "flips": flips, "mesh_axes": {"data": 4, "model": 1},
        "step": 5, "error": "host lost"}]
    # straggler EMA re-baselined: only the post-recovery steps counted
    assert runner.straggler.count == 5


def test_remesh_hook_returning_none_is_plain_restart(tmp_path):
    runner, state, batches = _make(tmp_path)
    runner.remesh_hook = lambda step, err: None
    fails = {5}

    def inject(step):
        if step in fails:
            fails.discard(step)
            raise FaultError("transient")

    runner.inject_failures(inject)
    state, hist = runner.run(state, batches, 8)
    assert runner.restarts == 1
    assert runner.comm_replan_events == []
