"""Fault tolerance: restart-on-failure, straggler detection, elastic mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import SyntheticTokenStream
from repro.models.transformer import RunFlags
from repro.runtime.fault import (FaultError, FaultTolerantRunner,
                                 StragglerStats, shrink_mesh)
from repro.runtime.train import make_train_step, init_state


def _make(tmp_path, ckpt_every=3):
    cfg = get_reduced("smollm-135m")
    flags = RunFlags(remat="none")
    step_fn, _, _ = make_train_step(cfg, flags)
    jstep = jax.jit(step_fn, donate_argnums=0)
    state = init_state(jax.random.key(0), cfg, flags)
    stream = SyntheticTokenStream(cfg.vocab_size, 4, 64)
    batches = lambda s: {k: jnp.asarray(v) for k, v in stream.batch(s).items()}
    runner = FaultTolerantRunner(jstep, str(tmp_path), ckpt_every=ckpt_every)
    return runner, state, batches


def test_restart_replays_deterministically(tmp_path):
    runner, state, batches = _make(tmp_path)
    fails = {5}

    def inject(step):
        if step in fails:
            fails.discard(step)
            raise FaultError("injected node failure")

    runner.inject_failures(inject)
    state, hist = runner.run(state, batches, 8)
    assert runner.restarts == 1
    steps = [h["step"] for h in hist]
    assert steps == [0, 1, 2, 3, 4, 3, 4, 5, 6, 7]
    # deterministic replay: the re-run of steps 3-4 reproduces the losses
    by_step = {}
    for h in hist:
        by_step.setdefault(h["step"], []).append(h["loss"])
    for s in (3, 4):
        assert by_step[s][0] == pytest.approx(by_step[s][1], rel=1e-6)


def test_failure_before_first_checkpoint_raises(tmp_path):
    runner, state, batches = _make(tmp_path, ckpt_every=100)

    def inject(step):
        if step == 1:
            raise FaultError("early failure")

    runner.inject_failures(inject)
    with pytest.raises(FaultError):
        runner.run(state, batches, 4)


def test_straggler_stats():
    st = StragglerStats()
    for _ in range(10):
        assert not st.update(1.0, factor=3.0)
    assert st.update(10.0, factor=3.0)     # 10x the EMA: flagged
    assert st.events == 1
    # EMA not polluted by the straggler sample
    assert st.ema == pytest.approx(1.0, rel=0.05)


def test_shrink_mesh_keeps_tp_groups():
    devs = list(range(12))  # stand-ins; Mesh accepts any array-like of devices
    with pytest.raises(Exception):
        shrink_mesh([], 4)
    mesh_like = shrink_mesh(np.asarray(jax.devices() * 12)[:12], 1)
    assert mesh_like.shape["data"] == 12
    assert mesh_like.shape["model"] == 1


def test_nan_loss_triggers_restart(tmp_path):
    runner, state, batches = _make(tmp_path)
    calls = {"n": 0}
    orig = runner.step_fn

    def poisoned(state, batch):
        new_state, metrics = orig(state, batch)
        calls["n"] += 1
        if calls["n"] == 5:
            metrics = dict(metrics)
            metrics["loss"] = jnp.float32(jnp.nan)
        return new_state, metrics

    runner.step_fn = poisoned
    state, hist = runner.run(state, batches, 6)
    assert runner.restarts == 1
    assert hist[-1]["step"] == 5
