"""CommMode / CommRequest / CommPlan semantics (paper C1 + C4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.comm import (CommMode, CommPlan, CommRequest,
                             validate_p2p_totals, reblock)


def test_user_field_encoding():
    # read channel: 0 = DMA, k = P2P source k
    assert CommRequest(8, 4, CommMode.MEM).user_field_read() == 0
    assert CommRequest(8, 4, CommMode.P2P, source=3).user_field_read() == 3
    # write channel: 0 = DMA, 1 = unicast, n>=2 = multicast
    assert CommRequest(8, 4, CommMode.MEM).user_field_write() == 0
    assert CommRequest(8, 4, CommMode.P2P, dests=(2,)).user_field_write() == 1
    assert CommRequest(8, 4, CommMode.MCAST,
                       dests=(1, 2, 3)).user_field_write() == 3


def test_plan_mixes_modes_per_tensor():
    # the paper's NN example: weights from memory, activations from the
    # previous accelerator — in the same invocation
    plan = CommPlan({"weights": CommMode.MEM,
                     "prev_layer_acts": CommMode.P2P})
    assert plan.mode("weights") is CommMode.MEM
    assert plan.mode("prev_layer_acts") is CommMode.P2P
    assert plan.mode("unknown") is CommMode.MEM
    plan2 = plan.with_mode("moe_dispatch", CommMode.MCAST)
    assert plan2.mode("moe_dispatch") is CommMode.MCAST
    assert plan.mode("moe_dispatch") is CommMode.MEM  # immutable update


@given(bursts_p=st.lists(st.integers(1, 64), min_size=1, max_size=10),
       scale=st.integers(1, 4))
def test_p2p_totals_flexible_patterns(bursts_p, scale):
    """C1: producer/consumer may differ in burst count and size as long as
    totals agree."""
    total = sum(bursts_p)
    consumer = [total * scale // scale]  # single burst of equal total
    assert validate_p2p_totals(bursts_p, consumer)


@given(bursts=st.lists(st.integers(1, 64), min_size=1, max_size=10),
       extra=st.integers(1, 16))
def test_p2p_totals_mismatch_raises(bursts, extra):
    with pytest.raises(ValueError):
        validate_p2p_totals(bursts, [sum(bursts) + extra])


@given(n_bursts=st.integers(1, 8), burst=st.sampled_from([4, 8, 16]),
       out_burst=st.sampled_from([2, 4, 8, 32]))
def test_reblock_preserves_stream(n_bursts, burst, out_burst):
    total = n_bursts * burst
    x = jnp.arange(total, dtype=jnp.float32).reshape(n_bursts, burst)
    if total % out_burst:
        with pytest.raises(ValueError):
            reblock(x, out_burst)
        return
    y = reblock(x, out_burst)
    assert y.shape == (total // out_burst, out_burst)
    np.testing.assert_array_equal(np.asarray(y).ravel(),
                                  np.asarray(x).ravel())
