"""Property tests (tier-2): the user-field ISA encode/decode round trip.

Runs under real ``hypothesis`` when installed, else the deterministic
vendored fallback (``tests/_hypothesis_vendor.py``) — strategies used
here (integers / sampled_from / lists / booleans) are all part of the
vendored surface; extend the vendor in lockstep if new ones appear."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isa
from repro.core.comm import (CommMode, CommRequest, mode_from_read_field,
                             mode_from_write_field)

pytestmark = pytest.mark.tier2

_LEN = st.integers(1, 1 << 20)
_WORD = st.sampled_from([1, 2, 4, 8])
_PEER = st.integers(1, 31)


@settings(deadline=None, max_examples=60)
@given(length=_LEN, word=_WORD, source=_PEER, mem=st.booleans())
def test_read_channel_roundtrip(length, word, source, mem):
    """Read channel: user 0 = MEM, k >= 1 = P2P pull from LUT index k; the
    encoded field must decode to the same mode and source."""
    req = (CommRequest(length, word, CommMode.MEM) if mem
           else CommRequest(length, word, CommMode.P2P, source=source))
    user = req.user_field_read()
    assert mode_from_read_field(user) is req.mode
    instr = isa.encode(req, isa.CH_READ)
    assert instr.user == user
    back = isa.decode(instr)
    assert back.mode is req.mode
    assert back.length == length and back.word_bytes == word
    if not mem:
        assert back.source == source
    # wire-level fixed point: re-encoding the decoded request is identity
    assert isa.encode(back, isa.CH_READ) == instr
    assert isa.roundtrip_exact(req, isa.CH_READ)


@settings(deadline=None, max_examples=60)
@given(length=_LEN, word=_WORD,
       dests=st.lists(_PEER, min_size=0, max_size=16, unique=True))
def test_write_channel_roundtrip(length, word, dests):
    """Write channel: user 0 = MEM, 1 = unicast, n >= 2 = multicast to the
    n-entry header list.  Decode recovers the destination list exactly;
    the mode matches the field's triad."""
    dests = tuple(dests)
    if not dests:
        req = CommRequest(length, word, CommMode.MEM)
    elif len(dests) == 1:
        req = CommRequest(length, word, CommMode.P2P, dests=dests)
    else:
        req = CommRequest(length, word, CommMode.MCAST, dests=dests)
    user = req.user_field_write()
    assert user == len(dests) if dests else user == 0
    instr = isa.encode(req, isa.CH_WRITE)
    back = isa.decode(instr)
    assert back.dests == dests
    assert back.length == length and back.word_bytes == word
    assert isa.encode(back, isa.CH_WRITE) == instr
    assert isa.roundtrip_exact(req, isa.CH_WRITE)


@settings(deadline=None, max_examples=40)
@given(length=_LEN, word=_WORD, dest=_PEER)
def test_user1_unicast_multicast_degeneracy(length, word, dest):
    """The paper's degeneracy: a 1-destination multicast and a unicast P2P
    write share the ``user=1`` encoding — same wire transaction.  Both
    requests encode to the identical instruction, and decode lands on the
    P2P label (the socket treats the pair as conforming)."""
    as_p2p = CommRequest(length, word, CommMode.P2P, dests=(dest,))
    as_mcast = CommRequest(length, word, CommMode.MCAST, dests=(dest,))
    i1 = isa.encode(as_p2p, isa.CH_WRITE)
    i2 = isa.encode(as_mcast, isa.CH_WRITE)
    assert i1 == i2
    assert i1.user == 1
    assert mode_from_write_field(1) is CommMode.P2P
    assert isa.decode(i1).mode is CommMode.P2P
    # the degenerate pair still round-trips exactly at the wire level
    assert isa.roundtrip_exact(as_mcast, isa.CH_WRITE)


@settings(deadline=None, max_examples=40)
@given(user=st.integers(0, 64))
def test_field_triad_total(user):
    """Every non-negative field value decodes; the triad is total and
    consistent between the read and write channels at 0."""
    rm = mode_from_read_field(user)
    wm = mode_from_write_field(user)
    if user == 0:
        assert rm is CommMode.MEM and wm is CommMode.MEM
    else:
        assert rm is CommMode.P2P
        assert wm is (CommMode.P2P if user == 1 else CommMode.MCAST)


@settings(deadline=None, max_examples=20)
@given(user=st.integers(-8, -1))
def test_negative_field_rejected(user):
    with pytest.raises(ValueError):
        mode_from_read_field(user)
    with pytest.raises(ValueError):
        mode_from_write_field(user)
