"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in TPU interpret
mode.  Multi-device kernels run in an 8-device subprocess (device count is
locked at first jax init in the main process)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ------------------------------------------------- single-device kernels ----

@pytest.mark.parametrize("shape", [(16, 128), (64, 128), (32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_blocks", [2, 4])
def test_dma_double_buffer_sweep(shape, dtype, n_blocks):
    if shape[0] % n_blocks:
        pytest.skip("rows not divisible")
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    y = ops.dma_stream(x, 1.3, n_blocks=n_blocks,
                       interpret=ops.interpret_params())
    expect = ref.dma_stream_ref(x, 1.3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


# ------------------------------------------------ multi-device (subproc) ----

_SWEEP_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.kernels import ops, ref

mesh = compat.make_mesh((8,), ("x",), axis_types=(compat.AxisType.Auto,))
ip = ops.interpret_params()
P = 8

for dtype, tol in ((jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)):
    m, k, n = 8, 16, 8
    xs = jax.random.normal(jax.random.key(0), (P * m, k), dtype)
    w = jax.random.normal(jax.random.key(1), (k, n), dtype)
    out = ops.allgather_matmul(xs, w, mesh, "x", interpret=ip)
    expect = ref.allgather_matmul_ref(xs.reshape(P, m, k), w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol * 10)
print("AG_OK", flush=True)

x = jax.random.normal(jax.random.key(2), (16, 32), jnp.float32)
w = jax.random.normal(jax.random.key(3), (32, 8), jnp.float32)
out = ops.reducescatter_matmul(x, w, mesh, "x", interpret=ip)
np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w, np.float32),
                           rtol=1e-3, atol=1e-3)
print("RS_OK", flush=True)

for src, n_chunks in ((0, 4), (3, 2)):
    xm = jax.random.normal(jax.random.key(src), (16, 32), jnp.float32)
    outm = ops.multicast(xm, mesh, "x", src=src, n_chunks=n_chunks,
                         interpret=ip)
    np.testing.assert_allclose(outm, jnp.tile(xm, (8, 1)),
                               rtol=1e-6, atol=1e-6)
print("MCAST_OK", flush=True)
"""


def test_collective_kernel_sweep(subproc):
    out = subproc(_SWEEP_CODE, n_devices=8)
    assert "AG_OK" in out and "RS_OK" in out and "MCAST_OK" in out
