"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in TPU interpret
mode.  Multi-device kernels run in an 8-device subprocess (device count is
locked at first jax init in the main process)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ------------------------------------------------- single-device kernels ----

@pytest.mark.parametrize("shape", [(16, 128), (64, 128), (32, 256),
                                   (13, 128), (50, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_blocks", [2, 4])
def test_dma_double_buffer_sweep(shape, dtype, n_blocks):
    # (13, .) / (50, .): rows do NOT divide n_blocks — the final block
    # clamps its DMA window and rewrites a few trailing rows (elementwise
    # op, so the re-written values are identical)
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    y = ops.dma_stream(x, 1.3, n_blocks=n_blocks,
                       interpret=ops.interpret_params())
    expect = ref.dma_stream_ref(x, 1.3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m", [16, 13])   # 13: uneven final block (clamped)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_streamed_gather_matmul_bitwise(m, dtype):
    """The double-buffered streamed weights-gather matmul is BIT-identical
    to the unfused reference (``jnp.dot`` at f32 accumulate) — row-blocking
    the streamed operand keeps every output row's contraction intact, so
    the socket's streamed-MEM rung and its serial fallback cannot drift."""
    from repro.kernels.streamed_gather import streamed_gather_matmul
    x = jax.random.normal(jax.random.key(0), (m, 32), dtype)
    w = jax.random.normal(jax.random.key(1), (32, 8), dtype)
    y = streamed_gather_matmul(x, w, n_blocks=4,
                               interpret=ops.interpret_params())
    expect = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        jnp.promote_types(x.dtype, w.dtype))
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(expect, np.float32))


# ------------------------------------------------ multi-device (subproc) ----

_SWEEP_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.kernels import ops, ref

mesh = compat.make_mesh((8,), ("x",), axis_types=(compat.AxisType.Auto,))
ip = ops.interpret_params()
P = 8

for dtype, tol in ((jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)):
    m, k, n = 8, 16, 8
    xs = jax.random.normal(jax.random.key(0), (P * m, k), dtype)
    w = jax.random.normal(jax.random.key(1), (k, n), dtype)
    out = ops.allgather_matmul(xs, w, mesh, "x", interpret=ip)
    expect = ref.allgather_matmul_ref(xs.reshape(P, m, k), w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol * 10)
print("AG_OK", flush=True)

x = jax.random.normal(jax.random.key(2), (16, 32), jnp.float32)
w = jax.random.normal(jax.random.key(3), (32, 8), jnp.float32)
out = ops.reducescatter_matmul(x, w, mesh, "x", interpret=ip)
np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w, np.float32),
                           rtol=1e-3, atol=1e-3)
print("RS_OK", flush=True)

for src, n_chunks in ((0, 4), (3, 2)):
    xm = jax.random.normal(jax.random.key(src), (16, 32), jnp.float32)
    outm = ops.multicast(xm, mesh, "x", src=src, n_chunks=n_chunks,
                         interpret=ip)
    np.testing.assert_allclose(outm, jnp.tile(xm, (8, 1)),
                               rtol=1e-6, atol=1e-6)
print("MCAST_OK", flush=True)
"""


def test_collective_kernel_sweep(subproc):
    out = subproc(_SWEEP_CODE, n_devices=8)
    assert "AG_OK" in out and "RS_OK" in out and "MCAST_OK" in out


# ------------------------------------------ ring kernels vs lax reference ----

_RING_EQUIV_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.kernels import ops

mesh = compat.make_mesh((8,), ("x",), axis_types=(compat.AxisType.Auto,))
ip = ops.interpret_params()
Pn = 8

def lax_ag_mm(x, w):
    def body(xs, ws):
        full = jax.lax.all_gather(xs, "x", axis=0, tiled=True)
        return jnp.dot(full, ws, preferred_element_type=jnp.float32
                       ).astype(jnp.promote_types(xs.dtype, ws.dtype))
    return jax.jit(compat.shard_map(body, mesh=mesh,
                                    in_specs=(P("x", None), P(None, None)),
                                    out_specs=P(None, None),
                                    check_vma=False))(x, w)

def lax_rs_mm(x, w):
    def body(xs, ws):
        part = jnp.dot(xs, ws, preferred_element_type=jnp.float32)
        return jax.lax.psum_scatter(part, "x", scatter_dimension=0,
                                    tiled=True)
    return jax.jit(compat.shard_map(body, mesh=mesh,
                                    in_specs=(P(None, "x"), P("x", None)),
                                    out_specs=P("x", None),
                                    check_vma=False))(x, w)

# all-gather matmul: 2 dtypes x uneven per-rank chunk counts (m = 3 rows
# per rank is NOT a power of two; m = 8 is the friendly case)
for dtype, tol in ((jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)):
    for m in (3, 8):
        k, n = 16, 8
        x = jax.random.normal(jax.random.key(m), (Pn * m, k), dtype)
        w = jax.random.normal(jax.random.key(m + 1), (k, n), dtype)
        fused = ops.allgather_matmul(x, w, mesh, "x", interpret=ip)
        ref = lax_ag_mm(x, w)
        np.testing.assert_allclose(np.asarray(fused, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol * 10,
                                   err_msg=f"ag dtype={dtype} m={m}")
print("RING_AG_EQUIV_OK", flush=True)

# reduce-scatter matmul: 2 dtypes x uneven output chunks (m = 24 -> 3
# rows per rank; m = 16 -> 2)
for dtype, tol in ((jnp.float32, 1e-3), (jnp.bfloat16, 5e-2)):
    for m in (16, 24):
        x = jax.random.normal(jax.random.key(m), (m, 32), dtype)
        w = jax.random.normal(jax.random.key(m + 3), (32, 8), dtype)
        fused = ops.reducescatter_matmul(x, w, mesh, "x", interpret=ip)
        ref = lax_rs_mm(x, w)
        np.testing.assert_allclose(np.asarray(fused, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol * 10,
                                   err_msg=f"rs dtype={dtype} m={m}")
print("RING_RS_EQUIV_OK", flush=True)
"""


def test_ring_kernels_match_unfused_lax(subproc):
    """Interpret-mode equivalence of the fused ring kernels against the
    unfused lax lowering (all_gather+dot / dot+psum_scatter) across two
    dtypes and uneven chunk counts — the numerical contract behind the
    socket's FUSED_RING dispatch."""
    out = subproc(_RING_EQUIV_CODE, n_devices=8)
    assert "RING_AG_EQUIV_OK" in out and "RING_RS_EQUIV_OK" in out


# -------------------------------------------- socket FUSED_RING dispatch ----

_FUSED_DISPATCH_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.comm import (CommMode, CommPlan, TransferDescriptor,
                             register_fusion_target)
from repro.core import socket as SOCK

mesh = compat.make_mesh((8,), ("x",), axis_types=(compat.AxisType.Auto,))
ip = compat.interpret_params()
plan = CommPlan({"weights": CommMode.P2P, "grad_scatter": CommMode.P2P})
# this subprocess never imports repro.models.layers, so the consumer-matmul
# labels the descriptors fuse with must be registered here (the socket
# rejects a dangling fused_with at issue time)
register_fusion_target("mlp.up_proj")
register_fusion_target("mlp.down_proj")
gdesc = TransferDescriptor("weights", fused_with="mlp.up_proj",
                           site="t.gather")
rdesc = TransferDescriptor("grad_scatter", fused_with="mlp.down_proj",
                           site="t.rs")

x = jax.random.normal(jax.random.key(0), (8 * 4, 16), jnp.float32)
w = jax.random.normal(jax.random.key(1), (16, 8), jnp.float32)

def run_gather(use_kernels, p=plan):
    def body(xs, ws):
        s = SOCK.socket_for_axis("x", p, use_kernels=use_kernels,
                                 interpret=ip)
        return s.gather_matmul(xs, ws, gdesc)
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("x", None), P(None, None)),
        out_specs=P(None, None), check_vma=False))(x, w)

SOCK.reset_issue_log()
fused = run_gather(True)
rec = SOCK.issued_records()[-1]
assert rec.fused and rec.impl == "ring_allgather_matmul", rec
assert rec.channel == "gather_matmul" and rec.issued == "P2P"
assert rec.user == 1   # ring hop = unicast write (the user=1 degeneracy)
SOCK.reset_issue_log()
unfused = run_gather(False)
rec = SOCK.issued_records()[-1]
assert not rec.fused and rec.impl == "lax_all_gather", rec
np.testing.assert_allclose(np.asarray(fused), np.asarray(x @ w),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(unfused), np.asarray(x @ w),
                           rtol=1e-4, atol=1e-4)
assert SOCK.issued_matches_plan(plan)

# a MEM verdict falls back serially and is charged the round-trip
SOCK.reset_issue_log()
memp = CommPlan({"weights": CommMode.MEM})
out_mem = run_gather(True, memp)
rec = SOCK.issued_records()[-1]
assert rec.issued == "MEM" and not rec.fused and rec.user == 0
np.testing.assert_allclose(np.asarray(out_mem), np.asarray(x @ w),
                           rtol=1e-4, atol=1e-4)
print("FUSED_GM_OK", flush=True)

xr = jax.random.normal(jax.random.key(2), (16, 8 * 4), jnp.float32)
wr = jax.random.normal(jax.random.key(3), (8 * 4, 8), jnp.float32)

def run_rs(use_kernels):
    def body(xs, ws):
        s = SOCK.socket_for_axis("x", plan, use_kernels=use_kernels,
                                 interpret=ip)
        return s.matmul_reduce_scatter(xs, ws, rdesc)
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
        out_specs=P("x", None), check_vma=False))(xr, wr)

SOCK.reset_issue_log()
f = run_rs(True)
rec = SOCK.issued_records()[-1]
assert rec.fused and rec.impl == "ring_reducescatter_matmul", rec
u = run_rs(False)
rec = SOCK.issued_records()[-1]
assert not rec.fused and rec.impl == "lax_psum_scatter", rec
np.testing.assert_allclose(np.asarray(f), np.asarray(xr @ wr),
                           rtol=1e-3, atol=1e-3)
np.testing.assert_allclose(np.asarray(u), np.asarray(xr @ wr),
                           rtol=1e-3, atol=1e-3)
print("FUSED_RS_OK", flush=True)

# the migrated attention o-projection site: head-sharded context x
# row-sharded w_o combined by the fused ring, output sequence-sharded
from repro.models.attention import o_proj_tp

ctx = jax.random.normal(jax.random.key(4), (16, 8 * 4), jnp.float32)
w_o = jax.random.normal(jax.random.key(5), (8 * 4, 8), jnp.float32)

def run_oproj(use_kernels):
    def body(cs, ws):
        s = SOCK.socket_for_axis("x", plan, use_kernels=use_kernels,
                                 interpret=ip)
        return o_proj_tp(cs, ws, socket=s)
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
        out_specs=P("x", None), check_vma=False))(ctx, w_o)

SOCK.reset_issue_log()
of = run_oproj(True)
rec = SOCK.issued_records()[-1]
assert rec.site == "attn.o_proj" and rec.fused, rec
ou = run_oproj(False)
np.testing.assert_allclose(np.asarray(of), np.asarray(ctx @ w_o),
                           rtol=1e-3, atol=1e-3)
np.testing.assert_allclose(np.asarray(ou), np.asarray(ctx @ w_o),
                           rtol=1e-3, atol=1e-3)
print("FUSED_OPROJ_OK", flush=True)
"""


def test_socket_fused_ring_dispatch(subproc):
    """The FUSED_RING outcome end-to-end: a P2P verdict + declared
    consumer matmul + use_kernels dispatches the ring kernels (IssueRecord
    marked fused, user=1 ring-hop encoding), the lax fallback and the MEM
    round-trip produce identical numbers, and every issue conforms to the
    plan."""
    out = subproc(_FUSED_DISPATCH_CODE, n_devices=8)
    assert "FUSED_GM_OK" in out and "FUSED_RS_OK" in out
    assert "FUSED_OPROJ_OK" in out


_FFN_TP_CODE = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs import get_reduced
from repro.core import socket as SOCK
from repro.core.sharding import use_rules, DEFAULT_RULES
from repro.models import transformer as T

mesh = compat.make_mesh((2, 4), ("data", "model"),
                        axis_types=(compat.AxisType.Auto,) * 2)
cfg = get_reduced("qwen3-4b")
B, S = 4, 32
flags0 = T.RunFlags(distributed=True, remat="none")
flags1 = dataclasses.replace(flags0, ffn_tp=True)
params = T.init_params(jax.random.key(0), cfg, jnp.float32)
batch = {"tokens": jnp.full((B, S), 3, jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}

def loss(flags):
    with use_rules(dict(DEFAULT_RULES), mesh):
        return jax.jit(lambda p, b: T.forward_train(p, b, cfg, flags))(
            params, batch)

l0 = float(loss(flags0))
SOCK.reset_issue_log()
l1 = float(loss(flags1))
np.testing.assert_allclose(l0, l1, rtol=2e-2)
sites = {r.site for r in SOCK.issued_records()}
assert "mlp.up_gather" in sites and "mlp.down_proj" in sites, sites
print("FFN_TP_OK", flush=True)
"""


def test_transformer_ffn_tp_matches_gspmd(subproc):
    """The migrated dense-MLP blocks (socket-issued fused transfers inside
    shard_map) reproduce the GSPMD baseline loss, and both fused call
    sites appear in the issue log."""
    out = subproc(_FFN_TP_CODE, n_devices=8)
    assert "FFN_TP_OK" in out


# ---------------------- streamed-MEM gather + fused MoE dispatch chain ------

_STREAMED_AND_CHAIN_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.comm import CommMode, CommPlan, TransferDescriptor
from repro.core import socket as SOCK

mesh = compat.make_mesh((8,), ("s",), axis_types=(compat.AxisType.Auto,))
ip = compat.interpret_params()

# ---- streamed-MEM weights gather: plan.streamed drives the DMA schedule ---
from repro.models.layers import MLP_GATHER_DESC

splan = CommPlan({"weights": CommMode.MEM},
                 streamed_names=frozenset({"weights"}))
x = jax.random.normal(jax.random.key(0), (8 * 4, 16), jnp.float32)
w = jax.random.normal(jax.random.key(1), (16, 8), jnp.float32)

def run_gather(use_kernels, plan):
    def body(xs, ws):
        s = SOCK.socket_for_axis("s", plan, use_kernels=use_kernels,
                                 interpret=ip)
        return s.gather_matmul(xs, ws, MLP_GATHER_DESC)
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("s", None), P(None, None)),
        out_specs=P(None, None), check_vma=False))(x, w)

SOCK.reset_issue_log()
streamed = run_gather(True, splan)
rec = SOCK.issued_records()[-1]
assert rec.fused and rec.impl == "streamed_gather_matmul", rec
assert rec.issued == "MEM" and rec.user == 0, rec
serial = run_gather(False, splan)
rec = SOCK.issued_records()[-1]
assert not rec.fused and rec.impl == "mem_roundtrip", rec
# bit-identical: the streamed schedule only reorders HBM reads
np.testing.assert_array_equal(np.asarray(streamed), np.asarray(serial))
assert SOCK.issued_matches_plan(splan)
# a plain (non-streamed) MEM verdict never dispatches the stream, kernels
# on or not: streaming is an attribute of the PRICED decision
plain = CommPlan({"weights": CommMode.MEM})
run_gather(True, plain)
rec = SOCK.issued_records()[-1]
assert rec.impl == "mem_roundtrip" and not rec.fused, rec
print("STREAMED_GM_OK", flush=True)

# ---- fused MoE chain: dispatch -> expert FFN -> combine -------------------
import dataclasses
from repro.configs import get_reduced
from repro.models import moe as M

cfg = get_reduced("dbrx-132b")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, n_experts=8, capacity_factor=16.0))
params = M.moe_init(jax.random.key(0), cfg)
B, S, d = 2, 16, cfg.d_model
xx = jax.random.normal(jax.random.key(1), (B, S, d), jnp.float32)
pspec = {"router": P(), "w_gate": P("s", None, None),
         "w_up": P("s", None, None), "w_down": P("s", None, None)}

def run_moe(use_kernels):
    def body(p, v):
        return M.moe_apply(p, v, cfg, mode="mcast", model_axis="s",
                           use_kernels=use_kernels, interpret=ip)[0]
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(pspec, P(None, "s", None)),
        out_specs=P(None, "s", None), check_vma=False))(params, xx)

SOCK.reset_issue_log()
y_fused = run_moe(True)
by_site = {r.site: r for r in SOCK.issued_records()}
drec, crec = by_site["moe.dispatch"], by_site["moe.combine"]
assert drec.fused and drec.impl == "ring_dispatch_ffn", drec
assert drec.channel == "dispatch_chain" and drec.issued == "MCAST", drec
assert crec.fused and crec.impl == "ring_dispatch_ffn", crec
SOCK.reset_issue_log()
y_serial = run_moe(False)
by_site = {r.site: r for r in SOCK.issued_records()}
assert not by_site["moe.dispatch"].fused, by_site
# the ring pipeline's per-slab FFN is bit-identical to the full-batch FFN
# of the serial all_to_all pair (row-independent expert einsums)
np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_serial))
print("MOE_CHAIN_OK", flush=True)
"""


def test_streamed_gather_and_moe_chain_dispatch(subproc):
    """The two new fused paths end-to-end through the socket: a streamed
    MEM verdict (``CommPlan.streamed_names``) dispatches the double-buffered
    gather kernel, and the mcast MoE dispatch->FFN->combine chain dispatches
    the ring pipeline — each bit-identical to its unfused fallback, each
    leaving the right IssueRecord."""
    out = subproc(_STREAMED_AND_CHAIN_CODE, n_devices=8)
    assert "STREAMED_GM_OK" in out and "MOE_CHAIN_OK" in out
