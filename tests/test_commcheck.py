"""commcheck: the static analyzer for the communication spine.

Covers the rule catalog against the fixture corpus (each fixture file
trips exactly one rule), the zero-findings invariant on the real tree,
the suppression/allowlist layers, the ``--against-artifact`` coverage
cross-check, the CLI exit protocol, and the two runtime mirrors the PR
hardened (``UnregisteredFusionTargetError`` at the socket,
``UserFieldRangeError`` in the ISA encoder).
"""

import json
import os
import textwrap

import pytest

from repro.analysis import (analyze, check_rule_ids, default_rules,
                            extract_module, format_suppression,
                            parse_allowlist, parse_suppression_comment,
                            zone_of)
from repro.analysis.__main__ import main as commcheck_main
from repro.analysis.extract import (ZONE_CORE, ZONE_KERNELS, ZONE_TESTS,
                                    ZONE_USER)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "commcheck")
SCAN_ROOTS = [os.path.join(REPO, p)
              for p in ("src/repro", "examples", "benchmarks", "scripts")]

# fixture file -> the single rule id it must trip (and no other)
FIXTURE_RULES = {
    "viol_boundary_p2p_alias.py": "boundary-p2p",
    "viol_boundary_p2p_attr.py": "boundary-p2p",
    "viol_boundary_p2p_importlib.py": "boundary-p2p",
    "viol_boundary_ring.py": "boundary-ring",
    "viol_calib_boundary.py": "boundary-p2p",
    "viol_descriptor_dup_site.py": "descriptor-dup-site",
    "viol_descriptor_dangling_fused.py": "descriptor-dangling-fused",
    "viol_descriptor_literal_flags.py": "descriptor-literal-flags",
    "viol_degraded_without_reason.py": "degraded-without-reason",
    "viol_fence_double_write.py": "fence-double-write",
    "viol_fence_fused_cycle.py": "fence-fused-cycle",
    "viol_fused_target_unregistered.py": "fused-target-unregistered",
}


# ------------------------------------------------------------- fixtures ----

@pytest.mark.parametrize("fname,rule", sorted(FIXTURE_RULES.items()))
def test_fixture_trips_exactly_one_rule(fname, rule):
    report = analyze([os.path.join(FIXTURES, fname)])
    assert [f.rule for f in report.findings] == [rule], \
        [f.render() for f in report.findings]


def test_fixture_corpus_is_exhaustive():
    """Every viol_* fixture is claimed by the table above, and together
    they exercise every tree-scan rule id."""
    on_disk = {f for f in os.listdir(FIXTURES)
               if f.startswith("viol_") and f.endswith(".py")}
    assert on_disk == set(FIXTURE_RULES)
    assert set(FIXTURE_RULES.values()) == {r.id for r in default_rules()}


def test_whole_corpus_scan_is_the_union():
    """Scanning the corpus directory at once reports each fixture's rule
    (cross-file resolution does not let one fixture mask another) and the
    ok_* files stay silent."""
    report = analyze([FIXTURES])
    got = {}
    for f in report.findings:
        got.setdefault(os.path.basename(f.path), []).append(f.rule)
    assert got == {k: [v] for k, v in FIXTURE_RULES.items()}


def test_suppressed_fixture_is_clean_but_recorded():
    report = analyze([os.path.join(FIXTURES, "ok_suppressed.py")])
    assert report.ok
    assert [f.rule for f in report.suppressed] == ["boundary-p2p"]


def test_clean_fixture_has_nothing_at_all():
    report = analyze([os.path.join(FIXTURES, "ok_clean.py")])
    assert report.ok and not report.suppressed and not report.allowlisted


def test_serve_downgrade_fixture_is_clean_with_reason(tmp_path):
    """The serve-path decode downgrade idiom (literal reason= + site=)
    passes; the same record with the reason stripped trips
    degraded-without-reason — the exact regression the serve bugfix
    sweep closed."""
    fixture = os.path.join(FIXTURES, "ok_degraded_serve_downgrade.py")
    report = analyze([fixture])
    assert report.ok, [f.render() for f in report.findings]
    with open(fixture) as f:
        src = f.read()
    stripped = src.replace('reason="decode_no_seq_dim",\n        ', "")
    assert stripped != src
    mod = tmp_path / "runtime_ext.py"
    mod.write_text(stripped)
    bad = analyze([str(mod)])
    assert [f.rule for f in bad.findings] == ["degraded-without-reason"]


# ------------------------------------------------------------- real tree ----

def test_real_tree_is_clean():
    """The acceptance invariant: the shipped tree carries zero findings
    (the same scan scripts/ci.sh gates on)."""
    report = analyze(SCAN_ROOTS,
                     allowlist_path=os.path.join(
                         REPO, "scripts", "commcheck_allowlist.txt"))
    assert report.ok, [f.render() for f in report.findings]
    assert len(report.files) > 50   # the scan actually covered the tree


def test_seeded_violation_fails_the_gate(tmp_path):
    """The end-to-end CI story: drop an aliased p2p import into a
    models/-like user-zone file and the AST rule catches it."""
    mod = tmp_path / "models_ext.py"
    mod.write_text("import repro.core.p2p as _x\n")
    report = analyze([str(mod)])
    assert [f.rule for f in report.findings] == ["boundary-p2p"]


def test_degraded_reason_dynamic_string_trips(tmp_path):
    mod = tmp_path / "runtime_ext.py"
    mod.write_text(textwrap.dedent("""\
        from repro.core.socket import record_implicit_issue
        def log_it(plan, why):
            record_implicit_issue(
                "t", planned=plan.mode("t"), issued=None,
                impl="xla", site="lab.t", reason=why)
    """))
    report = analyze([str(mod)])
    assert [f.rule for f in report.findings] == ["degraded-without-reason"]


def test_degraded_reason_conditional_of_literals_passes(tmp_path):
    """The runtime.train idiom: reason= picks between two literal strings
    — statically readable, so no finding.  A direct IssueRecord with a
    dynamic degraded_reason= in user code still trips."""
    mod = tmp_path / "runtime_ext.py"
    mod.write_text(textwrap.dedent("""\
        from repro.core.socket import IssueRecord, record_implicit_issue
        def log_it(plan, pod, why):
            record_implicit_issue(
                "t", planned=plan.mode("t"), issued=None, impl="xla",
                site="lab.t",
                reason="active" if pod > 1 else "inactive")
            return IssueRecord(site="lab.r", name="r", channel="reduce",
                               planned=None, issued=None, impl="xla",
                               user=0, nbytes=0, degraded_reason=why)
    """))
    report = analyze([str(mod)])
    assert [f.rule for f in report.findings] == ["degraded-without-reason"]
    assert report.findings[0].line == 7


def test_degraded_reason_core_zone_is_exempt():
    """The socket's ladder accumulates its reasons dynamically — the one
    place dynamic strings are the mechanism, not a bypass.  The live core
    tree must stay clean under the rule."""
    report = analyze([os.path.join(REPO, "src", "repro", "core")])
    assert "degraded-without-reason" not in {f.rule for f in report.findings}


def test_zones():
    assert zone_of("src/repro/core/p2p.py") == ZONE_CORE
    assert zone_of("src/repro/kernels/ring_allgather_matmul.py") == ZONE_KERNELS
    assert zone_of("tests/test_socket.py") == ZONE_TESTS
    assert zone_of("src/repro/models/moe.py") == ZONE_USER
    # the calibration subsystem sits outside core/: user zone, so the
    # boundary rules police its imports like any other spine consumer
    assert zone_of("src/repro/calib/fit.py") == ZONE_USER
    # the fixture corpus is deliberately user-zone despite living in tests/
    assert zone_of("tests/fixtures/commcheck/viol_boundary_ring.py") == ZONE_USER


def test_parse_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    report = analyze([str(bad)])
    assert [f.rule for f in report.findings] == ["parse-error"]


# ---------------------------------------------------- suppression/allowlist ----

def test_suppression_comment_above_the_line(tmp_path):
    mod = tmp_path / "m.py"
    mod.write_text(textwrap.dedent("""\
        # commcheck: allow(boundary-p2p)
        import repro.core.p2p as _x
    """))
    report = analyze([str(mod)])
    assert report.ok and [f.rule for f in report.suppressed] == ["boundary-p2p"]


def test_suppression_is_rule_scoped(tmp_path):
    """An allow() for one rule does not silence a different rule on the
    same line."""
    mod = tmp_path / "m.py"
    mod.write_text("import repro.core.p2p as _x  "
                   "# commcheck: allow(boundary-ring)\n")
    report = analyze([str(mod)])
    assert [f.rule for f in report.findings] == ["boundary-p2p"]


def test_suppression_roundtrip_helpers():
    assert parse_suppression_comment(
        format_suppression(["boundary-p2p", "fence-double-write"])) == \
        ["boundary-p2p", "fence-double-write"]
    assert parse_suppression_comment("x = 1  # plain comment") is None


def test_allowlist_covers_and_malformed_raises(tmp_path):
    mod = tmp_path / "legacy.py"
    mod.write_text("import repro.core.p2p as _x\n")
    allow = tmp_path / "allow.txt"
    allow.write_text("# exemption under review\nboundary-p2p legacy.py\n")
    report = analyze([str(mod)], allowlist_path=str(allow))
    assert report.ok
    assert [f.rule for f in report.allowlisted] == ["boundary-p2p"]
    with pytest.raises(ValueError, match="allowlist line"):
        parse_allowlist("boundary-p2p\n")


def test_rule_ids_are_unique():
    check_rule_ids(default_rules())        # must not raise
    dup = default_rules() + [default_rules()[0]]
    with pytest.raises(ValueError, match="duplicate rule id"):
        check_rule_ids(dup)


# ------------------------------------------------------------- coverage ----

def test_artifact_coverage(tmp_path):
    mod = tmp_path / "m.py"
    mod.write_text(textwrap.dedent("""\
        from repro.core.comm import TransferDescriptor
        from repro.core.socket import mem_write
        D = TransferDescriptor("moe_dispatch", site="moe.dispatch")
        def out(x):
            return mem_write(x, "moe_output", ("batch",))
    """))
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"comm_issued": {
        "moe.dispatch": {"tensor": "moe_dispatch"},
        "moe_output": {"tensor": "moe_output"}}}))
    assert analyze([str(mod)], artifact_path=str(good)).ok

    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"comm_issued": {
        "moe.dispatch": {"tensor": "moe_dispatch"},
        "renamed.site": {"tensor": "ghost"}}}))
    report = analyze([str(mod)], artifact_path=str(stale))
    assert [f.rule for f in report.findings] == ["plan-uncovered-site"]
    assert "renamed.site" in report.findings[0].message

    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"comm_issued": None}))
    report = analyze([str(mod)], artifact_path=str(empty))
    assert [f.rule for f in report.findings] == ["plan-uncovered-site"]


def test_real_artifact_coverage_when_present():
    """When a dbrx dryrun artifact exists (ci.sh regenerates it), its
    comm_issued sites must all map into the real tree's site universe."""
    droot = os.path.join(REPO, "experiments", "dryrun")
    cands = sorted(f for f in (os.listdir(droot) if os.path.isdir(droot)
                               else [])
                   if f.startswith("dbrx-132b_train_4k") and
                   f.endswith("autoplan.json"))
    if not cands:
        pytest.skip("no dbrx-132b train_4k autoplan artifact on disk")
    report = analyze(SCAN_ROOTS,
                     artifact_path=os.path.join(droot, cands[-1]),
                     allowlist_path=os.path.join(
                         REPO, "scripts", "commcheck_allowlist.txt"))
    assert report.ok, [f.render() for f in report.findings]


# ------------------------------------------------------------------ CLI ----

def test_cli_exit_protocol(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert commcheck_main([str(clean), "-q"]) == 0
    dirty = tmp_path / "dirty.py"
    dirty.write_text("import repro.core.p2p as _x\n")
    assert commcheck_main([str(dirty), "-q"]) == 1
    out = capsys.readouterr().out
    assert "[boundary-p2p]" in out


def test_cli_list_rules(capsys):
    assert commcheck_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in default_rules():
        assert rule.id in out
    assert "plan-uncovered-site" in out


# ------------------------------------------------------- runtime mirrors ----

def test_socket_rejects_dangling_fused_at_issue_time():
    """The runtime mirror of descriptor-dangling-fused: issuing a
    descriptor whose fused_with was never registered raises the typed
    error instead of silently never fusing."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.comm import (TransferDescriptor,
                                 UnregisteredFusionTargetError)
    from repro.core.socket import socket_for_axis
    sock = socket_for_axis("model")
    bad = TransferDescriptor("weights", site="t.dangling",
                             fused_with="no.such_matmul")
    with pytest.raises(UnregisteredFusionTargetError, match="no.such_matmul"):
        sock.write(jnp.ones((2, 2)), bad)


def test_socket_accepts_registered_and_self_loop_fused():
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.comm import TransferDescriptor, register_fusion_target
    from repro.core.socket import socket_for_axis
    sock = socket_for_axis("model")
    register_fusion_target("t.some_matmul")
    ok = TransferDescriptor("weights", site="t.registered",
                            fused_with="t.some_matmul")
    sock.write(jnp.ones((2, 2)), ok)
    # a descriptor named after its own consumer matmul is its own target
    self_loop = TransferDescriptor("grad_scatter", site="t.self_loop",
                                   fused_with="t.self_loop")
    sock.write(jnp.ones((2, 2)), self_loop)


def test_isa_user_field_range():
    """The runtime half of the 16x16-mesh truncation bug: encode()
    validates user fields and dest LUT indices against the coord-bits
    capacity instead of silently truncating in the header flit."""
    from repro.core.comm import CommMode, CommRequest
    from repro.core.isa import (CH_READ, CH_WRITE, UserFieldRangeError,
                                encode, user_field_capacity)
    assert user_field_capacity(4) == 255
    assert user_field_capacity(3) == 63
    # the capacity boundary encodes; one past it raises
    ok = encode(CommRequest(8, 4, CommMode.P2P, source=255), CH_READ)
    assert ok.user == 255
    with pytest.raises(UserFieldRangeError, match=r"\[0, 255\]"):
        encode(CommRequest(8, 4, CommMode.P2P, source=256), CH_READ)
    with pytest.raises(UserFieldRangeError):
        encode(CommRequest(8, 4, CommMode.MCAST,
                           dests=tuple(range(1, 300))), CH_WRITE)
    with pytest.raises(UserFieldRangeError, match="LUT index"):
        encode(CommRequest(8, 4, CommMode.MCAST, dests=(1, 999)), CH_WRITE)
    # a smaller mesh tightens the range
    with pytest.raises(UserFieldRangeError):
        encode(CommRequest(8, 4, CommMode.P2P, source=64), CH_READ,
               coord_bits=3)


def test_extract_does_not_import_jax():
    """The CLI stays cheap: extracting a module must not pull jax in."""
    import subprocess
    import sys
    code = ("import sys; import repro.analysis; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env)
    assert proc.returncode == 0, "repro.analysis imported jax"


def test_extractor_mem_write_and_implicit_sites(tmp_path):
    mod = tmp_path / "m.py"
    mod.write_text(textwrap.dedent("""\
        from repro.core.socket import mem_write, record_implicit_issue
        def f(x):
            y = mem_write(x, "block_activation", ("batch",))
            record_implicit_issue("weights", site="train.weights_gather")
            return y
    """))
    facts = extract_module(str(mod))
    assert set(facts.implicit_sites) == {"block_activation",
                                         "train.weights_gather"}
