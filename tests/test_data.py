"""Synthetic data pipeline: determinism, shard disjointness, prefetch."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticTokenStream, PrefetchLoader


def test_deterministic_replay():
    s = SyntheticTokenStream(1000, 8, 32, seed=3)
    a = s.batch(5)
    b = s.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    s = SyntheticTokenStream(1000, 2, 16)
    b = s.batch(0)
    # labels[t] == tokens[t+1] by construction of the causal LM stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(deadline=None, max_examples=10)
@given(num_shards=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 50))
def test_shards_partition_global_batch(num_shards, step):
    """Any worker reconstructs exactly its slice — the elastic-restart
    property (no data-state handoff after a re-mesh)."""
    s = SyntheticTokenStream(5000, 8, 16, seed=1)
    full = s.batch(step, 0, 1)
    parts = [s.batch(step, i, num_shards)["tokens"] for i in range(num_shards)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full["tokens"])


def test_vocab_bound():
    s = SyntheticTokenStream(257, 4, 64)
    b = s.batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 257


def test_prefetch_loader_order_and_close():
    s = SyntheticTokenStream(100, 2, 8)
    loader = PrefetchLoader(s, depth=2, start_step=10)
    try:
        step, batch = next(loader)
        assert step == 10
        np.testing.assert_array_equal(batch["tokens"], s.batch(10)["tokens"])
        step, batch = next(loader)
        assert step == 11
    finally:
        loader.close()
