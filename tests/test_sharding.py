"""Logical-axis rule resolution (mesh-free unit tests)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.sharding import (DEFAULT_RULES, logical_to_pspec, use_rules,
                                 current_rules)


class _FakeMesh:
    """Minimal stand-in: logical_to_pspec only touches axis_names/shape."""

    def __init__(self, shape):
        self._shape = dict(shape)

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


MESH1 = _FakeMesh({"data": 16, "model": 16})
MESH2 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_mapping():
    spec = logical_to_pspec(("batch", "seq", "embed"), DEFAULT_RULES, MESH1)
    assert spec == P("data", None, None)


def test_pod_axis_filtered_when_absent():
    spec1 = logical_to_pspec(("batch",), DEFAULT_RULES, MESH1)
    assert spec1 == P("data")
    spec2 = logical_to_pspec(("batch",), DEFAULT_RULES, MESH2)
    assert spec2 == P(("pod", "data"))


def test_axis_used_once():
    # kv_seq and kv_heads both map to model; first dim wins
    spec = logical_to_pspec(("batch", "kv_seq", "kv_heads", "head_dim"),
                            DEFAULT_RULES, MESH1)
    assert spec == P("data", "model", None, None)


def test_divisibility_drop_with_shape():
    # 3 kv heads cannot shard on a 16-way axis for jit ARGUMENTS
    spec = logical_to_pspec(("w_fsdp", "kv_heads", "head_dim"),
                            DEFAULT_RULES, MESH1, shape=(576, 3, 64))
    assert spec == P("data", None, None)
    # but 32 heads can
    spec2 = logical_to_pspec(("w_fsdp", "heads", "head_dim"),
                             DEFAULT_RULES, MESH1, shape=(4096, 32, 128))
    assert spec2 == P("data", "model", None)


def test_unknown_logical_name_is_replicated():
    spec = logical_to_pspec(("nonexistent",), DEFAULT_RULES, MESH1)
    assert spec == P(None)


def test_use_rules_context():
    custom = dict(DEFAULT_RULES)
    custom["seq"] = "model"
    with use_rules(custom):
        assert current_rules()["seq"] == "model"
        spec = logical_to_pspec(("batch", "seq"), None, MESH1)
        assert spec == P("data", "model")
    assert current_rules()["seq"] is None
