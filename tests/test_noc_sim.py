"""Flit-level NoC properties (hypothesis) + Fig. 6 performance-model trends."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.noc.router import dor_route, next_port, LOCAL
from repro.core.noc.simulator import MeshNoC, Message, mesh_coord_bits
from repro.core.noc.reference_sim import ReferenceMeshNoC
from repro.core.noc.perfmodel import SoCPerfModel, SoCParams, PAPER_MILESTONES

coord = st.tuples(st.integers(0, 3), st.integers(0, 2))


# ----------------------------------------------------------- routing ----

@given(a=coord, b=coord)
def test_dor_path_properties(a, b):
    path = dor_route(a, b)
    assert path[0] == a and path[-1] == b
    # manhattan-minimal
    assert len(path) - 1 == abs(a[0] - b[0]) + abs(a[1] - b[1])
    # X first, then Y (dimension order => deadlock freedom)
    turned = False
    for p, q in zip(path, path[1:]):
        if p[0] != q[0]:
            assert not turned, "route moved in X after turning to Y"
        else:
            turned = True


@given(a=coord, b=coord)
def test_next_port_follows_dor(a, b):
    if a == b:
        assert next_port(a, b) == LOCAL
        return
    path = dor_route(a, b)
    assert path[1] != a


# ------------------------------------------------------ flit delivery ----

@settings(deadline=None, max_examples=30)
@given(src=coord,
       dests=st.lists(coord, min_size=1, max_size=5, unique=True),
       n_flits=st.integers(1, 6))
def test_multicast_delivers_exactly_to_dest_set(src, dests, n_flits):
    noc = MeshNoC(4, 3, bitwidth=256)
    mid = noc.inject(Message(src, tuple(dests), n_flits))
    noc.drain()
    for d in dests:
        got = noc.received(d, mid)
        # header + payload flits, in order, exactly once
        assert len(got) == n_flits + 1
        assert [f.seq for f in got] == list(range(n_flits + 1))
    for other in noc.delivered:
        if other not in dests:
            assert noc.received(other, mid) == []


@settings(deadline=None, max_examples=15)
@given(msgs=st.lists(
    st.tuples(coord, coord, st.integers(1, 4)), min_size=1, max_size=6))
def test_concurrent_traffic_drains(msgs):
    """Consumption assumption: finite traffic always drains under DOR."""
    noc = MeshNoC(4, 3)
    ids = []
    for src, dst, n in msgs:
        ids.append((noc.inject(Message(src, (dst,), n)), dst, n))
    noc.drain()
    for mid, dst, n in ids:
        assert len(noc.received(dst, mid)) == n + 1


def test_unicast_hop_count():
    noc = MeshNoC(4, 3)
    mid = noc.inject(Message((0, 0), ((3, 2),), 1))
    noc.drain()
    assert len(noc.received((3, 2), mid)) == 2
    # 2 flits x 5 hops each
    assert noc.total_hops == 2 * 5


# -------------------------------- vectorized vs object-based reference ----

def _mesh_traffic(w, h, raw):
    """Map raw integer draws onto in-range (src, dests, n_flits) traffic."""
    nodes = [(x, y) for x in range(w) for y in range(h)]
    msgs = []
    for (a, b, c, d, n) in raw:
        dests = {nodes[b % len(nodes)], nodes[c % len(nodes)],
                 nodes[d % len(nodes)]}
        msgs.append((nodes[a % len(nodes)], tuple(dests), n))
    return msgs


@settings(deadline=None, max_examples=15)
@given(raw=st.lists(st.tuples(st.integers(0, 255), st.integers(0, 255),
                              st.integers(0, 255), st.integers(0, 255),
                              st.integers(1, 6)),
                    min_size=1, max_size=10),
       mesh=st.sampled_from([(4, 3), (5, 5), (8, 8)]))
def test_vectorized_matches_reference(raw, mesh):
    """The SoA stepper and the object-based reference deliver identical
    (dest, msg_id, flit-order) sequences — and identical cycle and hop
    counts — on randomized multicast traffic."""
    w, h = mesh
    vec, ref = MeshNoC(w, h), ReferenceMeshNoC(w, h)
    for src, dests, n in _mesh_traffic(w, h, raw):
        assert vec.inject(Message(src, dests, n)) == \
            ref.inject(Message(src, dests, n))
    assert vec.drain() == ref.drain()
    assert vec.total_hops == ref.total_hops
    for c in vec.delivered:
        assert [(f.msg_id, f.seq) for f in vec.delivered[c]] == \
            [(f.msg_id, f.seq) for f in ref.delivered[c]], c


@settings(deadline=None, max_examples=15)
@given(raw=st.lists(st.tuples(st.integers(0, 255), st.integers(0, 255),
                              st.integers(0, 255), st.integers(1, 5),
                              st.sampled_from((0, 0, 1, 3, 17, 80, 400))),
                    min_size=1, max_size=8),
       mesh=st.sampled_from([(4, 3), (5, 5)]))
def test_fast_forward_matches_reference_on_staggered_traffic(raw, mesh):
    """Timed injections: messages scheduled in the future sit pending,
    and when nothing is in flight the vectorized stepper jumps straight
    to the next injection cycle.  The reference steps every quiescent
    cycle one by one — flit-for-flit, cycle-for-cycle identity proves the
    fast-forward honest (round-robin pointer continuity included)."""
    w, h = mesh
    nodes = [(x, y) for x in range(w) for y in range(h)]
    vec, ref = MeshNoC(w, h), ReferenceMeshNoC(w, h)
    for (a, b, c, n, at) in raw:
        src = nodes[a % len(nodes)]
        dests = tuple({nodes[b % len(nodes)], nodes[c % len(nodes)]})
        assert vec.inject(Message(src, dests, n, inject_cycle=at)) == \
            ref.inject(Message(src, dests, n, inject_cycle=at))
    assert vec.drain() == ref.drain()
    assert vec.total_hops == ref.total_hops
    for coord in vec.delivered:
        assert [(f.msg_id, f.seq) for f in vec.delivered[coord]] == \
            [(f.msg_id, f.seq) for f in ref.delivered[coord]], coord


def test_fast_forward_skips_quiescent_gap():
    """A lone message scheduled far in the future is reached in O(1)
    steps: the quiescent gap is jumped, not stepped, and the delivery
    cycle matches the reference exactly."""
    w, h = 4, 3
    vec, ref = MeshNoC(w, h), ReferenceMeshNoC(w, h)
    for noc in (vec, ref):
        noc.inject(Message((0, 0), ((3, 2),), 1))
        noc.inject(Message((0, 0), ((3, 2),), 1, inject_cycle=5000))
    steps = 0
    while vec.step():
        steps += 1
        assert steps < 200, "fast-forward did not skip the quiescent gap"
    assert vec.cycles == ref.drain()
    assert vec.ffwd_cycles > 4000
    assert ref._pending == [] and vec._pending == []
    for coord in vec.delivered:
        assert [(f.msg_id, f.seq) for f in vec.delivered[coord]] == \
            [(f.msg_id, f.seq) for f in ref.delivered[coord]], coord


def test_vectorized_matches_reference_across_drains():
    """Reused instances stay equivalent: the round-robin pointer advances
    on idle steps too (drain's terminal failed step included), so a second
    injection round must still track the reference cycle for cycle."""
    import random
    rng = random.Random(11)
    w, h = 4, 3
    nodes = [(x, y) for x in range(w) for y in range(h)]
    vec, ref = MeshNoC(w, h), ReferenceMeshNoC(w, h)
    for phase in range(3):
        for _ in range(4):
            src = rng.choice(nodes)
            dests = tuple(set(rng.sample(nodes, rng.randint(1, 4))))
            n = rng.randint(1, 5)
            vec.inject(Message(src, dests, n))
            ref.inject(Message(src, dests, n))
        assert vec.drain() == ref.drain(), phase
        assert vec.total_hops == ref.total_hops, phase
    for c in vec.delivered:
        assert [(f.msg_id, f.seq) for f in vec.delivered[c]] == \
            [(f.msg_id, f.seq) for f in ref.delivered[c]], c


def test_mesh_scale_16x16_delivery():
    """Pod-scale envelope: a 16x16 mesh with hundreds of in-flight
    multicast messages drains with exact per-destination delivery."""
    import random
    rng = random.Random(7)
    w, h = 16, 16
    assert mesh_coord_bits(w, h) == 4
    nodes = [(x, y) for x in range(w) for y in range(h)]
    msgs = []
    noc = MeshNoC(w, h)
    for _ in range(120):
        src = rng.choice(nodes)
        dests = tuple(set(rng.sample(nodes, rng.randint(1, 8))))
        n = rng.randint(1, 6)
        msgs.append((noc.inject(Message(src, dests, n)), dests, n))
    noc.drain()
    for mid, dests, n in msgs:
        for d in dests:
            got = noc.received(d, mid)
            assert [f.seq for f in got] == list(range(n + 1)), (mid, d)
    delivered = sum(len(v) for v in noc.delivered.values())
    assert delivered == sum((n + 1) * len(dests) for _, dests, n in msgs)


# --------------------------------------------------- Fig. 6 perf model ----

@pytest.fixture(scope="module")
def model():
    return SoCPerfModel()


def test_speedup_monotone_in_consumers(model):
    for size in (4096, 1048576):
        sp = [model.speedup(n, size) for n in (1, 2, 4, 8, 16)]
        assert all(a < b for a, b in zip(sp, sp[1:])), sp


def test_speedup_monotone_in_size(model):
    for n in (1, 4, 16):
        sp = [model.speedup(n, s)
              for s in (4096, 65536, 262144, 1048576)]
        assert all(a < b for a, b in zip(sp, sp[1:])), sp


def test_speedup_plateaus_at_1mb(model):
    # "This phenomenon plateaus at 1MB"
    s1 = model.speedup(16, 1048576)
    s4 = model.speedup(16, 4194304)
    assert abs(s4 - s1) / s1 < 0.05


def test_paper_milestones_within_10pct(model):
    for (n, size), target in PAPER_MILESTONES.items():
        got = model.speedup(n, size)
        assert abs(got - target) / target < 0.10, ((n, size), got, target)


def test_multicast_capacity_enforced(model):
    with pytest.raises(ValueError):
        model.multicast_cycles(17, 4096)


def test_all_speedups_above_one(model):
    sw = model.sweep()
    assert min(sw.values()) > 1.0
