"""Cost-model-driven communication-mode planner (paper C4, automated).

Unit tests pin the planner to the paper's Fig. 6 preferences and the
header-flit capacity constraint; the subprocess test proves the plan flows
end-to-end through sharding/runtime/dryrun and actually switches the
collective that XLA emits.
"""

import numpy as np
import pytest

from repro.core.comm import (CommMode, mode_from_read_field,
                             mode_from_write_field)
from repro.core.noc.header import ESP_MAX_DESTS, max_multicast_dests
from repro.core.noc.perfmodel import PAPER_MILESTONES, SoCPerfModel
from repro.core.planner import CommPlanner, TransferSpec, step_transfer_specs


# ------------------------------------------------------- mode selection ----

def test_milestones_select_mcast_within_10pct():
    """Acceptance: at the three paper milestones the planner picks MCAST and
    its predicted speedup over always-MEM is within +-10% of the quoted
    1.72x / 2.20x / 3.03x."""
    planner = CommPlanner()
    specs = [TransferSpec(f"m{n}_{s}", nbytes=s, fan_out=n)
             for (n, s) in PAPER_MILESTONES]
    plan, decisions = planner.plan_with_decisions(specs)
    for d, ((n, s), target) in zip(decisions, PAPER_MILESTONES.items()):
        assert d.mode is CommMode.MCAST, (n, s, d.reason)
        assert plan.mode(d.spec.name) is CommMode.MCAST
        assert d.speedup_vs_mem == pytest.approx(target, rel=0.10), (n, s)


def test_fanout_crossover_mcast_to_mem():
    """Mode selection flips exactly at the multicast capacity: every
    feasible fan-out takes the direct path (the model predicts it faster at
    every Fig. 6 point), one past capacity degrades to MEM."""
    planner = CommPlanner()
    cap = planner.capacity
    assert cap == min(max_multicast_dests(SoCPerfModel().p.bitwidth),
                      ESP_MAX_DESTS)
    specs = [TransferSpec(f"f{n}", nbytes=65536, fan_out=n)
             for n in range(1, cap + 3)]
    decisions = planner.price(specs)
    for d in decisions:
        if d.spec.fan_out <= cap:
            assert d.mode is CommMode.MCAST, d
        else:
            assert d.mode is CommMode.MEM, d
            assert "capacity" in d.reason


def test_speedup_grows_with_size_at_max_fanout():
    """The Fig. 6 trend the milestones quote: at 16 consumers the multicast
    advantage grows with transfer size (1.72x @ 4KB ... 3.03x @ 1MB)."""
    planner = CommPlanner()
    sizes = (4096, 65536, 1048576)
    decisions = planner.price(
        [TransferSpec(f"s{s}", nbytes=s, fan_out=16) for s in sizes])
    speedups = [d.speedup_vs_mem for d in decisions]
    assert speedups == sorted(speedups)
    assert all(d.mode is CommMode.MCAST for d in decisions)


def test_narrower_noc_lowers_capacity():
    """A 64-bit NoC's header flit only fits 5 destinations (paper Fig. 4
    anchor): fan-out 6 must fall back to MEM there."""
    planner = CommPlanner(max_dests=max_multicast_dests(64))
    assert planner.capacity == 5
    d5, d6 = planner.price([TransferSpec("a", nbytes=65536, fan_out=5),
                            TransferSpec("b", nbytes=65536, fan_out=6)])
    assert d5.mode is CommMode.MCAST
    assert d6.mode is CommMode.MEM


def test_pull_unicast_is_p2p_push_is_mcast():
    planner = CommPlanner()
    pull, push = planner.price([
        TransferSpec("stage_activation", nbytes=65536, fan_out=1, pull=True),
        TransferSpec("trafficgen", nbytes=65536, fan_out=1)])
    assert pull.mode is CommMode.P2P       # read channel: consumer pulls
    assert push.mode is CommMode.MCAST     # write channel: 1-dest multicast
    # both ride the same direct path in the model
    assert pull.cycles["mcast"] == push.cycles["mcast"]


def test_zero_fanout_is_mem():
    (d,) = CommPlanner().price([TransferSpec("store", nbytes=4096, fan_out=0)])
    assert d.mode is CommMode.MEM


# ------------------------------------------------- user-field round-trip ----

def test_requests_user_field_roundtrip():
    """Planner-emitted CommRequests encode the paper's user fields, and the
    fields decode back to the planned mode."""
    planner = CommPlanner()
    specs = [
        TransferSpec("mcast4", nbytes=4096, fan_out=4),
        TransferSpec("pull1", nbytes=4096, fan_out=1, pull=True, source=3),
        TransferSpec("overflow", nbytes=4096, fan_out=100),
    ]
    reqs = planner.requests(specs)

    mc, p2p, mem = reqs
    assert mc.mode is CommMode.MCAST and mc.dests == (1, 2, 3, 4)
    assert mc.user_field_write() == 4
    assert mode_from_write_field(mc.user_field_write()) is CommMode.MCAST

    assert p2p.mode is CommMode.P2P and p2p.source == 3
    assert p2p.user_field_read() == 3
    assert mode_from_read_field(p2p.user_field_read()) is CommMode.P2P
    # write channel: a single destination encodes user=1 — the paper's
    # unicast degeneracy (1-dest multicast == P2P write)
    assert p2p.user_field_write() == 1
    assert mode_from_write_field(p2p.user_field_write()) is CommMode.P2P

    assert mem.mode is CommMode.MEM and mem.dests == ()
    assert mem.user_field_read() == 0 and mem.user_field_write() == 0
    assert mode_from_read_field(0) is CommMode.MEM
    assert mode_from_write_field(0) is CommMode.MEM

    # request length mirrors the control-channel beat: words * word size
    assert mc.nbytes == 4096


def test_write_field_degeneracy_documented():
    """MCAST with one destination and unicast P2P are the same wire
    transaction: both encode write user field 1."""
    planner = CommPlanner()
    (req,) = planner.requests([TransferSpec("uni", nbytes=4096, fan_out=1)])
    assert req.mode is CommMode.MCAST and len(req.dests) == 1
    assert req.user_field_write() == 1
    assert mode_from_write_field(req.user_field_write()) is CommMode.P2P


# ------------------------------------------------------ batched model API ----

def test_batch_cycles_matches_scalar_des():
    """The vectorized sweep is exact against the scalar discrete-event model
    (it exists to make planning cheap, not approximate)."""
    model = SoCPerfModel()
    pts = [(n, s) for n in (1, 2, 5, 16) for s in (4096, 65536, 1048576)]
    ns = np.array([p[0] for p in pts])
    ds = np.array([p[1] for p in pts])
    batch = model.batch_cycles(ns, ds)
    for i, (n, s) in enumerate(pts):
        assert batch["mem"][i] == pytest.approx(
            model.shared_memory_cycles(n, s), abs=1e-6), (n, s)
        assert batch["mcast"][i] == pytest.approx(
            model.multicast_cycles(n, s), abs=1e-6), (n, s)
    # p2p column is the unicast path wherever fan-out is 1
    one = ns == 1
    assert np.allclose(batch["p2p"][one], batch["mcast"][one])
    assert np.all(np.isnan(batch["p2p"][~one]))


def test_batch_cycles_capacity_nan_and_extrapolation():
    model = SoCPerfModel()
    over = model.batch_cycles(np.array([model.max_dests + 1]),
                              np.array([4096]))
    assert np.isnan(over["mcast"][0]) and np.isfinite(over["mem"][0])
    # beyond the burst cap: finite, monotone in size
    big = model.batch_cycles(np.array([4, 4]),
                             np.array([32 << 20, 64 << 20]))
    assert np.all(np.isfinite(big["mcast"]))
    assert big["mcast"][1] > big["mcast"][0]
    assert big["mem"][1] > big["mem"][0]


# ---------------------------------------------------------- step planning ----

def test_step_specs_weight_broadcast_degrades_multi_pod():
    """The paper's constraint at system scale: 16 data-parallel replicas fit
    the destination-set limit (MCAST weight broadcast); the 32-replica
    multi-pod mesh exceeds it and the planner degrades weights to MEM."""
    from repro.configs import get_config, SHAPES
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    planner = CommPlanner()

    single = planner.plan(step_transfer_specs(cfg, shape,
                                              {"data": 16, "model": 16}))
    multi = planner.plan(step_transfer_specs(
        cfg, shape, {"pod": 2, "data": 16, "model": 16}))
    assert single.mode("weights") is CommMode.MCAST
    assert multi.mode("weights") is CommMode.MEM
    # MoE dispatch (top-4) and the stage hand-off stay on the direct paths
    for plan in (single, multi):
        assert plan.mode("moe_dispatch") is CommMode.MCAST
        assert plan.mode("stage_activation") is CommMode.P2P


def test_step_specs_price_compressed_pod_gradients():
    """The pod-axis int8 gradient all-reduce is a real priced spec: one
    byte per element (4x fewer than f32), reduce-pinned, emitted only when
    the mesh has a pod axis."""
    from repro.configs import get_config, SHAPES
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]

    flat = {s.name: s for s in
            step_transfer_specs(cfg, shape, {"data": 16, "model": 16})}
    assert "grad_reduce_compressed" not in flat   # no pod axis: inactive

    pod = {s.name: s for s in step_transfer_specs(
        cfg, shape, {"pod": 4, "data": 16, "model": 16})}
    spec = pod["grad_reduce_compressed"]
    assert spec.reduce and spec.word_bytes == 1 and spec.fan_out == 4
    assert spec.nbytes == cfg.param_count() // 16   # int8: 1 B / element
    plan, dec = CommPlanner().plan_with_decisions(list(pod.values()))
    assert plan.mode("grad_reduce_compressed") is CommMode.MEM  # pinned
    d = {x.spec.name: x for x in dec}["grad_reduce_compressed"]
    assert "reduction" in d.reason or "combine" in d.reason


# ----------------------------------------------- HLO-derived transfers ----

_FAKE_HLO = """
ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[256,64]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = f32[64,64]{1,0} all-to-all(%ar), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  ROOT %cp = f32[16,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""


def test_transfer_specs_from_hlo_archetypes():
    """Fan-out and bytes come from the lowered collective ops themselves:
    all-to-all -> per-peer unicast chunks, all-gather -> shard broadcast,
    all-reduce -> MEM-pinned reduction, collective-permute -> pull P2P."""
    from repro.launch.hlo_analysis import transfer_specs_from_hlo
    by_name = {s.name: s for s in transfer_specs_from_hlo(_FAKE_HLO)}

    a2a = by_name["moe_dispatch"]
    assert a2a.fan_out == 1 and not a2a.reduce
    assert a2a.nbytes == 64 * 64 * 4 // 8          # result bytes / group

    ag = by_name["weights"]
    assert ag.fan_out == 3                          # group 4 -> 3 peers
    assert ag.nbytes == 256 * 64 * 2 // 4           # per-shard bytes

    ar = by_name["grad_reduce"]
    assert ar.reduce and ar.fan_out == 15           # group 16
    assert ar.nbytes == 16 * 64 * 4

    cp = by_name["stage_activation"]
    assert cp.pull and cp.fan_out == 1
    assert cp.nbytes == 16 * 64 * 4


def test_transfer_specs_async_start_result_bytes():
    """Async -start collectives are tuple-typed (operand, result): pricing
    must use the result buffer, not the tuple sum ((g+1)/g over-count)."""
    from repro.launch.hlo_analysis import transfer_specs_from_hlo
    hlo = """
ENTRY %main (p: f32[16,64]) -> f32[64,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %ags = (f32[16,64]{1,0}, f32[64,64]{1,0}) all-gather-start(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %agd = f32[64,64]{1,0} all-gather-done(%ags)
}
"""
    (ag,) = [s for s in transfer_specs_from_hlo(hlo) if s.name == "weights"]
    assert ag.nbytes == 64 * 64 * 4 // 4        # result bytes / group
    assert ag.fan_out == 3


def test_transfer_specs_fallback_merge():
    """Config estimates survive only for transfers the HLO does not
    exhibit; HLO-derived specs win on collisions and keep fallback order."""
    from repro.launch.hlo_analysis import transfer_specs_from_hlo
    fallback = [TransferSpec("weights", nbytes=999, fan_out=9),
                TransferSpec("custom_stream", nbytes=123, fan_out=2)]
    specs = transfer_specs_from_hlo(_FAKE_HLO, fallback=fallback)
    names = [s.name for s in specs]
    assert names[:2] == ["weights", "custom_stream"]
    by_name = {s.name: s for s in specs}
    assert by_name["weights"].nbytes != 999         # HLO-derived won
    assert by_name["custom_stream"].nbytes == 123   # config-only survives


def test_reduction_specs_pinned_to_mem():
    """The NoC forks multicast flits but cannot combine in flight: reduce
    transfers never take the direct path, whatever the model predicts."""
    (d,) = CommPlanner().price(
        [TransferSpec("grad_reduce", nbytes=65536, fan_out=4, reduce=True)])
    assert d.mode is CommMode.MEM
    assert "reduction" in d.reason


def test_resolve_policy_plan_cache():
    """--comm-plan=auto prices once per launch: identical (cfg, shape,
    mesh, policy) resolutions hit the cache, HLO-keyed ones included.
    Starts from a clean cache without clearing it itself — the autouse
    ``_reset_planner_state`` fixture guarantees no leakage across tests."""
    from repro.configs import get_config, SHAPES
    from repro.core.planner import plan_cache_stats, resolve_policy
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    axes = {"data": 16, "model": 16}
    assert plan_cache_stats() == {"hits": 0, "misses": 0, "size": 0}
    p1, d1 = resolve_policy("auto", cfg, shape, axes)
    p2, d2 = resolve_policy("auto", cfg, shape, axes)
    assert plan_cache_stats() == {"hits": 1, "misses": 1, "size": 1}
    assert dict(p1.modes) == dict(p2.modes) and d1 is d2
    h1, _ = resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO)
    h2, _ = resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO)
    stats = plan_cache_stats()
    assert stats["hits"] == 2 and stats["misses"] == 2
    assert h1.mode("grad_reduce") is CommMode.MEM


# a second module with DIFFERENT collectives (extra group member changes the
# all-gather bytes/fan-out): same policy + overlay must still miss the cache
_FAKE_HLO2 = _FAKE_HLO.replace("{{0,1,2,3}}", "{{0,1,2,3,4}}")


def test_plan_cache_overlay_and_collective_keying():
    """The cache key is (policy, profile, rule overlay, specs): same HLO +
    same overlay hits; a changed overlay or changed collectives misses."""
    from repro.configs import get_config, SHAPES
    from repro.core.planner import plan_cache_stats, resolve_policy
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    axes = {"data": 16, "model": 16}
    assert plan_cache_stats()["size"] == 0   # autouse fixture reset held

    resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO)
    resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO)
    assert plan_cache_stats() == {"hits": 1, "misses": 1, "size": 1}

    # same HLO, rule overlay applied -> distinct entry; repeat -> hit
    ov = {"w_fsdp": None}
    resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO,
                   rules_overlay=ov)
    resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO,
                   rules_overlay=dict(ov))
    assert plan_cache_stats() == {"hits": 2, "misses": 2, "size": 2}

    # changed overlay -> miss
    resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO,
                   rules_overlay={"w_fsdp": "data"})
    assert plan_cache_stats()["misses"] == 3

    # changed collectives (different module) -> miss, same overlay or not
    resolve_policy("auto", cfg, shape, axes, hlo_text=_FAKE_HLO2,
                   rules_overlay=ov)
    stats = plan_cache_stats()
    assert stats["misses"] == 4 and stats["hits"] == 2 and stats["size"] == 4


def test_pod_profile_planner():
    """A pod-scale model prices through the same planner: the ESP cap still
    binds capacity and direct paths still win at feasible fan-outs."""
    from repro.core.noc.perfmodel import SoCParams
    planner = CommPlanner(SoCPerfModel(SoCParams.pod(16, 16)))
    assert planner.capacity == 16
    d8, d17 = planner.price([TransferSpec("a", nbytes=262144, fan_out=8),
                             TransferSpec("b", nbytes=262144, fan_out=17)])
    assert d8.mode is CommMode.MCAST and d8.speedup_vs_mem > 1.0
    assert d17.mode is CommMode.MEM and "capacity" in d17.reason


# ---------------------------------------------- per-layer + rule feedback ----

# a 4-iteration scan-over-layers body with an all-gather (weights) and an
# all-to-all (moe dispatch); one unscanned collective-permute in the entry
_FAKE_SCANNED_HLO = """
%cond.1 (c: (s32[], f32[16,64])) -> pred[] {
  %c = (s32[], f32[16,64]) parameter(0)
  %i = s32[] get-tuple-element(%c), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body.1 (b: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %b = (s32[], f32[16,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%b), index=0
  %x = f32[16,64]{1,0} get-tuple-element(%b), index=1
  %ag = f32[64,64]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = f32[16,64]{1,0} all-to-all(%x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %one = s32[] constant(1)
  %i3 = s32[] add(%i2, %one)
  ROOT %t = (s32[], f32[16,64]) tuple(%i3, %x)
}

ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,64]) tuple(%zero, %p)
  %w = (s32[], f32[16,64]) while(%init), condition=%cond.1, body=%body.1
  %cp = f32[16,64]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
  ROOT %out = f32[16,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_per_layer_specs_from_scanned_hlo():
    """A collective inside the scan-over-layers while body (trip count 4)
    becomes four per-layer specs with stable ``.L<i>`` names; the unscanned
    entry-computation op keeps its bare archetype name."""
    from repro.launch.hlo_analysis import transfer_specs_from_hlo
    specs = transfer_specs_from_hlo(_FAKE_SCANNED_HLO)
    names = [s.name for s in specs]
    assert names == (["moe_dispatch.L%d" % i for i in range(4)] +
                     ["stage_activation"] +
                     ["weights.L%d" % i for i in range(4)])
    for s in specs:
        if s.name.startswith("weights"):
            assert s.fan_out == 3 and s.nbytes == 64 * 64 * 4 // 4
            assert s.layer == int(s.name.rsplit(".L", 1)[1])
    assert {s.layer for s in specs if s.name == "stage_activation"} == {None}


def test_per_layer_plan_publishes_base_aggregate():
    """Runtime collective sites query the logical archetype name; a layered
    plan publishes the dominant layer's mode under the base name."""
    from repro.launch.hlo_analysis import transfer_specs_from_hlo
    plan, decisions = CommPlanner().plan_with_decisions(
        transfer_specs_from_hlo(_FAKE_SCANNED_HLO))
    assert plan.mode("weights.L2") is CommMode.MCAST
    assert plan.mode("weights") is CommMode.MCAST
    assert plan.mode("moe_dispatch") is CommMode.MCAST
    assert plan.mode("stage_activation") is CommMode.P2P
    from repro.core.planner import mode_mix
    mix = mode_mix(decisions)
    assert mix["MCAST"] == 8 and mix["P2P"] == 1 and mix["MEM"] == 0


def test_resolve_rules_w_fsdp_overlay():
    """The feedback pass: weights planning to MCAST turns w_fsdp off
    (weights replicated + broadcast on the direct path); a MEM verdict —
    e.g. the 32-replica multi-pod broadcast past the destination cap —
    keeps FSDP.  The modeled step cost never gets worse and strictly
    improves when the overlay applies."""
    from repro.configs import get_config, SHAPES
    from repro.core.planner import modeled_step_cycles
    from repro.core.sharding import resolve_rules
    from repro.runtime.train import TRAIN_RULES
    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    planner = CommPlanner()

    plan_s, dec_s = planner.plan_with_decisions(
        step_transfer_specs(cfg, shape, {"data": 16, "model": 16}))
    rules_s, overlay_s = resolve_rules(plan_s, TRAIN_RULES)
    assert overlay_s == {"w_fsdp": None}
    assert rules_s["w_fsdp"] is None
    assert modeled_step_cycles(dec_s, rules_s) < \
        modeled_step_cycles(dec_s, TRAIN_RULES)

    plan_m, dec_m = planner.plan_with_decisions(step_transfer_specs(
        cfg, shape, {"pod": 2, "data": 16, "model": 16}))
    rules_m, overlay_m = resolve_rules(plan_m, TRAIN_RULES)
    assert overlay_m == {}
    assert rules_m["w_fsdp"] == TRAIN_RULES["w_fsdp"]
    assert modeled_step_cycles(dec_m, rules_m) == \
        modeled_step_cycles(dec_m, TRAIN_RULES)


# ------------------------------------------------------------ end-to-end ----

_E2E_CODE = r"""
import jax
from repro import compat
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.core.comm import CommMode
from repro.launch.dryrun import build_comm_plan, lower_cell, make_flags

mesh = compat.make_mesh((4, 4), ("data", "model"),
                        axis_types=(compat.AxisType.Auto,) * 2)
cfg = get_reduced("dbrx-132b")
shape = ShapeConfig("t", 128, 16, "train")
flags = make_flags(cfg, shape)

plan, decisions = build_comm_plan("auto", cfg, shape, mesh)
assert plan.mode("moe_dispatch") is CommMode.MCAST, plan.modes
assert plan.mode("stage_activation") is CommMode.P2P, plan.modes
assert decisions and all(d.speedup_vs_mem >= 1.0 for d in decisions)
lowered, _ = lower_cell(cfg, shape, mesh, flags, comm_plan=plan)
hlo_auto = lowered.compile().as_text()

mem_plan, _ = build_comm_plan("mem", cfg, shape, mesh)
lowered_mem, _ = lower_cell(cfg, shape, mesh, flags, comm_plan=mem_plan)
hlo_mem = lowered_mem.compile().as_text()

# the plan switched the collective XLA emits for MoE dispatch: the mcast
# path is all_to_all-based, the mem baseline is a psum combine
assert "all-to-all" in hlo_auto, "auto plan should lower to all-to-all dispatch"
assert "all-to-all" not in hlo_mem, "mem plan must not use all-to-all"
print("PLANNER_E2E_OK", flush=True)
"""


def test_dryrun_auto_plan_switches_collectives(subproc):
    """--comm-plan=auto reaches the lowered HLO: the planner's MCAST choice
    turns the MoE dispatch into the all_to_all path, the forced-MEM plan
    keeps the shared-memory combine."""
    out = subproc(_E2E_CODE, n_devices=16)
    assert "PLANNER_E2E_OK" in out
