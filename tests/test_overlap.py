"""Overlap-aware planning (the tentpole of the Fig. 6 reproduction at
framework level): a transfer that declares the FLOPs of the consumer
matmul it feeds is priced with overlap credit — ``max(comm, compute) +
ramp`` for fusible modes vs the serial ``comm + compute`` — and the
fused ring chain prices past the multicast header capacity."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.comm import CommMode
from repro.core.noc.perfmodel import SoCPerfModel, overlapped_cycles
from repro.core.planner import (FUSIBLE_MODES, CommPlanner, TransferSpec,
                                chosen_cycles, comm_overlap_fraction,
                                modeled_step_cycles)


# ------------------------------------------------------ overlapped_cycles ----

def test_overlapped_cycles_ramp_clamp():
    """The ramp is clamped by both terms, so overlap <= serial always and
    a transfer with nothing to hide behind costs exactly its comm."""
    assert overlapped_cycles(100.0, 0.0, 163.0) == 100.0
    assert overlapped_cycles(100.0, 40.0, 163.0) == 140.0     # ramp -> 40
    assert overlapped_cycles(100.0, 400.0, 30.0) == 430.0
    for comm, compute, ramp in ((1, 1, 1000), (5000, 3, 163), (7, 7, 7)):
        assert overlapped_cycles(comm, compute, ramp) <= comm + compute


def test_model_compute_cycles():
    m = SoCPerfModel()
    assert m.compute_cycles(0.0) == 0.0
    assert m.compute_cycles(m.p.flops_per_cycle * 10) == 10.0
    assert m.overlap_ramp_cycles == m.p.flits_per_burst + m.p.request_latency


def test_fusible_modes_table():
    """MEM round-trips hide nothing; both direct modes overlap (P2P ring,
    MCAST double-buffered stream)."""
    assert not FUSIBLE_MODES[CommMode.MEM]
    assert FUSIBLE_MODES[CommMode.P2P] and FUSIBLE_MODES[CommMode.MCAST]
    assert set(FUSIBLE_MODES) == set(CommMode)


# ------------------------------------------------------- pricing behaviour ----

def test_zero_compute_prices_exactly_as_before():
    """compute_flops = 0 is the historical serial pricing, decision for
    decision: same mode, same speedup, no fused flag."""
    planner = CommPlanner()
    specs = [TransferSpec("weights", nbytes=65536, fan_out=4),
             TransferSpec("stage_activation", nbytes=65536, fan_out=1,
                          pull=True),
             TransferSpec("grad_reduce", nbytes=65536, fan_out=4,
                          reduce=True),
             TransferSpec("overflow", nbytes=65536, fan_out=100)]
    decisions = planner.price(specs)
    assert [d.mode for d in decisions] == [CommMode.MCAST, CommMode.P2P,
                                           CommMode.MEM, CommMode.MEM]
    assert all(not d.fused and d.compute_cycles == 0.0 for d in decisions)
    mem, direct = decisions[0].cycles["mem"], decisions[0].cycles["mcast"]
    assert decisions[0].speedup_vs_mem == pytest.approx(mem / direct)


def test_fused_decision_carries_overlap_terms():
    planner = CommPlanner()
    (d,) = planner.price([TransferSpec("weights", nbytes=65536, fan_out=4,
                                       compute_flops=1e9)])
    assert d.fused and d.mode is CommMode.MCAST
    assert d.compute_cycles == planner.model.compute_cycles(1e9)
    assert d.ramp_cycles == planner.model.overlap_ramp_cycles
    assert "ring" in d.cycles      # the ring candidate was priced too
    # the overlap credit can only help: speedup against the serial memory
    # baseline is at least 1
    assert d.speedup_vs_mem >= 1.0


def test_fused_ring_is_capacity_exempt():
    """A matmul-adjacent broadcast past the multicast header capacity goes
    direct as a P2P ring chain (hop-by-hop user=1 unicasts) where the
    serial planner had to degrade to MEM."""
    planner = CommPlanner()
    serial, fused = planner.price([
        TransferSpec("weights", nbytes=1 << 20, fan_out=40),
        TransferSpec("weights", nbytes=1 << 20, fan_out=40,
                     compute_flops=1e10)])
    assert serial.mode is CommMode.MEM and "capacity" in serial.reason
    assert fused.mode is CommMode.P2P and fused.fused
    assert "capacity-exempt" in fused.reason
    # the P2P column now carries the ring chain's cost
    assert fused.cycles["p2p"] == fused.cycles["ring"]
    assert np.isfinite(fused.cycles["ring"])


def test_fused_reduce_scatter_lifts_mem_pin():
    """A plain reduction stays pinned to MEM (the NoC cannot combine in
    flight); a matmul-adjacent reduce-scatter rides the fused ring — the
    combine happens in the accelerator at every hop."""
    planner = CommPlanner()
    plain, fused = planner.price([
        TransferSpec("grad_scatter", nbytes=1 << 20, fan_out=8, reduce=True),
        TransferSpec("grad_scatter", nbytes=1 << 20, fan_out=8, reduce=True,
                     compute_flops=1e10)])
    assert plain.mode is CommMode.MEM and "reduction" in plain.reason
    assert fused.mode is CommMode.P2P and fused.fused
    assert "fused ring reduce-scatter" in fused.reason


def test_tiny_compute_does_not_flip_the_mem_verdict():
    """When even the overlapped direct path beats nothing, MEM wins: a
    negligible compute credit must not make a slower direct path look
    attractive."""
    planner = CommPlanner(max_dests=1)
    # fan-out 2 exceeds this narrow capacity and the ring is priced at
    # 2x bytes; with epsilon compute, overlap credit ~ 0
    (d,) = planner.price([TransferSpec("x", nbytes=4096, fan_out=2,
                                       compute_flops=1.0)])
    serial_best = d.cycles["mem"] + d.compute_cycles
    if d.mode is CommMode.MEM:
        assert not d.fused
    else:
        eff = overlapped_cycles(chosen_cycles(d), d.compute_cycles,
                                d.ramp_cycles)
        assert eff < serial_best


# -------------------------------------------------------- step objectives ----

def _mixed_decisions(planner=None):
    planner = planner or CommPlanner()
    return planner.price([
        TransferSpec("weights.L0", nbytes=1 << 20, fan_out=8,
                     compute_flops=5e8, layer=0),
        TransferSpec("weights.L1", nbytes=1 << 18, fan_out=8,
                     compute_flops=5e8, layer=1, mult=3),
        TransferSpec("moe_dispatch", nbytes=1 << 16, fan_out=1,
                     compute_flops=2e8),
        TransferSpec("grad_reduce", nbytes=1 << 20, fan_out=8, reduce=True),
        TransferSpec("stage_activation", nbytes=1 << 14, fan_out=1,
                     pull=True),
    ])


def test_overlap_objective_never_worse_than_serial():
    decisions = _mixed_decisions()
    overlap = modeled_step_cycles(decisions)
    serial = modeled_step_cycles(decisions, objective="serial")
    assert overlap <= serial
    # something actually fused, so the inequality is strict here
    assert any(d.fused for d in decisions)
    assert overlap < serial
    with pytest.raises(ValueError):
        modeled_step_cycles(decisions, objective="bogus")


def test_overlap_objective_equals_serial_without_compute():
    decisions = CommPlanner().price(
        [TransferSpec("weights", nbytes=1 << 20, fan_out=8),
         TransferSpec("grad_reduce", nbytes=1 << 16, fan_out=4,
                      reduce=True)])
    assert modeled_step_cycles(decisions) == \
        modeled_step_cycles(decisions, objective="serial")
    assert comm_overlap_fraction(decisions) == 0.0


def test_rule_gating_disables_overlap_credit():
    """A rule-gated fused verdict charged the memory path is serial: the
    sharding rules, not the plan label, decide what XLA lowers — and a
    memory round-trip hides nothing."""
    from repro.core.sharding import resolve_rules
    from repro.runtime.train import TRAIN_RULES
    planner = CommPlanner()
    plan, decisions = planner.plan_with_decisions(
        [TransferSpec("weights", nbytes=1 << 20, fan_out=8,
                      compute_flops=5e9)])
    (d,) = decisions
    assert d.fused and d.mode is not CommMode.MEM
    gated = modeled_step_cycles(decisions, TRAIN_RULES)
    assert gated == d.cycles["mem"] + d.compute_cycles    # serial MEM charge
    resolved, overlay = resolve_rules(plan, TRAIN_RULES)
    assert overlay == {"w_fsdp": None}
    cleared = modeled_step_cycles(decisions, resolved)
    assert cleared < gated
    assert comm_overlap_fraction(decisions, TRAIN_RULES) == 0.0
    assert comm_overlap_fraction(decisions, resolved) > 0.0


def test_overlap_fraction_bounds():
    decisions = _mixed_decisions()
    frac = comm_overlap_fraction(decisions)
    assert 0.0 < frac <= 1.0
    assert comm_overlap_fraction([]) == 0.0


def test_p2p_ring_overlay_realizes_w_fsdp_rewrite():
    """The overlap planner's ring-P2P weights verdict drives the same
    sharding feedback as MCAST: w_fsdp off (the ring broadcast replaces
    the FSDP gather), so an overlap-flipped decision retriggers sharding
    resolution in the CLIs."""
    from repro.core.comm import CommPlan
    from repro.core.sharding import resolve_rules, rule_gated_issued_mode
    from repro.runtime.train import TRAIN_RULES
    plan = CommPlan({"weights": CommMode.P2P})
    resolved, overlay = resolve_rules(plan, dict(TRAIN_RULES))
    assert overlay == {"w_fsdp": None}
    assert rule_gated_issued_mode("weights", plan, resolved) is CommMode.P2P
    assert rule_gated_issued_mode("weights", plan,
                                  dict(TRAIN_RULES)) is CommMode.MEM


# -------------------------------------------- HLO: compute flops attached ----

_SCANNED_HLO_WITH_DOT = """
%cond.1 (c: (s32[], f32[16,64])) -> pred[] {
  %c = (s32[], f32[16,64]) parameter(0)
  %i = s32[] get-tuple-element(%c), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%fused_mm (fp: f32[64,64]) -> f32[64,32] {
  %fp = f32[64,64]{1,0} parameter(0)
  ROOT %d2 = f32[64,32]{1,0} dot(%fp, %fp), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%body.1 (b: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %b = (s32[], f32[16,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%b), index=0
  %x = f32[16,64]{1,0} get-tuple-element(%b), index=1
  %ag = f32[64,64]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %mm = f32[64,32]{1,0} fusion(%ag), kind=kOutput, calls=%fused_mm
  %rs = f32[16,64]{1,0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %one = s32[] constant(1)
  %i3 = s32[] add(%i2, %one)
  ROOT %t = (s32[], f32[16,64]) tuple(%i3, %x)
}

ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,64]) tuple(%zero, %p)
  %w = (s32[], f32[16,64]) while(%init), condition=%cond.1, body=%body.1
  %ar = f32[16,64]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%body.1
  ROOT %out = f32[16,64]{1,0} get-tuple-element(%w), index=1
}
"""

# the one dot: (64, 64) @ (64, 64->32) = 2 * 64*32 * 64 flops
_DOT_FLOPS = 2.0 * 64 * 32 * 64


def test_hlo_specs_carry_computation_dot_flops():
    """A collective lowered into a computation carries a share of that
    computation's per-execution dot FLOPs (fusion callees included) as
    compute_flops — the pool is apportioned *bytes-weighted* across the
    computation's compute-bearing collectives so a layer's matmuls are
    charged once per layer, not once per transfer, with the bigger
    transfer (more time on the wire to hide behind the matmul) taking
    the bigger share."""
    from repro.launch.hlo_analysis import transfer_specs_from_hlo
    specs = transfer_specs_from_hlo(_SCANNED_HLO_WITH_DOT)
    by_name = {s.name: s for s in specs}
    for i in range(4):
        ag = by_name[f"weights.L{i}"]
        rs = by_name[f"grad_scatter.L{i}"]
        assert rs.reduce
        # the body's two compute-bearing collectives split the dot pool by
        # bytes (ag moves 4096 B, rs 1024 B -> 4/5 vs 1/5): together they
        # account for the layer's matmul exactly once
        total = ag.nbytes + rs.nbytes
        assert ag.compute_flops == pytest.approx(_DOT_FLOPS * ag.nbytes / total)
        assert rs.compute_flops == pytest.approx(_DOT_FLOPS * rs.nbytes / total)
        assert ag.compute_flops + rs.compute_flops == pytest.approx(_DOT_FLOPS)
    # the entry all-reduce: its to_apply computation contains the dot, but
    # a reduction's combiner is the wire-side add, not producer/consumer
    # compute — no overlap credit leaks in through it
    ar = by_name["grad_reduce"]
    assert ar.reduce and ar.compute_flops == 0.0


def test_hlo_fused_plan_end_to_end():
    """Pricing the scanned module yields fused per-layer decisions: the
    matmul-adjacent weights gathers fuse, the plain all-reduce does not."""
    from repro.launch.hlo_analysis import transfer_specs_from_hlo
    planner = CommPlanner()
    decisions = planner.price(transfer_specs_from_hlo(_SCANNED_HLO_WITH_DOT))
    by_name = {d.spec.name: d for d in decisions}
    assert by_name["weights.L0"].fused
    assert not by_name["grad_reduce"].fused
    assert by_name["grad_reduce"].mode is CommMode.MEM
    assert modeled_step_cycles(decisions) <= \
        modeled_step_cycles(decisions, objective="serial")


# ------------------------------------------------- streamed MEM verdicts ----

def test_streamed_gather_verdict_reaches_plan():
    """A weights gather whose direct paths all lose still earns overlap
    credit through the double-buffered streamed MEM schedule, and the
    verdict flows into ``CommPlan.streamed_names`` so the socket can
    dispatch the DMA-stream kernel; a mode override invalidates it."""
    planner = CommPlanner()
    plan, (d,) = planner.plan_with_decisions(
        [TransferSpec("weights", nbytes=1 << 26, fan_out=64,
                      compute_flops=1e11)])
    assert d.mode is CommMode.MEM and d.streamed and d.fused
    assert "streamed gather" in d.reason
    assert d.speedup_vs_mem > 1.0
    assert plan.streamed("weights")
    # streaming is an attribute of the *priced* MEM decision: overriding
    # the mode (a what-if sweep, a serve downgrade) must clear it
    assert not plan.with_mode("weights", CommMode.P2P).streamed("weights")


def test_streamed_reduce_verdict():
    """A matmul-adjacent reduction where the ring loses on cycles keeps
    riding memory (the combine happens at the memory tile) but earns the
    streamed credit — the dominant dbrx grad_reduce shape."""
    planner = CommPlanner()
    (d,) = planner.price([TransferSpec("grad_reduce", nbytes=1 << 20,
                                       fan_out=16, reduce=True,
                                       compute_flops=1e9)])
    assert d.mode is CommMode.MEM and d.streamed and d.fused
    assert "streamed memory-path reduction" in d.reason
    # the streamed verdict earns credit at its own mode...
    assert comm_overlap_fraction([d]) > 0.0
    # ...but a rule-gated demotion of a DIRECT verdict to MEM still hides
    # nothing — only the priced streamed schedule overlaps on memory
    assert modeled_step_cycles([d]) < \
        modeled_step_cycles([d], objective="serial")


def test_moe_dispatch_mem_overlay_replicates_seq_sp():
    """The seq_sp axis rule follows the MoE dispatch verdict: the mcast
    dispatch requires sequence-sharded activations (the static default),
    while a MEM verdict is the shared-memory baseline — tokens replicate
    over the model axis, so the overlay replicates ``seq_sp`` to avoid a
    per-block reshard boundary."""
    from repro.core.comm import CommPlan
    from repro.core.sharding import DEFAULT_RULES, resolve_rules
    mem_plan = CommPlan({"moe_dispatch": CommMode.MEM})
    resolved, overlay = resolve_rules(mem_plan, dict(DEFAULT_RULES))
    assert overlay == {"seq_sp": None}
    assert resolved["seq_sp"] is None
    # the mcast verdict keeps the static sequence-parallel rule
    mc_plan = CommPlan({"moe_dispatch": CommMode.MCAST})
    resolved, overlay = resolve_rules(mc_plan, dict(DEFAULT_RULES))
    assert "seq_sp" not in overlay
    assert resolved["seq_sp"] == DEFAULT_RULES["seq_sp"]
    # mixed per-layer verdicts keep the conservative static rule
    mixed = CommPlan({"moe_dispatch.L0": CommMode.MEM,
                      "moe_dispatch.L1": CommMode.MCAST})
    _, overlay = resolve_rules(mixed, dict(DEFAULT_RULES))
    assert "seq_sp" not in overlay


# ------------------------------------------- tier-2: fusible-kind property ----

@pytest.mark.tier2
@settings(deadline=None, max_examples=60)
@given(nbytes=st.integers(1 << 10, 1 << 26),
       fan_out=st.integers(1, 128),
       flops=st.integers(0, 10 ** 11))
def test_every_fusible_kind_never_worse_than_serial(nbytes, fan_out, flops):
    """For every fusible kind the planner can choose — the fused ring
    (P2P), the double-buffered multicast stream / MoE dispatch chain
    (MCAST), and the streamed MEM gather and reduction — the overlapped
    charge never exceeds the serial one, decision by decision and for the
    whole step.  (The matching bit-identity half of the contract lives in
    tests/test_kernels.py: each fused dispatch equals its unfused
    fallback.)"""
    planner = CommPlanner()
    decisions = planner.price([
        TransferSpec("weights", nbytes=nbytes, fan_out=fan_out,
                     compute_flops=float(flops)),
        TransferSpec("moe_dispatch", nbytes=nbytes, fan_out=fan_out,
                     compute_flops=float(flops)),
        TransferSpec("grad_reduce", nbytes=nbytes, fan_out=fan_out,
                     reduce=True, compute_flops=float(flops)),
        TransferSpec("stage_activation", nbytes=nbytes, fan_out=1,
                     pull=True, compute_flops=float(flops)),
    ])
    for d in decisions:
        serial = chosen_cycles(d) + d.compute_cycles
        eff = overlapped_cycles(chosen_cycles(d), d.compute_cycles,
                                d.ramp_cycles)
        assert eff <= serial
        if d.streamed:
            # streamed is an attribute of a MEM verdict, and it only
            # exists where there is compute to hide behind
            assert d.mode is CommMode.MEM and d.compute_cycles > 0
        if d.fused or d.streamed:
            assert d.speedup_vs_mem >= 1.0
    assert modeled_step_cycles(decisions) <= \
        modeled_step_cycles(decisions, objective="serial")
    assert 0.0 <= comm_overlap_fraction(decisions) <= 1.0
