"""Minimal, deterministic stand-in for ``hypothesis`` on network-less boxes.

Activated by ``conftest.py`` ONLY when the real package is absent: it is
installed into ``sys.modules`` under the names ``hypothesis`` and
``hypothesis.strategies`` before test modules import, so the property-test
modules collect and run offline.  It implements exactly the surface those
modules use — ``given``, ``settings``, and the ``integers`` / ``tuples`` /
``lists`` / ``sampled_from`` / ``booleans`` / ``just`` / ``text`` /
``floats`` / ``one_of`` / ``permutations`` / ``fixed_dictionaries``
strategies — with *deterministic* example sampling:

* example 0 is minimal (lower bounds, ``min_size`` lists, first choice),
* example 1 is maximal (upper bounds, ``max_size`` lists, last choice),
* the rest are drawn from a ``random.Random`` seeded by CRC32 of the test's
  qualified name and the example index — stable across runs and machines.

No shrinking, no database, no health checks: a failing example's kwargs are
attached to the assertion message instead.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 20

__version__ = "0.0-vendored-fallback"


class _Strategy:
    def __init__(self, minimal, maximal, sample):
        self._minimal = minimal
        self._maximal = maximal
        self._sample = sample

    def example_at(self, index: int, rng: random.Random):
        if index == 0:
            return self._minimal(rng)
        if index == 1:
            return self._maximal(rng)
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: min_value, lambda r: max_value,
                     lambda r: r.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda r: elems[0], lambda r: elems[-1],
                     lambda r: r.choice(elems))


def booleans() -> _Strategy:
    return _Strategy(lambda r: False, lambda r: True,
                     lambda r: r.random() < 0.5)


def just(value) -> _Strategy:
    return _Strategy(lambda r: value, lambda r: value, lambda r: value)


def text(alphabet="abcdefghijklmnopqrstuvwxyz0123456789_.-",
         min_size: int = 0, max_size: int = 12) -> _Strategy:
    """Strings over ``alphabet``: minimal example repeats the first
    character ``min_size`` times, maximal the last ``max_size`` times."""
    elems = list(alphabet)

    def build(size: int, idx: int, rng: random.Random) -> str:
        if size == 0:
            return ""
        if idx == 0:
            return elems[0] * size
        if idx == 1:
            return elems[-1] * size
        return "".join(rng.choice(elems) for _ in range(size))

    return _Strategy(
        lambda r: build(min_size, 0, r),
        lambda r: build(max_size, 1, r),
        lambda r: build(r.randint(min_size, max_size), 2, r))


def floats(min_value: float, max_value: float,
           allow_nan: bool = False, allow_infinity: bool = False) -> _Strategy:
    """Bounded finite floats (the retry/backoff-schedule surface): minimal
    example is ``min_value``, maximal ``max_value``, the rest uniform."""
    return _Strategy(lambda r: min_value, lambda r: max_value,
                     lambda r: r.uniform(min_value, max_value))


def one_of(*strategies: _Strategy) -> _Strategy:
    """Choose among alternative strategies (used to sample fault kinds —
    router kill vs link kill): minimal draws the first alternative's
    minimum, maximal the last alternative's maximum."""
    return _Strategy(
        lambda r: strategies[0].example_at(0, r),
        lambda r: strategies[-1].example_at(1, r),
        lambda r: r.choice(strategies).example_at(2, r))


def permutations(values) -> _Strategy:
    """Permutations of a fixed sequence (used to shuffle physical block
    assignment in the paged-KV equivalence suite): minimal is the
    identity order, maximal the reversal, the rest Fisher-Yates draws."""
    seq = list(values)

    def shuffled(rng: random.Random):
        out = list(seq)
        rng.shuffle(out)
        return out

    return _Strategy(lambda r: list(seq), lambda r: list(reversed(seq)),
                     shuffled)


def fixed_dictionaries(mapping) -> _Strategy:
    """Dict with a fixed key set, each value drawn from its own strategy
    (used to sample SoCParams field overrides in the calibration
    round-trip suite): minimal draws every value's minimum, maximal every
    maximum.  Keys are iterated in sorted order so the per-key draws are
    stable regardless of the caller's dict ordering."""
    items = sorted(mapping.items())

    def build(idx: int, rng: random.Random):
        return {k: s.example_at(idx, rng) for k, s in items}

    return _Strategy(lambda r: build(0, r), lambda r: build(1, r),
                     lambda r: build(2, r))


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(
        lambda r: tuple(s.example_at(0, r) for s in strategies),
        lambda r: tuple(s.example_at(1, r) for s in strategies),
        lambda r: tuple(s.example_at(2, r) for s in strategies))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          unique: bool = False) -> _Strategy:
    def build(size: int, idx: int, rng: random.Random):
        out, seen = [], set()
        attempts = 0
        while len(out) < size and attempts < 50 * (size + 1):
            attempts += 1
            # only the very first element honours the min/max anchor; the
            # rest are random draws (a constant list defeats uniqueness)
            e = elements.example_at(idx if not out else 2, rng)
            if unique:
                if e in seen:
                    continue
                seen.add(e)
            out.append(e)
        return out

    return _Strategy(
        lambda r: build(min_size, 0, r),
        lambda r: build(max_size, 1, r),
        lambda r: build(r.randint(min_size, max_size), 2, r))


class settings:
    """Decorator recording (deadline, max_examples); other hypothesis
    settings are accepted and ignored."""

    def __init__(self, deadline=None, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        # runs above @given: tag whichever callable we received
        fn._vendored_hyp_max_examples = self.max_examples
        return fn


def given(**strategy_kwargs):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(
                wrapper, "_vendored_hyp_max_examples",
                getattr(fn, "_vendored_hyp_max_examples",
                        DEFAULT_MAX_EXAMPLES))
            base = f"{fn.__module__}.{fn.__qualname__}"
            for i in range(max_examples):
                rng = random.Random(
                    zlib.crc32(f"{base}:{i}".encode()) & 0xFFFFFFFF)
                example = {k: s.example_at(i, rng)
                           for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **example)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {example!r}") from e

        # pytest resolves fixtures from the signature: strip the strategy
        # params (filled per example) and the copied __wrapped__ reference
        # (which would make pytest inspect the original function instead)
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs])
        return wrapper
    return decorate


def assume(condition) -> bool:
    """Weak stand-in: vacuously skip nothing; callers in this repo never
    use it, but keep the symbol for drop-in parity."""
    return bool(condition)
