"""Tier-1 invariants for the paged (block) KV cache behind the serving
engine: allocator free-list discipline, name-based leaf classification,
gather/scatter/write_prefix geometry, and the ``grow_caches`` regression
(the old shape-coincidence grow padded the wrong axis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.runtime import kv_blocks as KB
from repro.runtime.serve import grow_caches


# ----------------------------------------------------------- allocator ----

def test_allocator_reserves_the_null_block():
    with pytest.raises(ValueError):
        KB.BlockAllocator(1)          # nothing left after the null block
    a = KB.BlockAllocator(5)
    assert a.n_free == 4 and a.n_used == 0
    got = a.alloc(4)
    assert KB.NULL_BLOCK not in got   # block 0 is never handed out
    assert sorted(got) == [1, 2, 3, 4]


def test_allocator_alloc_free_discipline():
    a = KB.BlockAllocator(6)
    first = a.alloc(3)
    second = a.alloc(2)
    # no block is ever live twice
    assert len(set(first) | set(second)) == 5
    assert a.n_free == 0
    with pytest.raises(KB.OutOfBlocksError):
        a.alloc(1)
    a.free(first)
    assert a.n_free == 3 and a.n_used == 2
    with pytest.raises(ValueError):
        a.free(first[:1])             # double free
    with pytest.raises(ValueError):
        a.free([KB.NULL_BLOCK])       # the reserved null block
    # freed blocks recirculate without colliding with live ones
    third = a.alloc(3)
    assert not set(third) & set(second)


# ------------------------------------------------------- classification ----

def test_layout_dense_attention_pages():
    cfg = get_reduced("qwen3-4b")
    lay = KB.paged_layout(cfg, n_slots=3, prompt_len=16, max_new_tokens=8,
                          block_size=8)
    assert lay.s_max == 24 and lay.max_blocks == 3
    assert lay.capacity_blocks == 9
    specs = [sp for sp in jax.tree.leaves(lay.specs,
                                          is_leaf=KB._spec_is_leaf)]
    assert specs and all(sp.paged and sp.skv == 24 for sp in specs)
    # grouped leaves carry the leading scan dim as an unnamed axis
    assert all(sp.names[0] is None and "kv_seq" in sp.names for sp in specs)


def test_layout_ring_and_recurrent_state_are_slot_state():
    # window <= prompt: the contiguous serve contract keeps the ring at
    # S_prompt and wraps — it never pages
    swa = KB.paged_layout(get_reduced("h2o-danube-3-4b"), n_slots=2,
                          prompt_len=36, max_new_tokens=4, block_size=8)
    assert all(not sp.paged and sp.skv == 36
               for sp in jax.tree.leaves(swa.specs,
                                         is_leaf=KB._spec_is_leaf))
    # prompt < window: the same leaves hold full history and page
    deep = KB.paged_layout(get_reduced("h2o-danube-3-4b"), n_slots=2,
                           prompt_len=16, max_new_tokens=8, block_size=8)
    assert all(sp.paged and sp.skv == 24
               for sp in jax.tree.leaves(deep.specs,
                                         is_leaf=KB._spec_is_leaf))
    # recurrent state (mamba) has no full-sequence history at all
    ssm = KB.paged_layout(get_reduced("falcon-mamba-7b"), n_slots=2,
                          prompt_len=16, max_new_tokens=8, block_size=8)
    assert all(not sp.paged
               for sp in jax.tree.leaves(ssm.specs,
                                         is_leaf=KB._spec_is_leaf))


def test_layout_block_size_must_divide_depth():
    with pytest.raises(ValueError):
        KB.paged_layout(get_reduced("qwen3-4b"), n_slots=2, prompt_len=16,
                        max_new_tokens=8, block_size=7)


def test_blocks_needed_is_monotone_and_capped():
    lay = KB.paged_layout(get_reduced("qwen3-4b"), n_slots=2, prompt_len=16,
                          max_new_tokens=16, block_size=8)
    needs = [lay.blocks_needed(p) for p in range(lay.s_max)]
    assert needs[0] == 1 and needs[-1] == lay.max_blocks
    assert all(b - a in (0, 1) for a, b in zip(needs, needs[1:]))
    assert lay.blocks_needed(10 * lay.s_max) == lay.max_blocks


def test_null_table_shape_and_value():
    lay = KB.paged_layout(get_reduced("qwen3-4b"), n_slots=3, prompt_len=16,
                          max_new_tokens=8, block_size=8)
    t = KB.null_table(lay)
    assert t.shape == (3, lay.max_blocks) and t.dtype == np.int32
    assert (t == KB.NULL_BLOCK).all()


# --------------------------------------------- gather / scatter / prefix ----

def _layout_and_pools(arch="qwen3-4b", n_slots=2, S=16, gen=8, bs=8):
    lay = KB.paged_layout(get_reduced(arch), n_slots=n_slots, prompt_len=S,
                          max_new_tokens=gen, block_size=bs,
                          dtype=jnp.float32)
    return lay, KB.make_pools(lay)


def _prefix_like(layout, seed=0):
    """A random cache tree shaped like one request's prefill output."""
    keys = iter(jax.random.split(jax.random.key(seed), 64))

    def leaf(sp):
        sh = list(sp.contig_shape)
        sh[sp.batch_ax] = 1
        if sp.paged:
            sh[sp.kv_ax] = layout.prompt_len
        return jax.random.normal(next(keys), tuple(sh),
                                 jnp.float32).astype(sp.dtype)

    return jax.tree.map(leaf, layout.specs, is_leaf=KB._spec_is_leaf)


def test_write_prefix_then_gather_roundtrips():
    lay, pools = _layout_and_pools()
    prefix = _prefix_like(lay, seed=3)
    blocks = [5, 2]                      # permuted physical order on purpose
    tables = KB.null_table(lay)
    tables[1, :2] = blocks
    pools = KB.write_prefix(lay, pools, prefix, jnp.int32(1),
                            jnp.asarray(blocks, jnp.int32))
    contig = KB.gather_caches(lay, pools, jnp.asarray(tables))

    def check(sp, pre, got):
        got = jnp.moveaxis(got, sp.batch_ax, 0)
        pre = jnp.moveaxis(pre, sp.batch_ax, 0)[0]
        if sp.paged:
            kv = sp.kv_ax - (sp.kv_ax > sp.batch_ax)   # axis after the move
            S = lay.prompt_len
            lead = jnp.take(got[1], jnp.arange(S), axis=kv)
            np.testing.assert_array_equal(np.asarray(lead), np.asarray(pre))
            tail = jnp.take(got[1], jnp.arange(S, got[1].shape[kv]), axis=kv)
            assert not np.asarray(tail).any()           # unwritten blocks
        else:
            np.testing.assert_array_equal(np.asarray(got[1]),
                                          np.asarray(pre))
        assert not np.asarray(got[0]).any()             # other slot untouched

    jax.tree.map(check, lay.specs, prefix, contig, is_leaf=KB._spec_is_leaf)


def test_scatter_touches_only_the_position_block():
    lay, pools = _layout_and_pools()
    prefix = _prefix_like(lay, seed=4)
    blocks = [3, 1, 6]
    tables = KB.null_table(lay)
    tables[0, :3] = blocks
    pools = KB.write_prefix(lay, pools, prefix, jnp.int32(0),
                            jnp.asarray(blocks[:2], jnp.int32))
    before = KB.gather_caches(lay, pools, jnp.asarray(tables))
    bumped = jax.tree.map(lambda c: c + 1.0, before)
    pos = jnp.asarray([lay.prompt_len, 0], jnp.int32)   # slot 1 inactive
    pools = KB.scatter_caches(lay, pools, bumped, jnp.asarray(tables), pos)
    after = KB.gather_caches(lay, pools, jnp.asarray(tables))

    def check(sp, b, a):
        b = np.asarray(jnp.moveaxis(b, sp.batch_ax, 0))
        a = np.asarray(jnp.moveaxis(a, sp.batch_ax, 0))
        if not sp.paged:
            # slot state is replacement: the whole array took the bump
            np.testing.assert_array_equal(a, b + 1.0)
            return
        kv = sp.kv_ax - (sp.kv_ax > sp.batch_ax)
        bs = lay.block_size
        j = lay.prompt_len // bs                       # slot 0's write block
        b0 = np.moveaxis(b[0], kv, 0).copy()
        a0 = np.moveaxis(a[0], kv, 0).copy()
        np.testing.assert_array_equal(a0[j * bs:(j + 1) * bs],
                                      b0[j * bs:(j + 1) * bs] + 1.0)
        a0[j * bs:(j + 1) * bs] = b0[j * bs:(j + 1) * bs]
        np.testing.assert_array_equal(a0, b0)          # nothing else moved
        # slot 1 owns no blocks: its write landed on the null block, so its
        # own gathered view reads that garbage back — every logical block
        # shows the same null-block content (the decode validity mask is
        # what hides it).  The active slot above saw none of it.
        a1 = np.moveaxis(a[1], kv, 0)
        a1 = a1.reshape((lay.max_blocks, bs) + a1.shape[1:])
        for blk in a1[1:]:
            np.testing.assert_array_equal(blk, a1[0])

    jax.tree.map(check, lay.specs, before, after, is_leaf=KB._spec_is_leaf)


def test_scatter_slot_state_keeps_pool_dtype():
    # a decode step may hand recurrent state back in its compute dtype; the
    # scatter must coerce to the pool dtype or the next step retraces
    lay, pools = _layout_and_pools("falcon-mamba-7b")
    wrong = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.bfloat16), pools)
    out = KB.scatter_caches(lay, pools, wrong, jnp.asarray(KB.null_table(lay)),
                            jnp.zeros((2,), jnp.int32))
    assert all(o.dtype == p.dtype
               for o, p in zip(jax.tree.leaves(out), jax.tree.leaves(pools)))


# ------------------------------------------------- grow_caches regression ----

def _old_buggy_grow(cfg, caches, S, gen):
    """The pre-engine serve driver's grow: a *shape* test that pads any
    leaf whose dim -3 happens to equal the prompt length."""
    window = cfg.local_window if "swa" in cfg.pattern else cfg.sliding_window

    def grow(leaf):
        if leaf.ndim >= 4 and leaf.shape[-3] == S and not (
                window and S >= window):
            pad = [(0, 0)] * leaf.ndim
            pad[-3] = (0, gen)
            return jnp.pad(leaf, pad)
        return leaf

    return jax.tree.map(grow, caches)


def test_grow_caches_grows_only_the_kv_axis():
    cfg = get_reduced("qwen3-4b")
    caches = T.make_cache(cfg, 2, 16)
    grown = grow_caches(cfg, caches, 16, 8)
    lay = KB.paged_layout(cfg, n_slots=2, prompt_len=16, max_new_tokens=8,
                          block_size=8)

    def check(sp, old, new):
        want = list(old.shape)
        want[sp.kv_ax] += 8
        assert new.shape == tuple(want), (old.shape, new.shape)

    jax.tree.map(check, lay.specs, caches, grown, is_leaf=KB._spec_is_leaf)


def test_grow_caches_never_pads_recurrent_state():
    # regression: with batch == prompt_len the old shape test matched the
    # grouped mamba state leaves (dim -3 is the batch axis) and padded the
    # *batch* — name-based classification must leave slot state alone
    cfg = get_reduced("falcon-mamba-7b")
    B = S = 3
    caches = T.make_cache(cfg, B, S)
    buggy = _old_buggy_grow(cfg, caches, S, gen=5)
    assert any(b.shape != c.shape for b, c in
               zip(jax.tree.leaves(buggy), jax.tree.leaves(caches))), \
        "the historical false positive no longer reproduces"
    grown = grow_caches(cfg, caches, S, 5)
    assert all(g.shape == c.shape and g.dtype == c.dtype for g, c in
               zip(jax.tree.leaves(grown), jax.tree.leaves(caches)))


def test_grow_caches_keeps_rings_at_prompt_length():
    # window <= prompt: the ring wraps in place — growing it would both
    # waste memory and break the decode wrap arithmetic
    cfg = get_reduced("h2o-danube-3-4b")
    caches = T.make_cache(cfg, 2, 36)          # window = 32 in reduced cfg
    grown = grow_caches(cfg, caches, 36, 4)
    assert all(g.shape == c.shape for g, c in
               zip(jax.tree.leaves(grown), jax.tree.leaves(caches)))
