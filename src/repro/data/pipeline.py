"""Deterministic synthetic data pipeline with host-side prefetch.

The stream is a counter-mode hash (splitmix64) of (seed, step, position), so
any worker can materialize any shard of any step independently — exactly the
property elastic restarts need: after a re-mesh, workers recompute their new
shards of the same global batch with no data-state handoff.

``PrefetchLoader`` double-buffers batches on a background thread — the
IDMA/CDMA pattern (paper C5) applied at the framework level: issue the next
load asynchronously, poll completion when the step needs it.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class SyntheticTokenStream:
    """Deterministic (seed, step) -> {"tokens", "labels"} batches."""

    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        """Materialize this worker's shard of global step ``step``."""
        assert self.global_batch % num_shards == 0
        b_loc = self.global_batch // num_shards
        rows = np.arange(shard * b_loc, (shard + 1) * b_loc, dtype=np.uint64)
        cols = np.arange(self.seq_len + 1, dtype=np.uint64)
        base = (np.uint64(self.seed) << np.uint64(40)) + \
            (np.uint64(step) << np.uint64(20))
        grid = base + rows[:, None] * np.uint64(1 << 20) + cols[None, :]
        toks = (_splitmix64(grid) % np.uint64(self.vocab_size)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class PrefetchLoader:
    """Background-thread double buffering over a SyntheticTokenStream."""

    def __init__(self, stream: SyntheticTokenStream, shard: int = 0,
                 num_shards: int = 1, depth: int = 2, start_step: int = 0):
        self.stream = stream
        self.shard, self.num_shards = shard, num_shards
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.stream.batch(step, self.shard, self.num_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
