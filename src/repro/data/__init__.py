from repro.data.pipeline import SyntheticTokenStream, PrefetchLoader

__all__ = ["SyntheticTokenStream", "PrefetchLoader"]
