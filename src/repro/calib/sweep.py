"""Design-space sweep: ``SoCParams`` grid -> modeled step cycles vs cost.

The Lumos-style MPSoC design-space-exploration shape, applied to our
planner: once the performance model is calibrated, "which pod profile
should I build" is a parametric sweep, not a redesign.  Each design point
is a pod-profile :class:`~repro.core.noc.perfmodel.SoCParams` (mesh size x
per-hop link latency x burst-framing profile); a *fixed* workload — the
named config's per-step transfer specs, priced by
:class:`~repro.core.planner.CommPlanner` on that fabric — yields modeled
step cycles, and the paper's Fig. 4 post-synthesis area model yields a
cost proxy.  The Pareto set over (cycles, cost) is the one-command answer.

The cost proxy is *relative* (ranking fabric candidates), not a signoff
area number: routers are priced by the paper's synthesis anchors
(``router_area`` with each router sized for the model's multicast
destination capacity), links by wire bits x a repeater factor that grows
with the per-hop latency (a 2-cycle pipelined hop is a longer, buffered
wire).
"""

from __future__ import annotations

import itertools
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.noc.perfmodel import SoCParams, SoCPerfModel
from repro.core.noc.router import router_area
from repro.core.planner import (CommPlanner, mode_mix, modeled_step_cycles,
                                step_transfer_specs)

DEFAULT_MESHES: Tuple[Tuple[int, int], ...] = ((4, 3), (8, 8), (16, 16))
DEFAULT_LINK_LATENCIES: Tuple[int, ...] = (1, 2, 4)
# burst-framing profiles: the DMA burst size the platform's transfer
# framing is built around (paper: 4 KB traffic-generator bursts; pod
# profiles default to 8 KB)
DEFAULT_PROFILES: Tuple[Tuple[str, int], ...] = (
    ("burst4k", 4096), ("burst8k", 8192), ("burst16k", 16384))

# Wire-cost proxy: um^2 per link wire bit, scaled by link latency (a
# deeper-pipelined hop is a longer repeated wire).  Relative knob for
# ranking, deliberately coarse — see module docstring.
WIRE_UM2_PER_BIT = 2.0


def fabric_cost_um2(params: SoCParams, max_dests: int) -> float:
    """Area proxy of the fabric: per-tile multicast-capable routers
    (Fig. 4 synthesis anchors) + mesh link wires."""
    n_tiles = params.mesh_w * params.mesh_h
    n_links = 2 * ((params.mesh_w - 1) * params.mesh_h +
                   params.mesh_w * (params.mesh_h - 1))
    routers = n_tiles * router_area(params.bitwidth, max_dests)
    wires = (n_links * params.bitwidth * WIRE_UM2_PER_BIT *
             params.link_latency)
    return routers + wires


def design_grid(meshes: Sequence[Tuple[int, int]] = DEFAULT_MESHES,
                link_latencies: Sequence[int] = DEFAULT_LINK_LATENCIES,
                profiles: Sequence[Tuple[str, int]] = DEFAULT_PROFILES
                ) -> List[SoCParams]:
    """The swept ``SoCParams`` candidates, one per grid point."""
    out = []
    for (w, h), lat, (pname, burst) in itertools.product(
            meshes, link_latencies, profiles):
        out.append(SoCParams.pod(
            w, h, link_latency=lat, burst_bytes=burst,
            name=f"pod-{w}x{h}-l{lat}-{pname}"))
    return out


def sweep_design_space(arch: str = "dbrx-132b",
                       shape_name: str = "train_4k",
                       candidates: Optional[Sequence[SoCParams]] = None,
                       mesh_axes: Optional[Dict[str, int]] = None
                       ) -> List[Dict]:
    """Price the named workload on every candidate fabric.

    The workload is held fixed — ``step_transfer_specs`` of the named
    config on the production mesh axes — so cycle differences are the
    fabric's doing, not the parallelism layout's.  Returns one dict per
    design point with the fitted plan's modeled step cycles, the cost
    proxy, the mode mix, and a ``pareto`` flag."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    axes = dict(mesh_axes or {"data": 16, "model": 16})
    specs = step_transfer_specs(cfg, shape, axes)
    points = []
    for params in (candidates if candidates is not None else design_grid()):
        model = SoCPerfModel(params)
        planner = CommPlanner(model)
        _, decisions = planner.plan_with_decisions(specs)
        points.append({
            "name": params.name,
            "mesh": [params.mesh_w, params.mesh_h],
            "link_latency": params.link_latency,
            "burst_bytes": params.burst_bytes,
            "cycles": modeled_step_cycles(decisions),
            "cost_um2": fabric_cost_um2(params, model.max_dests),
            "mode_mix": mode_mix(decisions),
        })
    for p in points:
        p["pareto"] = not any(_dominates(q, p) for q in points)
    return points


def _dominates(a: Dict, b: Dict) -> bool:
    """a dominates b: no worse on both objectives, strictly better on one
    (both minimized)."""
    return (a["cycles"] <= b["cycles"] and a["cost_um2"] <= b["cost_um2"]
            and (a["cycles"] < b["cycles"] or a["cost_um2"] < b["cost_um2"]))


def pareto_front(points: Sequence[Dict]) -> List[Dict]:
    """The non-dominated design points, cheapest-fabric first."""
    return sorted((p for p in points if p["pareto"]),
                  key=lambda p: p["cost_um2"])


def write_frontier(points: Sequence[Dict], path: str, *,
                   arch: str, shape_name: str) -> None:
    """The frontier artifact: every priced design point plus the Pareto
    set, under the same experiments/ convention as the dryrun
    artifacts."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({
            "arch": arch, "shape": shape_name,
            "objectives": ["cycles", "cost_um2"],
            "points": list(points),
            "pareto": pareto_front(points),
        }, f, indent=1, sort_keys=True)
