"""Calibration + design-space exploration (ROADMAP open item 1).

Three pillars, one subsystem (outside ``core/`` — anything it issues on
the fabric goes through ``AcceleratorSocket`` like every other user of
the communication spine):

* :mod:`repro.calib.measure` — typed :class:`Observation` records from
  flit-sim runs, bench rows, the socket issue log, and dryrun/serve
  artifacts;
* :mod:`repro.calib.fit` — least-squares / coordinate-search recovery of
  ``SoCParams`` fields, emitting a :class:`CalibratedParams` artifact;
* :mod:`repro.calib.sweep` — the parametric design-space sweep
  (``python -m repro.calib sweep``) with a Pareto frontier artifact.

See ``docs/calibration.md``.
"""

from repro.calib.measure import (Observation, compute_observations,
                                 flit_sim_cycles, flit_sim_observations,
                                 observations_from_artifact,
                                 observations_from_bench,
                                 observations_from_issue_log)
from repro.calib.fit import (CalibratedParams, FieldFit, fit_report,
                             fit_soc_params)
from repro.calib.sweep import (design_grid, fabric_cost_um2, pareto_front,
                               sweep_design_space, write_frontier)

__all__ = [
    "Observation", "compute_observations", "flit_sim_cycles",
    "flit_sim_observations", "observations_from_artifact",
    "observations_from_bench", "observations_from_issue_log",
    "CalibratedParams", "FieldFit", "fit_report", "fit_soc_params",
    "design_grid", "fabric_cost_um2", "pareto_front",
    "sweep_design_space", "write_frontier",
]
