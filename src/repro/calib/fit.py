"""Fit ``SoCParams`` fields from observations (the calibration fitter).

The planner prices transfers with :class:`~repro.core.noc.perfmodel.
SoCPerfModel` closed forms whose free constants were calibrated once
against the paper's quoted milestones.  This module closes ROADMAP open
item 1's inner loop: given :class:`~repro.calib.measure.Observation`
records, recover the timing-relevant ``SoCParams`` fields by weighted
least squares:

* ``link_latency`` and ``burst_bytes`` — coordinate (grid) search over
  candidate values, pricing every network observation through its forward
  model: the flit-sim mapping (:func:`measure.flit_sim_cycles`) for
  ``kind == "flit_sim"``, the ``SoCPerfModel.batch_cycles`` closed forms
  for model-shaped kinds.  The search is exact: when the ground truth
  lies on the candidate grids (both fields are small discrete hardware
  choices — per-hop pipeline depth, DMA burst framing), the residual at
  the truth is the observation noise floor and the argmin recovers it;
  off-grid truths resolve to the nearest candidate (documented tolerance:
  one grid step).
* ``flops_per_cycle`` — closed-form weighted least squares through the
  origin on ``kind == "compute"`` observations
  (``measured = flops / flops_per_cycle``).

Residuals are *relative* (scale-free across 4 KB and 1 MB experiments)
and weighted by each observation's ``weight`` (bench rows are
down-weighted by their run-to-run spread).  The result is a
:class:`CalibratedParams` artifact: the fitted params plus per-field
value/residual/confidence — ready to install via
``perfmodel.set_default_params`` (the plan cache fingerprints the
effective params, so installation invalidates stale-priced plans).
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.noc.perfmodel import SoCParams, SoCPerfModel

from repro.calib import measure
from repro.calib.measure import Observation

# Candidate grids: per-hop link pipeline depths and power-of-two DMA burst
# framings a real SoC would actually ship.
DEFAULT_LINK_CANDIDATES: Tuple[int, ...] = (1, 2, 3, 4, 6, 8)
DEFAULT_BURST_CANDIDATES: Tuple[int, ...] = (1024, 2048, 4096, 8192, 16384)

# Observation kinds priced through SoCPerfModel.batch_cycles closed forms
# (vs the flit-sim forward model).
_MODEL_KINDS = ("model",)
FIT_FIELDS = ("link_latency", "burst_bytes", "flops_per_cycle")


@dataclasses.dataclass(frozen=True)
class FieldFit:
    """One fitted field: the recovered value, the relative RMS residual of
    the observations that inform it, a ``1/(1+residual)`` confidence in
    (0, 1], and how many observations voted."""
    field: str
    value: float
    residual: float
    confidence: float
    n_obs: int

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class CalibratedParams:
    """The calibration artifact: fitted params + per-field diagnostics."""
    params: SoCParams
    fields: Dict[str, FieldFit]
    residual: float                # weighted relative RMS over fitted obs
    n_obs: int

    def summary(self) -> Dict:
        """JSON-able artifact payload (dryrun ``calibration`` field, the
        CLI's ``--json`` output)."""
        return {
            "params": dataclasses.asdict(self.params),
            "fields": {k: f.to_dict() for k, f in sorted(self.fields.items())},
            "residual": self.residual,
            "n_obs": self.n_obs,
        }

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.summary(), f, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, path: str) -> "CalibratedParams":
        with open(path) as f:
            d = json.load(f)
        p = dict(d["params"])
        # JSON turns the coordinate tuples into lists; coerce them back
        for k in ("mem_tile", "cpu_tile"):
            p[k] = tuple(p[k])
        p["io_tiles"] = tuple(tuple(t) for t in p["io_tiles"])
        return cls(params=SoCParams(**p),
                   fields={k: FieldFit(**f) for k, f in d["fields"].items()},
                   residual=d["residual"], n_obs=d["n_obs"])


def _predict(params: SoCParams, obs: Observation) -> Optional[float]:
    """Forward model dispatch: modeled cycles for ``obs`` under
    ``params`` (None when no forward model prices this kind)."""
    if obs.kind == "flit_sim":
        return measure.flit_sim_cycles(params, obs.fan_out, obs.nbytes)
    if obs.kind in _MODEL_KINDS:
        import numpy as np
        got = SoCPerfModel(params).batch_cycles([obs.fan_out], [obs.nbytes])
        val = float(got[obs.mode][0])
        return val if np.isfinite(val) else None
    if obs.kind == "compute":
        return obs.flops / params.flops_per_cycle
    return None


def _rel_residual(params: SoCParams, observations: Sequence[Observation]
                  ) -> Tuple[float, int]:
    """Weighted relative RMS residual over the observations a forward
    model prices; ``(inf, 0)`` when none are priceable."""
    num = den = 0.0
    n = 0
    for o in observations:
        pred = _predict(params, o)
        if pred is None or o.measured_cycles <= 0:
            continue
        r = (o.measured_cycles - pred) / o.measured_cycles
        num += o.weight * r * r
        den += o.weight
        n += 1
    if n == 0:
        return math.inf, 0
    return math.sqrt(num / den), n


def fit_soc_params(observations: Sequence[Observation],
                   base: Optional[SoCParams] = None,
                   fit_fields: Sequence[str] = FIT_FIELDS,
                   link_candidates: Sequence[int] = DEFAULT_LINK_CANDIDATES,
                   burst_candidates: Sequence[int] = DEFAULT_BURST_CANDIDATES,
                   ) -> CalibratedParams:
    """Fit the requested ``SoCParams`` fields from ``observations``.

    ``base`` carries everything the fit does *not* touch (mesh shape, tile
    placement, the Fig. 6 driver constants): calibration refines the
    timing constants of a known floorplan, it does not infer topology.
    Fields with no informing observations keep their ``base`` value with
    confidence 0.
    """
    base = base or SoCParams()
    net_obs = [o for o in observations
               if o.kind in ("flit_sim",) + _MODEL_KINDS
               and o.measured_cycles > 0]
    comp_obs = [o for o in observations
                if o.kind == "compute" and o.flops > 0
                and o.measured_cycles > 0]
    fields: Dict[str, FieldFit] = {}

    # --- network fields: coordinate search over (burst_bytes, link) -----
    fit_link = "link_latency" in fit_fields and net_obs
    fit_burst = "burst_bytes" in fit_fields and net_obs
    links = tuple(link_candidates) if fit_link else (base.link_latency,)
    bursts = tuple(burst_candidates) if fit_burst else (base.burst_bytes,)
    best: Optional[Tuple[float, int, SoCParams, int]] = None
    for b, l in itertools.product(bursts, links):
        cand = dataclasses.replace(base, burst_bytes=b, link_latency=l)
        res, n = _rel_residual(cand, net_obs)
        # strict < keeps the first (smallest) candidate on exact ties —
        # deterministic, and ties only occur below the noise floor
        if best is None or res < best[0]:
            best = (res, n, cand, l)
    net_res, net_n, net_params, _ = best
    if fit_link:
        fields["link_latency"] = FieldFit(
            "link_latency", float(net_params.link_latency), net_res,
            1.0 / (1.0 + net_res) if math.isfinite(net_res) else 0.0, net_n)
    if fit_burst:
        fields["burst_bytes"] = FieldFit(
            "burst_bytes", float(net_params.burst_bytes), net_res,
            1.0 / (1.0 + net_res) if math.isfinite(net_res) else 0.0, net_n)

    # --- flops_per_cycle: closed-form weighted LS through the origin ----
    fitted = net_params if net_obs else base
    if "flops_per_cycle" in fit_fields and comp_obs:
        # measured = flops * theta with theta = 1/flops_per_cycle:
        # theta* = sum(w * flops * measured) / sum(w * flops^2)
        num = sum(o.weight * o.flops * o.measured_cycles for o in comp_obs)
        den = sum(o.weight * o.flops * o.flops for o in comp_obs)
        fpc = den / num if num > 0 else base.flops_per_cycle
        fitted = dataclasses.replace(fitted, flops_per_cycle=fpc)
        comp_res, comp_n = _rel_residual(fitted, comp_obs)
        fields["flops_per_cycle"] = FieldFit(
            "flops_per_cycle", fpc, comp_res,
            1.0 / (1.0 + comp_res) if math.isfinite(comp_res) else 0.0,
            comp_n)

    # un-informed requested fields: keep base, confidence 0
    for name in fit_fields:
        if name not in fields:
            fields[name] = FieldFit(name, float(getattr(base, name)),
                                    math.inf, 0.0, 0)

    fitted = dataclasses.replace(fitted, name=f"{base.name}-cal")
    total_res, total_n = _rel_residual(fitted, list(net_obs) + list(comp_obs))
    return CalibratedParams(params=fitted, fields=fields,
                            residual=(total_res if math.isfinite(total_res)
                                      else math.inf),
                            n_obs=total_n)


def fit_report(cp: CalibratedParams,
               truth: Optional[SoCParams] = None) -> str:
    """Human-readable per-field table (the CLI's output)."""
    lines = [f"# calibrated: {cp.params.name} "
             f"(residual={cp.residual:.5f}, n_obs={cp.n_obs})",
             "# field,value,residual,confidence,n_obs" +
             (",truth" if truth else "")]
    for name in sorted(cp.fields):
        f = cp.fields[name]
        row = (f"{name},{f.value:g},{f.residual:.5f},"
               f"{f.confidence:.3f},{f.n_obs}")
        if truth is not None:
            row += f",{getattr(truth, name):g}"
        lines.append(row)
    return "\n".join(lines)
