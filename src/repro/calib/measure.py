"""Measurement ingestion for the calibration subsystem.

Everything the running system already measures — flit-level simulator
drains (``core/noc/simulator.py``, the cycle-accurate ground truth), bench
rows (``BENCH_noc.json``, best-of-N minima per the documented noise
convention), the socket's trace-time issue log, and dryrun/serve artifacts
— funnels into one typed :class:`Observation` record here.  ``calib.fit``
inverts the observations into :class:`~repro.core.noc.perfmodel.SoCParams`
fields; ``planner.refine_plan_from_measurements`` consumes them directly
(it reads the same field names duck-typed, so the socket's plain dicts and
these records are interchangeable).

The flit-sim forward model
--------------------------

:func:`flit_sim_cycles` maps a Fig. 6-style ``(fan_out, nbytes)``
experiment onto the flit-level mesh: the payload is framed into bursts of
``flits_per_burst`` payload flits (one header flit each — exactly the
framing ``SoCParams.burst_bytes``/``bitwidth`` imply), multicast from the
first accelerator tile to the next ``fan_out`` tiles, injected
back-to-back; the drained cycle count is charged ``link_latency`` per
simulator cycle (the simulator's hop costs one cycle, so the per-hop
latency scales the whole schedule uniformly).  This is the *forward
model* the fitter inverts for ``kind == "flit_sim"`` observations: burst
framing moves the header-flit count and the pipelining pattern,
``link_latency`` scales the drain — both leave a distinct, recoverable
signature.
"""

from __future__ import annotations

import dataclasses
import json
import re
from functools import lru_cache
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.noc.header import max_multicast_dests, mesh_coord_bits
from repro.core.noc.perfmodel import SoCParams, default_params
from repro.core.noc.simulator import MeshNoC, Message


@dataclasses.dataclass(frozen=True)
class Observation:
    """One timing (or conformance) measurement the calibration loop
    consumes.

    ``kind`` names the source family and selects the forward model the
    fitter prices it with:

    * ``"flit_sim"`` — a flit-level mesh drain of a ``(fan_out, nbytes)``
      experiment (:func:`flit_sim_cycles`); informs ``link_latency`` and
      ``burst_bytes``.
    * ``"compute"``  — cycles a known-FLOPs workload occupied; informs
      ``flops_per_cycle`` (``measured = flops / flops_per_cycle``).
    * ``"bench"``    — a ``BENCH_noc.json`` row (best-of-N minimum, with
      the run-to-run ``spread`` folded into ``weight``).
    * ``"issue"``    — a socket issue-log record: ``planned`` vs
      ``issued`` mode at a site; drives re-planning, not fitting.
    * ``"artifact"`` — lifted from a dryrun/serve artifact.

    ``weight`` scales the observation's residual in the least-squares
    objective (noisy bench rows are down-weighted by their spread)."""
    kind: str
    name: str
    measured_cycles: float = 0.0
    fan_out: int = 1
    nbytes: int = 0
    mode: str = "mcast"            # "mem" | "p2p" | "mcast" | "compute"
    modeled_cycles: Optional[float] = None
    flops: float = 0.0
    planned: Optional[str] = None
    issued: Optional[str] = None
    site: Optional[str] = None
    degraded_reason: Optional[str] = None
    weight: float = 1.0
    source: str = ""

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "Observation":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def observations_to_json(observations: Sequence[Observation], path: str
                         ) -> None:
    with open(path, "w") as f:
        json.dump([o.to_dict() for o in observations], f, indent=1)


def observations_from_json(path: str) -> List[Observation]:
    with open(path) as f:
        return [Observation.from_dict(d) for d in json.load(f)]


# ------------------------------------------------- flit-sim forward model

# Default experiment grid: small enough that a fit stays interactive, wide
# enough that burst framing and fan-out both leave a signature (sizes span
# 1..8 bursts at the default 4 KB framing).
DEFAULT_GRID: Tuple[Tuple[int, int], ...] = (
    (1, 4096), (2, 4096), (4, 8192), (4, 16384), (8, 32768))

DEFAULT_FLOPS_GRID: Tuple[int, ...] = (1 << 20, 1 << 22, 1 << 24)


def flit_sim_max_fan(params: SoCParams) -> int:
    """Largest fan-out the forward model can realize on this mesh: one
    consumer per distinct accelerator tile (the flit sim addresses tiles,
    not generators), within the header-flit destination capacity."""
    tiles = list(dict.fromkeys(params.accel_tiles()))
    cap = max_multicast_dests(
        params.bitwidth,
        coord_bits=mesh_coord_bits(params.mesh_w, params.mesh_h))
    return max(1, min(len(tiles) - 1, cap))


@lru_cache(maxsize=4096)
def _sim_unit_cycles(mesh_w: int, mesh_h: int, bitwidth: int,
                     mem_tile: Tuple[int, int], cpu_tile: Tuple[int, int],
                     io_tiles: Tuple[Tuple[int, int], ...],
                     accel_per_tile: int, n_accel: Optional[int],
                     flits_per_burst: int, n_bursts: int, fan_out: int
                     ) -> int:
    """Drain cycles at unit link latency (the simulator's hop = 1 cycle).
    Cached: the fitter's coordinate search re-prices the same framing many
    times, and the drain is deterministic in these arguments."""
    p = SoCParams(mesh_w=mesh_w, mesh_h=mesh_h, bitwidth=bitwidth,
                  mem_tile=mem_tile, cpu_tile=cpu_tile, io_tiles=io_tiles,
                  accel_per_tile=accel_per_tile, n_accel=n_accel)
    tiles = list(dict.fromkeys(p.accel_tiles()))
    prod, cons = tiles[0], tuple(tiles[1:1 + fan_out])
    noc = MeshNoC(mesh_w, mesh_h, bitwidth)
    for k in range(n_bursts):
        # back-to-back production: burst k enters the source queue as soon
        # as the producer could have serialized burst k-1
        noc.inject(Message(src=prod, dests=cons,
                           n_payload_flits=flits_per_burst,
                           inject_cycle=k * flits_per_burst))
    return noc.drain()


def flit_sim_cycles(params: SoCParams, fan_out: int, nbytes: int) -> float:
    """The forward model for ``kind == "flit_sim"`` observations: drained
    cycles of the ``(fan_out, nbytes)`` experiment on this mesh, at this
    burst framing, charged ``link_latency`` per simulator cycle."""
    fan = min(max(fan_out, 1), flit_sim_max_fan(params))
    n_bursts = max(1, nbytes // params.burst_bytes)
    unit = _sim_unit_cycles(
        params.mesh_w, params.mesh_h, params.bitwidth,
        tuple(params.mem_tile), tuple(params.cpu_tile),
        tuple(tuple(t) for t in params.io_tiles),
        params.accel_per_tile, params.n_accel,
        params.flits_per_burst, n_bursts, fan)
    return float(params.link_latency) * unit


def flit_sim_observations(params: Optional[SoCParams] = None,
                          grid: Sequence[Tuple[int, int]] = DEFAULT_GRID,
                          noise: float = 0.0, seed: int = 0,
                          ) -> List[Observation]:
    """Measure the ``(fan_out, nbytes)`` grid on the flit-level mesh under
    ``params`` (ground truth when synthesizing for a round-trip test; the
    live profile when self-checking the model).  ``noise`` applies a
    deterministic multiplicative jitter (``random.Random(seed)``) so the
    fit's robustness is exercised without nondeterminism."""
    import random
    p = params or default_params()
    rng = random.Random(seed)
    out = []
    for fan, nbytes in grid:
        fan = min(fan, flit_sim_max_fan(p))
        cycles = flit_sim_cycles(p, fan, nbytes)
        if noise:
            cycles *= 1.0 + rng.uniform(-noise, noise)
        out.append(Observation(
            kind="flit_sim", name=f"flit_sim_n{fan}_b{nbytes}",
            fan_out=fan, nbytes=nbytes, mode="mcast",
            measured_cycles=cycles,
            source=f"simulator:{p.mesh_w}x{p.mesh_h}"))
    return out


def compute_observations(params: Optional[SoCParams] = None,
                         flops_grid: Sequence[int] = DEFAULT_FLOPS_GRID,
                         noise: float = 0.0, seed: int = 0
                         ) -> List[Observation]:
    """Known-FLOPs workload timings (``measured = flops /
    flops_per_cycle``): the compute side of the overlap objective, fitted
    independently of the network observations."""
    import random
    p = params or default_params()
    rng = random.Random(seed + 1)
    out = []
    for flops in flops_grid:
        cycles = float(flops) / p.flops_per_cycle
        if noise:
            cycles *= 1.0 + rng.uniform(-noise, noise)
        out.append(Observation(
            kind="compute", name=f"compute_f{flops}", flops=float(flops),
            mode="compute", measured_cycles=cycles,
            source=f"flops_per_cycle:{p.name}"))
    return out


# --------------------------------------------------------- row ingestion

# BENCH_noc.json rows whose derived field carries a cycle count (the NoC
# microbenches record "…;cycles=N;…" and fan=N where applicable)
_DERIVED_CYCLES = re.compile(r"(?:^|;)cycles=(\d+)")
_DERIVED_FAN = re.compile(r"(?:^|;)fan=(\d+)")


def observations_from_bench(rows: Dict[str, Dict],
                            params: Optional[SoCParams] = None
                            ) -> List[Observation]:
    """Lift ``BENCH_noc.json`` rows into observations.

    Rows follow the documented noise convention (``docs/perfmodel.md``):
    ``us_per_call`` is a best-of-N minimum and ``spread`` the max-min
    run-to-run wall-clock spread of those samples, in µs.  The spread
    down-weights the observation (``weight = 1 / (1 + spread/us)``) so a
    jittery box cannot drag the fit.  Rows whose ``derived`` string
    records a simulator cycle count keep it as ``measured_cycles``; for
    the rest, wall microseconds are converted on the modeled clock
    (``freq_mhz``)."""
    p = params or default_params()
    out = []
    for name, entry in sorted(rows.items()):
        us = entry.get("us_per_call")
        if us is None:
            continue
        spread = float(entry.get("spread") or 0.0)
        weight = 1.0 / (1.0 + (spread / us if us > 0 else 0.0))
        derived = str(entry.get("derived", ""))
        m_cycles = _DERIVED_CYCLES.search(derived)
        m_fan = _DERIVED_FAN.search(derived)
        out.append(Observation(
            kind="bench", name=name,
            measured_cycles=(float(m_cycles.group(1)) if m_cycles
                             else float(us) * p.freq_mhz),
            fan_out=int(m_fan.group(1)) if m_fan else 1,
            mode="mcast", weight=weight, source="BENCH_noc.json"))
    return out


def observations_from_issue_log(records: Iterable[Dict]
                                ) -> List[Observation]:
    """Lift ``socket.issue_observations()`` dicts into typed records (the
    planner consumes either form; the typed form serializes uniformly
    into calibration artifacts)."""
    return [Observation.from_dict(r) for r in records]


def observations_from_artifact(artifact: Dict) -> List[Observation]:
    """Lift a dryrun/serve artifact's per-site issue log
    (``comm_issued``) into issue observations — the planned-vs-issued
    conformance record the re-pricing pass consumes.  Tolerant of absent
    fields: artifacts predating the calibration subsystem yield []."""
    out = []
    for site, entry in sorted((artifact.get("comm_issued") or {}).items()):
        out.append(Observation(
            kind="artifact", name=entry.get("tensor", site), site=site,
            planned=entry.get("planned"), issued=entry.get("issued"),
            nbytes=int(entry.get("nbytes") or 0),
            degraded_reason=(entry.get("degraded_reason")
                             if entry.get("degraded_reason") is not None
                             else entry.get("degraded")),
            source=f"artifact:{artifact.get('arch', '?')}"))
    return out
