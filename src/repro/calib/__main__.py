"""Calibration CLI.

``python -m repro.calib fit``
    Round-trip calibration smoke: synthesize timings from a ground-truth
    ``SoCParams`` via the flit simulator (optionally with deterministic
    seeded noise), fit from a deliberately wrong starting point, print the
    per-field recovery table, and exit nonzero when the residual exceeds
    ``--max-residual`` or a grid-covered field was not recovered exactly.
    This is the CI calibration gate (scripts/ci.sh).

``python -m repro.calib fit --from-bench BENCH_noc.json``
    Ingest bench rows (best-of-N minima, spread-weighted) alongside the
    flit-sim grid instead of pure synthesis.

``python -m repro.calib sweep``
    Design-space sweep for a named config: ``SoCParams`` grid (mesh size x
    link latency x burst profile) -> modeled step cycles vs the Fig. 4
    area cost proxy; writes the frontier artifact and prints the Pareto
    set.  Exits nonzero if the Pareto set is empty (it never is for a
    well-formed grid — the check keeps the CI smoke honest).

See ``docs/calibration.md`` for the workflow.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

from repro.core.noc.perfmodel import SoCParams

from repro.calib import fit as fitmod
from repro.calib import measure, sweep as sweepmod


def _cmd_fit(args: argparse.Namespace) -> int:
    w, h = args.mesh
    if (w, h) == (4, 3):
        truth = SoCParams(link_latency=args.truth_link,
                          burst_bytes=args.truth_burst,
                          flops_per_cycle=args.truth_fpc)
    else:
        truth = SoCParams.pod(w, h, link_latency=args.truth_link,
                              burst_bytes=args.truth_burst,
                              flops_per_cycle=args.truth_fpc)
    obs = measure.flit_sim_observations(truth, noise=args.noise,
                                        seed=args.seed)
    obs += measure.compute_observations(truth, noise=args.noise,
                                        seed=args.seed)
    if args.from_bench:
        with open(args.from_bench) as f:
            obs += measure.observations_from_bench(json.load(f), truth)
    # deliberately wrong starting point: calibration must *recover* the
    # truth, not inherit it
    base = dataclasses.replace(
        truth, link_latency=1, burst_bytes=4096, flops_per_cycle=8192.0,
        name=truth.name)
    cp = fitmod.fit_soc_params(obs, base=base)
    print(fitmod.fit_report(cp, truth=truth))
    if args.json:
        cp.to_json(args.json)
        print(f"# wrote {args.json}")
    ok = cp.residual <= args.max_residual
    # grid-covered discrete fields must land exactly (see docs tolerance)
    ok &= cp.params.link_latency == truth.link_latency
    ok &= cp.params.burst_bytes == truth.burst_bytes
    rel_fpc = (abs(cp.params.flops_per_cycle - truth.flops_per_cycle)
               / truth.flops_per_cycle)
    ok &= rel_fpc <= max(args.max_residual, 1e-9)
    print(f"# fit {'OK' if ok else 'FAIL'}: residual={cp.residual:.5f} "
          f"(max {args.max_residual}), fpc_err={rel_fpc:.5f}")
    return 0 if ok else 1


def _cmd_sweep(args: argparse.Namespace) -> int:
    meshes = tuple(tuple(int(v) for v in m.split("x"))
                   for m in args.meshes.split(","))
    lats = tuple(int(v) for v in args.link_latencies.split(","))
    profiles = tuple((f"burst{b // 1024}k", b)
                     for b in (int(v) for v in args.bursts.split(",")))
    cands = sweepmod.design_grid(meshes, lats, profiles)
    points = sweepmod.sweep_design_space(args.arch, args.shape,
                                         candidates=cands)
    out = args.out or (f"experiments/calib/"
                       f"sweep_{args.arch}_{args.shape}.json")
    sweepmod.write_frontier(points, out, arch=args.arch,
                            shape_name=args.shape)
    front = sweepmod.pareto_front(points)
    print(f"# {len(points)} design points, {len(front)} on the Pareto "
          f"frontier -> {out}")
    print("# name,cycles,cost_um2,mode_mix")
    for p in front:
        mix = "/".join(f"{k}:{v}" for k, v in sorted(p["mode_mix"].items())
                       if v)
        print(f"{p['name']},{p['cycles']:.0f},{p['cost_um2']:.0f},{mix}")
    if not front:
        print("# sweep FAIL: empty Pareto set")
        return 1
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.calib",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    f = sub.add_parser("fit", help="round-trip calibration smoke / fitter")
    f.add_argument("--mesh", nargs=2, type=int, default=(4, 3),
                   metavar=("W", "H"))
    f.add_argument("--truth-link", type=int, default=2)
    f.add_argument("--truth-burst", type=int, default=8192)
    f.add_argument("--truth-fpc", type=float, default=4096.0)
    f.add_argument("--noise", type=float, default=0.0,
                   help="deterministic multiplicative jitter on synthesized "
                        "timings (fraction; seeded)")
    f.add_argument("--seed", type=int, default=0)
    f.add_argument("--max-residual", type=float, default=0.1)
    f.add_argument("--from-bench", default=None,
                   help="also ingest BENCH_noc.json rows")
    f.add_argument("--json", default=None,
                   help="write the CalibratedParams artifact here")
    f.set_defaults(fn=_cmd_fit)

    s = sub.add_parser("sweep", help="design-space sweep -> Pareto frontier")
    s.add_argument("--arch", default="dbrx-132b")
    s.add_argument("--shape", default="train_4k")
    s.add_argument("--meshes", default="4x3,8x8,16x16")
    s.add_argument("--link-latencies", default="1,2,4")
    s.add_argument("--bursts", default="4096,8192,16384")
    s.add_argument("--out", default=None)
    s.set_defaults(fn=_cmd_sweep)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
