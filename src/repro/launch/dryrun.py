import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**specs).compile()`` must succeed on the
single-pod (16, 16) mesh and the 2-pod (2, 16, 16) mesh for every assigned
architecture and shape.  ``memory_analysis()`` proves the per-device working
set fits; ``cost_analysis()`` + HLO collective parsing feed the roofline
(EXPERIMENTS.md §Roofline).

The XLA_FLAGS line above must execute before any other jax-touching import:
jax locks the device count on first initialization.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import dataclasses
import functools
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import (ARCH_NAMES, SHAPES, get_config, shape_applicable)
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.comm import CommMode
from repro.core import socket as socket_mod
from repro.core.planner import (comm_overlap_fraction, mode_mix,
                                modeled_step_cycles, refine_plan_from_hlo,
                                resolve_policy)
from repro.launch.mesh import make_production_mesh, PEAK_FLOPS_BF16
from repro.launch import hlo_analysis
from repro.models import transformer as T
from repro.runtime.train import (TRAIN_RULES, SERVE_RULES, make_train_step,
                                 init_state, train_shardings)
from repro.runtime.serve import serve_shardings, make_prefill_step, make_decode_step


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6*N_active*D for train (D = tokens), 2*N_active per
    decoded token, plus exact-ish attention terms."""
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    attn_layers = [k for k in cfg.block_kinds() if k in ("attn", "swa")]

    def attn_flops_train():
        total = 0.0
        for k in attn_layers:
            w = cfg.local_window if k == "swa" else 0
            eff = min(w, S) if w else S
            # qk + pv, causal ~ S*eff/2 pairs, x3 for fwd+bwd
            total += 6.0 * B * cfg.n_heads * hd * S * (eff if w else S / 2) * 2
        return total

    if shape.kind == "train":
        return 6.0 * n_active * B * S + attn_flops_train()
    if shape.kind == "prefill":
        total = 2.0 * n_active * B * S
        for k in attn_layers:
            w = cfg.local_window if k == "swa" else 0
            eff = min(w, S) if w else S
            total += 2.0 * B * cfg.n_heads * hd * S * (eff if w else S / 2) * 2
        return total
    # decode: one token against a seq_len cache
    total = 2.0 * n_active * B
    for k in attn_layers:
        w = cfg.local_window if k == "swa" else 0
        skv = min(w, S) if w else S
        total += 4.0 * B * cfg.n_heads * hd * skv
    return total


_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def make_flags(cfg: ArchConfig, shape: ShapeConfig, *, moe_mode="mem",
               remat="full", attn_chunk=512, param_dtype="f32",
               opt_dtype="f32") -> T.RunFlags:
    if shape.kind == "train":
        # flash (custom-vjp blockwise) attention: no S^2 materialization in
        # either direction, no scan-residual stacking
        return T.RunFlags(param_dtype=_DTYPES[param_dtype],
                          opt_dtype=_DTYPES[opt_dtype], remat=remat,
                          moe_mode=moe_mode, distributed=True,
                          attn_impl="flash", attn_chunk=attn_chunk)
    # no-grad serving: blockwise pair-scan keeps 32k prefill in VMEM budget
    return T.RunFlags(param_dtype=jnp.bfloat16, remat="none",
                      moe_mode=moe_mode, distributed=True,
                      attn_impl="blockwise", attn_chunk=attn_chunk)


def _base_rules(shape: ShapeConfig, rules_train=None, rules_serve=None):
    """The sharding-rule table a cell lowers under — the single train-vs-
    serve dispatch both ``lower_cell`` and the feedback loop consult."""
    return dict((rules_train or TRAIN_RULES) if shape.kind == "train"
                else (rules_serve or SERVE_RULES))


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, flags: T.RunFlags,
               rules_train=None, rules_serve=None, comm_plan=None):
    """Returns (lowered, meta).  No device memory is allocated: all inputs
    are ShapeDtypeStructs.  ``comm_plan`` (optional CommPlan) reaches every
    collective site through the step factories."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        rules = _base_rules(shape, rules_train, rules_serve)
        step, state_sh, batch_sh = make_train_step(cfg, flags, mesh, rules,
                                                   batch_shape=(B, S),
                                                   comm_plan=comm_plan)
        state_specs = jax.eval_shape(
            lambda: init_state(jax.random.key(0), cfg, flags))
        batch_specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
        return fn.lower(state_specs, batch_specs), {"step": "train_step"}

    rules = _base_rules(shape, rules_train, rules_serve)
    params_specs = jax.eval_shape(
        lambda: T.init_params(jax.random.key(0), cfg, flags.param_dtype))
    param_sh, cache_sh, tok_sh = serve_shardings(cfg, mesh, B, S, rules,
                                                 flags.param_dtype)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, flags, mesh, rules, comm_plan=comm_plan)
        tok_specs = jax.ShapeDtypeStruct((B, S), jnp.int32)
        fn = jax.jit(step, in_shardings=(param_sh, tok_sh))
        return fn.lower(params_specs, tok_specs), {"step": "prefill_step"}

    # decode: one new token against a pre-filled cache of seq_len
    step = make_decode_step(cfg, flags, mesh, rules, comm_plan=comm_plan)
    cache_specs = T.make_cache(cfg, B, S, flags.cache_dtype, as_specs=True)
    tok_specs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_specs = jax.ShapeDtypeStruct((), jnp.int32)
    fn = jax.jit(step, in_shardings=(param_sh, tok_sh, None, cache_sh),
                 out_shardings=(None, cache_sh), donate_argnums=(3,))
    return fn.lower(params_specs, tok_specs, pos_specs, cache_specs), \
        {"step": "serve_step"}


def build_comm_plan(policy: str, cfg: ArchConfig, shape: ShapeConfig, mesh,
                    hlo_text=None, noc_profile: str = "espsoc-3x4"):
    """Resolve a --comm-plan policy against a concrete mesh: ``manual``
    keeps the legacy flag-driven behaviour; ``auto`` prices the step's
    transfers with the NoC cost model (from the compiled module's own
    collectives when ``hlo_text`` is given; on the ``noc_profile`` link
    parameters — pod-scale profiles in configs.espsoc_trafficgen.PROFILES);
    ``mem``/``mcast`` are the constant baselines the benchmark compares
    against.  The rule-overlay feedback path goes through
    ``planner.refine_plan_from_hlo`` instead (see ``run_cell``)."""
    from repro.configs.espsoc_trafficgen import noc_model
    return resolve_policy(policy, cfg, shape, dict(mesh.shape),
                          hlo_text=hlo_text, model=noc_model(noc_profile))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             moe_mode: str = "mem", remat: str = "full",
             attn_chunk: int = 512, rules_train=None, rules_serve=None,
             param_dtype: str = "f32", opt_dtype: str = "f32",
             comm_plan: str = "manual", noc_profile: str = "espsoc-3x4",
             calibrate: bool = False, verbose: bool = True
             ) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    plan, decisions = build_comm_plan(comm_plan, cfg, shape, mesh,
                                      noc_profile=noc_profile)
    if plan is not None and cfg.moe is not None:
        # keep the recorded moe_mode coherent with what the plan selects
        moe_mode = ("mem" if plan.mode("moe_dispatch") is CommMode.MEM
                    else "mcast")
    flags = make_flags(cfg, shape, moe_mode=moe_mode, remat=remat,
                       attn_chunk=attn_chunk, param_dtype=param_dtype,
                       opt_dtype=opt_dtype)
    t0 = time.monotonic()
    socket_mod.reset_issue_log()   # capture the *issued* modes of this trace
    lowered, meta = lower_cell(cfg, shape, mesh, flags, rules_train,
                               rules_serve, comm_plan=plan)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    # --comm-plan=auto phase 2: re-price from the *compiled* module's own
    # collective ops (ground truth for fan-out/bytes, one spec per layer),
    # then close the loop into the sharding rules: resolve_rules rewrites
    # the axis table from the per-layer plan (e.g. w_fsdp off when the
    # weight all-gather prices to MCAST).  If the rules changed or a mode
    # the step consults changed, relower ONCE with the resolved rules +
    # refined plan — no further feedback iteration (once-iff-changed).
    replanned = False
    overlay = {}
    cycles_static = cycles_resolved = cycles_serial = None
    overlap_frac = None
    replan_events = None
    if comm_plan == "auto" and plan is not None:
        from repro.configs.espsoc_trafficgen import noc_model
        from repro.core.sharding import resolve_rules
        base_rules = _base_rules(shape, rules_train, rules_serve)
        plan2, decisions2, rules_resolved, overlay, rebuild = \
            refine_plan_from_hlo(plan, cfg, shape, dict(mesh.shape),
                                 compiled.as_text(),
                                 lambda p: resolve_rules(p, base_rules),
                                 model=noc_model(noc_profile))
        cycles_static = modeled_step_cycles(decisions2, base_rules)
        cycles_resolved = modeled_step_cycles(decisions2, rules_resolved)
        # the overlap objective's win over serial compute-waits-for-comm
        # pricing, for the SAME decisions and resolved rules — plus the
        # fraction of comm cycles hidden behind the compute they feed
        cycles_serial = modeled_step_cycles(decisions2, rules_resolved,
                                            objective="serial")
        overlap_frac = comm_overlap_fraction(decisions2, rules_resolved)
        # every decision the HLO ground truth flipped vs the estimate plan
        # — the same machine-readable record the elastic re-mesh path
        # appends to FaultTolerantRunner.comm_replan_events
        from repro.core.planner import plan_decision_flips
        replan_events = [dict(f, cause="hlo_refine")
                         for f in plan_decision_flips(plan, plan2)]
        plan, decisions = plan2, decisions2
        if rebuild:
            replanned = True
            if overlay:
                if shape.kind == "train":
                    rules_train = rules_resolved
                else:
                    rules_serve = rules_resolved
            if cfg.moe is not None:
                moe_mode = ("mem" if plan.mode("moe_dispatch") is CommMode.MEM
                            else "mcast")
                flags = make_flags(cfg, shape, moe_mode=moe_mode, remat=remat,
                                   attn_chunk=attn_chunk,
                                   param_dtype=param_dtype,
                                   opt_dtype=opt_dtype)
            t0 = time.monotonic()
            # re-capture: the artifact reports the FINAL step's issued modes
            socket_mod.reset_issue_log()
            lowered, meta = lower_cell(cfg, shape, mesh, flags, rules_train,
                                       rules_serve, comm_plan=plan)
            compiled = lowered.compile()
            t_compile += time.monotonic() - t0

    # --calibrate: a calibration is a re-plan (symmetric with the re-mesh
    # and hlo_refine paths).  Fit the live profile's SoCParams from a
    # seeded flit-sim run of the standard experiment grid (self-check:
    # residual ~ the noise floor when the closed forms and the flit fabric
    # agree), then re-price plan entries from the socket's issued-vs-
    # planned trace; every flip lands in comm_replan_events with its own
    # cause, exactly like the hlo_refine events above.
    calibration = None
    if calibrate and comm_plan == "auto" and plan is not None:
        from repro.calib import fit as calib_fit, measure
        from repro.configs.espsoc_trafficgen import noc_model
        from repro.core.noc.perfmodel import SoCParams
        from repro.core.planner import refine_plan_from_measurements
        model = noc_model(noc_profile)
        params = model.p if model is not None else SoCParams()
        sim_obs = (measure.flit_sim_observations(params) +
                   measure.compute_observations(params))
        cp = calib_fit.fit_soc_params(sim_obs, base=params)
        issue_obs = measure.observations_from_issue_log(
            socket_mod.issue_observations(plan))
        plan, calib_flips = refine_plan_from_measurements(
            plan, issue_obs, decisions=decisions)
        calibration = cp.summary()
        replan_events = (replan_events or []) + calib_flips
        if verbose:
            print(f"--calibrate: residual={cp.residual:.5f} "
                  f"({len(sim_obs)} sim obs, {len(issue_obs)} issue obs, "
                  f"{len(calib_flips)} plan flips)")

    ma = compiled.memory_analysis()
    ma_peak = compat.peak_memory_in_bytes(ma)
    mf = model_flops(cfg, shape)
    roof = hlo_analysis.analyze(compiled, model_flops_total=mf,
                                n_chips=n_chips)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": meta["step"],
        "moe_mode": moe_mode if cfg.moe else None,
        "comm_plan": ({name: plan.mode(name).name
                       for name in plan.modes} if plan is not None else None),
        "comm_plan_policy": comm_plan,
        "comm_plan_hlo_refined": (replanned if comm_plan == "auto" else None),
        # decision flips between the estimate plan and the plan in force
        # after re-planning (HLO refine here; shrink_mesh recovery appends
        # its flips to the runner's comm_replan_events the same way)
        "comm_replan_events": (replan_events
                               if comm_plan == "auto" else None),
        # --calibrate: the CalibratedParams artifact (per-field fit
        # diagnostics) for this cell's NoC profile; None when not requested
        "calibration": calibration,
        # planner -> sharding feedback: the axis rules the plan rewrote
        # (e.g. {"w_fsdp": null} when weights broadcast on MCAST) and the
        # modeled step cost under static vs resolved rules
        "comm_rule_overlay": (overlay or None) if comm_plan == "auto" else None,
        "comm_plan_static_cycles": cycles_static,
        "comm_plan_resolved_cycles": cycles_resolved,
        # overlap objective: resolved-rule cycles under serial pricing
        # (compute waits for comm) vs the default overlapped pricing, and
        # the fraction of comm cycles hidden behind the compute they feed
        "comm_plan_serial_cycles": cycles_serial,
        "comm_overlap_fraction": overlap_frac,
        "comm_plan_layer_mix": (mode_mix(decisions)
                                if decisions is not None else None),
        # per-site *issued* modes from the socket's trace-time issue log:
        # what each migrated call site actually dispatched (vs planned) in
        # the step the artifact describes
        "comm_issued": socket_mod.issued_modes() or None,
        "comm_issued_matches_plan": (
            socket_mod.issued_matches_plan(plan) if plan is not None
            else None),
        "comm_plan_decisions": ([
            {"tensor": d.spec.name, "layer": d.spec.layer,
             "fan_out": d.spec.fan_out,
             "nbytes": d.spec.nbytes, "mode": d.mode.name,
             "speedup_vs_mem": round(d.speedup_vs_mem, 3),
             "fused": d.fused, "streamed": d.streamed,
             "compute_cycles": round(d.compute_cycles, 1),
             "reason": d.reason} for d in decisions]
            if decisions is not None else None),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_dev": ma.argument_size_in_bytes,
            "output_bytes_per_dev": ma.output_size_in_bytes,
            "temp_bytes_per_dev": ma.temp_size_in_bytes,
            "peak_bytes_per_dev": ma_peak,
            "alias_bytes_per_dev": ma.alias_size_in_bytes,
            # XLA's memory_analysis misses while-carried buffers (verified);
            # peak_bytes_est adds the deepest live while-carry chain.
            "peak_bytes_est_per_dev": roof.peak_bytes_est,
            "fits_16gb": bool(max(ma_peak, roof.peak_bytes_est) < 16e9),
        },
        "roofline": {
            "flops_per_dev": roof.flops_per_dev,
            "hbm_bytes_per_dev": roof.hbm_bytes_per_dev,
            "wire_bytes_per_dev": roof.wire_bytes_per_dev,
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "model_flops_total": mf,
            "model_flops_per_dev": roof.model_flops_per_dev,
            "useful_flops_ratio": roof.useful_flops_ratio,
            "roofline_fraction": roof.roofline_fraction(),
        },
        "collectives": roof.collectives,
    }
    if verbose:
        if comm_plan == "auto" and decisions is not None:
            mix = ",".join(f"{k}:{v}" for k, v in
                           result["comm_plan_layer_mix"].items())
            delta = (f"; step cycles {cycles_static:.0f} -> "
                     f"{cycles_resolved:.0f} "
                     f"({cycles_static / max(cycles_resolved, 1e-9):.2f}x)"
                     if overlay else "")
            print(f"[{result['mesh']}] {arch} x {shape_name}: comm-plan "
                  f"mix [{mix}] overlay={overlay or '{}'}{delta}")
            if cycles_serial is not None:
                print(f"[{result['mesh']}] {arch} x {shape_name}: overlap "
                      f"objective {cycles_serial:.3g} -> "
                      f"{cycles_resolved:.3g} cycles "
                      f"({cycles_serial / max(cycles_resolved, 1e-9):.2f}x "
                      f"vs serial; {overlap_frac:.1%} of comm hidden)")
            issued = result["comm_issued"] or {}
            sites = ",".join(f"{s}:{v['issued']}" for s, v in issued.items())
            print(f"[{result['mesh']}] {arch} x {shape_name}: issued "
                  f"[{sites}] matches_plan="
                  f"{result['comm_issued_matches_plan']}")
            if result["comm_issued_matches_plan"] is False:
                # name the offenders instead of silently recording the flag
                for mm in socket_mod.mismatched_sites(plan):
                    print(f"[{result['mesh']}] {arch} x {shape_name}: "
                          f"ISSUED != PLANNED at {mm['site']} "
                          f"({mm['tensor']}: planned {mm['planned']}, "
                          f"issued {mm['issued']})")
        r = result["roofline"]
        print(f"[{result['mesh']}] {arch} x {shape_name} ({meta['step']}): "
              f"compile {t_compile:.1f}s | "
              f"peak/dev ~{roof.peak_bytes_est/2**30:.2f} GiB | "
              f"compute {r['compute_s']*1e3:.2f}ms "
              f"memory {r['memory_s']*1e3:.2f}ms "
              f"collective {r['collective_s']*1e3:.2f}ms "
              f"-> {r['dominant']}-bound | useful-FLOPs "
              f"{r['useful_flops_ratio']:.2f} | roofline frac "
              f"{r['roofline_fraction']:.3f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-mode", default="mem", choices=("mem", "mcast"))
    ap.add_argument("--comm-plan", default="manual",
                    choices=("manual", "auto", "mem", "mcast"),
                    help="communication-mode policy: 'manual' follows "
                         "--moe-mode; 'auto' lets the NoC cost model pick "
                         "per transfer; 'mem'/'mcast' force one mode "
                         "everywhere (benchmark baselines)")
    ap.add_argument("--noc-profile", default="espsoc-3x4",
                    help="NoC cost-model profile for --comm-plan=auto "
                         "(espsoc-3x4 | pod-8x8 | pod-16x16; see "
                         "configs.espsoc_trafficgen.PROFILES)")
    ap.add_argument("--calibrate", action="store_true",
                    help="with --comm-plan=auto: fit SoCParams from a "
                         "seeded flit-sim run, re-price plan entries from "
                         "the socket's issued-vs-planned trace, and record "
                         "the CalibratedParams artifact + plan flips in "
                         "the output (docs/calibration.md)")
    ap.add_argument("--remat", default="full",
                    choices=("none", "full", "save_collectives"))
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--param-dtype", default="f32", choices=("f32", "bf16"))
    ap.add_argument("--opt-dtype", default="f32", choices=("f32", "bf16"))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            try:
                res = run_cell(arch, shape, multi_pod=multi_pod,
                               moe_mode=args.moe_mode, remat=args.remat,
                               attn_chunk=args.attn_chunk,
                               param_dtype=args.param_dtype,
                               opt_dtype=args.opt_dtype,
                               comm_plan=args.comm_plan,
                               noc_profile=args.noc_profile,
                               calibrate=args.calibrate)
            except Exception as e:  # a failing cell is a bug in the system
                failures.append((arch, shape, multi_pod, repr(e)))
                print(f"FAIL [{'2x16x16' if multi_pod else '16x16'}] "
                      f"{arch} x {shape}: {e!r}")
                continue
            tag = ("_" + args.tag) if args.tag else ""
            if args.comm_plan != "manual":
                tag = f"_{args.comm_plan}plan" + tag
            mode = f"_{res['moe_mode']}" if res.get("moe_mode") else ""
            fname = (f"{arch}_{shape}_{res.get('mesh', 'skip')}"
                     f"{mode}{tag}.json")
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(res, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f4 in failures:
            print("  ", f4)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
