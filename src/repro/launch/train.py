"""End-to-end training driver with fault tolerance.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --preset reduced --steps 300 --ckpt /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --preset full --global-batch 8 --seq 512 --steps 100

On real hardware the same driver runs under the production mesh: pass
--mesh single|multi to shard with make_production_mesh (requires the
matching device count; on this CPU container use the default --mesh none).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.configs.base import ShapeConfig
from repro.configs.espsoc_trafficgen import noc_model
from repro.core import socket as socket_mod
from repro.core.planner import (plan_summary_lines, refine_plan_from_hlo,
                                resolve_policy)
from repro.data import SyntheticTokenStream
from repro.models.transformer import RunFlags
from repro.runtime.fault import (FaultTolerantRunner, FaultError,
                                 replan_for_mesh, shrink_mesh)
from repro.runtime.train import (make_train_step, init_state,
                                 resolved_train_rules)
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_NAMES)
    ap.add_argument("--preset", default="reduced", choices=("reduced", "full"))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none", choices=("none", "single", "multi"))
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a node failure at this step (demo)")
    ap.add_argument("--elastic-drop", type=int, default=0,
                    help="with --inject-failure-at: treat the failure as "
                         "losing this many devices — shrink_mesh onto the "
                         "survivors, re-plan the comm modes on the new "
                         "topology (re-mesh => re-plan), rebuild the step, "
                         "and restore onto it")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--comm-plan", default="manual",
                    choices=("manual", "auto", "mem", "mcast"),
                    help="per-transfer communication-mode policy (auto = "
                         "NoC cost model picks; see core.planner)")
    ap.add_argument("--noc-profile", default="espsoc-3x4",
                    help="NoC cost-model profile for --comm-plan=auto "
                         "(espsoc-3x4 | pod-8x8 | pod-16x16)")
    ap.add_argument("--calibrate", action="store_true",
                    help="after the run: fit SoCParams from flit-sim "
                         "timings, re-price the plan from the issued "
                         "record (a calibration is a re-plan; see "
                         "docs/calibration.md)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.preset == "full" else \
        get_reduced(args.arch)
    flags = RunFlags(remat="none" if args.preset == "reduced" else "full")
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    shape = ShapeConfig("train_cli", args.seq, args.global_batch, "train")
    mesh_axes = dict(mesh.shape) if mesh is not None else {}
    model = noc_model(args.noc_profile)
    plan, decisions = resolve_policy(args.comm_plan, cfg, shape, mesh_axes,
                                     model=model)

    step_fn, state_sh, _ = make_train_step(
        cfg, flags, mesh, lr=args.lr, total_steps=args.steps,
        batch_shape=(args.global_batch, args.seq), comm_plan=plan)
    jstep = jax.jit(step_fn, donate_argnums=0)

    if args.comm_plan == "auto" and mesh is not None:
        # price from the compiled step's own collectives (fan-out/bytes from
        # the lowered ops, not the config estimates); rebuild the step only
        # if the refined plan disagrees, else run the already-compiled
        # executable — no second XLA compile
        state_specs = jax.eval_shape(
            lambda: init_state(jax.random.key(0), cfg, flags))
        batch_specs = {
            "tokens": jax.ShapeDtypeStruct(
                (args.global_batch, args.seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (args.global_batch, args.seq), jnp.int32),
        }
        socket_mod.reset_issue_log()
        compiled = jstep.lower(state_specs, batch_specs).compile()
        # planner -> sharding feedback: re-price per layer from the
        # compiled HLO, rewrite the rule table (e.g. w_fsdp off when
        # weights broadcast on MCAST), rebuild the step once iff changed
        plan, decisions, rules, overlay, rebuild = refine_plan_from_hlo(
            plan, cfg, shape, mesh_axes, compiled.as_text(),
            resolved_train_rules, model=model)
        if rebuild:
            if overlay:
                print(f"comm-plan: rule overlay {overlay} applied; "
                      "rebuilding the step")
            else:
                print("comm-plan: HLO-derived pricing changed the plan; "
                      "rebuilding the step")
            step_fn, state_sh, _ = make_train_step(
                cfg, flags, mesh, rules=rules, lr=args.lr,
                total_steps=args.steps,
                batch_shape=(args.global_batch, args.seq), comm_plan=plan)
            jstep = jax.jit(step_fn, donate_argnums=0)
            # the rebuilt step traces at its first call: drop the
            # discarded step's issue records so the post-run issued
            # summary describes the step that actually ran
            socket_mod.reset_issue_log()
        else:
            jstep = compiled
    for line in plan_summary_lines(decisions or ()):
        print(line)
    state = init_state(jax.random.key(0), cfg, flags)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.global_batch}x{args.seq}")

    stream = SyntheticTokenStream(cfg.vocab_size, args.global_batch, args.seq)
    batches = lambda s: {k: jnp.asarray(v) for k, v in stream.batch(s).items()}

    remesh_hook = None
    if args.elastic_drop > 0 and mesh is not None:
        def remesh_hook(at_step, err):
            nonlocal mesh, plan, decisions
            survivors = list(mesh.devices.flat)[: -args.elastic_drop]
            model_parallel = dict(mesh.shape).get("model", 1)
            new_mesh = shrink_mesh(survivors, model_parallel)
            new_axes = dict(new_mesh.shape)
            # re-mesh => re-plan: re-price on the survivor topology and
            # re-resolve the rule overlay; with --comm-plan=auto, refine
            # from the relowered step's own HLO (same feedback loop as
            # launch, now inside the recovery path)
            new_plan, new_dec, rules, _, flips = replan_for_mesh(
                plan, cfg, shape, new_axes, resolve=resolved_train_rules,
                model=model)
            sfn, sh, _ = make_train_step(
                cfg, flags, new_mesh, rules=rules, lr=args.lr,
                total_steps=args.steps,
                batch_shape=(args.global_batch, args.seq),
                comm_plan=new_plan)
            jfn = jax.jit(sfn, donate_argnums=0)
            if args.comm_plan == "auto":
                state_specs = jax.eval_shape(
                    lambda: init_state(jax.random.key(0), cfg, flags))
                batch_specs = {
                    k: jax.ShapeDtypeStruct(
                        (args.global_batch, args.seq), jnp.int32)
                    for k in ("tokens", "labels")}
                hlo = jfn.lower(state_specs, batch_specs).compile().as_text()
                ref_plan, new_dec, rules, _, flips = replan_for_mesh(
                    plan, cfg, shape, new_axes,
                    hlo_text=hlo, resolve=resolved_train_rules, model=model)
                if any(ref_plan.mode(k) is not new_plan.mode(k)
                       for k in new_plan.modes):
                    sfn, sh, _ = make_train_step(
                        cfg, flags, new_mesh, rules=rules, lr=args.lr,
                        total_steps=args.steps,
                        batch_shape=(args.global_batch, args.seq),
                        comm_plan=ref_plan)
                    jfn = jax.jit(sfn, donate_argnums=0)
                new_plan = ref_plan
            mesh, plan, decisions = new_mesh, new_plan, new_dec
            print(f"!! re-mesh at step {at_step}: {new_mesh.size + args.elastic_drop}"
                  f" -> {new_mesh.size} devices, "
                  f"{len(flips)} comm decision(s) flipped")
            for f in flips:
                print(f"!! re-plan flip: {f['tensor']} "
                      f"{f['old']} -> {f['new']}")
            return {"step_fn": jfn, "shardings": sh, "flips": flips,
                    "mesh_axes": new_axes}

    runner = FaultTolerantRunner(jstep, args.ckpt,
                                 ckpt_every=args.ckpt_every,
                                 remesh_hook=remesh_hook)
    if args.inject_failure_at >= 0:
        fails = {args.inject_failure_at}

        def inject(step):
            if step in fails:
                fails.discard(step)
                print(f"!! injected node failure at step {step}")
                raise FaultError("injected")

        runner.inject_failures(inject)

    t0 = time.monotonic()
    state, hist = runner.run(state, batches, args.steps)
    dt = time.monotonic() - t0
    issued = socket_mod.issued_modes()
    if issued:
        print("comm-plan issued: " + ", ".join(
            f"{s}->{v['issued']}" for s, v in issued.items()))
        for mm in socket_mod.mismatched_sites(plan):
            print(f"comm-plan MISMATCH at {mm['site']}: {mm['tensor']} "
                  f"planned {mm['planned']}, issued {mm['issued']}")
    if args.calibrate and plan is not None:
        # plan -> measure -> re-plan: fit the timing constants from
        # flit-sim ground truth on this profile's fabric, then re-price
        # the plan against what the sockets actually issued — each flip
        # lands in the same comm_replan_events schema as a re-mesh
        from repro.calib import fit as calib_fit, measure
        from repro.core.noc.perfmodel import SoCParams
        from repro.core.planner import refine_plan_from_measurements
        params = model.p if model is not None else SoCParams()
        cp = calib_fit.fit_soc_params(
            measure.flit_sim_observations(params) +
            measure.compute_observations(params), base=params)
        obs = measure.observations_from_issue_log(
            socket_mod.issue_observations(plan))
        plan, calib_flips = refine_plan_from_measurements(
            plan, obs, decisions=decisions)
        print(f"calibrate: {params.name} -> {cp.params.name} "
              f"residual={cp.residual:.5f} n_obs={cp.n_obs}, "
              f"{len(calib_flips)} plan flip(s)")
        for f in calib_flips:
            print(f"calibrate flip: {f['tensor']} {f['old']} -> {f['new']} "
                  f"({f['cause']})")
    for h in hist:
        if h["step"] % args.log_every == 0 or h["step"] == args.steps - 1:
            print(f"step {h['step']:5d} loss {h['loss']:.4f} "
                  f"dt {h['dt']*1e3:.0f}ms"
                  + (" [straggler]" if h["straggler"] else ""))
    tok_s = args.steps * args.global_batch * args.seq / dt
    print(f"done: {args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s), "
          f"restarts={runner.restarts}, "
          f"re-mesh events={len(runner.comm_replan_events)}, "
          f"stragglers={runner.straggler.events}, "
          f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
