"""Roofline-term extraction from compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` and ``memory_analysis()`` on this backend
count while-loop (lax.scan) bodies ONCE and ignore loop-carried buffers —
verified empirically (a 50-iteration scan reports 1x body flops and misses
its carry).  Since the whole framework is scan-over-layers, we walk the
post-partitioning HLO text ourselves:

* computations are parsed into per-op symbol tables (name -> shape/dtype);
* every ``while`` contributes a trip-count multiplier, read from the
  ``s32[] constant(N)`` bound in its condition computation (lax.scan always
  lowers to such a bound); nested loops multiply;
* FLOPs: ``dot`` ops at 2 * result_elems * contraction_size * multiplier.
  Elementwise flops are not counted (documented; matmuls dominate every
  assigned arch, including decode matvecs);
* HBM traffic proxy: per op, result bytes + operand bytes (post-fusion HLO,
  so one op ~= one materialized buffer) * multiplier;
* collective wire bytes: ring-cost factors per op kind * multiplier;
* peak-memory estimate: entry arguments + the deepest chain of live
  while-carry tuples (remat stacks live there) + the largest single
  temporary.

Collective ring costs per chip (g = group size, B = per-device result):
  all-reduce 2B(g-1)/g; all-gather B(g-1)/g; reduce-scatter B(g-1);
  all-to-all B(g-1)/g; collective-permute B.
"""

from __future__ import annotations

import dataclasses
import math
import re
import warnings
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import (PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK,
                               ICI_LINKS_PER_RING)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_TYPE_RE = re.compile(r"^([a-z0-9]+)\[([\d,]*)\]")
_TUPLE_TYPE_RE = re.compile(r"^\(")
_OP_RE = re.compile(r"^(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?)\s+"
                    r"([\w\-]+)\(")
_SHAPE_IN_TUPLE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_WHILE_RE = re.compile(r"condition=(%[\w\.\-]+),\s*body=(%[\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=(%[\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
# an operand token: optional inline type annotation + %name.  Newer XLA
# prints operands with their types ("dot(f32[128,256]{1,0} %Arg_0.1, ...)"),
# older HLO prints bare names ("dot(%p, %q)") — both must parse.
_OPERAND_TOKEN = re.compile(
    r"(?:([a-z0-9]+\[[\d,]*\])(?:\{[\d,]*\})?\s+)?(%[\w\.\-]+)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes_from_type(tstr: str) -> int:
    """Bytes of a type string: 'bf16[2,3]{...}' or '(f32[2], s32[])'."""
    total = 0
    for m in _SHAPE_IN_TUPLE.finditer(tstr):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


def _elems(shape: str) -> int:
    n = 1
    for d in shape.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    type_str: str
    line: str


def _operands(op: _Op) -> List[Tuple[str, str]]:
    """Parse an op's operand list into (name, inline_type) pairs; the inline
    type is "" on older HLO that prints bare %names.  The argument group is
    found by matching the parenthesis after the op kind (depth-counted:
    tuple-typed operands contain nested parens)."""
    i = op.line.find(op.kind + "(")
    if i < 0:
        return []
    j = i + len(op.kind) + 1
    depth, k = 1, j
    while k < len(op.line) and depth:
        ch = op.line[k]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        k += 1
    inner = op.line[j:k - 1]
    return [(m.group(2), m.group(1) or "")
            for m in _OPERAND_TOKEN.finditer(inner)]


def _operand_type(table: Dict[str, str], name: str, inline: str) -> str:
    """Operand type string: the defining op's full type when the operand is
    defined in this computation, else the inline annotation."""
    return table.get(name) or inline


def _dot_contraction_size(op: _Op, table: Dict[str, str]) -> int:
    """Contraction size K of a ``dot``: product of the lhs dims named by
    ``lhs_contracting_dims``.  A silent failure here used to leave K = 1 and
    under-count 2*M*N*K as 2*M*N, so any unparsable piece now *warns loudly*
    (flops remain a lower bound) instead of passing as exact."""
    cm = _CONTRACT_RE.search(op.line)
    opnds = _operands(op)
    problem = None
    if not cm:
        problem = "no lhs_contracting_dims attribute"
    elif not opnds:
        problem = "could not parse operand list"
    else:
        lhs_name, lhs_inline = opnds[0]
        lhs_t = _operand_type(table, lhs_name, lhs_inline)
        lm = _TYPE_RE.match(lhs_t)
        if not lm:
            problem = f"no type found for lhs operand {lhs_name!r}"
        else:
            dims = lm.group(2).split(",")
            csize = 1
            try:
                for ci in cm.group(1).split(","):
                    if ci:
                        csize *= int(dims[int(ci)])
            except (IndexError, ValueError):
                problem = (f"contracting dims {cm.group(1)!r} out of range "
                           f"for lhs shape {lhs_t!r}")
            else:
                return csize
    warnings.warn(
        f"hlo_analysis: cannot determine dot contraction size "
        f"({problem}); FLOPs will be UNDER-counted for: {op.line.strip()}",
        stacklevel=2)
    return 1


def parse_computations(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        om = _OP_RE.match(rhs)
        kind = om.group(1) if om else rhs.split("(")[0].split()[-1]
        tm = rhs.split(" " + kind + "(")[0] if om else ""
        comps[cur].append(_Op(name, kind, tm, line))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _trip_count(cond_ops: List[_Op]) -> int:
    best = 1
    for op in cond_ops:
        for m in _CONST_RE.finditer(op.line):
            best = max(best, int(m.group(1)))
    return best


def comp_multipliers(comps: Dict[str, List[_Op]]) -> Dict[str, float]:
    """Execution-count multiplier per computation (entry = 1)."""
    entry = comps.get("__entry_name__")
    mult: Dict[str, float] = {entry: 1.0} if entry else {}
    order = [entry] if entry else []
    seen = set(order)
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        m = mult.get(cname, 0.0)
        for op in comps.get(cname, []):
            if op.kind == "while":
                wm = _WHILE_RE.search(op.line)
                if not wm:
                    continue
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                mult[body] = mult.get(body, 0.0) + m * trips
                mult[cond] = mult.get(cond, 0.0) + m * (trips + 1)
                for c in (body, cond):
                    if c not in seen:
                        seen.add(c)
                        order.append(c)
            else:
                cm = _CALLS_RE.search(op.line)
                if cm:
                    callee = cm.group(1)
                    mult[callee] = mult.get(callee, 0.0) + m
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)
    return mult


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([e for e in m.group(1).split(",") if e.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    op: str
    count: float = 0
    result_bytes: float = 0    # per-device result bytes (x executions)
    wire_bytes: float = 0.0    # per-chip ring-model traffic (x executions)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    dot_count: float = 0.0
    collectives: Dict[str, CollectiveStats] = dataclasses.field(
        default_factory=dict)
    peak_bytes_est: float = 0.0


_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "while", "conditional", "after-all", "iota",
                 "partition-id", "replica-id"}


def analyze_hlo_text(hlo: str, argument_bytes: int = 0) -> HloCost:
    comps = parse_computations(hlo)
    mult = comp_multipliers(comps)
    cost = HloCost()

    # symbol tables: computation -> {op name -> type string}
    symtab: Dict[str, Dict[str, str]] = {}
    for cname, ops in comps.items():
        if cname.startswith("__"):
            continue
        symtab[cname] = {op.name: op.type_str for op in ops}

    def _param_types(cname: str) -> List[str]:
        return [op.type_str for op in comps.get(cname, [])
                if op.kind == "parameter"]

    def _dus_update_bytes(cname: str) -> Optional[float]:
        """If computation ``cname`` is rooted in a dynamic-update-slice
        (modulo bitcast/convert), return the update operand's bytes."""
        ops = comps.get(cname, [])
        table = symtab.get(cname, {})
        for op in ops:
            if op.kind == "dynamic-update-slice":
                opnds = _operands(op)
                if len(opnds) >= 2:
                    return float(_shape_bytes_from_type(
                        _operand_type(table, *opnds[1])))
        return None

    def _fusion_read_bytes(cname: str, operand_types: List[str]) -> float:
        """Effective read traffic of a fusion: a parameter consumed ONLY by
        dynamic-slice/gather ops inside the fusion is read at slice size,
        not full size (XLA emits the slice loads directly)."""
        ops = comps.get(cname, [])
        params = [op for op in ops if op.kind == "parameter"]
        # map parameter order to operand types (same order by construction)
        reads = 0.0
        for idx, pop in enumerate(params):
            full = _shape_bytes_from_type(
                operand_types[idx] if idx < len(operand_types)
                else pop.type_str)
            slice_bytes = 0.0
            sliced_only = True
            used = False
            for op in ops:
                if op.kind == "parameter":
                    continue
                names = [n for n, _ in _operands(op)]
                if pop.name not in names:
                    continue
                used = True
                if op.kind in ("dynamic-slice", "gather"):
                    slice_bytes += _shape_bytes_from_type(op.type_str)
                elif op.kind == "dynamic-update-slice" and \
                        names and names[0] == pop.name:
                    pass  # aliased in-place destination: no read
                else:
                    sliced_only = False
                    break
            if not used:
                continue
            reads += slice_bytes if sliced_only else full
        return reads

    while_tree: Dict[str, List[Tuple[str, float]]] = {}  # comp -> [(body, bytes)]
    largest_tmp = 0.0

    for cname, ops in comps.items():
        if cname.startswith("__"):
            continue
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        table = symtab[cname]
        for op in ops:
            tbytes = _shape_bytes_from_type(op.type_str)
            if op.kind == "dot":
                tm = _TYPE_RE.match(op.type_str)
                if tm:
                    res_elems = _elems(tm.group(2))
                    csize = _dot_contraction_size(op, table)
                    cost.flops += 2.0 * res_elems * csize * m
                    cost.dot_count += m
            if op.kind in COLLECTIVE_OPS or any(
                    op.kind == c + "-start" for c in COLLECTIVE_OPS):
                kind = op.kind.replace("-start", "")
                g = _group_size(op.line)
                b = tbytes
                if kind == "all-reduce":
                    wire = 2 * b * (g - 1) / max(g, 1)
                elif kind == "all-gather":
                    wire = b * (g - 1) / max(g, 1)
                elif kind == "reduce-scatter":
                    wire = b * (g - 1)
                elif kind == "all-to-all":
                    wire = b * (g - 1) / max(g, 1)
                else:
                    wire = b
                st = cost.collectives.setdefault(kind, CollectiveStats(kind))
                st.count += m
                st.result_bytes += b * m
                st.wire_bytes += wire * m
                cost.wire_bytes += wire * m
            if op.kind == "while":
                wm = _WHILE_RE.search(op.line)
                if wm:
                    while_tree.setdefault(cname, []).append(
                        (wm.group(2), tbytes))
            if op.kind not in _SKIP_TRAFFIC:
                # dynamic-slice/gather read only the slice, not the operand
                if op.kind in ("dynamic-slice", "gather"):
                    cost.hbm_bytes += 2.0 * tbytes * m
                    largest_tmp = max(largest_tmp, tbytes)
                    continue
                # in-place dynamic-update-slice only touches the slice: XLA
                # aliases the buffer, so charge 2x the update bytes, not the
                # full tensor (fusions rooted in a DUS included).
                dus_update = None
                if op.kind == "dynamic-update-slice":
                    opnds = _operands(op)
                    if len(opnds) >= 2:
                        dus_update = float(_shape_bytes_from_type(
                            _operand_type(table, *opnds[1])))
                elif op.kind == "fusion" and "dynamic-update-slice" in op.line:
                    cm = _CALLS_RE.search(op.line)
                    if cm:
                        dus_update = _dus_update_bytes(cm.group(1))
                if dus_update is not None:
                    cost.hbm_bytes += 2.0 * dus_update * m
                    continue
                operand_types = [_operand_type(table, nm, it)
                                 for nm, it in _operands(op)]
                if op.kind == "fusion":
                    cm = _CALLS_RE.search(op.line)
                    if cm and cm.group(1) in comps:
                        reads = _fusion_read_bytes(cm.group(1), operand_types)
                    else:
                        reads = sum(_shape_bytes_from_type(t)
                                    for t in operand_types)
                else:
                    reads = sum(_shape_bytes_from_type(t)
                                for t in operand_types)
                cost.hbm_bytes += (tbytes + reads) * m
                largest_tmp = max(largest_tmp, tbytes)

    # Peak estimate: arguments + the LARGEST single while-carry tuple + the
    # largest temporary.  Chaining nested tuples double-counts: inner-loop
    # carries and xs stacks alias slices of the outer carry (donated
    # arguments alias the param/opt stacks), so max() is the honest bracket
    # upper bound next to XLA's (loop-blind) lower bound.
    max_tuple = 0.0

    def walk(comp: str, seen) -> None:
        nonlocal max_tuple
        if comp in seen:
            return
        seen.add(comp)
        for body, b in while_tree.get(comp, []):
            max_tuple = max(max_tuple, b)
            walk(body, seen)

    entry = comps.get("__entry_name__")
    walk(entry, set())
    # donated arguments alias the training-state loop carry, so args and the
    # carry tuple are the SAME buffers: take the max, plus one transient.
    cost.peak_bytes_est = max(argument_bytes, max_tuple) + largest_tmp
    return cost


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    wire_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collectives: Dict[str, Dict]
    model_flops_per_dev: float = 0.0
    peak_bytes_est: float = 0.0

    @property
    def useful_flops_ratio(self) -> float:
        return (self.model_flops_per_dev / self.flops_per_dev
                if self.flops_per_dev else 0.0)

    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Useful-compute time over the dominant-term bound: how close the
        step is to the hardware roofline if it ran exactly at the bound."""
        t = self.bound_time()
        return (self.model_flops_per_dev / PEAK_FLOPS_BF16) / t if t else 0.0


def analyze(compiled, model_flops_total: float = 0.0, n_chips: int = 256
            ) -> Roofline:
    ma = compiled.memory_analysis()
    cost = analyze_hlo_text(compiled.as_text(),
                            argument_bytes=ma.argument_size_in_bytes)
    compute_s = cost.flops / PEAK_FLOPS_BF16
    memory_s = cost.hbm_bytes / HBM_BW
    coll_s = cost.wire_bytes / (ICI_BW_PER_LINK * ICI_LINKS_PER_RING)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    return Roofline(
        flops_per_dev=cost.flops, hbm_bytes_per_dev=cost.hbm_bytes,
        wire_bytes_per_dev=cost.wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dom,
        collectives={k: dataclasses.asdict(v)
                     for k, v in cost.collectives.items()},
        model_flops_per_dev=model_flops_total / max(n_chips, 1),
        peak_bytes_est=cost.peak_bytes_est,
    )


def parse_collectives(hlo_text: str) -> Dict[str, CollectiveStats]:
    """Collective stats with trip-count multipliers (public helper)."""
    return analyze_hlo_text(hlo_text).collectives


# ----------------------------------------------- HLO -> transfer specs ----
#
# The planner's config-level ``step_transfer_specs`` are *estimates*; the
# compiled step's HLO is ground truth for what actually moves.  Each
# collective lowering maps onto one of the paper's transfer archetypes:
#
#   all-to-all          -> "moe_dispatch":  every shard exchanges distinct
#                          b/g-byte chunks with its g-1 peers — per-pair
#                          unicast writes (the 1-destination multicast
#                          degeneracy), priced at fan-out 1;
#   collective-permute  -> "stage_activation": the next stage pulls its
#                          predecessor's output — read-channel P2P;
#   all-gather          -> "weights": each shard broadcasts its b/g-byte
#                          shard to the g-1 peers — the multicast archetype;
#   all-reduce          -> "grad_reduce", reduce-scatter -> "grad_scatter":
#                          reductions; the NoC forks multicast flits but
#                          cannot combine in flight, so these are marked
#                          ``reduce`` and the planner pins them to MEM.
#
# Specs are emitted *per layer*: a collective op inside the
# scan-over-layers while body executes once per layer (its trip-count
# multiplier), and each execution is one transfer, named
# ``"<archetype>.L<index>"`` with layer indices assigned in module parse
# order across the archetype's op instances (scanned groups expand to their
# trip count; unscanned remainder layers are their own instances).  Config
# estimates are kept only for logical transfers the HLO does not exhibit.

_HLO_SPEC_ARCHETYPES = {
    "all-to-all": "moe_dispatch",
    "collective-permute": "stage_activation",
    "all-gather": "weights",
    "all-reduce": "grad_reduce",
    "reduce-scatter": "grad_scatter",
}

# Per-layer expansion bound: a collective under a non-layer loop (e.g. a
# long chunk scan) can carry a huge multiplier; past this many layers the
# archetype degrades to the single dominant-op spec instead of flooding the
# planner with identical rows.  Sized above the all-reduce census of a
# 40-layer training step (dbrx train_4k executes 137: the gradient
# reductions of three scanned regions plus optimizer-side reductions), so
# real per-layer gradient traffic prices layer by layer — collapsing it to
# one dominant spec with mult=137 charges every execution at the dominant
# byte count and buries the overlap fraction.
_PER_LAYER_CAP = 160

_SPEC_CACHE: Dict[str, Dict[str, List]] = {}


def _collective_result_bytes(tstr: str) -> int:
    """Result-buffer bytes of a collective's type string.  Async ``-start``
    ops are tuple-typed ``(operand, result[, context])`` — summing the whole
    tuple would over-count the transfer (e.g. (g+1)/g x for an all-gather),
    so take the largest member: the gathered/permuted result."""
    if not tstr.lstrip().startswith("("):
        return _shape_bytes_from_type(tstr)
    best = 0
    for m in _SHAPE_IN_TUPLE.finditer(tstr):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES.get(m.group(1), 4))
    return best


def _comp_dot_flops(comps: Dict[str, List[_Op]]) -> Dict[str, float]:
    """Per-*execution* dot FLOPs of each computation, including the
    computations it calls (``calls``/``to_apply`` — fusions hide the dots
    one level down) but NOT its while loops (loop compute is not adjacent
    to a single collective execution) and NOT the ``to_apply`` of a
    collective op (a reduction's combiner is the wire-side add, not
    producer/consumer compute the transfer can hide behind).  This is the
    "compute a collective feeds" term of the overlap objective: a
    collective lowered into a computation overlaps the matmuls that
    computation runs."""
    direct: Dict[str, float] = {}
    callees: Dict[str, List[str]] = {}
    for cname, ops in comps.items():
        if cname.startswith("__"):
            continue
        table = {op.name: op.type_str for op in ops}
        f = 0.0
        calls: List[str] = []
        for op in ops:
            if op.kind == "dot":
                tm = _TYPE_RE.match(op.type_str)
                if tm:
                    f += 2.0 * _elems(tm.group(2)) * \
                        _dot_contraction_size(op, table)
            elif op.kind != "while" and \
                    op.kind.replace("-start", "") not in COLLECTIVE_OPS:
                cm = _CALLS_RE.search(op.line)
                if cm:
                    calls.append(cm.group(1))
        direct[cname] = f
        callees[cname] = calls

    closed: Dict[str, float] = {}

    def total(cname: str, stack: Tuple[str, ...] = ()) -> float:
        if cname in closed:
            return closed[cname]
        if cname in stack:   # defensive: HLO call graphs are acyclic
            return 0.0
        f = direct.get(cname, 0.0) + sum(
            total(c, stack + (cname,)) for c in callees.get(cname, ()))
        closed[cname] = f
        return f

    for cname in direct:
        total(cname)
    return closed


def collective_op_details(hlo: str) -> List[Dict]:
    """One entry per collective op in the module: kind, per-execution
    result bytes, group size, the trip-count multiplier of its
    computation, the computation name (``comp``) — ops sharing a
    computation execute together (one layer of a scanned stack) — and the
    computation's per-execution dot FLOPs (``dot_flops``, the consumer
    compute the overlap objective hides the transfer behind)."""
    comps = parse_computations(hlo)
    mult = comp_multipliers(comps)
    dot_flops = _comp_dot_flops(comps)
    out: List[Dict] = []
    for cname, ops in comps.items():
        if cname.startswith("__"):
            continue
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for op in ops:
            kind = op.kind.replace("-start", "")
            if kind not in COLLECTIVE_OPS or (
                    op.kind != kind and op.kind != kind + "-start"):
                continue
            out.append({
                "kind": kind,
                "bytes": _collective_result_bytes(op.type_str),
                "group": _group_size(op.line),
                "mult": m,
                "comp": cname,
                "dot_flops": dot_flops.get(cname, 0.0),
            })
    return out


def _spec_from_detail(kind: str, name: str, det: Dict, layer=None, mult=1):
    """One TransferSpec from a collective op's (bytes, group) per the
    archetype table above.  ``mult`` > 1 marks a capped dominant spec
    standing for that many layer executions.

    The computation's dot FLOPs ride along as ``compute_flops`` — the
    adjacent compute the overlap objective hides the transfer behind.
    ``all-reduce`` carries it too: the combine itself still cannot ride
    the NoC (the reduce pin in the planner holds), but the C5 IDMA/CDMA
    decoupling lets the memory-path round-trip stream behind the producer
    matmuls of the same computation (``PlanDecision.streamed``), and the
    fused ring reduce-scatter remains a candidate when the chain beats
    the round-trip outright."""
    from repro.core.planner import TransferSpec

    g = max(det["group"], 1)
    b = int(det["bytes"])
    flops = float(det.get("dot_flops", 0.0))
    if kind == "all-to-all":
        return TransferSpec(name, nbytes=max(b // g, 1), fan_out=1,
                            layer=layer, mult=mult, compute_flops=flops)
    if kind == "collective-permute":
        return TransferSpec(name, nbytes=max(b, 1), fan_out=1, pull=True,
                            layer=layer, mult=mult, compute_flops=flops)
    if kind == "all-gather":
        return TransferSpec(name, nbytes=max(b // g, 1),
                            fan_out=max(g - 1, 1), layer=layer, mult=mult,
                            compute_flops=flops)
    if kind == "all-reduce":
        return TransferSpec(name, nbytes=max(b, 1), fan_out=max(g - 1, 1),
                            reduce=True, layer=layer, mult=mult,
                            compute_flops=flops)
    # reduce-scatter: the fused ring kernel's combine-at-every-hop makes
    # this the canonical FUSED_RING producer-side transfer
    return TransferSpec(name, nbytes=max(b // g, 1),
                        fan_out=max(g - 1, 1), reduce=True, layer=layer,
                        mult=mult, compute_flops=flops)


def transfer_specs_from_hlo(hlo_text: str, fallback=None):
    """Derive planner :class:`~repro.core.planner.TransferSpec`s from the
    compiled step's collective ops (see the archetype table above), one
    spec per layer per archetype.

    Same-kind ops within one computation execute together — they are the
    distinct tensors of ONE layer of a scanned stack (e.g. each weight
    matrix's all-gather) — so they aggregate into a single per-layer
    transfer (bytes summed, group size from the largest op).  The
    aggregate then expands by the computation's trip-count multiplier
    ``m`` into ``m`` layer-specs (``"weights.L0"`` ...
    ``"weights.L<m-1>"``); computations number consecutively in parse
    order, so names are stable for a given module.  An archetype exhibited
    by exactly one execution keeps its bare name (``"weights"``).
    ``fallback`` (the config-level spec list) fills in logical transfers
    absent from the HLO and fixes the output order — a fallback entry
    whose archetype the HLO exhibits is replaced by that archetype's
    per-layer specs in place.  Parsed results are cached by module digest
    so repeated pricing per launch is free.
    """
    import hashlib

    digest = hashlib.sha1(hlo_text.encode()).hexdigest()
    derived = _SPEC_CACHE.get(digest)
    if derived is None:
        # (kind, computation) -> one aggregated per-execution transfer
        agg: Dict[Tuple[str, str], Dict] = {}
        for det in collective_op_details(hlo_text):
            key = (det["kind"], det["comp"])
            cur = agg.get(key)
            if cur is None:
                agg[key] = dict(det, dom_bytes=det["bytes"])
            else:
                cur["bytes"] += det["bytes"]
                if det["bytes"] > cur["dom_bytes"]:
                    cur["dom_bytes"] = det["bytes"]
                    cur["group"] = det["group"]
        # a computation's dot FLOPs are ONE pool of adjacent compute
        # shared by all its collectives: apportion it across the
        # compute-bearing aggregates so the serial objective charges the
        # compute once per computation (not once per transfer) and the
        # overlap objective cannot hide every transfer behind the same
        # matmul simultaneously.  The split is weighted by each
        # aggregate's wire bytes — a transfer's DMA spans a window of the
        # surrounding compute proportional to its payload, so the big
        # gradient reduction gets the wide backward-matmul window while a
        # small dispatch gets the sliver it actually needs; an even split
        # would strand most of the pool on transfers whose comm is already
        # far smaller than their share.  All-reduce aggregates share too:
        # the combine itself stays wire-side (see ``_spec_from_detail``),
        # but the C5 streamed memory path hides the round-trip behind the
        # producer matmuls of the same computation.
        sharers: Dict[str, List[Dict]] = {}
        for (kind, comp), a in agg.items():
            if a.get("dot_flops", 0.0) > 0:
                sharers.setdefault(comp, []).append(a)
        for items in sharers.values():
            total_bytes = sum(max(a["bytes"], 1) for a in items)
            for a in items:
                a["dot_flops"] = (a["dot_flops"] *
                                  max(a["bytes"], 1) / total_bytes)
        per_kind: Dict[str, List[Dict]] = {}
        for (kind, _), a in agg.items():
            per_kind.setdefault(kind, []).append(a)
        derived = {}
        for kind, name in _HLO_SPEC_ARCHETYPES.items():
            dets = per_kind.get(kind)
            if not dets:
                continue
            layers: List[Dict] = []
            for det in dets:
                layers.extend([det] * max(int(round(det["mult"])), 1))
            if len(layers) == 1:
                derived[name] = [_spec_from_detail(kind, name, layers[0])]
            elif len(layers) > _PER_LAYER_CAP:
                # degrade to the dominant per-layer transfer but keep the
                # execution count: step-cost totals stay continuous across
                # the cap instead of collapsing to one execution
                dom = max(dets, key=lambda d: d["bytes"])
                derived[name] = [_spec_from_detail(kind, name, dom,
                                                   mult=len(layers))]
            else:
                derived[name] = [
                    _spec_from_detail(kind, f"{name}.L{i}", det, layer=i)
                    for i, det in enumerate(layers)]
        _SPEC_CACHE[digest] = derived
    out, taken = [], set()
    for s in fallback or ():
        group = derived.get(s.name)
        if group is not None:
            out.extend(group)
            taken.add(s.name)
        else:
            out.append(s)
    for base in sorted(set(derived) - taken):
        out.extend(derived[base])
    return out
