"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (TPU v5e pod,
2-D ICI torus).  Multi-pod: 2 pods x 256 chips; the leading "pod" axis
crosses the inter-pod links (data-parallel outer axis, where the gradient
compression of `optim.compression` applies).
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AxisType.Auto,) * len(axes))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s/link
ICI_LINKS_PER_RING = 2            # bidirectional ring on one torus dim
