"""Serving driver: batched prefill + decode with the serve sharding rules.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --preset reduced --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.configs.base import ShapeConfig
from repro.configs.espsoc_trafficgen import noc_model
from repro.core import socket as socket_mod
from repro.core.planner import (plan_summary_lines, refine_plan_from_hlo,
                                resolve_policy)
from repro.models import transformer as T
from repro.models.transformer import RunFlags
from repro.runtime.serve import (make_prefill_step, make_decode_step,
                                 grow_caches, resolved_serve_rules)
from repro.launch.mesh import make_production_mesh


def run_engine(args, cfg) -> int:
    """``--engine``: drive the continuous-batching ServeEngine over a
    deterministic Poisson trace and (with ``--artifact``) write the
    serve dryrun artifact the CI coverage gate cross-checks with
    ``python -m repro.analysis --against-artifact``."""
    import json

    from repro.core.planner import plan_summary_lines
    from repro.runtime.engine import ServeEngine, poisson_trace

    socket_mod.reset_issue_log()
    eng = ServeEngine(cfg, prompt_len=args.prompt_len,
                      max_new_tokens=args.gen, n_slots=args.batch,
                      block_size=args.block_size)
    trace = poisson_trace(args.requests, rate=args.rate,
                          prompt_len=args.prompt_len, vocab=cfg.vocab_size,
                          max_new_tokens=args.gen, seed=args.seed)
    metrics = eng.run(trace)
    for line in plan_summary_lines(eng.plan_decisions or ()):
        print(line)
    issued = socket_mod.issued_modes()
    mismatched = socket_mod.mismatched_sites(eng.plan)
    print(f"engine: arch={cfg.name} slots={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen} "
          f"requests={metrics.n_requests}")
    print(f"  {metrics.total_new_tokens} tokens in {metrics.steps} steps: "
          f"{metrics.tokens_per_s:.1f} tok/s, "
          f"p50={metrics.p50_latency_s*1e3:.1f} ms, "
          f"p99={metrics.p99_latency_s*1e3:.1f} ms")
    print("comm-plan issued: " + ", ".join(
        f"{s}->{v['issued']}" for s, v in issued.items()))
    for mm in mismatched:
        print(f"comm-plan MISMATCH at {mm['site']}: {mm['tensor']} "
              f"planned {mm['planned']}, issued {mm['issued']}")
    if args.artifact:
        artifact = {
            "kind": "serve_engine", "arch": cfg.name,
            "shape": {"n_slots": args.batch, "prompt_len": args.prompt_len,
                      "max_new_tokens": args.gen,
                      "block_size": args.block_size},
            "metrics": metrics.summary(),
            "comm_plan": {k: v.name for k, v in eng.plan.modes.items()},
            "comm_issued": issued,
            "comm_issued_matches_plan": not mismatched,
            "trace_counts": eng.trace_counts,
        }
        with open(args.artifact, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
        print(f"wrote {args.artifact}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_NAMES)
    ap.add_argument("--preset", default="reduced", choices=("reduced", "full"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="none", choices=("none", "single", "multi"))
    ap.add_argument("--comm-plan", default="manual",
                    choices=("manual", "auto", "mem", "mcast"),
                    help="per-transfer communication-mode policy (auto = "
                         "NoC cost model picks; see core.planner)")
    ap.add_argument("--noc-profile", default="espsoc-3x4",
                    help="NoC cost-model profile for --comm-plan=auto "
                         "(espsoc-3x4 | pod-8x8 | pod-16x16)")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching ServeEngine over a "
                         "deterministic Poisson trace (paged KV cache)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="--engine: KV block size (must divide "
                         "prompt_len + gen)")
    ap.add_argument("--requests", type=int, default=8,
                    help="--engine: requests in the Poisson trace")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="--engine: Poisson arrival rate (req/step)")
    ap.add_argument("--seed", type=int, default=0,
                    help="--engine: arrival-trace seed")
    ap.add_argument("--artifact", default=None,
                    help="--engine: write the serve dryrun artifact JSON "
                         "here (CI cross-checks it with --against-artifact)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.preset == "full" else \
        get_reduced(args.arch)
    if args.engine:
        return run_engine(args, cfg)
    flags = RunFlags(param_dtype=jnp.bfloat16, remat="none")
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
    mesh_axes = dict(mesh.shape) if mesh is not None else {}
    model = noc_model(args.noc_profile)
    plan, decisions = resolve_policy(args.comm_plan, cfg, shape, mesh_axes,
                                     model=model)
    prefill = None
    rules = None
    if args.comm_plan == "auto" and mesh is not None:
        # re-price from the compiled prefill step's own collective ops; in
        # the common no-replan case keep the compiled executable — no
        # second XLA compile
        params_specs = jax.eval_shape(
            lambda: T.init_params(jax.random.key(0), cfg, flags.param_dtype))
        tok_specs = jax.ShapeDtypeStruct((args.batch, args.prompt_len),
                                         jnp.int32)
        socket_mod.reset_issue_log()
        compiled = jax.jit(make_prefill_step(cfg, flags, mesh,
                                             comm_plan=plan)) \
            .lower(params_specs, tok_specs).compile()
        # planner -> sharding feedback: re-price per layer from the
        # compiled HLO, rewrite the serve rule table (e.g. the
        # w_fsdp="data" gather dropped when weights broadcast on MCAST),
        # rebuild once iff changed
        plan, decisions, rules, overlay, rebuild = refine_plan_from_hlo(
            plan, cfg, shape, mesh_axes, compiled.as_text(),
            resolved_serve_rules, model=model)
        if rebuild:
            if overlay:
                print(f"comm-plan: rule overlay {overlay} applied; "
                      "rebuilding the steps")
            else:
                print("comm-plan: HLO-derived pricing changed the plan")
            # the rebuilt steps trace at their first call: drop the
            # discarded trace's records so the post-run issued summary
            # describes the steps that actually ran
            socket_mod.reset_issue_log()
        else:
            prefill = compiled
            rules = None   # no rebuild: keep the default serve rules
    for line in plan_summary_lines(decisions or ()):
        print(line)

    params = T.init_params(jax.random.key(0), cfg, flags.param_dtype)
    if prefill is None:
        prefill = jax.jit(make_prefill_step(cfg, flags, mesh, rules=rules,
                                            comm_plan=plan))
    decode = jax.jit(make_decode_step(cfg, flags, mesh, rules=rules,
                                      comm_plan=plan))

    B, S = args.batch, args.prompt_len
    total = S + args.gen
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0,
                                 cfg.vocab_size)

    t0 = time.monotonic()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0

    # grow attention caches once to hold the generated tokens; leaves are
    # classified by logical axis names (runtime.serve.grow_caches), never
    # by shape coincidences
    caches = grow_caches(cfg, caches, S, args.gen)

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.monotonic()
    for i in range(args.gen - 1):
        logits, caches = decode(params, tok, jnp.int32(S + i), caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t0

    gen = jnp.concatenate(out, axis=1)
    issued = socket_mod.issued_modes()
    if issued:
        print("comm-plan issued: " + ", ".join(
            f"{s}->{v['issued']}" for s, v in issued.items()))
        for mm in socket_mod.mismatched_sites(plan):
            print(f"comm-plan MISMATCH at {mm['site']}: {mm['tensor']} "
                  f"planned {mm['planned']}, issued {mm['issued']}")
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
