"""Header-flit layout for the multicast NoC (paper C2).

The header flit of a NoC message carries routing metadata; multicast extends
the single destination to a *list*, so the number of destinations is bounded
by the NoC bitwidth.  The paper gives two anchor points: a 64-bit NoC
encodes up to 5 destinations and a 128-bit NoC up to 14, with ESP capping
multicast at 16 destinations.

Layout used here (consistent with those anchors), with ``c``-bit coordinate
fields (``c`` = 3 covers ESP's supported 8x8 tile grids; pod-scale meshes up
to 16x16 use ``c`` = 4 via the ``coord_bits`` parameter):

    [ src_x:c | src_y:c | msg_type:5 | reserved:15 ]  -> 2c + 20 overhead bits
    then per destination: [ valid:1 | x:c | y:c ]     -> 2c + 1 bits each

    c = 3:  max_dests(64)  = (64  - 26) // 7 = 5    (paper: 5)
            max_dests(128) = (128 - 26) // 7 = 14   (paper: 14)
            max_dests(256) = min((256-26)//7, 16) = 16  (ESP cap; paper: 16)
    c = 4:  max_dests(256) = min((256-28)//9, 16) = 16  (pod 16x16 mesh)
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

# Constants for the default 3-bit coordinate layout (ESP's 8x8 range).
BITS_PER_DEST = 7
HEADER_OVERHEAD_BITS = 26
ESP_MAX_DESTS = 16
_COORD_BITS = 3


def mesh_coord_bits(width: int, height: int) -> int:
    """Header coordinate field width covering a W x H mesh (>= ESP's
    3 bits).  The single source of truth for both the performance model
    (``SoCParams.coord_bits``) and the flit simulator — they must agree on
    multicast capacity."""
    return max(_COORD_BITS, (max(width, height) - 1).bit_length())


def bits_per_dest(coord_bits: int = _COORD_BITS) -> int:
    return 1 + 2 * coord_bits


def header_overhead_bits(coord_bits: int = _COORD_BITS) -> int:
    return 2 * coord_bits + 20


def max_multicast_dests(bitwidth: int, cap: int = ESP_MAX_DESTS,
                        coord_bits: int = _COORD_BITS) -> int:
    overhead = header_overhead_bits(coord_bits)
    if bitwidth <= overhead:
        return 0
    return min((bitwidth - overhead) // bits_per_dest(coord_bits), cap)


def encode_header(src: Tuple[int, int], dests: Sequence[Tuple[int, int]],
                  bitwidth: int, msg_type: int = 0,
                  coord_bits: int = _COORD_BITS) -> int:
    """Pack src + destination list into a single header flit (int)."""
    cap = max_multicast_dests(bitwidth, coord_bits=coord_bits)
    if len(dests) > cap:
        raise ValueError(
            f"{len(dests)} destinations exceed capacity {cap} of a "
            f"{bitwidth}-bit NoC header")
    cmask = (1 << coord_bits) - 1
    for (x, y) in list(dests) + [src]:
        if not (0 <= x <= cmask and 0 <= y <= cmask):
            raise ValueError(
                f"coordinate ({x},{y}) exceeds {coord_bits}-bit field")
    h = (src[0] & cmask) | ((src[1] & cmask) << coord_bits) | \
        ((msg_type & 0x1F) << (2 * coord_bits))
    off = header_overhead_bits(coord_bits)
    step = bits_per_dest(coord_bits)
    for (x, y) in dests:
        field = 0x1 | ((x & cmask) << 1) | ((y & cmask) << (1 + coord_bits))
        h |= field << off
        off += step
    return h


def decode_header(h: int, bitwidth: int, coord_bits: int = _COORD_BITS):
    """Returns (src, msg_type, dest list)."""
    cmask = (1 << coord_bits) - 1
    src = (h & cmask, (h >> coord_bits) & cmask)
    msg_type = (h >> (2 * coord_bits)) & 0x1F
    dests: List[Tuple[int, int]] = []
    off = header_overhead_bits(coord_bits)
    step = bits_per_dest(coord_bits)
    while off + step <= bitwidth:
        field = (h >> off) & ((1 << step) - 1)
        if field & 0x1:
            dests.append(((field >> 1) & cmask,
                          (field >> (1 + coord_bits)) & cmask))
        off += step
    return src, msg_type, dests
