"""Header-flit layout for the multicast NoC (paper C2).

The header flit of a NoC message carries routing metadata; multicast extends
the single destination to a *list*, so the number of destinations is bounded
by the NoC bitwidth.  The paper gives two anchor points: a 64-bit NoC
encodes up to 5 destinations and a 128-bit NoC up to 14, with ESP capping
multicast at 16 destinations.

Layout used here (consistent with those anchors):

    [ src_x:3 | src_y:3 | msg_type:5 | reserved:15 ]  -> 26 overhead bits
    then per destination: [ valid:1 | x:3 | y:3 ]     -> 7 bits each

    max_dests(64)  = (64  - 26) // 7 = 5    (paper: 5)
    max_dests(128) = (128 - 26) // 7 = 14   (paper: 14)
    max_dests(256) = min((256-26)//7, 16) = 16  (ESP cap; paper: 16)
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

BITS_PER_DEST = 7
HEADER_OVERHEAD_BITS = 26
ESP_MAX_DESTS = 16
_COORD_BITS = 3  # up to 8x8 tile grids (ESP's supported range)


def max_multicast_dests(bitwidth: int, cap: int = ESP_MAX_DESTS) -> int:
    if bitwidth <= HEADER_OVERHEAD_BITS:
        return 0
    return min((bitwidth - HEADER_OVERHEAD_BITS) // BITS_PER_DEST, cap)


def encode_header(src: Tuple[int, int], dests: Sequence[Tuple[int, int]],
                  bitwidth: int, msg_type: int = 0) -> int:
    """Pack src + destination list into a single header flit (int)."""
    cap = max_multicast_dests(bitwidth)
    if len(dests) > cap:
        raise ValueError(
            f"{len(dests)} destinations exceed capacity {cap} of a "
            f"{bitwidth}-bit NoC header")
    for (x, y) in list(dests) + [src]:
        if not (0 <= x < (1 << _COORD_BITS) and 0 <= y < (1 << _COORD_BITS)):
            raise ValueError(f"coordinate ({x},{y}) exceeds {_COORD_BITS}-bit field")
    h = (src[0] & 0x7) | ((src[1] & 0x7) << 3) | ((msg_type & 0x1F) << 6)
    off = HEADER_OVERHEAD_BITS
    for (x, y) in dests:
        field = 0x1 | ((x & 0x7) << 1) | ((y & 0x7) << 4)
        h |= field << off
        off += BITS_PER_DEST
    return h


def decode_header(h: int, bitwidth: int):
    """Returns (src, msg_type, dest list)."""
    src = (h & 0x7, (h >> 3) & 0x7)
    msg_type = (h >> 6) & 0x1F
    dests: List[Tuple[int, int]] = []
    off = HEADER_OVERHEAD_BITS
    while off + BITS_PER_DEST <= bitwidth:
        field = (h >> off) & 0x7F
        if field & 0x1:
            dests.append(((field >> 1) & 0x7, (field >> 4) & 0x7))
        off += BITS_PER_DEST
    return src, msg_type, dests
