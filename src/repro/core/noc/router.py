"""NoC router: lookahead dimension-ordered routing, multicast fork, and the
post-synthesis area model (paper Fig. 4).

The :class:`Router` object backs the object-based reference simulator
(``reference_sim.py``); the vectorized stepper in ``simulator.py``
replicates its arbitration semantics (per-input FIFOs, rotating priority,
all-ports-or-stall multicast fork) with precomputed routing tables and is
property-tested against it.

The area model is anchored on the paper's published numbers:
  * baseline router areas — 3620 / 6230 / 11520 um^2 at 64 / 128 / 256 bits
    ("roughly proportional ... input queues" => linear fit between anchors);
  * +200 um^2 per supported multicast destination on average
    (5.5% / 3.2% / 1.7% of the respective baselines — reproduced exactly).
"""

from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ports
LOCAL, NORTH, SOUTH, EAST, WEST = range(5)
PORT_NAMES = ("LOCAL", "NORTH", "SOUTH", "EAST", "WEST")
# pseudo-port returned by arbitration for destinations that became
# unreachable under an injected fault (dead router / dead link): the branch
# surfaces as recorded loss instead of stalling the fork forever.
LOST = -1

_BASE_AREA_ANCHORS = {64: 3620.0, 128: 6230.0, 256: 11520.0}
AREA_PER_DEST_UM2 = 200.0


def base_router_area(bitwidth: int) -> float:
    """Area of the unicast router at a given bitwidth (um^2), linearly
    interpolated/extrapolated between the paper's synthesis anchors."""
    ws = sorted(_BASE_AREA_ANCHORS)
    if bitwidth in _BASE_AREA_ANCHORS:
        return _BASE_AREA_ANCHORS[bitwidth]
    xs = np.array(ws, dtype=np.float64)
    ys = np.array([_BASE_AREA_ANCHORS[w] for w in ws], dtype=np.float64)
    slope, intercept = np.polyfit(xs, ys, 1)
    return float(slope * bitwidth + intercept)


def router_area(bitwidth: int, max_dests: int = 0) -> float:
    """Post-synthesis router area (um^2) with multicast support for up to
    ``max_dests`` destinations (0 = unicast baseline)."""
    return base_router_area(bitwidth) + AREA_PER_DEST_UM2 * max_dests


def dor_route(src: Tuple[int, int], dst: Tuple[int, int]) -> List[Tuple[int, int]]:
    """Dimension-ordered (X then Y) path, inclusive of both endpoints."""
    x, y = src
    path = [(x, y)]
    while x != dst[0]:
        x += 1 if dst[0] > x else -1
        path.append((x, y))
    while y != dst[1]:
        y += 1 if dst[1] > y else -1
        path.append((x, y))
    return path


def dor_route_yx(src: Tuple[int, int], dst: Tuple[int, int]) -> List[Tuple[int, int]]:
    """Dimension-ordered (Y then X) path, inclusive of both endpoints — the
    escape route the fault model falls back to when the XY path crosses a
    dead router or link."""
    x, y = src
    path = [(x, y)]
    while y != dst[1]:
        y += 1 if dst[1] > y else -1
        path.append((x, y))
    while x != dst[0]:
        x += 1 if dst[0] > x else -1
        path.append((x, y))
    return path


def _path_alive(path: Sequence[Tuple[int, int]], dead_nodes, dead_links) -> bool:
    for a, b in zip(path, path[1:]):
        if b in dead_nodes or (a, b) in dead_links:
            return False
    return True


def _port_toward(here: Tuple[int, int], nxt: Tuple[int, int]) -> int:
    if nxt[0] != here[0]:
        return EAST if nxt[0] > here[0] else WEST
    return SOUTH if nxt[1] > here[1] else NORTH


def fault_next_port(here: Tuple[int, int], dst: Tuple[int, int],
                    dead_nodes, dead_links) -> Optional[int]:
    """One-hop output port under an injected fault set, or ``None`` when
    ``dst`` is unreachable from ``here``.

    Deterministic escape routing: take the XY (DOR) path when it is fully
    alive, else the YX path when that one is, else give the destination up
    as lost.  Both candidate paths are suffix-consistent (the remainder of
    an alive path is itself the same dimension-ordered path from the next
    hop), and every hop strictly decreases the Manhattan distance, so
    per-hop re-evaluation can neither livelock nor strand a flit that was
    routable when forwarded — only a *new* fault can orphan it mid-flight,
    and then it surfaces as loss at its next arbitration."""
    if here == dst:
        return LOCAL
    if dst in dead_nodes:
        return None
    for path in (dor_route(here, dst), dor_route_yx(here, dst)):
        if _path_alive(path, dead_nodes, dead_links):
            return _port_toward(here, path[1])
    return None


def next_port(here: Tuple[int, int], dst: Tuple[int, int]) -> int:
    """Output port for one DOR hop (lookahead routing computes this for the
    *next* router; the arbitration is identical, so we model it per hop)."""
    if here == dst:
        return LOCAL
    if here[0] != dst[0]:
        return EAST if dst[0] > here[0] else WEST
    return SOUTH if dst[1] > here[1] else NORTH


def multicast_ports(here: Tuple[int, int],
                    dests: Sequence[Tuple[int, int]]) -> Dict[int, List[Tuple[int, int]]]:
    """Partition a destination list by the output port each takes from
    ``here`` — the replicated lookahead logic computing every destination's
    direction in parallel.  A flit is forked to every key port."""
    out: Dict[int, List[Tuple[int, int]]] = collections.defaultdict(list)
    for d in dests:
        out[next_port(here, d)].append(d)
    return dict(out)


class Router:
    """Single-plane router with per-input FIFO queues and one flit per
    output port per cycle (ESP: physical planes instead of virtual channels,
    single-cycle hop thanks to lookahead routing)."""

    def __init__(self, coord: Tuple[int, int]):
        self.coord = coord
        self.in_q: List[collections.deque] = [collections.deque() for _ in range(5)]
        self._rr = 0  # round-robin arbitration pointer
        # per-hop routing function (here, dst) -> port | None; the fault
        # model swaps in a fault-aware closure, None means plain DOR
        self.route_fn = None

    def accept(self, port: int, flit) -> None:
        self.in_q[port].append(flit)

    def arbitrate(self):
        """One cycle: pick flits to forward.  Returns a list of
        (out_port, flit_for_that_port) — a multicast flit appears on several
        ports, each copy carrying only that branch's destinations.  An input
        whose multicast fork cannot get ALL its ports this cycle stalls
        (ESP forwards to multiple output ports in parallel).  Destinations
        the routing function reports unreachable come back under the
        ``LOST`` pseudo-port; they occupy no output and never stall."""
        route = self.route_fn or next_port
        grants: Dict[int, Tuple[Dict, List]] = {}
        used_outs = set()
        for k in range(5):
            p = (self._rr + k) % 5
            if not self.in_q[p]:
                continue
            flit = self.in_q[p][0]
            ports: Dict[int, List[Tuple[int, int]]] = collections.defaultdict(list)
            lost: List[Tuple[int, int]] = []
            for d in flit.dests:
                port = route(self.coord, d)
                if port is None:
                    lost.append(d)
                else:
                    ports[port].append(d)
            if any(op in used_outs for op in ports):
                continue  # stall: fork needs all ports simultaneously
            used_outs.update(ports)
            grants[p] = (dict(ports), lost)
        out = []
        for p, (ports, lost) in grants.items():
            flit = self.in_q[p].popleft()
            if lost:
                out.append((LOST, flit.fork(lost)))
            for op, branch_dests in ports.items():
                out.append((op, flit.fork(branch_dests)))
        self._rr = (self._rr + 1) % 5
        return out
