from repro.core.noc.header import (BITS_PER_DEST, HEADER_OVERHEAD_BITS,
                                   ESP_MAX_DESTS, bits_per_dest,
                                   header_overhead_bits, max_multicast_dests,
                                   encode_header, decode_header)
from repro.core.noc.router import router_area, dor_route, next_port, Router
from repro.core.noc.simulator import MeshNoC, Message, Flit, mesh_coord_bits
from repro.core.noc.reference_sim import ReferenceMeshNoC
from repro.core.noc.perfmodel import SoCPerfModel, SoCParams

__all__ = [
    "BITS_PER_DEST", "HEADER_OVERHEAD_BITS", "ESP_MAX_DESTS",
    "bits_per_dest", "header_overhead_bits",
    "max_multicast_dests", "encode_header", "decode_header",
    "router_area", "dor_route", "next_port", "Router",
    "MeshNoC", "Message", "Flit", "mesh_coord_bits", "ReferenceMeshNoC",
    "SoCPerfModel", "SoCParams",
]
