"""Object-based flit-level mesh simulator (reference semantics).

This is the original dict-of-``Router`` implementation of the NoC
correctness model, kept as the executable specification the vectorized
struct-of-arrays stepper in ``simulator.py`` is property-tested against:
both must deliver identical (dest, msg_id, flit-order) sequences cycle for
cycle — fault injection (``inject_fault``: kill a router or link at cycle
*t*) included, down to the recorded ``lost`` set.  Use
:class:`~repro.core.noc.simulator.MeshNoC` for anything
performance-sensitive; this class walks every router as a Python object and
only scales to small meshes.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Tuple

from repro.core.noc.header import encode_header, max_multicast_dests
from repro.core.noc.router import (LOCAL, LOST, NORTH, SOUTH, EAST, WEST,
                                   Router, fault_next_port)
from repro.core.noc.simulator import Flit, Message, mesh_coord_bits

_OPPOSITE_ENTRY = {NORTH: SOUTH, SOUTH: NORTH, EAST: WEST, WEST: EAST}
_DELTA = {NORTH: (0, -1), SOUTH: (0, 1), EAST: (1, 0), WEST: (-1, 0)}


class ReferenceMeshNoC:
    """One physical plane of a W x H mesh (object-based reference)."""

    def __init__(self, width: int, height: int, bitwidth: int = 256):
        self.w, self.h = width, height
        self.bitwidth = bitwidth
        self.coord_bits = mesh_coord_bits(width, height)
        self.routers: Dict[Tuple[int, int], Router] = {
            (x, y): Router((x, y))
            for x in range(width) for y in range(height)}
        self.delivered: Dict[Tuple[int, int], List[Flit]] = {
            c: [] for c in self.routers}
        self._ids = itertools.count()
        self.cycles = 0
        self.total_hops = 0
        # future injections: (inject_cycle, arrival order, Message) heap —
        # the reference steps quiescent gaps one cycle at a time (this IS
        # the specification the vectorized fast-forward must match)
        self._pending: List[Tuple[int, int, Message]] = []
        self._inject_seq = 0
        # fault model: routers/links scheduled to die, the active dead sets,
        # and every (msg_id, seq, dest) flit copy that surfaced as loss
        self._fault_queue: List[Tuple[int, str, object]] = []
        self._dead_nodes = set()
        self._dead_links = set()
        self.lost: List[Tuple[int, int, Tuple[int, int]]] = []

    def inject_fault(self, *, router: Tuple[int, int] = None,
                     link: Tuple[Tuple[int, int], Tuple[int, int]] = None,
                     at_cycle: int = 0) -> None:
        """Schedule a fault: kill a ``router`` (x, y) or a directed ``link``
        ((x1, y1), (x2, y2)) at the start of cycle ``at_cycle``.  Flits
        queued inside a dead router are dropped and recorded in ``lost``;
        in-flight flits re-route around the fault (XY, then the YX escape
        path) or surface as loss at their next arbitration."""
        if (router is None) == (link is None):
            raise ValueError("pass exactly one of router= or link=")
        if router is not None:
            if router not in self.routers:
                raise ValueError(f"router {router} outside the mesh")
            self._fault_queue.append((at_cycle, "router", router))
        else:
            a, b = link
            if a not in self.routers or b not in self.routers or \
                    abs(a[0] - b[0]) + abs(a[1] - b[1]) != 1:
                raise ValueError(f"link {link} is not a mesh link")
            self._fault_queue.append((at_cycle, "link", (a, b)))

    def _activate_faults(self) -> None:
        fired = False
        rest = []
        for cyc, kind, payload in self._fault_queue:
            if cyc <= self.cycles:
                (self._dead_nodes if kind == "router"
                 else self._dead_links).add(payload)
                fired = True
            else:
                rest.append((cyc, kind, payload))
        self._fault_queue = rest
        if not fired:
            return
        # flits queued inside a dead router die with it
        for c in self._dead_nodes:
            for q in self.routers[c].in_q:
                while q:
                    f = q.popleft()
                    for d in f.dests:
                        self.lost.append((f.msg_id, f.seq, d))
        dead_n = frozenset(self._dead_nodes)
        dead_l = frozenset(self._dead_links)

        def route(here, dst, _n=dead_n, _l=dead_l):
            return fault_next_port(here, dst, _n, _l)

        for r in self.routers.values():
            r.route_fn = route

    def inject(self, msg: Message) -> int:
        cap = max_multicast_dests(self.bitwidth, coord_bits=self.coord_bits)
        if len(msg.dests) > cap:
            raise ValueError(f"{len(msg.dests)} dests > capacity {cap}")
        encode_header(msg.src, msg.dests, self.bitwidth,
                      coord_bits=self.coord_bits)  # validates coords
        msg.msg_id = next(self._ids)
        if msg.inject_cycle > self.cycles:
            heapq.heappush(self._pending,
                           (msg.inject_cycle, self._inject_seq, msg))
            self._inject_seq += 1
            return msg.msg_id
        self._enqueue(msg)
        return msg.msg_id

    def _enqueue(self, msg: Message) -> None:
        if msg.src in self._dead_nodes:
            # a dead source cannot inject: the whole message surfaces as loss
            for i in range(msg.n_payload_flits + 1):
                for d in msg.dests:
                    self.lost.append((msg.msg_id, i, d))
            return
        r = self.routers[msg.src]
        r.accept(LOCAL, Flit(msg.msg_id, 0, True, msg.src, tuple(msg.dests)))
        for i in range(msg.n_payload_flits):
            r.accept(LOCAL, Flit(msg.msg_id, i + 1, False, msg.src,
                                 tuple(msg.dests)))

    def _release_due(self) -> None:
        while self._pending and self._pending[0][0] <= self.cycles:
            self._enqueue(heapq.heappop(self._pending)[2])

    def step(self) -> bool:
        """One cycle.  Returns True if any flit moved (or time advanced
        toward a pending injection: a quiescent wait is still progress)."""
        if self._fault_queue:
            self._activate_faults()
        self._release_due()
        moved = False
        moves: List[Tuple[Tuple[int, int], int, Flit]] = []
        for coord, r in self.routers.items():
            if coord in self._dead_nodes:
                continue
            for out_port, flit in r.arbitrate():
                moves.append((coord, out_port, flit))
        for coord, out_port, flit in moves:
            moved = True
            if out_port == LOST:
                for d in flit.dests:
                    self.lost.append((flit.msg_id, flit.seq, d))
                continue
            if out_port == LOCAL:
                self.delivered[coord].append(flit)
                continue
            dx, dy = _DELTA[out_port]
            nxt = (coord[0] + dx, coord[1] + dy)
            assert nxt in self.routers, f"route fell off mesh at {coord}->{nxt}"
            self.total_hops += 1
            self.routers[nxt].accept(_OPPOSITE_ENTRY[out_port], flit)
        if moved:
            self.cycles += 1
        elif self._pending:
            # idle tick: nothing in flight, a future injection is waiting
            self.cycles += 1
            return True
        return moved

    def drain(self, max_cycles: int = 1_000_000) -> int:
        """Run until no traffic is in flight.  The consumption assumption
        guarantees this terminates; the cap catches livelock bugs."""
        for _ in range(max_cycles):
            if not self.step():
                return self.cycles
        raise RuntimeError("NoC failed to drain (deadlock/livelock?)")

    def received(self, coord: Tuple[int, int], msg_id: int) -> List[Flit]:
        return [f for f in self.delivered[coord] if f.msg_id == msg_id]
