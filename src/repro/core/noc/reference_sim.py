"""Object-based flit-level mesh simulator (reference semantics).

This is the original dict-of-``Router`` implementation of the NoC
correctness model, kept as the executable specification the vectorized
struct-of-arrays stepper in ``simulator.py`` is property-tested against:
both must deliver identical (dest, msg_id, flit-order) sequences cycle for
cycle.  Use :class:`~repro.core.noc.simulator.MeshNoC` for anything
performance-sensitive; this class walks every router as a Python object and
only scales to small meshes.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Tuple

from repro.core.noc.header import encode_header, max_multicast_dests
from repro.core.noc.router import LOCAL, NORTH, SOUTH, EAST, WEST, Router
from repro.core.noc.simulator import Flit, Message, mesh_coord_bits

_OPPOSITE_ENTRY = {NORTH: SOUTH, SOUTH: NORTH, EAST: WEST, WEST: EAST}
_DELTA = {NORTH: (0, -1), SOUTH: (0, 1), EAST: (1, 0), WEST: (-1, 0)}


class ReferenceMeshNoC:
    """One physical plane of a W x H mesh (object-based reference)."""

    def __init__(self, width: int, height: int, bitwidth: int = 256):
        self.w, self.h = width, height
        self.bitwidth = bitwidth
        self.coord_bits = mesh_coord_bits(width, height)
        self.routers: Dict[Tuple[int, int], Router] = {
            (x, y): Router((x, y))
            for x in range(width) for y in range(height)}
        self.delivered: Dict[Tuple[int, int], List[Flit]] = {
            c: [] for c in self.routers}
        self._ids = itertools.count()
        self.cycles = 0
        self.total_hops = 0
        # future injections: (inject_cycle, arrival order, Message) heap —
        # the reference steps quiescent gaps one cycle at a time (this IS
        # the specification the vectorized fast-forward must match)
        self._pending: List[Tuple[int, int, Message]] = []
        self._inject_seq = 0

    def inject(self, msg: Message) -> int:
        cap = max_multicast_dests(self.bitwidth, coord_bits=self.coord_bits)
        if len(msg.dests) > cap:
            raise ValueError(f"{len(msg.dests)} dests > capacity {cap}")
        encode_header(msg.src, msg.dests, self.bitwidth,
                      coord_bits=self.coord_bits)  # validates coords
        msg.msg_id = next(self._ids)
        if msg.inject_cycle > self.cycles:
            heapq.heappush(self._pending,
                           (msg.inject_cycle, self._inject_seq, msg))
            self._inject_seq += 1
            return msg.msg_id
        self._enqueue(msg)
        return msg.msg_id

    def _enqueue(self, msg: Message) -> None:
        r = self.routers[msg.src]
        r.accept(LOCAL, Flit(msg.msg_id, 0, True, msg.src, tuple(msg.dests)))
        for i in range(msg.n_payload_flits):
            r.accept(LOCAL, Flit(msg.msg_id, i + 1, False, msg.src,
                                 tuple(msg.dests)))

    def _release_due(self) -> None:
        while self._pending and self._pending[0][0] <= self.cycles:
            self._enqueue(heapq.heappop(self._pending)[2])

    def step(self) -> bool:
        """One cycle.  Returns True if any flit moved (or time advanced
        toward a pending injection: a quiescent wait is still progress)."""
        self._release_due()
        moved = False
        moves: List[Tuple[Tuple[int, int], int, Flit]] = []
        for coord, r in self.routers.items():
            for out_port, flit in r.arbitrate():
                moves.append((coord, out_port, flit))
        for coord, out_port, flit in moves:
            moved = True
            if out_port == LOCAL:
                self.delivered[coord].append(flit)
                continue
            dx, dy = _DELTA[out_port]
            nxt = (coord[0] + dx, coord[1] + dy)
            assert nxt in self.routers, f"route fell off mesh at {coord}->{nxt}"
            self.total_hops += 1
            self.routers[nxt].accept(_OPPOSITE_ENTRY[out_port], flit)
        if moved:
            self.cycles += 1
        elif self._pending:
            # idle tick: nothing in flight, a future injection is waiting
            self.cycles += 1
            return True
        return moved

    def drain(self, max_cycles: int = 1_000_000) -> int:
        """Run until no traffic is in flight.  The consumption assumption
        guarantees this terminates; the cap catches livelock bugs."""
        for _ in range(max_cycles):
            if not self.step():
                return self.cycles
        raise RuntimeError("NoC failed to drain (deadlock/livelock?)")

    def received(self, coord: Tuple[int, int], msg_id: int) -> List[Flit]:
        return [f for f in self.delivered[coord] if f.msg_id == msg_id]
