"""Burst-level discrete-event model of the paper's FPGA experiment (Fig. 6).

Reproduces the 1-producer / N-consumer traffic-generator dataflow on the
paper's 3x4 SoC (Fig. 5), comparing shared-memory communication against
multicast P2P.  Mechanisms modeled (the ones the paper credits for the
speedup):

* round-trip through the memory tile vs. direct forwarding;
* *invocation-granularity* synchronization in the baseline (consumers start
  only after the producer's whole invocation completes and the CPU serially
  re-invokes each consumer) vs. a single batched invocation round with
  *burst-granularity* P2P pipelining in multicast mode;
* multicast forking: one producer injection-port occupancy serves all N
  consumers (instead of N separate memory reads);
* multicast synchronization overhead: the producer drains N pull requests
  per burst through its ejection port ("synchronization overheads that
  require some degree of serialization", paper §4);
* contention: the memory tile's two DMA-plane ports and each accelerator's
  injection/ejection ports are single-server FIFO resources; DOR hop count
  is charged as latency (wormhole: hops + flits cycles).

The measured dataflow is producer->consumer delivery (the paper's baseline
definition: "the producer writes to main memory and then the N consumers
read the same data"); the identity traffic generator's own output lands in
its PLM, so consumer writes are excluded by default.

Cycle-approximate: link-internal contention is folded into the port model
(the 3x4 mesh's hot spots are the memory and producer ports).  Absolute
cycles differ from the 78 MHz FPGA; free constants (driver overheads,
memory latency) are calibrated once against three quoted milestones —
+72% (1 consumer, 4KB), +120% (16, 4KB), +203% (16, 1MB) — and the
benchmark reports both series plus the trend checks.

Two evaluation paths share the same semantics:

* the scalar DES (``shared_memory_cycles`` / ``multicast_cycles``) steps
  bursts through explicit FIFO resources — the authoritative reference;
* the batched path (``batch_cycles``) evaluates the *same* recurrences in
  closed form: the multicast pipeline collapses to a three-term max-plus
  expression, and the shared-memory consumer round-robin is iterated only
  until its max-plus state becomes periodic, after which the remaining
  bursts are jumped analytically.  Both paths are integer-valued in
  float64, so agreement with the scalar DES is bit-exact at every burst
  count — there is no extrapolation cap (see docs/perfmodel.md for the
  derivation).

``SoCParams`` is fully parametric (mesh size, tile placement, per-hop link
latency, generators per tile), so pod-scale profiles
(``SoCParams.pod(16, 16)``) price transfers on meshes far beyond the
calibrated 3x4 FPGA SoC; only the default 3x4 profile is calibrated
against the paper's milestones.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.noc.router import dor_route
from repro.core.noc.header import (max_multicast_dests, mesh_coord_bits,
                                   ESP_MAX_DESTS)


@dataclasses.dataclass
class SoCParams:
    mesh_w: int = 4
    mesh_h: int = 3
    bitwidth: int = 256               # paper's evaluated NoC
    burst_bytes: int = 4096           # traffic generator: 4KB per burst
    freq_mhz: float = 78.0            # Virtex US+ VCU128 prototype
    # Free constants calibrated once (grid search) against the paper's three
    # quoted milestones; see PAPER_MILESTONES below.  Model error after
    # calibration: -4% / -0.5% / +1.6% on the three milestones.
    mem_latency: int = 20             # DRAM access latency per burst (cycles)
    invocation_overhead: int = 7000   # CPU driver + interrupt, per round
    completion_frac: float = 0.5      # completion interrupt cost fraction
    baseline_start_cost: int = 1500   # serial per-consumer re-invocation
    mcast_start_cost: int = 500       # per-consumer cost of the batched round
    request_latency: int = 35         # per P2P request drained at producer
    consumer_write_bursts: bool = False
    # --- topology (defaults reproduce the calibrated 3x4 FPGA SoC) ---
    link_latency: int = 1             # cycles per mesh hop
    mem_tile: Tuple[int, int] = (0, 1)
    cpu_tile: Tuple[int, int] = (0, 0)
    io_tiles: Tuple[Tuple[int, int], ...] = ((0, 2),)
    accel_per_tile: int = 2           # traffic generators per accelerator tile
    n_accel: Optional[int] = 17       # total generators (None = fill tiles)
    # --- overlap objective (paper Fig. 6: the consumer starts on burst k
    # while burst k+1 is in flight) ---
    # FLOPs the modeled accelerator retires per NoC cycle; converts a
    # TransferSpec's declared consumer-matmul FLOPs into cycles on the same
    # clock the transfer is priced in.  Like the pod profiles this is a
    # relative knob (MEM-vs-direct comparisons), not a calibrated absolute.
    flops_per_cycle: float = 8192.0
    name: str = "espsoc-3x4"

    @property
    def flits_per_burst(self) -> int:
        return (self.burst_bytes * 8) // self.bitwidth

    @property
    def coord_bits(self) -> int:
        """Header coordinate field width for this mesh (>= ESP's 3 bits)."""
        return mesh_coord_bits(self.mesh_w, self.mesh_h)

    def accel_tiles(self) -> List[Tuple[int, int]]:
        """Tiles hosting the traffic generators, in invocation order.  The
        default profile places 17 generators 2-per-tile over the 9 free
        tiles of the 3x4 mesh (paper Fig. 5); pod profiles place one per
        free tile."""
        reserved = {self.mem_tile, self.cpu_tile, *self.io_tiles}
        tiles = [(x, y) for y in range(self.mesh_h) for x in range(self.mesh_w)
                 if (x, y) not in reserved]
        cap = (self.n_accel if self.n_accel is not None
               else self.accel_per_tile * len(tiles))
        out: List[Tuple[int, int]] = []
        for t in tiles * self.accel_per_tile:
            out.append(t)
            if len(out) == cap:
                break
        return out

    @classmethod
    def pod(cls, mesh_w: int = 16, mesh_h: int = 16, *,
            link_latency: int = 2, burst_bytes: int = 8192,
            name: Optional[str] = None, **overrides) -> "SoCParams":
        """Pod-scale profile: one generator per free tile, memory tile at
        the west-edge centre, 2-cycle links (longer wires at pod floorplan
        scale).  NOT calibrated against the FPGA milestones — use for
        relative MEM/P2P/MCAST comparisons, not absolute cycle claims."""
        kw = dict(mesh_w=mesh_w, mesh_h=mesh_h, link_latency=link_latency,
                  burst_bytes=burst_bytes,
                  mem_tile=(0, mesh_h // 2), cpu_tile=(0, 0),
                  io_tiles=((0, mesh_h - 1),),
                  accel_per_tile=1, n_accel=None,
                  name=name or f"pod-{mesh_w}x{mesh_h}")
        kw.update(overrides)
        return cls(**kw)


# ---------------------------------------------------- default-params install
# The process-wide default :class:`SoCParams`: what ``SoCPerfModel()`` — and
# therefore ``CommPlanner()`` and ``resolve_policy(model=None)`` — price
# against.  The calibration subsystem (``repro.calib``) installs fitted
# params here so every later pricing pass uses measured, not prior,
# constants.  The planner fingerprints the *effective* params into its
# plan-cache key (``resolve_policy``), so an install invalidates cached
# plans instead of silently aliasing them.
_DEFAULT_PARAMS: Optional[SoCParams] = None


def default_params() -> SoCParams:
    """The params ``SoCPerfModel()`` uses when none are passed — the
    built-in Fig. 6 calibration unless :func:`set_default_params` installed
    a fitted override."""
    return _DEFAULT_PARAMS if _DEFAULT_PARAMS is not None else SoCParams()


def set_default_params(params: Optional[SoCParams]) -> Optional[SoCParams]:
    """Install ``params`` as the process-wide default (``None`` restores
    the built-in calibration).  Returns the previous override so callers
    can restore it."""
    global _DEFAULT_PARAMS
    prev = _DEFAULT_PARAMS
    _DEFAULT_PARAMS = params
    return prev


@contextlib.contextmanager
def default_params_override(params: Optional[SoCParams]):
    """Scoped :func:`set_default_params` — the calibration CLI and tests
    price under fitted params without leaking them into later work."""
    prev = set_default_params(params)
    try:
        yield
    finally:
        set_default_params(prev)


class _Resource:
    """Single-server FIFO: start = max(ready, free); free = start + dur."""

    def __init__(self):
        self.free = 0.0

    def reserve(self, ready: float, duration: float) -> Tuple[float, float]:
        start = max(ready, self.free)
        self.free = start + duration
        return start, start + duration


def _hops(a: Tuple[int, int], b: Tuple[int, int]) -> int:
    return len(dor_route(a, b)) - 1


class SoCPerfModel:
    """One experiment = (n_consumers, data_bytes) -> cycles for each mode."""

    def __init__(self, params: Optional[SoCParams] = None):
        self.p = params or default_params()

    # -------------------------------------------------------------- helpers
    def _mem_burst(self, res_mem, ready: float, flits: int) -> float:
        """One burst through a memory-tile plane port; returns completion."""
        _, end = res_mem.reserve(ready, flits)
        return end + self.p.mem_latency

    def _hop_lat(self, a: Tuple[int, int], b: Tuple[int, int]) -> int:
        return _hops(a, b) * self.p.link_latency

    # ------------------------------------------------------------ baseline
    def shared_memory_cycles(self, n_consumers: int, data_bytes: int) -> float:
        p = self.p
        tiles = p.accel_tiles()
        prod, cons = tiles[0], tiles[1:1 + n_consumers]
        n_bursts = max(1, data_bytes // p.burst_bytes)
        F = p.flits_per_burst
        mem_rsp = _Resource()   # response plane (read data out of mem)
        mem_req = _Resource()   # request plane (write data into mem)

        # round 1: CPU invokes the producer, which loads each burst from
        # memory and writes it back (read/write channels overlap).
        t = float(p.invocation_overhead)
        read_done = t
        write_done = t
        h_pm = self._hop_lat(prod, p.mem_tile)
        for _ in range(n_bursts):
            read_done = self._mem_burst(mem_rsp, read_done, F) + h_pm
            write_done = self._mem_burst(mem_req, max(write_done, read_done),
                                         F) + h_pm
        prod_done = write_done

        # invocation-granularity sync: completion interrupt, then the CPU
        # serially re-invokes each consumer (one driver call per accelerator).
        t_round2 = prod_done + p.invocation_overhead
        start_at = {c: t_round2 + (i + 1) * p.baseline_start_cost
                    for i, c in enumerate(cons)}

        cons_read = dict(start_at)
        cons_write = dict(start_at)
        for _ in range(n_bursts):
            for c in cons:
                h_cm = self._hop_lat(c, p.mem_tile)
                rd = self._mem_burst(mem_rsp, cons_read[c], F) + h_cm
                cons_read[c] = rd
                if p.consumer_write_bursts:
                    cons_write[c] = self._mem_burst(
                        mem_req, max(cons_write[c], rd), F) + h_cm
        done = [max(cons_read[c], cons_write[c]) for c in cons]
        return max(done) + p.completion_frac * p.invocation_overhead

    # ----------------------------------------------------------- multicast
    def multicast_cycles(self, n_consumers: int, data_bytes: int) -> float:
        p = self.p
        if n_consumers > min(max_multicast_dests(p.bitwidth,
                                                 coord_bits=p.coord_bits),
                             ESP_MAX_DESTS):
            raise ValueError("consumer count exceeds multicast capacity")
        tiles = p.accel_tiles()
        prod, cons = tiles[0], tiles[1:1 + n_consumers]
        n_bursts = max(1, data_bytes // p.burst_bytes)
        F = p.flits_per_burst
        mem_rsp = _Resource()
        mem_req = _Resource()
        prod_inj = _Resource()  # producer injection port: one burst occupancy
        #                         serves all N consumers (the fork).
        prod_req = _Resource()  # producer ejection port draining pull requests

        # single batched invocation round: CPU configures producer + all N
        # consumers before starting the dataflow.
        t0 = p.invocation_overhead + p.mcast_start_cost * (1 + n_consumers)

        h_pm = self._hop_lat(prod, p.mem_tile)
        read_done = t0
        cons_recv = {c: t0 for c in cons}
        cons_write = {c: t0 for c in cons}
        for b in range(n_bursts):
            # producer loads burst from memory (as in the baseline)
            read_done = self._mem_burst(mem_rsp, read_done, F) + h_pm
            # pull-based sync: drain one request per consumer through the
            # producer's request queue (consumers pipeline requests 2 deep).
            req_ready = t0 if b < 2 else max(cons_recv.values())
            req_done = req_ready
            for c in cons:
                _, req_done = prod_req.reserve(
                    max(req_ready, req_done), p.request_latency)
            # one injection-port occupancy, forked to all consumers
            _, end = prod_inj.reserve(max(read_done, req_done), F)
            for c in cons:
                arrive = end + self._hop_lat(prod, c)
                cons_recv[c] = arrive
                if p.consumer_write_bursts:
                    cons_write[c] = self._mem_burst(
                        mem_req, max(cons_write[c], arrive),
                        F) + self._hop_lat(c, p.mem_tile)
        fin = [max(cons_recv[c], cons_write[c]) for c in cons]
        return max(fin) + p.completion_frac * p.invocation_overhead

    # ------------------------------------------------------- P2P (unicast)
    def p2p_cycles(self, data_bytes: int) -> float:
        """Direct producer->consumer transfer (the paper's P2P): the
        1-destination degenerate of the multicast path — same batched
        invocation round, same burst pipelining, a single pull stream.  The
        MEM-vs-P2P comparison is ``shared_memory_cycles(1, b)`` vs this."""
        return self.multicast_cycles(1, data_bytes)

    # ------------------------------------------------------------- speedup
    def speedup(self, n_consumers: int, data_bytes: int) -> float:
        base = self.shared_memory_cycles(n_consumers, data_bytes)
        mc = self.multicast_cycles(n_consumers, data_bytes)
        return base / mc

    def sweep(self, consumers=(1, 2, 4, 8, 16),
              sizes=(4096, 16384, 65536, 262144, 1048576, 4194304)):
        """Paper Fig. 6 grid.  Returns {(n, bytes): speedup}.

        Evaluated through the closed-form batch path (bit-exact vs the
        scalar DES; fan-outs above the multicast capacity yield NaN where
        the scalar path would raise)."""
        grid = [(n, s) for n in consumers for s in sizes]
        out = self.batch_cycles(np.array([g[0] for g in grid]),
                                np.array([g[1] for g in grid]))
        sp = out["mem"] / out["mcast"]
        return {g: float(sp[i]) for i, g in enumerate(grid)}

    # ---------------------------------------------------- batched (planner)
    @property
    def max_dests(self) -> int:
        """Multicast destination capacity: header-flit bound for this NoC
        bitwidth and mesh coordinate range, ESP's hard cap, and the tile
        budget of the modeled SoC."""
        return min(max_multicast_dests(self.p.bitwidth,
                                       coord_bits=self.p.coord_bits),
                   ESP_MAX_DESTS, len(self.p.accel_tiles()) - 1)

    def batch_cycles(self, n_consumers: Sequence[int],
                     data_bytes: Sequence[int]) -> Dict[str, np.ndarray]:
        """Vectorized sweep: cycles for every mode over a batch of
        (fan-out, bytes) experiment points in one call.

        Returns ``{"mem": ..., "p2p": ..., "mcast": ...}`` float arrays
        aligned with the inputs; ``mcast`` is NaN where the fan-out exceeds
        the multicast capacity (the planner treats NaN as infeasible and
        falls back to MEM).  ``p2p`` is the 1-consumer direct path
        regardless of the requested fan-out (NaN above fan-out 1).  Both
        columns are evaluated in closed form and agree bit-exactly with the
        scalar DES at every burst count (all quantities are integer-valued
        float64, so there is no rounding slack to absorb)."""
        n = np.asarray(n_consumers, dtype=np.int64)
        d = np.asarray(data_bytes, dtype=np.int64)
        if n.shape != d.shape:
            raise ValueError(f"shape mismatch: {n.shape} vs {d.shape}")
        bursts = np.maximum(1, d // self.p.burst_bytes)

        mem = self._batch_mem(n, bursts)
        mcast = self._batch_mcast(n, bursts)
        mcast = np.where((n >= 1) & (n <= self.max_dests), mcast, np.nan)
        p2p = self._batch_mcast(np.ones_like(n), bursts)
        p2p = np.where(n == 1, p2p, np.nan)
        return {"mem": mem, "p2p": p2p, "mcast": mcast}

    def _consumer_hops(self) -> Tuple[np.ndarray, np.ndarray]:
        """Hop latency consumer_i -> memory tile and producer -> consumer_i
        for the configured tile placement, as (h_cm, h_pc) arrays."""
        tiles = self.p.accel_tiles()
        prod, cons = tiles[0], tiles[1:]
        h_cm = np.array([self._hop_lat(c, self.p.mem_tile) for c in cons],
                        float)
        h_pc = np.array([self._hop_lat(prod, c) for c in cons], float)
        return h_cm, h_pc

    # Periodicity detection window for the shared-memory consumer round:
    # the max-plus round map can settle into a cycle of more than one round
    # (multiple critical cycles), so deltas are checked against up to
    # _PERIOD_MAX rounds back before jumping the remaining bursts.
    _PERIOD_MAX = 4
    # Hard bound on iterated rounds (transient + leftovers).  The transient
    # before periodicity is tens of rounds in practice; the cap only guards
    # against a pathological parameterization never settling.
    _ROUNDS_CAP = 1 << 16

    def _batch_mem(self, n: np.ndarray, bursts: np.ndarray) -> np.ndarray:
        """Vectorized ``shared_memory_cycles`` over experiment points.

        The producer round collapses to its closed form (the memory
        response port never back-pressures a single producer).  The
        consumer round — n consumers round-robin through the single
        response-plane port — is a max-plus linear recurrence on the state
        vector (port free time, per-tile-slot read completion): it is
        iterated round-by-round only until the state advances by a uniform
        per-round increment (the steady-state period, reached after the
        re-invocation stagger drains), after which the remaining bursts are
        added analytically.  Exact: the round map is max-plus homogeneous,
        so a uniform increment over p rounds persists forever, and all
        quantities are integer-valued float64.

        Faithful to the scalar DES's tile semantics: two traffic generators
        on the same tile share one read-state slot (the scalar model keys
        consumer state by tile coordinate), and the re-invocation stagger of
        a slot is that of its later co-tenant.
        """
        p = self.p
        if p.consumer_write_bursts:
            raise NotImplementedError("batch path models read-side delivery "
                                      "(consumer_write_bursts=False)")
        F, L, I = float(p.flits_per_burst), float(p.mem_latency), \
            float(p.invocation_overhead)
        tiles = p.accel_tiles()
        h_pm = float(self._hop_lat(tiles[0], p.mem_tile))
        cons_tiles = tiles[1:]
        n = np.minimum(n, len(cons_tiles))   # tile budget bounds fan-out
        # tile-coordinate slots: consumer i -> slot slot_of[i]
        coords: List[Tuple[int, int]] = []
        slot_of = []
        for c in cons_tiles:
            if c not in coords:
                coords.append(c)
            slot_of.append(coords.index(c))
        n_slots = len(coords)
        h_slot = np.array([self._hop_lat(c, p.mem_tile) for c in coords],
                          float)
        # last_idx[k, m]: highest consumer index < m living on tile k (-1 if
        # none) — the stagger that survives the scalar model's dict collapse
        last_idx = np.full((n_slots, len(cons_tiles) + 1), -1, dtype=np.int64)
        for m in range(1, len(cons_tiles) + 1):
            last_idx[:, m] = last_idx[:, m - 1]
            last_idx[slot_of[m - 1], m] = m - 1
        n_max = int(np.max(n))
        G = F + L + h_pm
        bursts_f = bursts.astype(float)

        prod_done = I + (bursts_f + 1.0) * G
        t2 = prod_done + I
        # response-plane port free time after the producer's reads
        free = I + (bursts_f - 1.0) * G + F
        used = last_idx[:, n].T >= 0                            # (P, n_slots)
        slot_read = t2[:, None] + (last_idx[:, n].T + 1.0) * \
            p.baseline_start_cost
        single_tenant = all(slot_of[i] == i for i in range(n_max))

        rounds_left = (bursts.astype(np.int64).copy() if n_max > 0
                       else np.zeros(len(bursts), dtype=np.int64))
        can_jump = np.ones(len(rounds_left), dtype=bool)
        hist: List[Tuple[np.ndarray, np.ndarray]] = []
        iterated = 0
        while np.any(rounds_left > 0):
            live = rounds_left > 0
            free, slot_read = self._mem_round(
                live, n, free, slot_read, slot_of, h_slot, n_max, F, L,
                single_tenant)
            rounds_left = rounds_left - live
            iterated += 1
            hist.append((free.copy(), slot_read.copy()))
            if len(hist) > self._PERIOD_MAX + 1:
                hist.pop(0)
            for per in range(1, len(hist)):
                f_old, s_old = hist[-1 - per]
                df = free - f_old                               # (P,)
                uniform = np.all((slot_read - s_old == df[:, None]) | ~used,
                                 axis=1)
                jump = live & can_jump & uniform & (rounds_left >= per)
                if np.any(jump):
                    q = rounds_left[jump] // per
                    add = q * df[jump]
                    free[jump] += add
                    slot_read[jump] += add[:, None]
                    rounds_left[jump] -= q * per
                    # history is stale for jumped points: at most per-1
                    # leftover rounds remain, iterate them plainly
                    can_jump[jump] = False
            if iterated > self._ROUNDS_CAP:   # pragma: no cover - guard
                raise RuntimeError(
                    "shared-memory batch path failed to reach steady state "
                    f"within {self._ROUNDS_CAP} rounds ({p.name})")
        done = np.max(np.where(used, slot_read, -np.inf), axis=1)
        return done + p.completion_frac * I

    def _mem_round(self, live, n, free, slot_read, slot_of, h_slot, n_max,
                   F, L, single_tenant):
        """One consumer round (one burst through every active consumer) of
        the shared-memory recurrence, advanced for all live points."""
        if single_tenant:
            # n distinct tiles served in slot order: the single-server
            # round-robin collapses to a prefix max.  Service i ends at
            #   serve_i = max(max_{j<=i}(ready_j - j*F), free) + (i+1)*F
            idx = np.arange(n_max)
            active = live[:, None] & (idx[None, :] < n[:, None])
            ready = np.where(active, slot_read[:, :n_max], -np.inf)
            run = np.maximum.accumulate(ready - idx * F, axis=1)
            serve = np.maximum(run, free[:, None]) + (idx + 1.0) * F
            slot_read = slot_read.copy()
            slot_read[:, :n_max] = np.where(
                active, serve + L + h_slot[None, :n_max],
                slot_read[:, :n_max])
            last = np.clip(n - 1, 0, n_max - 1)
            free = np.where(live & (n > 0),
                            serve[np.arange(len(n)), last], free)
            return free, slot_read
        # co-tenant tiles couple consecutive services of one slot within a
        # round: step consumers in invocation order (n_max <= 2x tiles)
        free = free.copy()
        slot_read = slot_read.copy()
        for i in range(n_max):
            k = slot_of[i]
            act = live & (i < n)
            end = np.maximum(slot_read[:, k], free) + F
            slot_read[:, k] = np.where(act, end + L + h_slot[k],
                                       slot_read[:, k])
            free = np.where(act, end, free)
        return free, slot_read

    def _batch_mcast(self, n: np.ndarray, bursts: np.ndarray) -> np.ndarray:
        """Closed-form ``multicast_cycles``: with E_b the forked injection
        end of burst b, the DES recurrence collapses to

            E_b = max(read_b, req_b, E_{b-1}) + F
            read_b = t0 + (b+1) G,      G = F + mem_latency + h(prod,mem)
            req_b  = E_{b-1} + maxh + n R          (b >= 2; pipelined 2 deep)

        so E_b = max(t0 + (b+1) G + F, E_{b-1} + B) with
        B = maxh + n R + F, whose unrolled max over the crossover burst is
        attained at an endpoint — three terms, no loop."""
        p = self.p
        if p.consumer_write_bursts:
            raise NotImplementedError("batch path models read-side delivery "
                                      "(consumer_write_bursts=False)")
        F, L, I = float(p.flits_per_burst), float(p.mem_latency), \
            float(p.invocation_overhead)
        tiles = p.accel_tiles()
        h_pm = float(self._hop_lat(tiles[0], p.mem_tile))
        _, h_pc = self._consumer_hops()
        # farthest consumer among the first n (prefix max of the hop table)
        maxh = np.maximum.accumulate(h_pc)[np.clip(n, 1, len(h_pc)) - 1]
        nf = n.astype(float)
        R = float(p.request_latency)
        G = F + L + h_pm
        B = maxh + nf * R + F

        t0 = I + p.mcast_start_cost * (1.0 + nf)
        # bursts 0 and 1: requests ride the start-up window (req_ready = t0)
        e0 = t0 + np.maximum(G, nf * R) + F
        e1 = np.maximum(np.maximum(t0 + 2.0 * G, t0 + 2.0 * nf * R), e0) + F
        # last burst index bl >= 2: E_bl = max over the burst j in [2, bl]
        # where the read chain last binds; linear in j, so endpoints only.
        bl = bursts.astype(float) - 1.0
        egen = np.maximum(
            np.maximum(e1 + (bl - 1.0) * B,              # request chain only
                       t0 + (bl + 1.0) * G + F),         # read-bound to the end
            t0 + 3.0 * G + F + (bl - 2.0) * B)           # crossover at j = 2
        e_last = np.where(bursts == 1, e0, np.where(bursts == 2, e1, egen))
        return e_last + maxh + p.completion_frac * I


    # ------------------------------------------------- overlap objective
    @property
    def overlap_ramp_cycles(self) -> float:
        """Pipeline-fill cost of a fused (burst-pipelined) transfer: the
        consumer cannot start until the first burst has been requested and
        delivered, so one request handshake plus one burst transmission is
        never hidden, however perfectly the rest overlaps."""
        return float(self.p.flits_per_burst + self.p.request_latency)

    def compute_cycles(self, flops: float) -> float:
        """Cycles the declared consumer compute occupies on this SoC's
        clock (0 FLOPs -> 0 cycles: nothing to hide behind)."""
        return float(flops) / self.p.flops_per_cycle

    def overlapped_cycles(self, comm: float, compute: float) -> float:
        """Fused cost of a transfer feeding ``compute`` cycles of consumer
        work: ``max(comm, compute) + ramp`` (paper Fig. 6 — bursts stream
        while the consumer works on the previous one), with the ramp
        clamped so overlap never charges more than the serial sum."""
        return overlapped_cycles(comm, compute, self.overlap_ramp_cycles)


def overlapped_cycles(comm: float, compute: float, ramp: float) -> float:
    """``max(comm, compute) + min(ramp, comm, compute)``.

    The clamp makes the objective sound without case analysis: with no
    declared compute the ramp vanishes and the fused cost IS the comm cost,
    and in general ``overlapped <= comm + compute`` (the serial sum), with
    equality exactly when there is nothing to hide behind."""
    return max(comm, compute) + min(ramp, comm, compute)


# Paper-quoted milestones used for calibration and the benchmark's checks.
PAPER_MILESTONES = {
    (1, 4096): 1.72,        # "72% speedup compared to the baseline"
    (16, 4096): 2.20,       # "a multicast to 16 consumers gives ... 120%"
    (16, 1048576): 3.03,    # "maximum speedup of 203% ... 16 consumers, 1MB"
}
