"""Burst-level discrete-event model of the paper's FPGA experiment (Fig. 6).

Reproduces the 1-producer / N-consumer traffic-generator dataflow on the
paper's 3x4 SoC (Fig. 5), comparing shared-memory communication against
multicast P2P.  Mechanisms modeled (the ones the paper credits for the
speedup):

* round-trip through the memory tile vs. direct forwarding;
* *invocation-granularity* synchronization in the baseline (consumers start
  only after the producer's whole invocation completes and the CPU serially
  re-invokes each consumer) vs. a single batched invocation round with
  *burst-granularity* P2P pipelining in multicast mode;
* multicast forking: one producer injection-port occupancy serves all N
  consumers (instead of N separate memory reads);
* multicast synchronization overhead: the producer drains N pull requests
  per burst through its ejection port ("synchronization overheads that
  require some degree of serialization", paper §4);
* contention: the memory tile's two DMA-plane ports and each accelerator's
  injection/ejection ports are single-server FIFO resources; DOR hop count
  is charged as latency (wormhole: hops + flits cycles).

The measured dataflow is producer->consumer delivery (the paper's baseline
definition: "the producer writes to main memory and then the N consumers
read the same data"); the identity traffic generator's own output lands in
its PLM, so consumer writes are excluded by default.

Cycle-approximate: link-internal contention is folded into the port model
(the 3x4 mesh's hot spots are the memory and producer ports).  Absolute
cycles differ from the 78 MHz FPGA; free constants (driver overheads,
memory latency) are calibrated once against three quoted milestones —
+72% (1 consumer, 4KB), +120% (16, 4KB), +203% (16, 1MB) — and the
benchmark reports both series plus the trend checks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.noc.router import dor_route
from repro.core.noc.header import max_multicast_dests, ESP_MAX_DESTS


@dataclasses.dataclass
class SoCParams:
    mesh_w: int = 4
    mesh_h: int = 3
    bitwidth: int = 256               # paper's evaluated NoC
    burst_bytes: int = 4096           # traffic generator: 4KB per burst
    freq_mhz: float = 78.0            # Virtex US+ VCU128 prototype
    # Free constants calibrated once (grid search) against the paper's three
    # quoted milestones; see PAPER_MILESTONES below.  Model error after
    # calibration: -4% / -0.5% / +1.6% on the three milestones.
    mem_latency: int = 20             # DRAM access latency per burst (cycles)
    invocation_overhead: int = 7000   # CPU driver + interrupt, per round
    completion_frac: float = 0.5      # completion interrupt cost fraction
    baseline_start_cost: int = 1500   # serial per-consumer re-invocation
    mcast_start_cost: int = 500       # per-consumer cost of the batched round
    request_latency: int = 35         # per P2P request drained at producer
    consumer_write_bursts: bool = False

    @property
    def flits_per_burst(self) -> int:
        return (self.burst_bytes * 8) // self.bitwidth

    # tile placement after paper Fig. 5: CPU, MEM, IO + accelerator tiles.
    @property
    def mem_tile(self) -> Tuple[int, int]:
        return (0, 1)

    @property
    def cpu_tile(self) -> Tuple[int, int]:
        return (0, 0)

    def accel_tiles(self) -> List[Tuple[int, int]]:
        reserved = {self.mem_tile, self.cpu_tile, (0, 2)}  # (0,2) = IO
        tiles = [(x, y) for y in range(self.mesh_h) for x in range(self.mesh_w)
                 if (x, y) not in reserved]
        # 9 accelerator tiles host the 17 traffic generators (2 per tile,
        # one tile with a single instance) — paper Fig. 5.
        out: List[Tuple[int, int]] = []
        for t in tiles + tiles:
            out.append(t)
            if len(out) == 17:
                break
        return out


class _Resource:
    """Single-server FIFO: start = max(ready, free); free = start + dur."""

    def __init__(self):
        self.free = 0.0

    def reserve(self, ready: float, duration: float) -> Tuple[float, float]:
        start = max(ready, self.free)
        self.free = start + duration
        return start, start + duration


def _hops(a: Tuple[int, int], b: Tuple[int, int]) -> int:
    return len(dor_route(a, b)) - 1


class SoCPerfModel:
    """One experiment = (n_consumers, data_bytes) -> cycles for each mode."""

    def __init__(self, params: Optional[SoCParams] = None):
        self.p = params or SoCParams()

    # -------------------------------------------------------------- helpers
    def _mem_burst(self, res_mem, ready: float, flits: int) -> float:
        """One burst through a memory-tile plane port; returns completion."""
        _, end = res_mem.reserve(ready, flits)
        return end + self.p.mem_latency

    # ------------------------------------------------------------ baseline
    def shared_memory_cycles(self, n_consumers: int, data_bytes: int) -> float:
        p = self.p
        tiles = p.accel_tiles()
        prod, cons = tiles[0], tiles[1:1 + n_consumers]
        n_bursts = max(1, data_bytes // p.burst_bytes)
        F = p.flits_per_burst
        mem_rsp = _Resource()   # response plane (read data out of mem)
        mem_req = _Resource()   # request plane (write data into mem)

        # round 1: CPU invokes the producer, which loads each burst from
        # memory and writes it back (read/write channels overlap).
        t = float(p.invocation_overhead)
        read_done = t
        write_done = t
        h_pm = _hops(prod, p.mem_tile)
        for _ in range(n_bursts):
            read_done = self._mem_burst(mem_rsp, read_done, F) + h_pm
            write_done = self._mem_burst(mem_req, max(write_done, read_done),
                                         F) + h_pm
        prod_done = write_done

        # invocation-granularity sync: completion interrupt, then the CPU
        # serially re-invokes each consumer (one driver call per accelerator).
        t_round2 = prod_done + p.invocation_overhead
        start_at = {c: t_round2 + (i + 1) * p.baseline_start_cost
                    for i, c in enumerate(cons)}

        cons_read = dict(start_at)
        cons_write = dict(start_at)
        for _ in range(n_bursts):
            for c in cons:
                h_cm = _hops(c, p.mem_tile)
                rd = self._mem_burst(mem_rsp, cons_read[c], F) + h_cm
                cons_read[c] = rd
                if p.consumer_write_bursts:
                    cons_write[c] = self._mem_burst(
                        mem_req, max(cons_write[c], rd), F) + h_cm
        done = [max(cons_read[c], cons_write[c]) for c in cons]
        return max(done) + p.completion_frac * p.invocation_overhead

    # ----------------------------------------------------------- multicast
    def multicast_cycles(self, n_consumers: int, data_bytes: int) -> float:
        p = self.p
        if n_consumers > min(max_multicast_dests(p.bitwidth), ESP_MAX_DESTS):
            raise ValueError("consumer count exceeds multicast capacity")
        tiles = p.accel_tiles()
        prod, cons = tiles[0], tiles[1:1 + n_consumers]
        n_bursts = max(1, data_bytes // p.burst_bytes)
        F = p.flits_per_burst
        mem_rsp = _Resource()
        mem_req = _Resource()
        prod_inj = _Resource()  # producer injection port: one burst occupancy
        #                         serves all N consumers (the fork).
        prod_req = _Resource()  # producer ejection port draining pull requests

        # single batched invocation round: CPU configures producer + all N
        # consumers before starting the dataflow.
        t0 = p.invocation_overhead + p.mcast_start_cost * (1 + n_consumers)

        h_pm = _hops(prod, p.mem_tile)
        read_done = t0
        cons_recv = {c: t0 for c in cons}
        cons_write = {c: t0 for c in cons}
        for b in range(n_bursts):
            # producer loads burst from memory (as in the baseline)
            read_done = self._mem_burst(mem_rsp, read_done, F) + h_pm
            # pull-based sync: drain one request per consumer through the
            # producer's request queue (consumers pipeline requests 2 deep).
            req_ready = t0 if b < 2 else max(cons_recv.values())
            req_done = req_ready
            for c in cons:
                _, req_done = prod_req.reserve(
                    max(req_ready, req_done), p.request_latency)
            # one injection-port occupancy, forked to all consumers
            _, end = prod_inj.reserve(max(read_done, req_done), F)
            for c in cons:
                arrive = end + _hops(prod, c)
                cons_recv[c] = arrive
                if p.consumer_write_bursts:
                    cons_write[c] = self._mem_burst(
                        mem_req, max(cons_write[c], arrive), F) + _hops(
                            c, p.mem_tile)
        fin = [max(cons_recv[c], cons_write[c]) for c in cons]
        return max(fin) + p.completion_frac * p.invocation_overhead

    # ------------------------------------------------------- P2P (unicast)
    def p2p_cycles(self, data_bytes: int) -> float:
        """Direct producer->consumer transfer (the paper's P2P): the
        1-destination degenerate of the multicast path — same batched
        invocation round, same burst pipelining, a single pull stream.  The
        MEM-vs-P2P comparison is ``shared_memory_cycles(1, b)`` vs this."""
        return self.multicast_cycles(1, data_bytes)

    # ------------------------------------------------------------- speedup
    def speedup(self, n_consumers: int, data_bytes: int) -> float:
        base = self.shared_memory_cycles(n_consumers, data_bytes)
        mc = self.multicast_cycles(n_consumers, data_bytes)
        return base / mc

    def sweep(self, consumers=(1, 2, 4, 8, 16),
              sizes=(4096, 16384, 65536, 262144, 1048576, 4194304)):
        """Paper Fig. 6 grid.  Returns {(n, bytes): speedup}."""
        return {(n, s): self.speedup(n, s) for n in consumers for s in sizes}

    # ---------------------------------------------------- batched (planner)
    @property
    def max_dests(self) -> int:
        """Multicast destination capacity: header-flit bound for this NoC
        bitwidth, ESP's hard cap, and the tile budget of the modeled SoC."""
        return min(max_multicast_dests(self.p.bitwidth), ESP_MAX_DESTS,
                   len(self.p.accel_tiles()) - 1)

    # Burst cap for the vectorized path: points beyond it are simulated to
    # the cap and linearly extrapolated from the steady-state rate (the DES
    # is periodic once ports saturate).  4x the largest Fig. 6 point, so the
    # whole paper grid stays exact.
    BATCH_BURST_CAP = 4096
    _BATCH_SLOPE_WINDOW = 64

    def batch_cycles(self, n_consumers: Sequence[int],
                     data_bytes: Sequence[int]) -> Dict[str, np.ndarray]:
        """Vectorized sweep: cycles for every mode over a batch of
        (fan-out, bytes) experiment points in one call.

        Returns ``{"mem": ..., "p2p": ..., "mcast": ...}`` float arrays
        aligned with the inputs; ``mcast`` is NaN where the fan-out exceeds
        the multicast capacity (the planner treats NaN as infeasible and
        falls back to MEM).  ``p2p`` is the 1-consumer direct path
        regardless of the requested fan-out (NaN above fan-out 1).  Exact
        match with the scalar DES up to ``BATCH_BURST_CAP`` bursts per
        transfer; beyond that, steady-state extrapolation.
        """
        n = np.asarray(n_consumers, dtype=np.int64)
        d = np.asarray(data_bytes, dtype=np.int64)
        if n.shape != d.shape:
            raise ValueError(f"shape mismatch: {n.shape} vs {d.shape}")
        bursts = np.maximum(1, d // self.p.burst_bytes)

        mem = self._eval_extrapolated(self._batch_mem, n, bursts)
        mcast = self._eval_extrapolated(self._batch_mcast, n, bursts)
        mcast = np.where((n >= 1) & (n <= self.max_dests), mcast, np.nan)
        p2p = self._eval_extrapolated(self._batch_mcast,
                                      np.ones_like(n), bursts)
        p2p = np.where(n == 1, p2p, np.nan)
        return {"mem": mem, "p2p": p2p, "mcast": mcast}

    def _eval_extrapolated(self, fn, n: np.ndarray, bursts: np.ndarray
                           ) -> np.ndarray:
        cap, win = self.BATCH_BURST_CAP, self._BATCH_SLOPE_WINDOW
        big = bursts > cap
        out = fn(n, np.minimum(bursts, cap))
        if np.any(big):
            lo = fn(n[big], np.full(np.sum(big), cap - win))
            rate = (out[big] - lo) / win
            out = out.astype(float)
            out[big] += (bursts[big] - cap) * rate
        return out

    def _consumer_hops(self) -> np.ndarray:
        """Hop count consumer_i -> memory tile and producer -> consumer_i
        for the fixed tile placement, as (h_cm, h_pc) arrays."""
        tiles = self.p.accel_tiles()
        prod, cons = tiles[0], tiles[1:]
        h_cm = np.array([_hops(c, self.p.mem_tile) for c in cons], float)
        h_pc = np.array([_hops(prod, c) for c in cons], float)
        return h_cm, h_pc

    def _batch_mem(self, n: np.ndarray, bursts: np.ndarray) -> np.ndarray:
        """Vectorized ``shared_memory_cycles`` over experiment points: the
        producer round collapses to its closed form (the memory response
        port never back-pressures a single producer); the consumer round —
        n consumers round-robin through the single response-plane port — is
        stepped burst-by-burst with all points advancing together.

        Faithful to the scalar DES's tile semantics: two traffic generators
        on the same tile share one read-state slot (the scalar model keys
        consumer state by tile coordinate), and the re-invocation stagger of
        a slot is that of its later co-tenant.
        """
        p = self.p
        if p.consumer_write_bursts:
            raise NotImplementedError("batch path models read-side delivery "
                                      "(consumer_write_bursts=False)")
        F, L, I = float(p.flits_per_burst), float(p.mem_latency), \
            float(p.invocation_overhead)
        tiles = p.accel_tiles()
        h_pm = float(_hops(tiles[0], p.mem_tile))
        cons_tiles = tiles[1:]
        n = np.minimum(n, len(cons_tiles))   # tile budget bounds fan-out
        # tile-coordinate slots: consumer i -> slot slot_of[i]
        coords: List[Tuple[int, int]] = []
        slot_of = []
        for c in cons_tiles:
            if c not in coords:
                coords.append(c)
            slot_of.append(coords.index(c))
        n_slots = len(coords)
        h_slot = np.array([_hops(c, p.mem_tile) for c in coords], float)
        # last_idx[k, m]: highest consumer index < m living on tile k (-1 if
        # none) — the stagger that survives the scalar model's dict collapse
        last_idx = np.full((n_slots, len(cons_tiles) + 1), -1, dtype=np.int64)
        for m in range(1, len(cons_tiles) + 1):
            last_idx[:, m] = last_idx[:, m - 1]
            last_idx[slot_of[m - 1], m] = m - 1
        n_max = int(np.max(n))
        b_max = int(np.max(bursts))

        prod_done = I + (bursts + 1.0) * (F + L + h_pm)
        t2 = prod_done + I
        # response-plane port free time after the producer's reads
        free = I + (bursts - 1.0) * (F + L + h_pm) + F
        used = last_idx[:, n].T >= 0                            # (P, n_slots)
        slot_read = t2[:, None] + (last_idx[:, n].T + 1.0) * \
            p.baseline_start_cost
        for j in range(b_max):
            for i in range(n_max):
                k = slot_of[i]
                active = (j < bursts) & (i < n)
                start = np.maximum(slot_read[:, k], free)
                end = start + F
                slot_read[:, k] = np.where(active, end + L + h_slot[k],
                                           slot_read[:, k])
                free = np.where(active, end, free)
        done = np.max(np.where(used, slot_read, -np.inf), axis=1)
        return done + p.completion_frac * I

    def _batch_mcast(self, n: np.ndarray, bursts: np.ndarray) -> np.ndarray:
        """Vectorized ``multicast_cycles``: the per-burst consumer loop
        collapses (the request drain is a pure chain through the producer's
        ejection port: n * request_latency past the ready point; delivery is
        one forked injection + the max consumer hop)."""
        p = self.p
        if p.consumer_write_bursts:
            raise NotImplementedError("batch path models read-side delivery "
                                      "(consumer_write_bursts=False)")
        F, L, I = float(p.flits_per_burst), float(p.mem_latency), \
            float(p.invocation_overhead)
        tiles = p.accel_tiles()
        h_pm = float(_hops(tiles[0], p.mem_tile))
        _, h_pc = self._consumer_hops()
        # farthest consumer among the first n (prefix max of the hop table)
        maxh = np.maximum.accumulate(h_pc)[np.clip(n, 1, len(h_pc)) - 1]
        b_max = int(np.max(bursts))

        t0 = I + p.mcast_start_cost * (1.0 + n)
        req_free = np.zeros_like(t0)
        inj_free = np.zeros_like(t0)
        end_prev = np.array(t0)
        for b in range(b_max):
            active = b < bursts
            read_done = t0 + (b + 1.0) * (F + L + h_pm)
            req_ready = t0 if b < 2 else end_prev + maxh
            req_done = np.maximum(req_ready, req_free) + \
                n * float(p.request_latency)
            end = np.maximum(np.maximum(read_done, req_done), inj_free) + F
            req_free = np.where(active, req_done, req_free)
            inj_free = np.where(active, end, inj_free)
            end_prev = np.where(active, end, end_prev)
        return end_prev + maxh + p.completion_frac * I


# Paper-quoted milestones used for calibration and the benchmark's checks.
PAPER_MILESTONES = {
    (1, 4096): 1.72,        # "72% speedup compared to the baseline"
    (16, 4096): 2.20,       # "a multicast to 16 consumers gives ... 120%"
    (16, 1048576): 3.03,    # "maximum speedup of 203% ... 16 consumers, 1MB"
}
