"""Flit-level 2-D mesh NoC simulator (correctness model), vectorized.

Used by the property tests and the ``noc_mesh_scale`` benchmark to validate
the routing/multicast *mechanism*: dimension-ordered paths, multicast
forking to exactly the destination set, in-order per-message delivery, and
drain (consumption assumption: finite traffic always drains — no routing
deadlock under DOR).

The simulator is a struct-of-arrays NumPy cycle stepper: every in-flight
flit copy is a row in pooled ``node/pos/msg/seq`` arrays with its
destination set packed into uint64 words; queues are monotonic (head, tail)
counters per (node, input-port) plus a circular row-id table, so one cycle
is a handful of vectorized passes sized by *active queues and grants*, not
by total in-flight flits — head selection, per-node round-robin grants with
the all-ports-or-stall multicast fork rule, neighbor hand-off.  A granted
flit's first output branch reuses its row; extra fork branches append;
consumed rows are tombstoned and compacted lazily.  Semantics are identical
— cycle for cycle, flit for flit — to the object-based reference
implementation kept in ``reference_sim.py`` (property-tested in
``tests/test_noc_sim.py``), but it scales to 16x16 meshes with thousands of
in-flight messages.

Performance questions (paper Fig. 6) are answered by ``perfmodel.py``; this
module favours checkable semantics over cycle exactness (store-and-forward
FIFOs rather than wormhole credits — same paths, same fork topology).

Fault injection (``inject_fault``): a router or directed link can be killed
at a scheduled cycle.  Affected flits re-route deterministically (XY, then
the YX escape path — ``router.fault_next_port``) or surface in ``lost`` as
(msg_id, seq, dest) records; ``docs/fault.md`` documents the model.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.noc.header import (encode_header, max_multicast_dests,
                                   mesh_coord_bits)
from repro.core.noc.router import (LOCAL, LOST, NORTH, SOUTH, EAST, WEST,
                                   fault_next_port)

# out port -> the input port the flit arrives on at the neighbor
_ENTRY = np.array([-1, SOUTH, NORTH, WEST, EAST], dtype=np.int64)


@dataclasses.dataclass
class Flit:
    msg_id: int
    seq: int                    # position within the message
    is_header: bool
    src: Tuple[int, int]
    dests: Tuple[Tuple[int, int], ...]
    payload: object = None

    def fork(self, branch_dests: Sequence[Tuple[int, int]]) -> "Flit":
        return dataclasses.replace(self, dests=tuple(branch_dests))


@dataclasses.dataclass
class Message:
    src: Tuple[int, int]
    dests: Tuple[Tuple[int, int], ...]
    n_payload_flits: int
    msg_id: int = -1
    # earliest cycle the message may enter its source queue (0 = inject
    # immediately, the historical behaviour).  A message scheduled in the
    # future sits in a pending heap; when nothing is in flight the
    # vectorized stepper fast-forwards straight to the next injection
    # cycle instead of stepping the quiescent gap cycle by cycle.
    inject_cycle: int = 0


class MeshNoC:
    """One physical plane of a W x H mesh (vectorized stepper)."""

    def __init__(self, width: int, height: int, bitwidth: int = 256):
        self.w, self.h = width, height
        self.bitwidth = bitwidth
        self.coord_bits = mesh_coord_bits(width, height)
        n = width * height
        self._n_nodes = n
        self._n_words = (n + 63) // 64
        self._dchunks: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._dlog_cache: Tuple[int, Dict] = (-1, {})
        self._delivered_cache: Tuple[int, Dict] = (-1, {})
        self._n_delivered = 0
        self.cycles = 0
        self.total_hops = 0
        self._next_id = 0
        self._src_of: Dict[int, Tuple[int, int]] = {}
        self._rr = 0
        # future injections: (inject_cycle, arrival order, Message) heap
        self._pending: List[Tuple[int, int, Message]] = []
        self._inject_seq = 0
        self.ffwd_cycles = 0          # quiescent cycles skipped, not stepped
        # fault model: scheduled (cycle, kind, payload) faults, active dead
        # sets, and lazily-expanded lost-flit chunks (msg, seq, dest mask)
        self._fault_queue: List[Tuple[int, str, object]] = []
        self._dead_nodes = set()
        self._dead_links = set()
        self._faulted = False
        self._lchunks: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []

        # routing tables: node index = y * width + x
        xs = np.arange(n) % width
        ys = np.arange(n) // width
        sx, dx = xs[:, None], xs[None, :]
        sy, dy = ys[:, None], ys[None, :]
        route = np.where(
            sx != dx, np.where(dx > sx, EAST, WEST),
            np.where(sy != dy, np.where(dy > sy, SOUTH, NORTH),
                     LOCAL)).astype(np.int8)
        self._dest_bit = (np.uint64(1) << (np.arange(n, dtype=np.uint64)
                                           % np.uint64(64)))
        # port_mask[s, p, w]: dests whose route leaves s through port p;
        # lost_mask[s, w]: dests unreachable from s (all zero until a fault)
        self._port_mask, self._lost_mask = self._mask_tables(route)
        off = np.array([0, -width, width, 1, -1], dtype=np.int64)
        self._neighbor = np.arange(n)[:, None] + off[None, :]

        # pooled flit table (struct of arrays); pos == -1 marks a tombstone
        self._cap = 256
        self._size = 0          # rows in use (live + tombstoned)
        self._live = 0
        self._node = np.zeros(self._cap, np.int64)
        self._qk = np.zeros(self._cap, np.int64)
        self._pos = np.zeros(self._cap, np.int64)
        self._msg = np.zeros(self._cap, np.int64)
        self._seq = np.zeros(self._cap, np.int64)
        self._dmask = np.zeros((self._cap, self._n_words), np.uint64)
        # cached output-port need set per row as a 5-bit word (function of
        # node + dest set, recomputed only when the row moves)
        self._needs_bits = np.zeros(self._cap, np.uint8)
        # queues are monotonic (head, tail) counters per (node, port): a row
        # is its queue's head iff pos == head_off[qk].  qbuf maps (qk,
        # pos mod qmax) -> row id, so head lookup costs O(active queues).
        self._head_off = np.zeros(n * 5, np.int64)
        self._qtail = np.zeros(n * 5, np.int64)
        self._qmax = 64
        self._qbuf = np.zeros((n * 5, self._qmax), np.int64)
        self._pow2 = np.uint8(1) << np.arange(5).astype(np.uint8)

    def _mask_tables(self, route: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Pack an (n, n) per-pair port table (``LOST`` = unreachable) into
        the bitmask form the stepper consumes."""
        n = self._n_nodes
        pm = np.zeros((n, 5, self._n_words), dtype=np.uint64)
        lm = np.zeros((n, self._n_words), dtype=np.uint64)
        for w in range(self._n_words):
            cols = slice(w * 64, min((w + 1) * 64, n))
            for p in range(5):
                bits = np.where(route[:, cols] == p,
                                self._dest_bit[None, cols], np.uint64(0))
                pm[:, p, w] = np.bitwise_or.reduce(bits, axis=1)
            bits = np.where(route[:, cols] == LOST,
                            self._dest_bit[None, cols], np.uint64(0))
            lm[:, w] = np.bitwise_or.reduce(bits, axis=1)
        return pm, lm

    # ------------------------------------------------------------- faults
    def inject_fault(self, *, router: Tuple[int, int] = None,
                     link: Tuple[Tuple[int, int], Tuple[int, int]] = None,
                     at_cycle: int = 0) -> None:
        """Schedule a fault: kill a ``router`` (x, y) or a directed ``link``
        ((x1, y1), (x2, y2)) at the start of cycle ``at_cycle``.  Flits
        queued inside a dead router are dropped and recorded in ``lost``;
        in-flight flits re-route around the fault (XY, then the YX escape
        path) or surface as loss at their next arbitration."""
        if (router is None) == (link is None):
            raise ValueError("pass exactly one of router= or link=")
        if router is not None:
            x, y = router
            if not (0 <= x < self.w and 0 <= y < self.h):
                raise ValueError(f"router {router} outside the mesh")
            self._fault_queue.append((at_cycle, "router", (x, y)))
        else:
            a, b = link
            for (x, y) in (a, b):
                if not (0 <= x < self.w and 0 <= y < self.h):
                    raise ValueError(f"link {link} is not a mesh link")
            if abs(a[0] - b[0]) + abs(a[1] - b[1]) != 1:
                raise ValueError(f"link {link} is not a mesh link")
            self._fault_queue.append((at_cycle, "link", (a, b)))

    def _activate_faults(self) -> None:
        fired = False
        rest = []
        for cyc, kind, payload in self._fault_queue:
            if cyc <= self.cycles:
                (self._dead_nodes if kind == "router"
                 else self._dead_links).add(payload)
                fired = True
            else:
                rest.append((cyc, kind, payload))
        self._fault_queue = rest
        if not fired:
            return
        self._faulted = True
        # flits queued inside a dead router die with it
        s = self._size
        if s and self._dead_nodes:
            dead_idx = np.array(sorted(self._coord_index(c)
                                       for c in self._dead_nodes))
            rows = np.nonzero((self._pos[:s] >= 0)
                              & np.isin(self._node[:s], dead_idx))[0]
            if len(rows):
                self._lchunks.append((self._msg[rows].copy(),
                                      self._seq[rows].copy(),
                                      self._dmask[rows].copy()))
                self._pos[rows] = -1
                self._live -= len(rows)
        for c in self._dead_nodes:
            ni = self._coord_index(c)
            self._head_off[ni * 5:(ni + 1) * 5] = \
                self._qtail[ni * 5:(ni + 1) * 5]
        # rebuild routing with the fault-aware escape path (shared per-pair
        # spec from router.py; the bitmask machinery stays this module's)
        dead_n = frozenset(self._dead_nodes)
        dead_l = frozenset(self._dead_links)
        n, w = self._n_nodes, self.w
        route = np.full((n, n), LOST, np.int8)
        for si in range(n):
            sc = (si % w, si // w)
            if sc in dead_n:
                continue     # no live flit ever sits at a dead node
            for di in range(n):
                p = fault_next_port(sc, (di % w, di // w), dead_n, dead_l)
                if p is not None:
                    route[si, di] = p
        self._port_mask, self._lost_mask = self._mask_tables(route)
        # re-aim every live row at the new tables
        live = np.nonzero(self._pos[:self._size] >= 0)[0]
        if len(live):
            self._needs_bits[live] = np.dot(
                (self._dmask[live][:, None, :]
                 & self._port_mask[self._node[live]]).any(axis=2), self._pow2)

    @property
    def lost(self) -> List[Tuple[int, int, Tuple[int, int]]]:
        """Every (msg_id, seq, dest) flit copy dropped by the fault model.
        Cold path: expanded from the internal chunks on access."""
        out = []
        w = self.w
        for msgs, seqs, masks in self._lchunks:
            for i in range(len(msgs)):
                m, q = int(msgs[i]), int(seqs[i])
                for wi in range(masks.shape[1]):
                    v = int(masks[i, wi])
                    base = wi * 64
                    while v:
                        b = (v & -v).bit_length() - 1
                        v &= v - 1
                        di = base + b
                        out.append((m, q, (di % w, di // w)))
        return out

    # ------------------------------------------------------------- pool
    def _reserve(self, extra: int) -> None:
        if self._size + extra <= self._cap:
            return
        cap = self._cap
        while self._size + extra > cap:
            cap *= 2
        for name in ("_node", "_qk", "_pos", "_msg", "_seq", "_needs_bits"):
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype)
            new[:self._size] = old[:self._size]
            setattr(self, name, new)
        dm = np.zeros((cap, self._n_words), np.uint64)
        dm[:self._size] = self._dmask[:self._size]
        self._dmask = dm
        self._cap = cap

    def _rebuild_qbuf(self) -> None:
        # only rows still enqueued (pos in [head_off, tail)): popped rows
        # awaiting tombstone/reuse would collide with live slots at full
        # queue depth
        s = self._size
        queued = (self._pos[:s] >= 0) & \
            (self._pos[:s] >= self._head_off[self._qk[:s]])
        rows = np.nonzero(queued)[0]
        self._qbuf[self._qk[rows], self._pos[rows] & (self._qmax - 1)] = rows

    def _grow_q(self, depth: int) -> None:
        while depth > self._qmax:
            self._qmax *= 2
        self._qbuf = np.zeros((self._n_nodes * 5, self._qmax), np.int64)
        self._rebuild_qbuf()

    def _compact(self) -> None:
        s = self._size
        alive = self._pos[:s] >= 0
        k = int(alive.sum())
        for name in ("_node", "_qk", "_pos", "_msg", "_seq", "_needs_bits"):
            arr = getattr(self, name)
            arr[:k] = arr[:s][alive]
        self._dmask[:k] = self._dmask[:s][alive]
        self._size = k
        self._rebuild_qbuf()   # row ids changed

    # ----------------------------------------------------------- traffic
    def _coord_index(self, c: Tuple[int, int]) -> int:
        return c[1] * self.w + c[0]

    def inject(self, msg: Message) -> int:
        cap = max_multicast_dests(self.bitwidth, coord_bits=self.coord_bits)
        if len(msg.dests) > cap:
            raise ValueError(f"{len(msg.dests)} dests > capacity {cap}")
        encode_header(msg.src, msg.dests, self.bitwidth,
                      coord_bits=self.coord_bits)  # validates coords
        for (x, y) in tuple(msg.dests) + (msg.src,):
            if not (0 <= x < self.w and 0 <= y < self.h):
                raise ValueError(f"coordinate ({x},{y}) outside the mesh")
        msg.msg_id = self._next_id
        self._next_id += 1
        self._src_of[msg.msg_id] = msg.src
        if msg.inject_cycle > self.cycles:
            heapq.heappush(self._pending,
                           (msg.inject_cycle, self._inject_seq, msg))
            self._inject_seq += 1
            return msg.msg_id
        self._enqueue(msg)
        return msg.msg_id

    def _release_due(self) -> None:
        """Move pending messages whose inject cycle has arrived into their
        source queues (in scheduling order, ties by injection order)."""
        while self._pending and self._pending[0][0] <= self.cycles:
            self._enqueue(heapq.heappop(self._pending)[2])

    def _enqueue(self, msg: Message) -> None:
        k = msg.n_payload_flits + 1
        src = self._coord_index(msg.src)
        qk = src * 5 + LOCAL
        dmask = np.zeros(self._n_words, np.uint64)
        for d in msg.dests:
            di = self._coord_index(d)
            dmask[di // 64] |= self._dest_bit[di]
        if self._faulted and msg.src in self._dead_nodes:
            # a dead source cannot inject: the whole message surfaces as loss
            self._lchunks.append((np.full(k, msg.msg_id, np.int64),
                                  np.arange(k, dtype=np.int64),
                                  np.tile(dmask, (k, 1))))
            return
        self._reserve(k)
        if self._qtail[qk] + k - self._head_off[qk] > self._qmax:
            self._grow_q(int(self._qtail[qk] + k - self._head_off[qk]))
        sl = slice(self._size, self._size + k)
        pos = self._qtail[qk] + np.arange(k)
        self._node[sl] = src
        self._qk[sl] = qk
        self._pos[sl] = pos
        self._msg[sl] = msg.msg_id
        self._seq[sl] = np.arange(k)
        self._dmask[sl] = dmask
        self._needs_bits[sl] = np.dot(
            (dmask[None, :] & self._port_mask[src]).any(axis=1), self._pow2)
        self._qbuf[qk, pos & (self._qmax - 1)] = np.arange(sl.start, sl.stop)
        self._qtail[qk] += k
        self._size += k
        self._live += k
        return msg.msg_id

    # ------------------------------------------------------------- cycle
    def step(self) -> bool:
        """One cycle.  Returns True if any flit moved."""
        if self._live == 0 and self._pending and \
                self._pending[0][0] > self.cycles:
            # quiescent fast-forward: no router has occupancy and the next
            # injection is in the future — jump straight to its cycle.
            # The round-robin pointer advances by the skipped count,
            # exactly as if the reference had idle-stepped each cycle
            # (flit-for-flit identity is property-tested against it).
            skip = self._pending[0][0] - self.cycles
            self.cycles += skip
            self.ffwd_cycles += skip
            self._rr = (self._rr + skip) % 5
        if self._fault_queue:
            # faults fire at the start of their cycle, before injections —
            # same ordering as the reference (a skipped quiescent gap cannot
            # hide one: nothing was in flight to observe the old topology)
            self._activate_faults()
        self._release_due()
        # the reference's per-router round-robin pointer advances on every
        # step, idle ones included — match it, or a drained-then-reinjected
        # instance diverges from the reference on the next drain
        rr = self._rr
        self._rr = (rr + 1) % 5
        if self._live == 0:
            return False
        if self._size - self._live > max(1024, self._live):
            self._compact()

        # queue heads: one row per non-empty queue
        act_qk = np.nonzero(self._qtail > self._head_off)[0]
        heads = self._qbuf[act_qk, self._head_off[act_qk] & (self._qmax - 1)]
        hnode = act_qk // 5
        # out ports each head needs (multicast fork: all or stall)
        bits = self._needs_bits[heads]                       # (H,) 5-bit
        # a node with a single head has no contention: grant immediately;
        # only multi-head nodes run the round-robin all-or-stall pass
        n_heads_at = np.bincount(hnode, minlength=self._n_nodes)
        solo = n_heads_at[hnode] == 1
        if solo.all():
            gh = np.arange(len(heads))
        else:
            busy = np.nonzero(~solo)[0]
            rot = (act_qk[busy] - rr) % 5     # port order seen from rr
            bn = hnode[busy]
            mat = np.zeros((self._n_nodes, 5), np.uint8)
            mat[bn, rot] = bits[busy]
            hrow = np.full((self._n_nodes, 5), -1, np.int64)
            hrow[bn, rot] = busy
            used = np.zeros(self._n_nodes, np.uint8)
            grant = np.empty((self._n_nodes, 5), bool)
            for k in range(5):
                mk = mat[:, k]
                ok = (mk & used) == 0
                used |= np.where(ok, mk, 0)
                grant[:, k] = ok
            gh = np.concatenate(
                [np.nonzero(solo)[0], hrow[grant & (hrow >= 0)]])
        g_rows = heads[gh]
        gneeds = (bits[gh][:, None] & self._pow2) != 0       # (G, 5)

        if self._faulted:
            # destinations unreachable from here surface as loss on grant
            # (the reference's LOST pseudo-port); they hold no output port
            # and never stall the fork
            gone = self._dmask[g_rows] & self._lost_mask[self._node[g_rows]]
            has = gone.any(axis=1)
            if has.any():
                rows = g_rows[has]
                self._lchunks.append((self._msg[rows].copy(),
                                      self._seq[rows].copy(), gone[has]))
                self._dmask[rows] &= ~gone[has]

        # local deliveries (amortized: per-coord fan-out happens lazily)
        lrows = g_rows[gneeds[:, LOCAL]]
        if len(lrows):
            self._n_delivered += len(lrows)
            self._dchunks.append((self._node[lrows], self._msg[lrows],
                                  self._seq[lrows]))

        # pop every granted head: advance its queue's head counter
        self._head_off[act_qk[gh]] += 1

        # fork granted heads into per-out-port copies (LOCAL consumed above)
        nl_mask = gneeds.copy()
        nl_mask[:, LOCAL] = False
        gi, op = np.nonzero(nl_mask)
        if len(gi):
            first = np.empty(len(gi), bool)
            first[0] = True
            first[1:] = gi[1:] != gi[:-1]
            rows_src = g_rows[gi]
            at = self._node[rows_src]
            branch = self._dmask[rows_src] & self._port_mask[at, op]
            new_node = self._neighbor[at, op]
            new_port = _ENTRY[op]
            new_qk = new_node * 5 + new_port
            new_pos = self._qtail[new_qk]
            self._qtail[new_qk] += 1   # <=1 arrival per queue per cycle
            self.total_hops += len(gi)

            rest = ~first             # extra fork branches append
            n_rest = int(rest.sum())
            if n_rest:
                self._reserve(n_rest)
            rows_new = np.empty(len(gi), np.int64)
            rows_new[first] = rows_src[first]   # first branch reuses the row
            if n_rest:
                sl = slice(self._size, self._size + n_rest)
                appended = np.arange(sl.start, sl.stop)
                rows_new[rest] = appended
                rsrc = rows_src[rest]
                self._msg[sl] = self._msg[rsrc]
                self._seq[sl] = self._seq[rsrc]
                self._size += n_rest
                self._live += n_rest
            self._node[rows_new] = new_node
            self._qk[rows_new] = new_qk
            self._pos[rows_new] = new_pos
            self._dmask[rows_new] = branch
            self._needs_bits[rows_new] = np.dot(
                (branch[:, None, :]
                 & self._port_mask[new_node]).any(axis=2), self._pow2)
            depth = new_pos - self._head_off[new_qk] + 1
            dmax = int(depth.max())
            if dmax > self._qmax:
                self._grow_q(dmax)   # rebuilds qbuf from live rows
            else:
                self._qbuf[new_qk, new_pos & (self._qmax - 1)] = rows_new

        # granted heads with no forwarding branch are fully consumed
        done = g_rows[~nl_mask.any(axis=1)]
        if len(done):
            self._pos[done] = -1
            self._live -= len(done)
        self.cycles += 1
        return True

    def drain(self, max_cycles: int = 1_000_000) -> int:
        """Run until no traffic is in flight.  The consumption assumption
        guarantees this terminates; the cap catches livelock bugs."""
        for _ in range(max_cycles):
            if not self.step():
                return self.cycles
        raise RuntimeError("NoC failed to drain (deadlock/livelock?)")

    def _dlog(self) -> Dict[Tuple[int, int], List[Tuple[int, int]]]:
        """Per-tile (msg_id, seq) delivery log, in delivery order."""
        stamp, cache = self._dlog_cache
        if stamp != self._n_delivered:
            cache = {(x, y): [] for x in range(self.w)
                     for y in range(self.h)}
            w = self.w
            for nodes, msgs, seqs in self._dchunks:
                for nd, m, q in zip(nodes.tolist(), msgs.tolist(),
                                    seqs.tolist()):
                    cache[(nd % w, nd // w)].append((m, q))
            self._dlog_cache = (self._n_delivered, cache)
        return cache

    @property
    def delivered(self) -> Dict[Tuple[int, int], List[Flit]]:
        """Per-tile delivered flits, in delivery order.  Materialized from
        the internal delivery log on access; the hot loop only stores row
        arrays."""
        stamp, cache = self._delivered_cache
        if stamp != self._n_delivered:
            cache = {c: [Flit(m, q, q == 0, self._src_of[m], (c,))
                         for (m, q) in log]
                     for c, log in self._dlog().items()}
            self._delivered_cache = (self._n_delivered, cache)
        return cache

    def received(self, coord: Tuple[int, int], msg_id: int) -> List[Flit]:
        return [Flit(m, q, q == 0, self._src_of[m], (coord,))
                for (m, q) in self._dlog()[coord] if m == msg_id]
