"""Flit-level 2-D mesh NoC simulator (correctness model).

Used by the property tests to validate the routing/multicast *mechanism*:
dimension-ordered paths, multicast forking to exactly the destination set,
in-order per-message delivery, and drain (consumption assumption: finite
traffic always drains — no routing deadlock under DOR).

Performance questions (paper Fig. 6) are answered by ``perfmodel.py``; this
module favours checkable semantics over cycle exactness (store-and-forward
FIFOs rather than wormhole credits — same paths, same fork topology).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.noc.header import encode_header, max_multicast_dests
from repro.core.noc.router import (LOCAL, NORTH, SOUTH, EAST, WEST, Router,
                                   next_port)

_OPPOSITE_ENTRY = {NORTH: SOUTH, SOUTH: NORTH, EAST: WEST, WEST: EAST}
_DELTA = {NORTH: (0, -1), SOUTH: (0, 1), EAST: (1, 0), WEST: (-1, 0)}


@dataclasses.dataclass
class Flit:
    msg_id: int
    seq: int                    # position within the message
    is_header: bool
    src: Tuple[int, int]
    dests: Tuple[Tuple[int, int], ...]
    payload: object = None

    def fork(self, branch_dests: Sequence[Tuple[int, int]]) -> "Flit":
        return dataclasses.replace(self, dests=tuple(branch_dests))


@dataclasses.dataclass
class Message:
    src: Tuple[int, int]
    dests: Tuple[Tuple[int, int], ...]
    n_payload_flits: int
    msg_id: int = -1


class MeshNoC:
    """One physical plane of a W x H mesh."""

    def __init__(self, width: int, height: int, bitwidth: int = 256):
        self.w, self.h = width, height
        self.bitwidth = bitwidth
        self.routers: Dict[Tuple[int, int], Router] = {
            (x, y): Router((x, y))
            for x in range(width) for y in range(height)}
        self.delivered: Dict[Tuple[int, int], List[Flit]] = {
            c: [] for c in self.routers}
        self._ids = itertools.count()
        self.cycles = 0
        self.total_hops = 0

    def inject(self, msg: Message) -> int:
        cap = max_multicast_dests(self.bitwidth)
        if len(msg.dests) > cap:
            raise ValueError(f"{len(msg.dests)} dests > capacity {cap}")
        encode_header(msg.src, msg.dests, self.bitwidth)  # validates coords
        msg.msg_id = next(self._ids)
        r = self.routers[msg.src]
        r.accept(LOCAL, Flit(msg.msg_id, 0, True, msg.src, tuple(msg.dests)))
        for i in range(msg.n_payload_flits):
            r.accept(LOCAL, Flit(msg.msg_id, i + 1, False, msg.src,
                                 tuple(msg.dests)))
        return msg.msg_id

    def step(self) -> bool:
        """One cycle.  Returns True if any flit moved."""
        moved = False
        moves: List[Tuple[Tuple[int, int], int, Flit]] = []
        for coord, r in self.routers.items():
            for out_port, flit in r.arbitrate():
                moves.append((coord, out_port, flit))
        for coord, out_port, flit in moves:
            moved = True
            if out_port == LOCAL:
                self.delivered[coord].append(flit)
                continue
            dx, dy = _DELTA[out_port]
            nxt = (coord[0] + dx, coord[1] + dy)
            assert nxt in self.routers, f"route fell off mesh at {coord}->{nxt}"
            self.total_hops += 1
            self.routers[nxt].accept(_OPPOSITE_ENTRY[out_port], flit)
        if moved:
            self.cycles += 1
        return moved

    def drain(self, max_cycles: int = 1_000_000) -> int:
        """Run until no traffic is in flight.  The consumption assumption
        guarantees this terminates; the cap catches livelock bugs."""
        for _ in range(max_cycles):
            if not self.step():
                return self.cycles
        raise RuntimeError("NoC failed to drain (deadlock/livelock?)")

    def received(self, coord: Tuple[int, int], msg_id: int) -> List[Flit]:
        return [f for f in self.delivered[coord] if f.msg_id == msg_id]
