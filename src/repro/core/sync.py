"""Accelerator synchronization via a dedicated small-payload path (paper C3).

ESP's proposal: reserve a slice of the accelerator's dataset for
*synchronization messages* carried by the fully-coherent path (MESI via the
3 coherence NoC planes) while bulk transfers stay on the DMA planes.  TPUs
have no inter-chip cache coherence; the transferable insight is the *split*:
tiny control values ride latency-optimized collectives, decoupled from and
explicitly ordered against the bulk stream.

``flag_allreduce``/``barrier`` are the control path;
``ordered_after``/``fence`` provide the ordering (XLA's optimization_barrier
is the analogue of the coherence protocol's ordering guarantees).  Inside
Pallas kernels the same role is played by DMA semaphores
(`kernels/dma_isa.py`).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat


def flag_allreduce(flag: jax.Array, axis_name: str) -> jax.Array:
    """Exchange a tiny control flag across ``axis_name`` (sync region)."""
    assert flag.size <= 128, "sync region is for small control payloads"
    return jax.lax.psum(flag, axis_name)


def barrier(axis_name: str) -> jax.Array:
    """All ranks reach this point; returns the participant count."""
    return jax.lax.psum(jnp.ones((), jnp.int32), axis_name)


def ordered_after(bulk, flag):
    """Order a bulk value after a control flag (consume-side sync): the
    returned bulk tensor cannot be scheduled before ``flag`` is available."""
    flag = jnp.sum(flag).astype(bulk.dtype if jnp.issubdtype(
        bulk.dtype, jnp.floating) else jnp.float32)
    bulk2, _ = jax.lax.optimization_barrier((bulk, flag))
    return bulk2


def fence(*values):
    """Mutual ordering fence across a group of values."""
    return jax.lax.optimization_barrier(values)


def ready_check(step_ok: jax.Array, axis_name: str) -> jax.Array:
    """Global 'every producer has produced' check before consumers proceed —
    the pull-request aggregation a multicast producer performs (it waits for
    N consumer requests before sending)."""
    n = compat.axis_size(axis_name)
    got = flag_allreduce(step_ok.astype(jnp.int32), axis_name)
    return got == n
