"""Pull-based point-to-point transfers (paper C1), jax-native.

ESP's P2P is *pull-based*: the consumer sends a request and the producer
forwards data only once the request arrives, satisfying the consumption
assumption (messages on the NoC are always drained -> no message-dependent
deadlock).  On a TPU pod the analogue is ``ppermute``: the collective is
issued by *both* endpoints (the receive buffer is committed before data
moves), which gives exactly the same guarantee — a ppermute cannot leave
undrained traffic in the ICI fabric.  Inside Pallas kernels the same
contract appears as the receiver-side DMA semaphore
(`kernels/ring_allgather_matmul`).

These helpers are used by pipeline-parallel stage forwarding and the
serving pipeline example.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.comm import validate_p2p_totals, reblock


def p2p_shift(x: jax.Array, axis_name: str, offset: int = 1) -> jax.Array:
    """Forward ``x`` from stage i to stage i+offset (ring) along
    ``axis_name``.  Must be called inside shard_map/pmap collective context."""
    n = compat.axis_size(axis_name)
    perm = [(i, (i + offset) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name, perm)


def p2p_send_recv(x: jax.Array, axis_name: str, src: int, dst: int) -> jax.Array:
    """Single producer -> single consumer transfer along ``axis_name``.
    Ranks other than ``dst`` receive zeros (nothing is addressed to them)."""
    return jax.lax.ppermute(x, axis_name, [(src, dst)])


def p2p_send_recv_dynamic(x: jax.Array, axis_name: str, src, dst) -> jax.Array:
    """P2P transfer whose peer indices may be *traced* values (the socket's
    LUT virtualization: the registry hands ranks in as step arguments, so
    retargeting a peer is a new argument value, not a retrace).

    ``ppermute`` requires a static permutation, so the dynamic path rides
    the sync-capable collective instead: the producer's value is masked in,
    carried by a psum (every rank issues it — consumption assumption
    holds), and masked out everywhere but ``dst``.  Wire cost is a
    broadcast, the price of dynamic peer selection."""
    idx = jax.lax.axis_index(axis_name)
    contrib = jnp.where(idx == src, x, jnp.zeros_like(x))
    y = jax.lax.psum(contrib, axis_name)
    return jnp.where(idx == dst, y, jnp.zeros_like(y))


def p2p_reblocked(x: jax.Array, axis_name: str, src: int, dst: int,
                  producer_burst: int, consumer_burst: int) -> jax.Array:
    """Flexible P2P (C1): producer emits bursts of ``producer_burst`` words;
    consumer ingests bursts of ``consumer_burst`` words.  Only the totals
    must agree — checked before the transfer."""
    total = x.size
    n_p, n_c = total // producer_burst, total // consumer_burst
    validate_p2p_totals([producer_burst] * n_p, [consumer_burst] * n_c)
    y = p2p_send_recv(x, axis_name, src, dst)
    return reblock(y, consumer_burst)


def pipeline_stage_forward(x: jax.Array, axis_name: str) -> jax.Array:
    """GPipe-style stage hand-off: every stage forwards its activation to the
    next (the paper's NN example: 'a previous layer's outputs from another
    accelerator')."""
    return p2p_shift(x, axis_name, offset=1)
