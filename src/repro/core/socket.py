"""The accelerator socket (ESP) as the single, plan-driven communication API.

ESP's socket decouples an accelerator from the SoC: it provides DMA,
address translation, interrupts, and config registers, plus (this paper)
the per-transfer ``user`` field and a small LUT that *virtualizes* peer
indices into tile coordinates.  Here it is the one place every on-chip
transfer goes through:

* model / runtime / example code issues a transfer from a typed
  :class:`~repro.core.comm.TransferDescriptor` — never by calling
  ``p2p_*`` / ``multicast_*`` (a CI grep gate forbids importing those
  helpers outside ``core/`` and ``tests/``) or raw GSPMD collectives
  (by convention — the gate cannot see ``jax.lax.*`` call sites);
* the socket resolves the *mode* against the active
  :class:`~repro.core.comm.CommPlan` (``use_rules(..., comm_plan=...)``
  context or an explicit plan), keyed by
  :func:`~repro.core.comm.base_transfer_name`;
* the transfer is encoded as the read/write user-field instruction
  (:mod:`repro.core.isa` — the format ``kernels/dma_isa`` consumes) and
  dispatched to the MEM / P2P / MCAST implementation, including the
  Pallas multicast-stream fast path when constraints allow;
* C3 sync fencing (``desc.sync``) is folded in here — the producer
  aggregates consumer requests on the sync region before the bulk moves —
  instead of being left to callers;
* every dispatch appends an :class:`IssueRecord` to a bounded trace-time
  log, so dryrun artifacts report the *issued* mode per site, not just
  the planned one.

:class:`StageRegistry` is the LUT — peers are addressed by *name*
("encoder", "decoder", "expert_shard"), never by mesh coordinate.  Peer
ranks may also be passed as traced values (``peer_rank``): the encoded
user field stays the stable *virtual* index while the LUT value rides in
as a step argument, so ``remap`` retargets a transfer without retracing
or relowering the stage function.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp

from repro.core import isa
from repro.core import multicast as MC
from repro.core import p2p as P2P
from repro.core import sync as SYNC
from repro.core.comm import (CommMode, CommPlan, CommRequest, FaultError,
                             TransferDescriptor,
                             UnregisteredFusionTargetError,
                             base_transfer_name, known_fusion_targets)
from repro.core.sharding import current_comm_plan, logical_constraint


@dataclasses.dataclass
class StageRegistry:
    """Virtualization LUT: name / virtual index -> rank on the stage axis.

    The paper: 'A small, configurable lookup table in the socket encodes
    the tile coordinates for each index, so that these values can be
    virtualized.'  The *virtual* index of a name (1-based registration
    order; 0 is reserved for the MEM encoding) is what the user field
    carries — ``remap`` rewrites the LUT entry, never the instruction."""
    axis_name: str
    table: Dict[str, int] = dataclasses.field(default_factory=dict)

    def register(self, name: str, rank: int) -> int:
        self.table[name] = rank
        return self.virtual_of(name)

    def rank_of(self, name: str) -> int:
        return self.table[name]

    def virtual_of(self, name: str) -> int:
        """1-based LUT index of ``name`` (stable under remap)."""
        return list(self.table).index(name) + 1

    def remap(self, name: str, new_rank: int):
        """Retarget a peer without touching accelerator code (e.g. after
        an elastic re-mesh migrates a stage)."""
        if name not in self.table:
            raise KeyError(name)
        self.table[name] = new_rank


# ------------------------------------------------------------- issue log ----

@dataclasses.dataclass(frozen=True)
class IssueRecord:
    """One socket dispatch, recorded at trace time: which mode was
    *issued* at the site (vs merely planned), through which
    implementation, under which user-field encoding."""
    site: str                 # call-site label (descriptor site_label)
    name: str                 # base transfer name (the plan key)
    channel: str              # "read" | "write" | "exchange" | "reduce" |
    #                           "gather_matmul" | "reduce_scatter"
    planned: str              # mode the active plan assigned (or hint)
    issued: str               # mode actually dispatched
    user: int                 # encoded user field
    nbytes: int
    impl: str                 # "constraint"|"ppermute"|"fork_tree"|...
    sync: bool = False
    # machine-readable reason whenever issued != planned: a topology
    # degradation ("no stage axis: ..."), a pinned-mode override
    # ("reduction: ..."), or a retry-ladder downgrade ("ladder
    # FUSED_RING->P2P: ...").  Never empty when issued and planned
    # disagree — commcheck's ``degraded-without-reason`` rule is the
    # static mirror of this contract.
    degraded_reason: Optional[str] = None
    # an OVERLAPPED implementation dispatched: the FUSED_RING kernels
    # (comm overlapped with the consumer matmul) or the double-buffered
    # multicast stream.  Strictly an *issued* property — a planner
    # decision may be priced fused (PlanDecision.fused, the platform's
    # capability) while this site's serial lowering records False.
    fused: bool = False
    # request/step epoch the issue belongs to (``issue_epoch``): under
    # continuous batching, prefill and decode traces (or two requests)
    # hit the *same* site label, and a site-keyed summary would let the
    # later trace overwrite the earlier record.  None outside an epoch
    # scope — single-trace dryruns keep their bare site keys.
    epoch: Optional[str] = None

    @property
    def degraded(self) -> Optional[str]:
        """Pre-ladder alias of ``degraded_reason`` (kept for artifact
        consumers written against the old field name)."""
        return self.degraded_reason


class _IssueLog(threading.local):
    def __init__(self):
        # bounded: tracing in long test sessions must not grow unbounded
        self.records = collections.deque(maxlen=4096)
        # the ambient (site, epoch) scope: continuous batching traces
        # prefill and decode steps that share site labels; the active
        # epoch tags each record so summaries stay audit-accurate
        self.epoch: Optional[str] = None


_LOG = _IssueLog()


def reset_issue_log() -> None:
    _LOG.records.clear()
    _LOG.epoch = None


def current_issue_epoch() -> Optional[str]:
    return _LOG.epoch


@contextlib.contextmanager
def issue_epoch(label: Optional[str]):
    """Scope trace-time issue records by (site, epoch).

    The serving engine traces its prefill and batched-decode steps
    separately, and both hit shared site labels (``moe.dispatch``, the
    weight-gather sites).  Without a scope, :func:`issued_modes` is
    last-write-wins per site and the earlier trace's record silently
    disappears from artifacts.  Inside ``issue_epoch("prefill")`` every
    record is stamped with the epoch and summarised under
    ``"<site>@prefill"`` — two epochs at one site coexist."""
    prev = _LOG.epoch
    _LOG.epoch = label
    try:
        yield
    finally:
        _LOG.epoch = prev


def _summary_key(r: IssueRecord) -> str:
    return r.site if r.epoch is None else f"{r.site}@{r.epoch}"


def issued_records() -> List[IssueRecord]:
    return list(_LOG.records)


def issued_modes() -> Dict[str, Dict[str, Any]]:
    """Per-(site, epoch) summary for dryrun artifacts: last record per
    scope key (a relower of the *same* step overwrites the earlier
    trace's entry; records from distinct :func:`issue_epoch` scopes —
    prefill vs decode, request A vs request B — keep separate
    ``"<site>@<epoch>"`` keys instead of clobbering each other)."""
    out: Dict[str, Dict[str, Any]] = {}
    for r in _LOG.records:
        out[_summary_key(r)] = {
            "tensor": r.name, "channel": r.channel, "planned": r.planned,
            "issued": r.issued, "user_field": r.user, "impl": r.impl,
            "nbytes": r.nbytes, "degraded": r.degraded_reason,
            "degraded_reason": r.degraded_reason, "fused": r.fused,
            "epoch": r.epoch,
        }
    return out


def mismatched_sites(plan: Optional[CommPlan]) -> List[Dict[str, str]]:
    """The logged sites whose issued mode silently disagrees with the
    plan, for the CLI summaries — each entry carries the site label, the
    plan key, and the planned vs issued modes.  An explicitly *degraded*
    issue (no stage axis / no peers on this topology) conforms by
    definition — degradation to MEM is the paper's own rule for
    unrealizable direct transfers — and a P2P/MCAST write pair is one
    wire transaction (the ``user=1`` degeneracy)."""
    if plan is None:
        return []
    direct = {CommMode.P2P.name, CommMode.MCAST.name}
    out: List[Dict[str, str]] = []
    for r in _LOG.records:
        planned = plan.mode(base_transfer_name(r.name)).name
        if r.issued == planned or r.degraded_reason is not None:
            continue
        if r.issued in direct and planned in direct:
            continue
        out.append({"site": r.site, "tensor": r.name,
                    "planned": planned, "issued": r.issued})
    return out


def issued_matches_plan(plan: Optional[CommPlan]) -> bool:
    """True when every logged site issued the mode the plan assigned
    (see :func:`mismatched_sites` for the conformance rules and the
    offending sites when this is False)."""
    return not mismatched_sites(plan)


def issue_observations(plan: Optional[CommPlan] = None
                       ) -> List[Dict[str, Any]]:
    """Export the trace-time issue log as plain measurement dicts for the
    calibration loop (``repro.calib.measure`` lifts them into typed
    ``Observation`` records; ``planner.refine_plan_from_measurements``
    consumes them directly — core stays import-free of ``repro.calib``).

    One dict per logged record, ``kind == "issue"``: the planned vs issued
    mode at the site, payload size, and the machine-readable degradation
    reason (``None`` marks a *silent* mismatch, the re-pricing trigger).
    With ``plan``, ``planned`` is re-read from the plan in force (a record
    traced under a hint can predate the resolved plan)."""
    out: List[Dict[str, Any]] = []
    for r in _LOG.records:
        planned = (plan.mode(base_transfer_name(r.name)).name
                   if plan is not None else r.planned)
        out.append({
            "kind": "issue", "site": _summary_key(r), "name": r.name,
            "planned": planned, "issued": r.issued, "nbytes": r.nbytes,
            "channel": r.channel, "impl": r.impl,
            "degraded_reason": r.degraded_reason, "epoch": r.epoch,
        })
    return out


def record_implicit_issue(name: str, *, planned: CommMode, issued: CommMode,
                          nbytes: int = 0, impl: str = "xla",
                          reason: Optional[str] = None,
                          site: Optional[str] = None) -> None:
    """Log a transfer the compiler issues on the socket's behalf (e.g. the
    rule-gated weight all-gather: the sharding rules, not a call site,
    generate it).  Runtime step factories call this at trace time so the
    issue log covers implicit transfers too."""
    # the user field of a compiler-issued transfer records the *triad
    # class* (0 = MEM, 1 = P2P, 2 = MCAST — consistent with
    # mode_from_write_field), not a destination count the socket never saw
    _LOG.records.append(IssueRecord(
        site=site or name, name=base_transfer_name(name), channel="rules",
        planned=planned.name, issued=issued.name,
        user=issued.value, nbytes=nbytes, impl=impl,
        degraded_reason=reason if issued is not planned else None,
        epoch=_LOG.epoch))


# ----------------------------------------------- retry / degradation ladder ----

# the typed downgrade order every fallible dispatch walks: the overlapped
# Pallas rung first, then the serial collective under the same direct
# verdict, then the same collective charged to the memory round-trip (the
# accounting of last resort — data still moves; a MEM rung cannot
# *substitute* a different dataflow without changing numerics).  A rung
# that keeps failing after its bounded retries hands to the next with a
# machine-readable ``degraded_reason``; past the last rung the socket
# raises :class:`~repro.core.comm.FaultError` so the fault-tolerant
# runner can checkpoint-restore instead of crashing opaquely mid-trace.
DEGRADATION_LADDER: Tuple[str, ...] = ("FUSED_RING", "P2P", "MEM")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry-with-backoff for one ladder rung.

    ``max_attempts`` counts *total* tries of a rung (1 = no retry);
    between tries the socket sleeps ``backoff_s * multiplier**k`` capped
    at ``max_backoff_s``.  ``sleep`` is injectable so tests (and the
    chaos harness) can observe the schedule without wall-clock waits.
    A socket constructed without a policy (the default) never catches:
    dispatch errors propagate exactly as before the ladder existed."""
    max_attempts: int = 3
    backoff_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 1.0
    sleep: Callable[[float], None] = time.sleep

    def schedule(self) -> Iterator[float]:
        """The sleep preceding each retry: ``max_attempts - 1`` entries,
        geometric from ``backoff_s``, each capped at ``max_backoff_s``."""
        delay = self.backoff_s
        for _ in range(max(self.max_attempts - 1, 0)):
            yield min(delay, self.max_backoff_s)
            delay *= self.multiplier


# ----------------------------------------------------------------- socket ----

PeerArg = Union[None, str, int, jax.Array]


class AcceleratorSocket:
    """Per-stage communication services.  Use inside shard_map over the
    stage axis (``registry.axis_name`` or ``axis_name``); without an axis
    the socket still issues — every transfer degrades to the MEM path,
    which is exactly what a topology with no direct path provides.

    ``use_kernels=True`` enables the Pallas fast paths (multicast stream)
    when the payload satisfies the kernel's constraints; ``interpret``
    is forwarded to the kernel (tests pass ``compat.interpret_params()``).

    ``retry`` binds a :class:`RetryPolicy`: the fallible kernel dispatch
    paths then walk the :data:`DEGRADATION_LADDER` (bounded retries per
    rung, machine-readable ``degraded_reason`` per downgrade,
    :class:`~repro.core.comm.FaultError` past the last rung) instead of
    letting a trace-time kernel error crash the step opaquely.  Without a
    policy the socket behaves exactly as before: nothing is caught.
    ``fence_timeout_s > 0`` arms a stall watchdog on the C3 sync fence —
    a hung barrier becomes a ``FaultError`` instead of a deadlock.  Note
    ``resolve_mode`` stays pure (and its
    ``UnregisteredFusionTargetError`` always propagates): retry and
    degradation apply to *dispatch*, never to plan resolution.
    """

    def __init__(self, registry: Optional[StageRegistry] = None,
                 plan: Optional[CommPlan] = None, *,
                 axis_name: Optional[str] = None,
                 use_kernels: bool = False, interpret=None,
                 retry: Optional[RetryPolicy] = None,
                 fence_timeout_s: float = 0.0):
        self.registry = registry
        self.axis_name = axis_name or (registry.axis_name if registry else None)
        self._plan = plan
        self.use_kernels = use_kernels
        self.interpret = interpret
        self.retry = retry
        self.fence_timeout_s = fence_timeout_s

    # ------------------------------------------------------- resolution ----
    def plan(self) -> Optional[CommPlan]:
        """The plan in force at issue time: an explicitly bound plan wins,
        else the ambient ``use_rules(..., comm_plan=...)`` context."""
        return self._plan if self._plan is not None else current_comm_plan()

    def resolve_mode(self, desc: TransferDescriptor,
                     hint: Optional[CommMode] = None) -> CommMode:
        """Plan-driven mode for a descriptor: exact name first, then the
        base archetype; a transfer the plan does not cover follows the
        caller's ``hint`` (manual/flag-driven behaviour), else the plan
        default (MEM).  First issue also validates ``fused_with``: a
        dangling target used to silently never fuse — now it raises
        (:class:`~repro.core.comm.UnregisteredFusionTargetError`, the
        runtime mirror of commcheck's ``descriptor-dangling-fused``)."""
        if desc.fused_with is not None and \
                desc.fused_with not in known_fusion_targets():
            raise UnregisteredFusionTargetError(
                f"descriptor {desc.site_label!r}: fused_with="
                f"{desc.fused_with!r} was never registered at trace time — "
                f"the transfer would silently take the unfused path. "
                f"Register the consumer matmul with "
                f"core.comm.register_fusion_target, or fix the label "
                f"(known targets: "
                f"{sorted(known_fusion_targets()) or 'none'})")
        plan = self.plan()
        if plan is not None:
            if desc.name in plan.modes:
                return plan.modes[desc.name]
            base = base_transfer_name(desc.name)
            if base in plan.modes:
                return plan.modes[base]
        if hint is not None:
            return hint
        return plan.default if plan is not None else CommMode.MEM

    def resolve(self, desc: TransferDescriptor, nbytes: int, channel: str,
                hint: Optional[CommMode] = None,
                word_bytes: Optional[int] = None
                ) -> Tuple[CommMode, CommRequest, isa.DmaInstruction]:
        """Full issue-site resolution: plan mode -> control-channel beat ->
        ISA instruction.  ``word_bytes`` is the tensor's dtype itemsize
        (the descriptor's own ``word_bytes`` overrides it; 4 when neither
        is known).  This is the per-dispatch overhead the
        ``socket_dispatch_overhead`` benchmark row measures."""
        mode = self.resolve_mode(desc, hint)
        word = desc.word_bytes or word_bytes or 4
        length = max(nbytes // word, 1)
        source = dests = None
        if mode is not CommMode.MEM and self.registry is not None:
            if desc.source is not None:
                source = self.registry.virtual_of(desc.source)
            if desc.dests:
                dests = tuple(self.registry.virtual_of(n) for n in desc.dests)
        # the instruction encodes the transfer as it will actually issue: a
        # direct verdict with no LUT peers on this topology degrades to the
        # memory encoding (user field 0) — the paper's own rule
        if channel == isa.CH_READ:
            enc = mode if source is not None else CommMode.MEM
            req = CommRequest(length, word, enc,
                              source=source if enc is not CommMode.MEM
                              else None)
        else:
            enc = mode if dests else CommMode.MEM
            req = CommRequest(length, word, enc, dests=dests or ())
        return mode, req, isa.encode(req, channel)

    def _nbytes(self, x) -> int:
        return int(x.size) * x.dtype.itemsize

    def _log(self, desc, channel, planned, issued, user, nbytes, impl,
             degraded=None, fused=False):
        _LOG.records.append(IssueRecord(
            site=desc.site_label, name=base_transfer_name(desc.name),
            channel=channel, planned=planned.name, issued=issued.name,
            user=user, nbytes=nbytes, impl=impl, sync=desc.sync,
            degraded_reason=degraded, fused=fused, epoch=_LOG.epoch))

    # ------------------------------------------- retry / degradation ladder ----
    def _attempt(self, thunk):
        """Run one ladder rung under the bound retry policy.  No policy:
        the thunk runs bare and errors propagate (legacy behaviour).
        With a policy: bounded retry with backoff — returns
        ``(True, result)`` on success, ``(False, (attempts, last_err))``
        once the rung is exhausted.  ``FaultError`` is never retried:
        it is already the ladder's own verdict (e.g. a fence watchdog
        firing inside the rung), not a transient."""
        if self.retry is None:
            return True, thunk()
        delays = self.retry.schedule()
        attempts = 0
        while True:
            attempts += 1
            try:
                return True, thunk()
            except FaultError:
                raise
            except Exception as err:
                delay = next(delays, None)
                if delay is None:
                    return False, (attempts, err)
                self.retry.sleep(delay)

    def _ladder(self, desc, channel, planned, nbytes, rungs):
        """Dispatch through the degradation ladder.  ``rungs`` is an
        ordered list of ``(rung_name, issued_mode, user, impl, fused,
        thunk)`` — names drawn from :data:`DEGRADATION_LADDER`.  Each
        rung runs under :meth:`_attempt`; a failure downgrades to the
        next rung carrying the accumulated machine-readable reason, and
        the last rung's failure raises ``FaultError`` (the runner's
        recovery signal)."""
        reason = None
        for i, (rung, issued, user, impl, fused, thunk) in enumerate(rungs):
            ok, res = self._attempt(thunk)
            if ok:
                self._log(desc, channel, planned, issued, user, nbytes, impl,
                          degraded=reason, fused=fused)
                return res
            attempts, err = res
            if i + 1 == len(rungs):
                raise FaultError(
                    f"socket {desc.site_label!r}: degradation ladder "
                    f"exhausted at rung {rung} after {attempts} attempt(s): "
                    f"{type(err).__name__}: {err}") from err
            hop = (f"ladder {rung}->{rungs[i + 1][0]}: {rung} failed after "
                   f"{attempts} attempt(s) ({type(err).__name__}: {err})")
            reason = f"{reason}; {hop}" if reason else hop

    def _peer(self, value: PeerArg, fallback_name: Optional[str]):
        """Resolve a peer argument: name -> LUT rank (static), int ->
        static rank, traced array -> dynamic rank; None falls back to the
        descriptor's name."""
        if value is None:
            value = fallback_name
        if value is None:
            return None
        if isinstance(value, str):
            # a named peer without a LUT cannot resolve: the caller's
            # guard degrades the transfer to the MEM path
            if self.registry is None:
                return None
            return self.registry.rank_of(value)
        return value

    def peer_rank(self, name: str) -> jnp.ndarray:
        """The LUT entry for ``name`` as a *value* (pass it into a jitted
        stage function): the transfer then follows a later ``remap``
        without retracing — the paper's virtualization."""
        return jnp.int32(self.registry.rank_of(name))

    @staticmethod
    def _is_static(rank) -> bool:
        import numpy as np
        return isinstance(rank, (int, np.integer))

    def _fence(self, x, mode: CommMode):
        """C3 folded in: before a direct transfer, exchange the sync-region
        flag (the producer's aggregation of consumer pull requests) and
        order the bulk payload after it.  The MEM path needs no fence —
        the memory round-trip is its own ordering point.  With
        ``fence_timeout_s > 0`` the barrier runs under a stall watchdog:
        a fence that hangs (a peer died mid sync region) surfaces as a
        ``FaultError`` the runner can recover from, not a deadlock."""
        if mode is CommMode.MEM or self.axis_name is None:
            return x
        flag = self._guarded_barrier()
        return SYNC.ordered_after(x, flag)

    def _guarded_barrier(self):
        if self.fence_timeout_s <= 0:
            return SYNC.barrier(self.axis_name)
        box: List[Tuple[str, Any]] = []

        def run():
            try:
                box.append(("ok", SYNC.barrier(self.axis_name)))
            except BaseException as err:  # surfaces in the caller below
                box.append(("err", err))

        t = threading.Thread(target=run, daemon=True, name="socket-fence")
        t.start()
        t.join(self.fence_timeout_s)
        if not box:
            # the daemon thread is abandoned, not killed — but the trace
            # no longer blocks on it, and the runner gets a typed fault
            raise FaultError(
                f"sync fence on axis {self.axis_name!r} stalled past the "
                f"{self.fence_timeout_s:g}s watchdog — peer lost mid "
                f"sync region?")
        tag, val = box[0]
        if tag == "err":
            raise val
        return val

    # -- read channel: user field selects the source -------------------------
    def read(self, x: jax.Array, desc: TransferDescriptor,
             source: PeerArg = None, consumer: PeerArg = None) -> jax.Array:
        """Pull-based read.  MEM: DMA resharding along the *descriptor's*
        logical axes.  P2P: the consumer pulls from the virtualized source
        — both endpoints resolve through the LUT, so retargeting a
        producer is a registry update (and with traced ranks, not even a
        retrace)."""
        hint = CommMode.P2P if desc.pull else None
        nbytes = self._nbytes(x)
        mode, req, instr = self.resolve(desc, nbytes, isa.CH_READ, hint,
                                        word_bytes=x.dtype.itemsize)
        src = self._peer(source, desc.source)
        dst = self._peer(consumer, desc.consumer)
        if self.axis_name is None or src is None or dst is None:
            # no stage axis / no peers on this topology: the only path is
            # through memory — the paper's degradation rule
            degraded = (None if mode is CommMode.MEM else
                        ("no stage axis: direct path unrealizable"
                         if self.axis_name is None
                         else "no source/consumer peers at this site"))
            self._log(desc, "read", mode, CommMode.MEM,
                      0 if degraded else instr.user, nbytes, "constraint",
                      degraded)
            return self._mem(x, desc)
        # peers on a live stage axis: data always moves; the mode selects
        # which path it is charged to (MEM = the emulated memory-tile
        # round-trip; same collective, different accounting and no fence)
        if desc.sync:
            x = self._fence(x, mode)
        issued = CommMode.MEM if mode is CommMode.MEM else CommMode.P2P
        if self._is_static(src) and self._is_static(dst):
            impl = ("mem_roundtrip" if mode is CommMode.MEM else "ppermute")
            self._log(desc, "read", mode, issued, instr.user, nbytes, impl)
            return P2P.p2p_send_recv(x, self.axis_name, int(src), int(dst))
        self._log(desc, "read", mode, issued, instr.user, nbytes,
                  "dynamic_lut")
        return P2P.p2p_send_recv_dynamic(x, self.axis_name, src, dst)

    # -- write channel: user field selects destination count -----------------
    def write(self, x: jax.Array, desc: TransferDescriptor,
              producer: PeerArg = None,
              dests: Optional[Sequence[PeerArg]] = None) -> jax.Array:
        """MEM: DMA to memory (resharding by the descriptor's axes).  One
        dest: unicast P2P (``user=1``).  Several: multicast — the producer
        waits for all consumer pulls (sync region, when ``desc.sync``),
        then sends once (C2).  Dispatches to the Pallas multicast-stream
        kernel when enabled and the payload qualifies."""
        dst_args = list(dests) if dests is not None else list(desc.dests)
        hint = (None if not dst_args else
                (CommMode.P2P if len(dst_args) == 1 else CommMode.MCAST))
        nbytes = self._nbytes(x)
        mode, req, instr = self.resolve(desc, nbytes, isa.CH_WRITE, hint,
                                        word_bytes=x.dtype.itemsize)
        src = self._peer(producer, desc.source)
        if self.axis_name is None or src is None or not dst_args:
            degraded = None
            if mode is not CommMode.MEM:
                degraded = ("no stage axis: direct path unrealizable"
                            if self.axis_name is None
                            else "no destination peers at this site")
            self._log(desc, "write", mode, CommMode.MEM,
                      0 if degraded else instr.user, nbytes, "constraint",
                      degraded)
            return self._mem(x, desc)
        ranks = [self._peer(d, None) for d in dst_args]
        if desc.sync:
            x = self._fence(x, mode)
        # data always moves to the listed peers; a MEM verdict charges the
        # transaction to the memory round-trip (user field 0) but delivery
        # rides the same collective — the socket never drops a transfer
        issued = (CommMode.MEM if mode is CommMode.MEM else
                  (CommMode.P2P if len(ranks) == 1 else CommMode.MCAST))
        mem = mode is CommMode.MEM
        if all(self._is_static(r) for r in ranks) and self._is_static(src):
            ranks = [int(r) for r in ranks]
            if len(ranks) == 1:
                self._log(desc, "write", mode, issued, instr.user, nbytes,
                          "mem_roundtrip" if mem else "ppermute")
                return P2P.p2p_send_recv(x, self.axis_name, int(src),
                                         ranks[0])
            if not mem and self._kernel_ok(x, ranks, int(src)):
                from repro.kernels.multicast_stream import \
                    multicast_stream_local

                # the double-buffered store-and-forward stream IS an
                # overlapped implementation: chunk k forwards while k+1
                # streams — a fused issue.  The ladder below it reissues
                # the same payload through the serial fork tree (identical
                # numbers), last under MEM accounting.
                def kernel():
                    return multicast_stream_local(
                        x, axis_name=self.axis_name, src=int(src),
                        n_chunks=self._kernel_chunks(x),
                        interpret=self.interpret)

                def serial():
                    return MC.multicast_subset(x, self.axis_name, int(src),
                                               ranks)

                return self._ladder(desc, "write", mode, nbytes, [
                    ("FUSED_RING", issued, instr.user,
                     "mcast_stream_kernel", True, kernel),
                    ("P2P", issued, instr.user, "fork_tree", False, serial),
                    ("MEM", CommMode.MEM, 0, "mem_roundtrip", False, serial),
                ])
            self._log(desc, "write", mode, issued, instr.user, nbytes,
                      "mem_roundtrip" if mem else "fork_tree")
            return MC.multicast_subset(x, self.axis_name, int(src), ranks)
        self._log(desc, "write", mode, issued, instr.user, nbytes,
                  "dynamic_lut")
        if len(ranks) == 1:
            return P2P.p2p_send_recv_dynamic(x, self.axis_name, src, ranks[0])
        return MC.multicast_subset_dynamic(x, self.axis_name, src,
                                           jnp.asarray(ranks, jnp.int32))

    # -- exchange: the all-to-all dispatch (each rank both ends) --------------
    def exchange(self, x: jax.Array, desc: TransferDescriptor, *,
                 split_axis: int, concat_axis: int, tiled: bool = False,
                 hint: Optional[CommMode] = None) -> jax.Array:
        """Symmetric dispatch (MoE): every shard writes a distinct slab to
        every peer — per-pair unicast writes with the destination list in
        the header, one issued transfer per source.  The plan decides
        whether this site runs at all (its MEM alternative is a different
        dataflow the *caller* traces), so ``hint`` carries the caller's
        flag-driven mode when no plan is active."""
        from repro import compat
        assert self.axis_name is not None, "exchange needs a stage axis"
        mode = self.resolve_mode(desc, hint)
        n = compat.axis_size(self.axis_name)
        nbytes = self._nbytes(x)
        word = desc.word_bytes or x.dtype.itemsize
        mem = mode is CommMode.MEM
        req = CommRequest(max(nbytes // word, 1), word, mode,
                          dests=() if mem else tuple(range(1, n)))
        instr = isa.encode(req, isa.CH_WRITE)
        # the dispatch still runs under a MEM verdict (the caller chose
        # this dataflow); it is charged to the memory round-trip, exactly
        # like read/write with peers — issued mode and user field agree
        issued = (CommMode.MEM if mem else
                  (CommMode.P2P if n <= 2 else CommMode.MCAST))
        if desc.sync:
            x = self._fence(x, mode)
        # desc.fused_with here is a *pricing* declaration (the planner
        # hides the dispatch behind the expert matmuls); this site's
        # lowering is one serial all_to_all, so the issue is NOT recorded
        # fused — the flag means an overlapped implementation dispatched
        self._log(desc, "exchange", mode, issued, instr.user, nbytes,
                  "mem_roundtrip" if mem else "all_to_all")
        return jax.lax.all_to_all(x, self.axis_name, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=tiled)

    # -- fused MoE chain: dispatch -> expert FFN -> combine -------------------
    def dispatch_expert_ffn(self, toks: jax.Array, ffn,
                            dispatch_desc: TransferDescriptor,
                            combine_desc: TransferDescriptor, *,
                            hint: Optional[CommMode] = None) -> jax.Array:
        """The whole MoE exchange chain as ONE socket dispatch: dispatch
        all-to-all -> per-expert FFN -> mirrored combine all-to-all.

        ``toks`` is destination-major ``(M, E_loc, C, d)`` — slab ``j``
        is this source's capacity buffers for the experts shard ``j``
        owns; ``ffn`` maps ``(E_loc, T, d) -> (E_loc, T, d)``
        token-row-independently (the expert einsums).  Returns the
        combine result ``(M, E_loc, C, d)``: slab ``j`` holds the
        outputs shard ``j`` computed for MY tokens.

        FUSED_RING dispatch: when the plan's verdict is a direct mode,
        kernels are on, and ``dispatch_desc.fused_with`` names the
        expert FFN, the chain runs as a ring pipeline — at offset ``s``
        each shard forwards its slab for peer ``rank+s`` while the slab
        that arrived from ``rank-s`` feeds the expert matmuls, and the
        result rides the mirrored hop home.  Hop ``s+1`` has no data
        dependence on step ``s``'s compute, so the dispatch streams
        behind the FFN exactly like the planner prices it.  ``ffn`` is
        row-independent, so the per-slab pipeline is bit-identical to
        the unfused path (one serial ``all_to_all`` each way around one
        full-batch FFN) — the fallback rungs and the non-fusible path
        below."""
        assert self.axis_name is not None, "dispatch_expert_ffn needs an axis"
        from repro import compat
        mode = self.resolve_mode(dispatch_desc, hint)
        n = compat.axis_size(self.axis_name)
        fusible = (mode is not CommMode.MEM and self.use_kernels and
                   dispatch_desc.fused_with is not None and
                   isinstance(n, int) and n > 1 and toks.ndim == 4 and
                   toks.shape[0] == n)
        if not fusible:
            # unfused chain: two serial exchanges through the normal
            # socket path (each logs its own site) around one FFN
            recv = self.exchange(toks, dispatch_desc, split_axis=0,
                                 concat_axis=0, hint=hint)
            M, E_loc, C, d = recv.shape
            out = ffn(jnp.moveaxis(recv, 0, 1).reshape(E_loc, M * C, d))
            out = jnp.moveaxis(out.reshape(E_loc, M, C, d), 1, 0)
            return self.exchange(out, combine_desc, split_axis=0,
                                 concat_axis=0, hint=hint)
        nbytes = self._nbytes(toks)
        word = dispatch_desc.word_bytes or toks.dtype.itemsize
        req = CommRequest(max(nbytes // word, 1), word, mode,
                          dests=tuple(range(1, n)))
        instr = isa.encode(req, isa.CH_WRITE)
        if dispatch_desc.sync:
            toks = self._fence(toks, mode)
        issued = CommMode.P2P if n <= 2 else CommMode.MCAST

        def _combine_log(impl, fused):
            # the chain's return hop, recorded under the combine site so
            # artifact consumers see both halves of the exchange
            self._log(combine_desc, "dispatch_chain", mode, issued,
                      instr.user, nbytes, impl, fused=fused)

        def fused():
            out = self._ring_dispatch_ffn(toks, ffn, n)
            _combine_log("ring_dispatch_ffn", True)
            return out

        def serial():
            out = self._serial_dispatch_ffn(toks, ffn)
            _combine_log("all_to_all", False)
            return out

        return self._ladder(dispatch_desc, "dispatch_chain", mode, nbytes, [
            ("FUSED_RING", issued, instr.user, "ring_dispatch_ffn", True,
             fused),
            ("P2P", issued, instr.user, "all_to_all", False, serial),
            ("MEM", CommMode.MEM, 0, "mem_roundtrip", False, serial),
        ])

    def _ring_dispatch_ffn(self, toks, ffn, n: int):
        """The overlapped chain: offset-``s`` ppermute hops around the
        ring, expert FFN on each arriving slab, mirrored hop home.  The
        forward hop at offset ``s+1`` is independent of step ``s``'s
        matmuls — the compiler is free to keep the wire busy under the
        MXU, which is exactly the schedule the planner priced."""
        M, E_loc, C, d = toks.shape
        axis = self.axis_name
        rank = jax.lax.axis_index(axis)
        # step 0: my own slab never touches the wire
        y0 = ffn(jax.lax.dynamic_index_in_dim(toks, rank, 0, keepdims=False))
        back = jnp.zeros((M, E_loc, C, d), y0.dtype)
        back = jax.lax.dynamic_update_index_in_dim(back, y0, rank, 0)
        for s in range(1, M):
            send_to = jax.lax.rem(rank + s, M)
            chunk = jax.lax.dynamic_index_in_dim(toks, send_to, 0,
                                                 keepdims=False)
            # every shard sends its slab for peer (i+s) — so the slab
            # arriving here is what peer (rank-s) packed for my experts
            fwd = [(i, (i + s) % M) for i in range(M)]
            arrived = jax.lax.ppermute(chunk, axis, perm=fwd)
            y = ffn(arrived)
            # mirrored hop: the result returns to its token owner, and
            # peer (rank+s)'s result for MY tokens lands here
            bwd = [(i, (i - s) % M) for i in range(M)]
            mine = jax.lax.ppermute(y, axis, perm=bwd)
            back = jax.lax.dynamic_update_index_in_dim(back, mine, send_to, 0)
        return back

    def _serial_dispatch_ffn(self, toks, ffn):
        """The unfused chain body (no logging — ladder rungs log): one
        all_to_all each way around one full-batch FFN."""
        recv = jax.lax.all_to_all(toks, self.axis_name, split_axis=0,
                                  concat_axis=0)
        M, E_loc, C, d = recv.shape
        out = ffn(jnp.moveaxis(recv, 0, 1).reshape(E_loc, M * C, d))
        out = jnp.moveaxis(out.reshape(E_loc, M, C, d), 1, 0)
        return jax.lax.all_to_all(out, self.axis_name, split_axis=0,
                                  concat_axis=0)

    # -- reduce: fan-in combining, pinned to the memory path ------------------
    def reduce(self, x: jax.Array, desc: TransferDescriptor, *,
               wire_bytes: Optional[int] = None) -> jax.Array:
        """Combining reduction over the stage axis.  The NoC forks
        multicast flits but cannot combine them in flight, so reductions
        always ride the memory path (planner pins them to MEM) — recorded
        as such regardless of what the plan says.  ``wire_bytes``
        overrides the logged byte count when the on-wire payload is
        narrower than the combined tensor (the int8 compressed-gradient
        transport: the wire moves a quarter of what the psum widens to) —
        the issue log must price what *moves*, not what is summed."""
        assert self.axis_name is not None, "reduce needs a stage axis"
        planned = self.resolve_mode(desc, CommMode.MEM)
        nbytes = wire_bytes if wire_bytes is not None else self._nbytes(x)
        self._log(desc, "reduce", planned, CommMode.MEM, 0, nbytes, "psum",
                  degraded=None if planned is CommMode.MEM else
                  "reduction: cannot combine in flight — memory path")
        return jax.lax.psum(x, self.axis_name)

    # -- FUSED_RING: comm fused with the consumer matmul (paper Fig. 6) -------
    def _fused_ring_ok(self, desc: TransferDescriptor, x) -> bool:
        """FUSED_RING preconditions: kernels enabled, the descriptor
        declares its consumer matmul (``fused_with``), a static ring size,
        and a 2-D payload the ring kernels accept.  Anything else takes
        the unfused lax path — always available, numerically identical."""
        if not self.use_kernels or desc.fused_with is None or x.ndim != 2:
            return False
        from repro import compat
        return isinstance(compat.axis_size(self.axis_name), int)

    def _streamed_ok(self, desc: TransferDescriptor, x) -> bool:
        """Streamed-MEM preconditions: kernels enabled, 2-D payload, a
        declared consumer matmul, and the active plan marks this transfer
        streamed (the planner's double-buffered MEM verdict).  Anything
        else takes the serial memory round-trip — always available,
        numerically identical."""
        if not self.use_kernels or desc.fused_with is None or x.ndim != 2:
            return False
        plan = self.plan()
        if plan is None:
            return False
        return (plan.streamed(desc.name) or
                plan.streamed(base_transfer_name(desc.name)))

    def _fused_site(self, desc: TransferDescriptor, x, hint
                    ) -> Tuple[CommMode, jax.Array, int, isa.DmaInstruction]:
        """Shared issue-site prolog of the two FUSED_RING methods:
        resolve the mode, build the write-channel control beat — MEM
        encodes user 0, a P2P ring hop the user=1 unicast degeneracy, an
        MCAST verdict the full ring's destination list — and fold the C3
        fence in."""
        from repro import compat
        mode = self.resolve_mode(desc, hint)
        nbytes = self._nbytes(x)
        word = desc.word_bytes or x.dtype.itemsize
        if mode is CommMode.MEM:
            dests: Tuple[int, ...] = ()
        elif mode is CommMode.P2P:
            dests = (1,)
        else:
            n = compat.axis_size(self.axis_name)
            dests = (tuple(range(1, n))
                     if isinstance(n, int) and n > 1 else (1,))
        req = CommRequest(max(nbytes // word, 1), word, mode, dests=dests)
        instr = isa.encode(req, isa.CH_WRITE)
        if desc.sync:
            x = self._fence(x, mode)
        return mode, x, nbytes, instr

    def gather_matmul(self, x: jax.Array, w: jax.Array,
                      desc: TransferDescriptor,
                      hint: Optional[CommMode] = None) -> jax.Array:
        """Fused all-gather + matmul: ``concat_ring(x) @ w`` where ``x``
        is this rank's (m, k) row shard and ``w`` the (k, n) replicated
        operand; returns (P*m, n) on every rank.

        FUSED_RING dispatch: when the active plan prices the transfer to
        P2P (the overlap planner's fused ring chain) and
        ``desc.fused_with`` names the consumer matmul, the ring
        all-gather-matmul kernel multiplies chunk k while chunk k+1
        streams to the right neighbour — the paper's burst-pipelined
        overlap on the MXU.  The unfused lax path (all_gather, then dot)
        is the always-available fallback — it also serves a P2P or MCAST
        verdict whose preconditions are unmet (issued serially under the
        resolved mode, ``fused=False``).

        A MEM verdict the plan marks *streamed* (``CommPlan.streamed``)
        dispatches the double-buffered stream instead of the serial
        round-trip: the gather still rides the memory path, but the
        gathered operand feeds the matmul in row blocks with block i+1's
        IDMA behind block i's compute (``kernels.streamed_gather``, the
        C5 schedule) — issued MEM, recorded ``fused=True``.  Plain MEM
        is charged the serial memory round-trip as before."""
        assert self.axis_name is not None, "gather_matmul needs a stage axis"
        mode, x, nbytes, instr = self._fused_site(desc, x, hint)
        if mode is CommMode.P2P and self._fused_ring_ok(desc, x):
            from repro.kernels.ring_allgather_matmul import \
                ring_allgather_matmul_local

            def kernel():
                return ring_allgather_matmul_local(
                    x, w, axis_name=self.axis_name, interpret=self.interpret)

            return self._ladder(desc, "gather_matmul", mode, nbytes, [
                ("FUSED_RING", CommMode.P2P, instr.user,
                 "ring_allgather_matmul", True, kernel),
                ("P2P", CommMode.P2P, instr.user, "lax_all_gather", False,
                 lambda: self._serial_gather_matmul(x, w)),
                ("MEM", CommMode.MEM, 0, "mem_roundtrip", False,
                 lambda: self._serial_gather_matmul(x, w)),
            ])
        if mode is CommMode.MEM and self._streamed_ok(desc, x):
            from repro.kernels.streamed_gather import \
                streamed_gather_matmul_local

            def stream():
                return streamed_gather_matmul_local(
                    x, w, axis_name=self.axis_name, interpret=self.interpret)

            return self._ladder(desc, "gather_matmul", mode, nbytes, [
                ("FUSED_RING", CommMode.MEM, 0,
                 "streamed_gather_matmul", True, stream),
                ("MEM", CommMode.MEM, 0, "mem_roundtrip", False,
                 lambda: self._serial_gather_matmul(x, w)),
            ])
        self._log(desc, "gather_matmul", mode, mode, instr.user, nbytes,
                  "mem_roundtrip" if mode is CommMode.MEM
                  else "lax_all_gather")
        return self._serial_gather_matmul(x, w)

    def _serial_gather_matmul(self, x, w):
        full = jax.lax.all_gather(x, self.axis_name, axis=0, tiled=True)
        out_dtype = jnp.promote_types(x.dtype, w.dtype)
        return jnp.dot(full, w,
                       preferred_element_type=jnp.float32).astype(out_dtype)

    def matmul_reduce_scatter(self, x: jax.Array, w: jax.Array,
                              desc: TransferDescriptor,
                              hint: Optional[CommMode] = None) -> jax.Array:
        """Fused matmul + ring reduce-scatter:
        ``reduce_scatter(x @ w, axis)`` where every rank holds ``x``
        (m, k_p) — a column shard of the contraction — and ``w`` (k_p, n);
        returns this rank's fully-reduced (m/P, n) in f32.

        Unlike a plain reduction (pinned MEM: the NoC cannot combine in
        flight), the fused ring combines the partial sums *in the
        accelerator* at every hop, so a P2P verdict dispatches the ring
        reduce-scatter-matmul kernel (FUSED_RING).  Fallback: dot then
        ``psum_scatter`` — same numbers, serial comm under the resolved
        mode."""
        assert self.axis_name is not None, \
            "matmul_reduce_scatter needs a stage axis"
        from repro import compat
        mode, x, nbytes, instr = self._fused_site(desc, x, hint)
        n = compat.axis_size(self.axis_name)
        divisible = isinstance(n, int) and x.shape[0] % n == 0
        if mode is CommMode.P2P and divisible and \
                self._fused_ring_ok(desc, x):
            from repro.kernels.ring_reducescatter_matmul import \
                ring_reducescatter_matmul_local

            def kernel():
                return ring_reducescatter_matmul_local(
                    x, w, axis_name=self.axis_name, interpret=self.interpret)

            return self._ladder(desc, "reduce_scatter", mode, nbytes, [
                ("FUSED_RING", CommMode.P2P, instr.user,
                 "ring_reducescatter_matmul", True, kernel),
                ("P2P", CommMode.P2P, instr.user, "lax_psum_scatter", False,
                 lambda: self._serial_matmul_reduce_scatter(x, w)),
                ("MEM", CommMode.MEM, 0, "mem_roundtrip", False,
                 lambda: self._serial_matmul_reduce_scatter(x, w)),
            ])
        self._log(desc, "reduce_scatter", mode, mode, instr.user, nbytes,
                  "mem_roundtrip" if mode is CommMode.MEM
                  else "lax_psum_scatter")
        return self._serial_matmul_reduce_scatter(x, w)

    def _serial_matmul_reduce_scatter(self, x, w):
        part = jnp.dot(x, w, preferred_element_type=jnp.float32)
        return jax.lax.psum_scatter(part, self.axis_name,
                                    scatter_dimension=0, tiled=True)

    # -- pipeline helpers -----------------------------------------------------
    def forward_to_next(self, x: jax.Array,
                        desc: Optional[TransferDescriptor] = None
                        ) -> jax.Array:
        """GPipe-style stage hand-off: every stage forwards its activation
        to the next (the paper's NN example).  The shift always happens —
        a MEM verdict charges it to the memory round-trip (the producer
        writes, the successor reads back), it does not drop the
        hand-off."""
        assert self.axis_name is not None, "forward_to_next needs a stage axis"
        desc = desc or TransferDescriptor("stage_activation", pull=True)
        mode = self.resolve_mode(desc, CommMode.P2P)
        nbytes = self._nbytes(x)
        mem = mode is CommMode.MEM
        if desc.sync:
            x = self._fence(x, mode)
        self._log(desc, "read", mode,
                  CommMode.MEM if mem else CommMode.P2P, 0 if mem else 1,
                  nbytes, "mem_roundtrip" if mem else "ppermute")
        return P2P.pipeline_stage_forward(x, self.axis_name)

    # ----------------------------------------------------------- internals ----
    def _mem(self, x, desc: TransferDescriptor):
        """The MEM path: a resharding constraint along the descriptor's
        own logical axes (a weight or KV descriptor names weight/KV axes —
        never an activation-shaped guess).  A descriptor with no axes is
        a placement no-op."""
        if not desc.axes:
            return x
        return logical_constraint(x, tuple(desc.axes)[: x.ndim])

    def _kernel_ok(self, x, ranks: Sequence[int], src: int) -> bool:
        """Pallas multicast-stream constraints: kernels enabled, 2-D
        payload with rows splittable into >= 2 chunks, and the
        destination set (excluding the source) covers the whole ring —
        the stream forwards hop-by-hop through EVERY member, so a rank
        the descriptor excluded must not be on the path."""
        if not self.use_kernels or x.ndim != 2:
            return False
        from repro import compat
        n = compat.axis_size(self.axis_name)
        if not isinstance(n, int):
            return False
        covers = len(set(ranks) - {src}) >= n - 1
        return covers and self._kernel_chunks(x) is not None

    def _kernel_chunks(self, x) -> Optional[int]:
        for c in (4, 2):
            if x.shape[0] % c == 0:
                return c
        return None


def socket_for_axis(axis_name: Optional[str],
                    plan: Optional[CommPlan] = None, *,
                    use_kernels: bool = False,
                    interpret=None,
                    retry: Optional[RetryPolicy] = None,
                    fence_timeout_s: float = 0.0) -> AcceleratorSocket:
    """A lightweight socket bound to a mesh axis (no LUT): the form model
    code uses inside shard_map bodies.  The plan defaults to the ambient
    ``use_rules`` context at issue time.  ``use_kernels``/``interpret``
    forward to the Pallas fast paths (multicast stream, FUSED_RING);
    ``retry``/``fence_timeout_s`` arm the degradation ladder and the
    fence stall watchdog (both off by default)."""
    return AcceleratorSocket(None, plan, axis_name=axis_name,
                             use_kernels=use_kernels, interpret=interpret,
                             retry=retry, fence_timeout_s=fence_timeout_s)


_AMBIENT = AcceleratorSocket()


def mem_write(x, name: str, axes: Sequence[Optional[str]],
              site: Optional[str] = None):
    """Issue a memory-path write on the ambient (axis-less) socket: the
    descriptor-based replacement for a bare ``logical_constraint`` at a
    transfer site — the DMA-to-memory half of the dispatch matrix, still
    logged per site."""
    return _AMBIENT.write(x, TransferDescriptor(name, axes=tuple(axes),
                                                site=site))
