"""The accelerator socket (ESP) as a framework object.

ESP's socket decouples an accelerator from the SoC: it provides DMA,
address translation, interrupts, and config registers, plus (this paper) the
per-transfer ``user`` field and a small LUT that *virtualizes* peer indices
into tile coordinates.

Here :class:`StageRegistry` is the LUT — model code addresses peers by
*name* ("encoder", "decoder", "expert_shard") or virtual index, never by
mesh coordinate — and :class:`AcceleratorSocket` is the service layer: its
``read``/``write`` take a :class:`CommRequest` and dispatch to the MEM / P2P
/ MCAST implementation, so a stage can switch modes per transfer (C4) with
no change to its own code.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import CommMode, CommPlan, CommRequest
from repro.core import p2p as P2P
from repro.core import multicast as MC
from repro.core.sharding import logical_constraint


@dataclasses.dataclass
class StageRegistry:
    """Virtualization LUT: name / virtual index -> rank on the stage axis.

    The paper: 'A small, configurable lookup table in the socket encodes the
    tile coordinates for each index, so that these values can be
    virtualized.'"""
    axis_name: str
    table: Dict[str, int] = dataclasses.field(default_factory=dict)

    def register(self, name: str, rank: int) -> int:
        self.table[name] = rank
        return len(self.table) - 1

    def rank_of(self, name: str) -> int:
        return self.table[name]

    def remap(self, name: str, new_rank: int):
        """Retarget a peer without touching accelerator code (e.g. after an
        elastic re-mesh migrates a stage)."""
        if name not in self.table:
            raise KeyError(name)
        self.table[name] = new_rank


class AcceleratorSocket:
    """Per-stage communication services.  Use inside shard_map over the
    stage axis."""

    def __init__(self, registry: StageRegistry, plan: Optional[CommPlan] = None):
        self.registry = registry
        self.plan = plan or CommPlan()

    # -- read channel: user field selects the source -------------------------
    def read(self, x: jax.Array, req: CommRequest,
             source_name: Optional[str] = None,
             consumer_name: Optional[str] = None) -> jax.Array:
        """Pull-based read.  MEM: DMA resharding.  P2P: the consumer
        (identified by its own registered name) pulls from the virtualized
        source — both endpoints resolve through the LUT, so retargeting a
        producer is a registry update, not a code change."""
        if req.mode is CommMode.MEM:
            # DMA from memory: a resharding constraint; XLA materializes the
            # HBM round-trip.
            return logical_constraint(x, ("batch", "seq", "embed")[: x.ndim])
        assert source_name is not None and consumer_name is not None, \
            "P2P read needs (virtualized) source and consumer names"
        src = self.registry.rank_of(source_name)
        dst = self.registry.rank_of(consumer_name)
        return P2P.p2p_send_recv(x, self.registry.axis_name, src, dst)

    # -- write channel: user field selects destination count -----------------
    def write(self, x: jax.Array, req: CommRequest,
              producer_name: Optional[str] = None,
              dest_names: Sequence[str] = ()) -> jax.Array:
        """MEM: DMA to memory (resharding).  One dest: unicast P2P.  Several
        dests: multicast — the producer waits for all consumer pulls
        (collective issue), then sends once (C2)."""
        axis = self.registry.axis_name
        if req.mode is CommMode.MEM or not dest_names:
            return logical_constraint(x, ("batch", "seq", "embed")[: x.ndim])
        assert producer_name is not None
        src = self.registry.rank_of(producer_name)
        dests = [self.registry.rank_of(n) for n in dest_names]
        if len(dests) == 1:
            return P2P.p2p_send_recv(x, axis, src, dests[0])
        return MC.multicast_subset(x, axis, src, dests)

    # -- pipeline helpers -----------------------------------------------------
    def forward_to_next(self, x: jax.Array) -> jax.Array:
        return P2P.pipeline_stage_forward(x, self.registry.axis_name)
