"""ISA-level encoding of socket transfers (the paper's C5, IDMA/CDMA).

The accelerator issues a transfer as one *instruction*: the read or write
control-channel beat carrying (length, word size) plus the ``user`` field
that selects the communication mode — the instruction format the
``kernels/dma_isa`` Pallas layer consumes (``user == 0`` -> local
``idma``; ``user >= 1`` -> ``idma_remote`` to the LUT-resolved peer).

Encoding table (paper Fig. 3):

    channel   user      meaning
    -------   -------   -----------------------------------------------
    read      0         DMA from memory (MEM)
    read      k >= 1    P2P pull from the accelerator at LUT index k
    write     0         DMA to memory (MEM)
    write     1         unicast write (P2P) — also a 1-destination
                        multicast: the two are the SAME wire transaction
                        (the paper's degeneracy)
    write     n >= 2    multicast to the n-entry destination list carried
                        in the header flit

Peer values are *virtual* LUT indices (``StageRegistry``), never tile
coordinates: remapping a peer rewrites the LUT, not the instruction
stream, so an encoded instruction survives an elastic re-mesh unchanged.

``encode``/``decode`` round-trip exactly: ``decode(encode(req, ch))``
reproduces ``req``'s wire-level content, with the single documented
exception that a one-destination MCAST write decodes as P2P — by design,
since the wire cannot distinguish them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.comm import (CommMode, CommRequest, mode_from_read_field,
                             mode_from_write_field)

CH_READ = "read"
CH_WRITE = "write"

# Header user/coordinate field width.  4 bits covers the largest mesh the
# perf model configures (16x16 -> ``noc.header.mesh_coord_bits(16, 16)``
# == 4); the wire field carries 2*coord_bits of peer addressing, so LUT
# indices and destination counts saturate at (1 << 2*coord_bits) - 1
# (user == 0 is reserved for MEM).
DEFAULT_COORD_BITS = 4


class UserFieldRangeError(ValueError):
    """A user field or destination LUT index exceeds what the header's
    coordinate bits can carry on the wire.  Before this check, an
    oversized value silently truncated when packed into the header flit
    — a 16x16-mesh config addressing peer 256 would alias peer 0 (MEM)
    with no error."""


def user_field_capacity(coord_bits: int = DEFAULT_COORD_BITS) -> int:
    """Largest encodable user-field value / LUT index: the header carries
    2*coord_bits of peer addressing and user == 0 is reserved for MEM."""
    if coord_bits < 1:
        raise ValueError(f"coord_bits must be >= 1, got {coord_bits}")
    return (1 << (2 * coord_bits)) - 1


def _check_user_range(value: int, what: str, coord_bits: int) -> int:
    cap = user_field_capacity(coord_bits)
    if not 0 <= value <= cap:
        raise UserFieldRangeError(
            f"{what} {value} outside the encodable range [0, {cap}] for "
            f"coord_bits={coord_bits} — the header flit would silently "
            f"truncate it on the wire")
    return value


@dataclasses.dataclass(frozen=True)
class DmaInstruction:
    """One IDMA instruction: the control beat + user field, as issued on
    the read or write channel.  ``tag`` is the transaction identifier the
    CDMA status query uses (on TPU: the DMA semaphore)."""
    channel: str                  # CH_READ | CH_WRITE
    user: int                     # the mode-selecting user field
    length: int                   # words
    word_bytes: int
    source: Optional[int] = None  # read channel: LUT index of the producer
    dests: Tuple[int, ...] = ()   # write channel: LUT header-flit dest list
    tag: int = 0

    @property
    def nbytes(self) -> int:
        return self.length * self.word_bytes

    @property
    def mode(self) -> CommMode:
        return (mode_from_read_field(self.user) if self.channel == CH_READ
                else mode_from_write_field(self.user))


def encode(req: CommRequest, channel: str, tag: int = 0,
           coord_bits: int = DEFAULT_COORD_BITS) -> DmaInstruction:
    """Encode a control-channel beat as the IDMA instruction the dma_isa
    kernel layer consumes.  Raises :class:`UserFieldRangeError` when the
    user field or a destination LUT index exceeds the wire capacity of
    ``coord_bits`` (instead of silently truncating in the header flit)."""
    if channel == CH_READ:
        user = _check_user_range(req.user_field_read(),
                                 "read-channel user field (P2P source)",
                                 coord_bits)
        return DmaInstruction(CH_READ, user, req.length, req.word_bytes,
                              source=req.source if user else None, tag=tag)
    if channel != CH_WRITE:
        raise ValueError(f"unknown channel: {channel!r}")
    user = _check_user_range(req.user_field_write(),
                             "write-channel user field (dest count)",
                             coord_bits)
    for d in (req.dests if user else ()):
        _check_user_range(d, "write header destination LUT index",
                          coord_bits)
    return DmaInstruction(CH_WRITE, user, req.length, req.word_bytes,
                          dests=req.dests if user else (), tag=tag)


def decode(instr: DmaInstruction) -> CommRequest:
    """Decode an instruction back into the request it encodes.  Exact up
    to the ``user=1`` degeneracy: a single-destination multicast decodes
    as the unicast P2P write it is on the wire."""
    if instr.channel == CH_READ:
        mode = mode_from_read_field(instr.user)
        return CommRequest(instr.length, instr.word_bytes, mode,
                           source=instr.user if mode is CommMode.P2P else None)
    if instr.channel != CH_WRITE:
        raise ValueError(f"unknown channel: {instr.channel!r}")
    mode = mode_from_write_field(instr.user)
    if mode is not CommMode.MEM and len(instr.dests) != instr.user:
        raise ValueError(
            f"write header carries {len(instr.dests)} destinations but "
            f"user field says {instr.user}")
    return CommRequest(instr.length, instr.word_bytes, mode,
                       dests=instr.dests if mode is not CommMode.MEM else ())


def roundtrip_exact(req: CommRequest, channel: str) -> bool:
    """True when encode/decode reproduces the request exactly at the wire
    level: re-encoding the decoded request yields the identical
    instruction (the degeneracy-aware fixed-point check)."""
    instr = encode(req, channel)
    return encode(decode(instr), channel) == instr
