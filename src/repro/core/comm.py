"""Generalized communication modes (the paper's C4 'user field', TPU-native).

The ESP accelerator interface encodes, per transfer, *where data comes from /
goes to*: ``user=0`` is a DMA to memory, ``user=k`` on the read channel pulls
from accelerator *k* (P2P), and ``user=n>=2`` on the write channel multicasts
to *n* consumers.  Here the same triad selects which collective path a
tensor takes on the pod:

* ``CommMode.MEM``   — through HBM / resharding (GSPMD collectives).
* ``CommMode.P2P``   — direct producer→consumer ``ppermute`` (pull-based).
* ``CommMode.MCAST`` — one-to-many broadcast / all_to_all dispatch.

A :class:`CommRequest` mirrors the interface's control-channel beat (length,
word size, source / destination count) and is what the "socket"
(`core.socket`) consumes.  A :class:`CommPlan` assigns modes per named
tensor, letting a single step mix modes — the paper's key flexibility: "fetch
model parameters from memory and a previous layer's outputs from another
accelerator"."""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, FrozenSet, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CommMode(enum.Enum):
    MEM = 0     # user field 0: DMA to memory
    P2P = 1     # user field 1..N-1 (read: source) / 1 (write: unicast)
    MCAST = 2   # user field 2..N-1 on the write channel: multicast


class UnregisteredFusionTargetError(ValueError):
    """A descriptor's ``fused_with`` names a consumer site that was never
    registered at trace time: the transfer would silently take the unfused
    path (a typo like ``"moe.expert_ffn "`` never fuses, with no warning).
    The socket raises this on first issue; ``commcheck``'s
    ``descriptor-dangling-fused`` rule is the same check, static."""


class FaultError(RuntimeError):
    """A fault the runtime recovers from by checkpoint-restore (and,
    elastically, re-mesh + re-plan): a lost host, a non-finite loss, a
    straggler timeout, a sync fence that stalled past its watchdog, or a
    socket dispatch whose whole degradation ladder failed.  Defined here
    (not in ``runtime/``) so the socket can raise it without inverting the
    core/runtime layering; ``repro.runtime.fault`` re-exports it."""


# -- fusion-target / descriptor-site registries (trace-time ground truth) ----
#
# ``fused_with`` targets resolve against two universes: consumer-matmul
# labels declared with :func:`register_fusion_target` (a matmul is not a
# transfer, so no descriptor names it), and the site labels of every
# constructed descriptor (a transfer named after its consumer matmul —
# "attn.o_proj" — is its own target).  The static analyzer
# (``repro.analysis``) extracts the same two universes from the AST, so
# runtime and lint agree on what a dangling target is.

_FUSION_TARGETS: set = set()
_DESCRIPTOR_SITES: set = set()


def register_fusion_target(label: str) -> str:
    """Declare ``label`` as a consumer-matmul site a transfer may fuse
    with (``TransferDescriptor.fused_with``).  Model modules register
    their matmul labels at import, next to the descriptors that feed
    them.  Returns the label so registration can inline into a
    declaration."""
    _FUSION_TARGETS.add(label)
    return label


def registered_fusion_targets() -> frozenset:
    return frozenset(_FUSION_TARGETS)


def registered_descriptor_sites() -> frozenset:
    return frozenset(_DESCRIPTOR_SITES)


def known_fusion_targets() -> frozenset:
    """Everything a ``fused_with`` may legally name: explicit fusion
    targets plus every constructed descriptor's site label."""
    return frozenset(_FUSION_TARGETS | _DESCRIPTOR_SITES)


def base_transfer_name(name: str) -> str:
    """Logical archetype of a (possibly per-layer) transfer name.

    Per-layer transfer specs derived from the compiled HLO are named
    ``"<archetype>.L<index>"`` (e.g. ``"weights.L3"``); runtime collective
    sites and the rule-overlay table are keyed by the archetype alone.
    """
    base, sep, layer = name.rpartition(".L")
    if sep and layer.isdigit():
        return base
    return name


@dataclasses.dataclass(frozen=True)
class CommRequest:
    """One control-channel beat (paper Fig. 3): length in words, word size in
    bytes, and the user field decoded into mode + peer(s)."""
    length: int
    word_bytes: int
    mode: CommMode
    source: Optional[int] = None          # read channel: producer index
    dests: Tuple[int, ...] = ()           # write channel: consumer indices

    @property
    def nbytes(self) -> int:
        return self.length * self.word_bytes

    def user_field_read(self) -> int:
        """Encode the read-channel user field (0 = DMA, k = P2P source k)."""
        if self.mode is CommMode.MEM:
            return 0
        assert self.source is not None and self.source >= 1
        return self.source

    def user_field_write(self) -> int:
        """Encode the write-channel user field (0 = DMA, 1 = unicast P2P,
        n>=2 = multicast to n destinations)."""
        if self.mode is CommMode.MEM:
            return 0
        return max(1, len(self.dests))


@dataclasses.dataclass(frozen=True)
class TransferDescriptor:
    """The typed issue-site description of one on-chip transfer (C4/C5).

    Every transfer outside ``core/`` is issued through
    :class:`~repro.core.socket.AcceleratorSocket` from one of these; the
    socket resolves the *mode* against the active :class:`CommPlan` (keyed
    by :func:`base_transfer_name` of ``name``), encodes the read/write
    user field, and dispatches to the MEM / P2P / MCAST implementation.
    The descriptor carries everything the mode decision must not depend
    on the call site for:

    * ``name``    — the plan key ("moe_dispatch", "weights", ...; a
      per-layer site may use "weights.L3" — the base name resolves);
    * ``axes``    — logical axis names of the tensor, used by the MEM
      path's resharding constraint (NOT an activation-shaped guess: a
      weight or KV descriptor names its own axes);
    * ``source`` / ``consumer`` / ``dests`` — *virtualized* peer names
      resolved through the socket's :class:`StageRegistry` LUT;
    * ``pull``    — read-channel (consumer-initiated) semantics;
    * ``sync``    — fold a C3 sync-region fence around the transfer
      (producer aggregates consumer requests before sending) instead of
      leaving it to the caller;
    * ``site``    — optional call-site label for the issue log (defaults
      to ``name``), so two sites sharing a plan key stay distinguishable
      in dryrun artifacts;
    * ``fused_with`` — label of the consumer *matmul* this transfer feeds
      (e.g. ``"mlp.down_proj"``).  Declaring it marks the transfer
      matmul-adjacent: the socket may dispatch the FUSED_RING path (the
      ring all-gather/reduce-scatter matmul kernels, comm overlapped with
      the MXU) when the plan prices the transfer to P2P and kernels are
      enabled; the planner's overlap objective prices it with the
      matching ``TransferSpec.compute_flops`` credit.
    """
    name: str
    axes: Tuple[Optional[str], ...] = ()
    source: Optional[str] = None
    consumer: Optional[str] = None
    dests: Tuple[str, ...] = ()
    pull: bool = False
    sync: bool = False
    word_bytes: int = 0           # 0 = infer from the tensor's dtype
    site: Optional[str] = None
    fused_with: Optional[str] = None

    def __post_init__(self):
        # every constructed descriptor's site label joins the fusion-target
        # universe (a transfer named after its consumer matmul is its own
        # target); validation of fused_with happens at issue time in the
        # socket, not here — descriptors are built at module import, and
        # the target's registration may legitimately come later
        _DESCRIPTOR_SITES.add(self.site_label)

    @property
    def site_label(self) -> str:
        return self.site or self.name


def mode_from_read_field(user: int) -> CommMode:
    """Decode a read-channel user field: 0 = DMA to memory, k >= 1 = P2P
    pull from accelerator k."""
    if user < 0:
        raise ValueError(f"user field must be non-negative, got {user}")
    return CommMode.MEM if user == 0 else CommMode.P2P


def mode_from_write_field(user: int) -> CommMode:
    """Decode a write-channel user field: 0 = DMA, 1 = unicast, n >= 2 =
    multicast.  Note the paper's degeneracy: a multicast with a single
    destination and a unicast P2P write share the encoding ``user=1`` —
    they are the same wire transaction."""
    if user < 0:
        raise ValueError(f"user field must be non-negative, got {user}")
    if user == 0:
        return CommMode.MEM
    return CommMode.P2P if user == 1 else CommMode.MCAST


@dataclasses.dataclass
class CommPlan:
    """Per-tensor communication-mode assignment.

    ``modes`` maps logical tensor names (e.g. "moe_dispatch",
    "stage_activation", "weights") to a CommMode.  The distribution layer
    queries the plan instead of hard-coding a collective.

    ``streamed_names`` holds the tensor names whose winning verdict was the
    *streamed* memory path (``PlanDecision.streamed``): mode MEM, but the
    socket should dispatch the double-buffered DMA schedule
    (``kernels.dma_double_buffer``) instead of the serial gather so block
    i+1's IDMA hides behind block i's consumer compute (paper C5).
    """
    modes: Dict[str, CommMode] = dataclasses.field(default_factory=dict)
    default: CommMode = CommMode.MEM
    streamed_names: FrozenSet[str] = frozenset()

    def mode(self, name: str) -> CommMode:
        return self.modes.get(name, self.default)

    def streamed(self, name: str) -> bool:
        """True when ``name``'s MEM verdict carries the double-buffered
        streaming schedule (overlap credit without a direct NoC path)."""
        return name in self.streamed_names

    def with_mode(self, name: str, mode: CommMode) -> "CommPlan":
        m = dict(self.modes)
        m[name] = mode
        # a mode override invalidates the streamed verdict for that name:
        # streaming is an attribute of the *priced* MEM decision
        return CommPlan(m, self.default, self.streamed_names - {name})


def validate_p2p_totals(producer_bursts: Sequence[int],
                        consumer_bursts: Sequence[int]) -> bool:
    """Paper C1: producer and consumer may use *different* access patterns
    (number and size of bursts) but must move the same total amount of data
    per P2P transaction.  Raises on violation, returns True otherwise."""
    pt, ct = int(np.sum(producer_bursts)), int(np.sum(consumer_bursts))
    if pt != ct:
        raise ValueError(
            f"P2P totals differ: producer {pt} words vs consumer {ct} words "
            f"(patterns {list(producer_bursts)} / {list(consumer_bursts)})")
    return True


def reblock(x: jax.Array, out_burst: int) -> jax.Array:
    """Re-block a producer's burst stream into consumer-sized bursts
    (flexible P2P, C1).  Total element count must be preserved."""
    flat = x.reshape(-1)
    if flat.shape[0] % out_burst:
        raise ValueError(
            f"total {flat.shape[0]} not divisible by consumer burst {out_burst}")
    return flat.reshape(-1, out_burst)
