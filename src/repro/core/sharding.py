"""Logical-axis sharding rules (MaxText-style).

Model code never names mesh axes directly.  Tensors carry *logical* axis
names ("batch", "heads", "mlp", ...) and a :class:`ShardingRules` table maps
them to physical mesh axes.  Rules mentioning axes absent from the current
mesh are silently dropped, so the same rules serve the single-pod
``(data, model)`` mesh and the multi-pod ``(pod, data, model)`` mesh.

This is the framework half of the paper's C4 contribution (the accelerator
interface's per-transfer ``user`` field): the *rule table* — not the model —
decides which physical path a tensor takes.

The context also carries an optional :class:`~repro.core.comm.CommPlan`
(installed via ``use_rules(..., comm_plan=...)``): collective sites query
``current_comm_plan()`` for the per-tensor communication mode instead of
hard-coding one, which is how the cost-model planner
(`core.planner.CommPlanner`) reaches every transfer from a single flag.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.comm import CommMode, CommPlan, base_transfer_name

AxisVal = Union[None, str, Tuple[str, ...]]


DEFAULT_RULES: Dict[str, AxisVal] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,              # replicated by default; "seq_sp" shards it
    "seq_sp": "model",        # sequence parallelism (activations in FFN/MoE)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "kv_seq": "model",        # decode-time KV cache sequence dim
    "state": "model",         # SSM / RG-LRU channel dim
    # weights (fsdp axis added dynamically when enabled)
    "w_embed": None,
    # FSDP/ZeRO weight sharding uses every data-parallel axis: on the
    # multi-pod mesh weights shard 32 ways (pod x data), not 16 (§Perf B4)
    "w_fsdp": ("pod", "data"),
    "expert_ff": None,
}


# ----------------------------------------------- planner -> rules feedback
#
# The mode decision must reach the code that *generates* the traffic, not
# just label it after the fact: with ``w_fsdp`` on, the per-step weight
# gather is an FSDP all-gather through memory regardless of what the plan
# says, so a MCAST verdict for the ``weights`` transfer is only realizable
# by rewriting the rule itself (weights replicated over the data axes and
# broadcast on the direct path).  ``RULE_OVERLAYS`` maps a transfer
# archetype's planned mode to the axis-rule rewrites that make the mode
# real; ``resolve_rules`` applies them.
RULE_OVERLAYS: Dict[str, Dict[CommMode, Dict[str, AxisVal]]] = {
    # weight all-gather prices to MCAST -> drop FSDP sharding (the gather
    # disappears; the platform broadcasts weights on the write channel).
    # A P2P verdict is the overlap planner's *fused ring chain* (hop-by-hop
    # user=1 unicasts hidden behind the consumer matmul — how a broadcast
    # past the multicast header capacity still goes direct): it replaces
    # the FSDP gather exactly like MCAST does, so it realizes the same
    # rewrite.  MEM keeps FSDP: the round-trip through memory is the
    # gather itself.
    "weights": {CommMode.MCAST: {"w_fsdp": None},
                CommMode.P2P: {"w_fsdp": None}},
    # sequence parallelism follows the MoE dispatch verdict.  The mcast
    # dispatch *requires* sequence-sharded activations (each source shard
    # packs its own token slice — ``seq_sp`` stays on the model axis, the
    # static default).  A MEM verdict is the shared-memory baseline:
    # tokens replicate across the model axis and every expert owner
    # selects locally — keeping the surrounding activations (attention
    # context, FFN inputs) sequence-sharded would insert a reshard
    # boundary at every block, so the overlay replicates ``seq_sp`` to
    # match the dataflow the plan chose.  Like ``w_fsdp`` this flows
    # through the dryrun's relower-once guard: resolved rules differ ->
    # one rebuild under the rewritten table.
    "moe_dispatch": {CommMode.MEM: {"seq_sp": None}},
}


def resolve_rules(plan: Optional[CommPlan], rules: Dict[str, AxisVal]
                  ) -> Tuple[Dict[str, AxisVal], Dict[str, AxisVal]]:
    """Rewrite a sharding-rule table from planner decisions.

    Returns ``(resolved_rules, overlay)`` where ``overlay`` holds exactly
    the entries that changed (empty when the plan demands no rewrite).
    Per-layer plan entries (``"weights.L3"``) vote as their archetype; the
    overlay applies only when every layer of the archetype agrees on the
    mode — axis rules are global, so a mixed per-layer verdict keeps the
    conservative static rule.  The pass is idempotent and only ever
    rewrites axes already present in ``rules`` with values drawn from the
    static ``RULE_OVERLAYS`` table, so it cannot invent an unshardable
    rule.
    """
    resolved = dict(rules)
    overlay: Dict[str, AxisVal] = {}
    if plan is None:
        return resolved, overlay
    for transfer, by_mode in RULE_OVERLAYS.items():
        modes = [m for name, m in plan.modes.items()
                 if base_transfer_name(name) == transfer]
        if not modes or any(m is not modes[0] for m in modes):
            continue
        for axis, val in (by_mode.get(modes[0]) or {}).items():
            if axis in resolved and resolved[axis] != val:
                overlay[axis] = val
                resolved[axis] = val
    return resolved, overlay


def rule_gated_issued_mode(name: str, plan: Optional[CommPlan],
                           rules: Dict[str, AxisVal]) -> CommMode:
    """The mode an overlay-gated transfer is *issued* with under a rule
    table: a direct plan verdict (e.g. MCAST weights) is only real once
    the table realizes its rewrite (``w_fsdp -> None``); until then the
    sharding rules — not the plan label — decide what XLA lowers, and the
    transfer issues on the memory path.  Runtime step factories use this
    to log implicit (compiler-issued) transfers in the socket issue log."""
    base = base_transfer_name(name)
    planned = plan.mode(base) if plan is not None else CommMode.MEM
    if planned is CommMode.MEM:
        return CommMode.MEM
    rewrite = (RULE_OVERLAYS.get(base) or {}).get(planned)
    if rewrite is None:
        return CommMode.MEM
    realized = all(rules.get(a, v) == v for a, v in rewrite.items())
    return planned if realized else CommMode.MEM


class _RulesCtx(threading.local):
    def __init__(self):
        self.rules: Dict[str, AxisVal] = dict(DEFAULT_RULES)
        self.mesh: Optional[Mesh] = None
        self.comm_plan: Optional[CommPlan] = None


_CTX = _RulesCtx()


class use_rules:
    """Context manager installing a rules table (+ optional mesh override
    and per-tensor communication-mode plan)."""

    def __init__(self, rules: Dict[str, AxisVal], mesh: Optional[Mesh] = None,
                 comm_plan: Optional[CommPlan] = None):
        self._new = rules
        self._mesh = mesh
        self._plan = comm_plan
        self._old: Optional[Dict[str, AxisVal]] = None
        self._old_mesh: Optional[Mesh] = None
        self._old_plan: Optional[CommPlan] = None

    def __enter__(self):
        self._old, self._old_mesh = _CTX.rules, _CTX.mesh
        self._old_plan = _CTX.comm_plan
        _CTX.rules = dict(self._new)
        if self._mesh is not None:
            _CTX.mesh = self._mesh
        if self._plan is not None:
            _CTX.comm_plan = self._plan
        return self

    def __exit__(self, *exc):
        _CTX.rules, _CTX.mesh = self._old, self._old_mesh
        _CTX.comm_plan = self._old_plan
        return False


def current_rules() -> Dict[str, AxisVal]:
    return _CTX.rules


def current_comm_plan() -> Optional[CommPlan]:
    """The active per-tensor communication-mode plan, if any (C4: collective
    sites consult the plan instead of a hard-coded mode)."""
    return _CTX.comm_plan


def current_mesh() -> Optional[Mesh]:
    if _CTX.mesh is not None:
        return _CTX.mesh
    m = None
    try:  # abstract mesh from jax context if set
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.axis_names:
            m = None
    except Exception:
        m = None
    return m


def _filter_axes(val: AxisVal, mesh_axes: Sequence[str]) -> AxisVal:
    if val is None:
        return None
    if isinstance(val, str):
        return val if val in mesh_axes else None
    kept = tuple(a for a in val if a in mesh_axes)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_pspec(names: Sequence[Optional[str]],
                     rules: Optional[Dict[str, AxisVal]] = None,
                     mesh: Optional[Mesh] = None,
                     shape: Optional[Sequence[int]] = None) -> P:
    """Map a tuple of logical axis names (None = replicated) to a
    PartitionSpec.  If ``shape`` is given, axes whose size does not divide
    the dimension are dropped (best-effort replication — e.g. 3 kv-heads on
    a 16-way model axis).  The resulting padding waste is what the roofline's
    MODEL_FLOPS/HLO_FLOPS ratio surfaces."""
    rules = rules if rules is not None else current_rules()
    mesh = mesh if mesh is not None else current_mesh()
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
    sizes = {a: mesh.shape[a] for a in mesh_axes} if mesh is not None else {}
    out, used = [], set()
    for i, n in enumerate(names):
        if n is None:
            out.append(None)
            continue
        val = _filter_axes(rules.get(n), mesh_axes)
        # an axis may appear at most once in a PartitionSpec
        if isinstance(val, tuple):
            val = tuple(a for a in val if a not in used) or None
            if isinstance(val, tuple) and len(val) == 1:
                val = val[0]
        if isinstance(val, str) and val in used:
            val = None
        if val is not None and shape is not None:
            ax_size = 1
            for a in (val if isinstance(val, tuple) else (val,)):
                ax_size *= sizes.get(a, 1)
            if ax_size == 0 or shape[i] % ax_size != 0:
                val = None
        if val is not None:
            used.update(val if isinstance(val, tuple) else (val,))
        out.append(val)
    return P(*out)


def logical_constraint(x, names: Sequence[Optional[str]]):
    """with_sharding_constraint by logical names (no-op without a mesh).

    Unlike jit *argument* shardings, constraints on intermediates may be
    uneven (GSPMD pads — e.g. 9 heads on a 16-way axis become 1.8x padded
    instead of 16x replicated), so no divisibility filtering here."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(names, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_concrete(mesh), spec))


def named_sharding(names: Sequence[Optional[str]],
                   mesh: Optional[Mesh] = None) -> NamedSharding:
    mesh = mesh if mesh is not None else current_mesh()
    return NamedSharding(_concrete(mesh), logical_to_pspec(names, mesh=mesh))


def _concrete(mesh):
    """NamedSharding wants a concrete Mesh; tolerate AbstractMesh inputs."""
    return mesh


def tree_pspecs(logical_tree, rules=None, mesh=None):
    """Map a pytree of logical-name-tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda names: logical_to_pspec(names, rules, mesh),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
