"""Multicast transfers (paper C2), jax-native.

The multicast NoC encodes a destination *list* in the header flit and forks
flits at routers.  The TPU analogues, in increasing generality:

* ``multicast_bcast``  — one producer, all ranks on an axis consume
  (header = every tile): a masked ``psum``; XLA lowers it to a single
  all-reduce whose ring traversal is precisely the NoC fork tree.
* ``multicast_subset`` — one producer, an arbitrary static destination set
  (the paper's <=16-destination list): chained ``ppermute`` rounds, one hop
  per round — a software fork tree.
* MoE top-k dispatch (``models.moe`` mode="mcast") — each token's activation
  multicast to its k expert tiles via one ``all_to_all``; top-1 degrades to
  unicast P2P exactly as in the paper.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def multicast_bcast(x: jax.Array, axis_name: str, src: int) -> jax.Array:
    """Broadcast rank ``src``'s value to every rank along ``axis_name``."""
    idx = jax.lax.axis_index(axis_name)
    masked = jnp.where(idx == src, x, jnp.zeros_like(x))
    return jax.lax.psum(masked, axis_name)


def multicast_subset_dynamic(x: jax.Array, axis_name: str, src,
                             dests: jax.Array) -> jax.Array:
    """Multicast with *traced* peer indices (``src`` scalar, ``dests`` a
    1-D index array): the socket's dynamic-LUT path — retargeting a
    consumer set is a new argument, not a retrace.  Implemented as a
    masked broadcast (the fork tree needs a static destination list)."""
    idx = jax.lax.axis_index(axis_name)
    contrib = jnp.where(idx == src, x, jnp.zeros_like(x))
    y = jax.lax.psum(contrib, axis_name)
    member = jnp.logical_or(idx == src, jnp.any(dests == idx))
    return jnp.where(member, y, jnp.zeros_like(y))


def multicast_subset(x: jax.Array, axis_name: str, src: int,
                     dests: Sequence[int]) -> jax.Array:
    """Multicast ``x`` from ``src`` to the static destination list ``dests``
    via a binary fork tree of ppermutes (log2(len(dests)) + 1 rounds).
    Non-destination ranks receive zeros.  Mirrors the paper's header-flit
    destination list: the set is fixed when the transfer is issued."""
    dests = [d for d in dests if d != src]
    if not dests:
        return x
    holders = [src]
    out = x
    remaining = list(dests)
    while remaining:
        perm = []
        new_holders = list(holders)
        for h in holders:
            if not remaining:
                break
            d = remaining.pop(0)
            perm.append((h, d))
            new_holders.append(d)
        recv = jax.lax.ppermute(out, axis_name, perm)
        idx = jax.lax.axis_index(axis_name)
        is_new = jnp.zeros((), jnp.bool_)
        for _, d in perm:
            is_new = jnp.logical_or(is_new, idx == d)
        out = jnp.where(is_new, recv, out)
        holders = new_holders
    # zero out ranks that are neither src nor dests
    idx = jax.lax.axis_index(axis_name)
    member = jnp.zeros((), jnp.bool_)
    for r in [src] + dests:
        member = jnp.logical_or(member, idx == r)
    return jnp.where(member, out, jnp.zeros_like(out))
