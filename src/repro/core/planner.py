"""Cost-model-driven communication-mode planner (the paper's C4, automated).

The paper's central claim is that *per-transfer* control over the
communication mode — memory DMA vs. P2P vs. multicast — is what unlocks the
Fig. 6 speedups; its evaluation hand-picks the mode per experiment.  This
module closes the loop: :class:`CommPlanner` queries the calibrated NoC
performance model (:class:`~repro.core.noc.perfmodel.SoCPerfModel`, batched
sweep API) for every named transfer of a step and emits the
:class:`~repro.core.comm.CommPlan` that hand-written configs used to
hard-code.  Selection follows the paper's constraints:

* fan-out above the multicast capacity (header-flit bound
  ``max_multicast_dests`` / ESP's ``ESP_MAX_DESTS`` cap) degrades to MEM —
  past the destination-set limit the transfer must round-trip through
  memory;
* a pull-type unicast (consumer fetches a known producer's output — the
  paper's "a previous layer's outputs from another accelerator") is
  labelled ``P2P`` and rides the read channel (``user = k``);
* push-type transfers take the write channel: ``MCAST`` with the
  destination list in the header flit (fan-out 1 encodes as ``user = 1``,
  the unicast degeneracy — a 1-destination multicast *is* a P2P write);
* when the direct path is not predicted faster than the memory baseline,
  MEM wins (it is the safe default the rest of the stack understands).

``plan()`` is batched: one vectorized model sweep prices every transfer,
so planning stays off the step's critical path even for many tensors.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.comm import CommMode, CommPlan, CommRequest, base_transfer_name
from repro.core.noc.perfmodel import (SoCPerfModel, default_params,
                                      overlapped_cycles)


# Per-mode fusibility under the overlap objective (paper Fig. 6: the
# consumer starts on burst k while burst k+1 is in flight).  P2P ring
# transfers overlap (the fused ring kernels consume chunk k while chunk
# k+1 streams); MCAST overlaps through the double-buffered multicast
# stream; a MEM round-trip serializes at the memory tile — the consumer
# is re-invoked only after the producer's whole payload landed — so it
# can hide nothing.
FUSIBLE_MODES = {
    CommMode.MEM: False,
    CommMode.P2P: True,
    CommMode.MCAST: True,
}


@dataclasses.dataclass(frozen=True)
class TransferSpec:
    """One named transfer the planner prices: ``name`` is the logical
    tensor key the :class:`CommPlan` is indexed by (e.g. "moe_dispatch",
    "stage_activation", "weights"); ``nbytes`` the payload per transfer;
    ``fan_out`` the consumer count; ``pull`` marks consumer-initiated
    unicasts (read channel -> P2P label); ``reduce`` marks transfers that
    combine data from the fan-in set (all-reduce/reduce-scatter lowerings)
    — the NoC forks multicast flits but cannot combine them in flight, so
    reductions always round-trip through the memory tile.

    HLO-derived specs are *per layer*: a collective op inside the
    scan-over-layers while body executes once per layer, and each execution
    is its own transfer named ``"<archetype>.L<layer>"`` with ``layer`` set
    — the planner can mix modes within one step instead of one verdict per
    step."""
    name: str
    nbytes: int
    fan_out: int
    pull: bool = False
    source: int = 1               # producer index for request encoding
    dests: Tuple[int, ...] = ()   # explicit consumer indices (else 1..fan_out)
    word_bytes: int = 4
    reduce: bool = False
    layer: Optional[int] = None   # per-layer specs: HLO layer index
    # executions this spec stands for: 1 normally; the total layer count
    # when a per-layer expansion past the cap degrades to one dominant
    # spec (keeps modeled step cost continuous across the cap)
    mult: int = 1
    # FLOPs of the consumer compute this transfer feeds (the dot ops of
    # the computation the collective lowered into, per execution — see
    # hlo_analysis).  Non-zero marks the transfer matmul-adjacent: a
    # fusible mode may hide its cycles behind this compute (overlap
    # objective), and a fused ring chain may carry it even past the
    # multicast header capacity (each hop is a user=1 unicast).
    compute_flops: float = 0.0


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """Why a transfer got its mode: predicted cycles per candidate path and
    the chosen mode's predicted speedup over the always-MEM baseline.
    ``compute_cycles``/``ramp_cycles`` carry the overlap objective's terms
    (0 when the spec declares no consumer compute); ``fused`` marks a
    decision whose chosen mode overlaps that compute — for P2P this is the
    fused ring chain the socket dispatches as FUSED_RING.  ``streamed``
    marks a MEM verdict that still overlaps: the double-buffered DMA
    stream (block i+1's IDMA issued behind block i's consumer compute,
    paper C5) hides memory-path cycles without a direct NoC path — the
    socket dispatches it via the ``kernels.dma_double_buffer`` schedule."""
    spec: TransferSpec
    mode: CommMode
    cycles: Dict[str, float]
    speedup_vs_mem: float
    reason: str
    compute_cycles: float = 0.0
    ramp_cycles: float = 0.0
    fused: bool = False
    streamed: bool = False


class CommPlanner:
    """Builds :class:`CommPlan`s from the NoC cost model.

    ``max_dests`` defaults to the model's multicast capacity (header-flit
    bound, ESP cap, tile budget); pass a smaller value to emulate a
    narrower NoC.
    """

    def __init__(self, model: Optional[SoCPerfModel] = None, *,
                 max_dests: Optional[int] = None):
        self.model = model or SoCPerfModel()
        cap = self.model.max_dests
        self.capacity = cap if max_dests is None else min(cap, max_dests)

    # ------------------------------------------------------------ pricing
    def price(self, specs: Sequence[TransferSpec]) -> List[PlanDecision]:
        """Batched pricing: one vectorized model sweep for all transfers.

        A spec with ``compute_flops == 0`` prices exactly as before (serial
        path-vs-path comparison).  A matmul-adjacent spec is priced under
        the overlap objective: each fusible candidate is charged
        ``max(comm, compute) + ramp`` against the serial ``mem + compute``
        baseline, and a *fused ring chain* (hop-by-hop user=1 unicasts,
        priced as the unicast path at the full ring payload) joins the
        candidate set — it needs no header-flit destination list, so it is
        exempt from the multicast capacity cap.
        """
        if not specs:
            return []
        fan = np.array([max(s.fan_out, 1) for s in specs])
        nbytes = np.array([max(s.nbytes, 1) for s in specs])
        cycles = self.model.batch_cycles(fan, nbytes)
        # ring chain: every link carries every peer's chunk once, so the
        # fused ring moves fan_out * nbytes over the unicast path
        ring = self.model.batch_cycles(np.ones_like(fan), nbytes * fan)["p2p"]
        ramp = self.model.overlap_ramp_cycles
        out: List[PlanDecision] = []
        for i, spec in enumerate(specs):
            mem = float(cycles["mem"][i])
            direct = float(cycles["mcast"][i])   # fan-out 1: == p2p path
            ring_i = float(ring[i])
            compute = self.model.compute_cycles(spec.compute_flops)
            point = {"mem": mem, "p2p": float(cycles["p2p"][i]),
                     "mcast": direct, "ring": ring_i}
            kw = dict(compute_cycles=compute, ramp_cycles=ramp)
            if spec.fan_out < 1:
                out.append(PlanDecision(spec, CommMode.MEM, point, 1.0,
                                        "no consumers: plain store to memory",
                                        **kw))
            elif spec.reduce:
                out.append(self._price_reduce(spec, point, compute, ramp, kw))
            elif compute > 0:
                out.append(self._price_fused(spec, point, compute, ramp, kw))
            elif spec.fan_out > self.capacity:
                out.append(PlanDecision(
                    spec, CommMode.MEM, point, 1.0,
                    f"fan-out {spec.fan_out} exceeds multicast capacity "
                    f"{self.capacity}: degrade to memory round-trip", **kw))
            elif not np.isfinite(direct) or direct >= mem:
                out.append(PlanDecision(
                    spec, CommMode.MEM, point, 1.0,
                    "memory path predicted no slower than direct path", **kw))
            else:
                mode = (CommMode.P2P if spec.pull and spec.fan_out == 1
                        else CommMode.MCAST)
                out.append(PlanDecision(
                    spec, mode, point, mem / direct,
                    f"direct path {mem / direct:.2f}x faster than memory "
                    f"({'read-channel pull' if mode is CommMode.P2P else 'write-channel push'})",
                    **kw))
        return out

    def _price_reduce(self, spec, point, compute, ramp, kw) -> PlanDecision:
        """A reduction cannot combine in flight on the NoC — unless it is
        matmul-adjacent: the fused ring reduce-scatter combines the partial
        sums *in the accelerator* at every hop (the consumer is the adder),
        so a declared consumer matmul lifts the MEM pin when the overlapped
        ring beats the serial memory round-trip.  When the ring loses on
        cycles, the *streamed* memory path still competes: the reduction
        keeps riding memory (mode MEM — the combine happens at the memory
        tile), but bucket i's DMA is issued behind bucket i+1's producer
        compute (IDMA issue / CDMA completion query, paper C5), so the
        round-trip hides behind the adjacent matmuls instead of
        serializing after them."""
        mem, ring_i = point["mem"], point["ring"]
        if compute > 0:
            eff_mem = mem + compute
            eff_ring = (overlapped_cycles(ring_i, compute, ramp)
                        if np.isfinite(ring_i) else np.inf)
            eff_stream = overlapped_cycles(mem, compute, ramp)
            if eff_ring < eff_mem and eff_ring <= eff_stream:
                # chosen_cycles reads the p2p column for a P2P verdict:
                # publish the ring chain's comm cost there
                point = dict(point, p2p=ring_i)
                return PlanDecision(
                    spec, CommMode.P2P, point, eff_mem / eff_ring,
                    f"fused ring reduce-scatter: combine rides the "
                    f"accelerator, comm hides behind the consumer matmul "
                    f"({eff_mem / eff_ring:.2f}x vs serial memory path)",
                    fused=True, **kw)
            if eff_stream < eff_mem:
                return PlanDecision(
                    spec, CommMode.MEM, point, eff_mem / eff_stream,
                    f"streamed memory-path reduction: bucket i's DMA "
                    f"issued behind bucket i+1's producer compute "
                    f"({eff_mem / eff_stream:.2f}x vs the serial memory "
                    f"round-trip)", fused=True, streamed=True, **kw)
        return PlanDecision(
            spec, CommMode.MEM, point, 1.0,
            "reduction: the NoC forks multicasts but cannot combine "
            "in flight — round-trip through memory", **kw)

    def _price_fused(self, spec, point, compute, ramp, kw) -> PlanDecision:
        """Overlap-aware selection for a matmul-adjacent (non-reduce)
        transfer: direct candidates are charged their overlapped cost, MEM
        the serial sum (a memory round-trip hides nothing)."""
        mem, direct, ring_i = point["mem"], point["mcast"], point["ring"]
        eff_mem = mem + compute
        # candidate set: the multicast path within header capacity, and the
        # capacity-exempt fused ring chain
        mcast_ok = (spec.fan_out <= self.capacity and np.isfinite(direct))
        eff_mcast = (overlapped_cycles(direct, compute, ramp)
                     if mcast_ok else np.inf)
        eff_ring = (overlapped_cycles(ring_i, compute, ramp)
                    if np.isfinite(ring_i) else np.inf)
        ring_won = False
        if spec.pull and spec.fan_out == 1 and mcast_ok:
            # read-channel pull keeps the P2P label on the direct path
            # (fan-out 1: ring == direct)
            mode, eff = CommMode.P2P, eff_mcast
            how = "read-channel pull"
        elif eff_mcast <= eff_ring:
            mode, eff = CommMode.MCAST, eff_mcast
            how = "double-buffered multicast stream"
        else:
            mode, eff, ring_won = CommMode.P2P, eff_ring, True
            how = ("fused ring chain (user=1 hops, capacity-exempt)"
                   if spec.fan_out > self.capacity else "fused ring chain")
        if not np.isfinite(eff) or eff >= eff_mem:
            # no direct path wins — but the memory path itself can still
            # stream: the double-buffered gather issues block i+1's IDMA
            # behind block i's consumer matmul (paper C5), hiding the
            # round-trip without any direct NoC path
            eff_stream = overlapped_cycles(mem, compute, ramp)
            if eff_stream < eff_mem and eff_stream < eff:
                return PlanDecision(
                    spec, CommMode.MEM, point, eff_mem / eff_stream,
                    f"double-buffered streamed gather: block i+1's IDMA "
                    f"issued behind block i's consumer matmul "
                    f"({eff_mem / eff_stream:.2f}x vs the serial memory "
                    f"path)", fused=True, streamed=True, **kw)
            return PlanDecision(
                spec, CommMode.MEM, point, 1.0,
                "memory path predicted no slower than any direct path "
                "even with overlap credit", **kw)
        if ring_won:
            # only a WINNING ring verdict publishes the chain's cost as
            # the p2p path (chosen_cycles reads it there); a losing
            # candidate must not overwrite the table
            point = dict(point, p2p=ring_i)
        return PlanDecision(
            spec, mode, point, eff_mem / eff,
            f"overlapped {how} {eff_mem / eff:.2f}x faster than the serial "
            f"memory path (comm hides behind the consumer matmul)",
            fused=True, **kw)

    # ----------------------------------------------------------- planning
    def plan(self, specs: Sequence[TransferSpec]) -> CommPlan:
        """The drop-in replacement for a hand-written CommPlan dict."""
        return self.plan_with_decisions(specs)[0]

    def plan_with_decisions(self, specs: Sequence[TransferSpec]
                            ) -> Tuple[CommPlan, List[PlanDecision]]:
        decisions = self.price(specs)
        plan = CommPlan()
        for d in decisions:
            plan = plan.with_mode(d.spec.name, d.mode)
            if d.streamed:
                plan = dataclasses.replace(
                    plan, streamed_names=plan.streamed_names |
                    {d.spec.name})
        # Per-layer specs also publish a base-archetype aggregate: runtime
        # collective sites are traced once per scanned layer group, so they
        # query the logical name ("moe_dispatch"), not a layer key.  The
        # aggregate takes the dominant (largest-payload) layer's mode —
        # exactly the transfer the pre-per-layer planner priced.  Duplicate
        # names dedupe last-wins first, matching CommPlan.with_mode.
        last_by_name: Dict[str, PlanDecision] = {}
        for d in decisions:
            last_by_name[d.spec.name] = d
        groups: Dict[str, List[PlanDecision]] = {}
        for d in last_by_name.values():
            base = base_transfer_name(d.spec.name)
            if base != d.spec.name:
                groups.setdefault(base, []).append(d)
        for base, ds in groups.items():
            if base not in plan.modes:
                dom = max(ds, key=lambda d: d.spec.nbytes)
                plan = plan.with_mode(base, dom.mode)
                if dom.streamed:
                    plan = dataclasses.replace(
                        plan, streamed_names=plan.streamed_names | {base})
        return plan, decisions

    # ----------------------------------------------------------- requests
    def requests(self, specs: Sequence[TransferSpec]) -> List[CommRequest]:
        """Control-channel beats for the planned transfers — the user-field
        encoding the accelerator interface consumes (paper Fig. 3)."""
        reqs = []
        for d in self.price(specs):
            s = d.spec
            dests = s.dests or tuple(range(1, max(s.fan_out, 0) + 1))
            if d.mode is CommMode.MEM:
                dests = ()
            reqs.append(CommRequest(
                length=max(1, s.nbytes // s.word_bytes),
                word_bytes=s.word_bytes, mode=d.mode,
                source=s.source if d.mode is not CommMode.MEM else None,
                dests=dests))
        return reqs


# ---------------------------------------------------------- step cost model

def chosen_cycles(d: PlanDecision) -> float:
    """Predicted cycles of the decision's chosen path."""
    if d.mode is CommMode.MEM:
        return d.cycles["mem"]
    return d.cycles["p2p"] if d.mode is CommMode.P2P else d.cycles["mcast"]


def _effective_comm(d: PlanDecision, rules: Optional[Dict]
                    ) -> Tuple[CommMode, float]:
    """The mode a decision is *charged* under a rule table and its comm
    cycles: a rule-gated direct verdict rides the memory path until the
    table realizes its mode's rewrite (see ``modeled_step_cycles``)."""
    from repro.core.sharding import RULE_OVERLAYS
    by_mode = (RULE_OVERLAYS.get(base_transfer_name(d.spec.name))
               if rules is not None else None)
    if by_mode is not None and d.mode is not CommMode.MEM:
        rewrite = by_mode.get(d.mode)
        realized = rewrite is not None and all(
            rules.get(a, v) == v for a, v in rewrite.items())
        if not realized:
            return CommMode.MEM, d.cycles["mem"]
    return d.mode, chosen_cycles(d)


def modeled_step_cycles(decisions: Sequence[PlanDecision],
                        rules: Optional[Dict] = None,
                        objective: str = "overlap") -> float:
    """Total modeled cycles of one step's transfers under a rule table.

    A rule-gated transfer (an archetype with a ``core.sharding.
    RULE_OVERLAYS`` entry) rides a direct path only once the rule table
    realizes its mode's rewrite (e.g. ``w_fsdp -> None`` for MCAST
    weights): until then it is charged the memory path — the sharding
    rules, not the plan label, decide what XLA lowers.  A direct mode the
    overlay table has no rewrite for is unrealizable under any rules and
    stays charged the memory path.  With ``rules`` omitted every decision
    is charged its chosen path (pure plan cost).  This is the quantity the
    feedback loop improves: for any plan, ``modeled_step_cycles(d,
    resolve_rules(plan, rules)[0]) <= modeled_step_cycles(d, rules)``.

    ``objective`` selects how a transfer's declared consumer compute is
    charged.  ``"serial"``: compute waits for communication — every
    decision costs ``comm + compute``.  ``"overlap"`` (default): a fusible
    charged mode (``FUSIBLE_MODES``) hides its comm behind the compute it
    feeds — ``max(comm, compute) + ramp`` — while MEM (and rule-gated
    verdicts charged as MEM) stays serial.  A ``streamed`` MEM verdict is
    the exception: the double-buffered DMA schedule overlaps the memory
    path itself, so it earns the same credit *at its own mode* — a
    rule-gated direct verdict demoted to MEM still hides nothing (the
    demoted charge is not the streamed schedule the planner priced).  The
    ramp clamp in ``overlapped_cycles`` guarantees overlap <= serial for
    the SAME decisions, decision by decision.
    """
    if objective not in ("overlap", "serial"):
        raise ValueError(f"unknown objective: {objective!r}")
    total = 0.0
    for d in decisions:
        w = max(d.spec.mult, 1)
        mode, comm = _effective_comm(d, rules)
        if objective == "overlap" and d.compute_cycles > 0 and \
                (FUSIBLE_MODES.get(mode, False) or
                 (d.streamed and mode is d.mode)):
            cost = overlapped_cycles(comm, d.compute_cycles, d.ramp_cycles)
        else:
            cost = comm + d.compute_cycles
        total += cost * w
    return total


def comm_overlap_fraction(decisions: Sequence[PlanDecision],
                          rules: Optional[Dict] = None) -> float:
    """Fraction of the step's communication cycles hidden behind the
    compute they feed under the overlap objective (0.0 when nothing
    fuses): ``hidden = serial - overlapped`` per decision, normalized by
    total comm cycles.  The dryrun artifact reports this per cell."""
    total_comm = hidden = 0.0
    for d in decisions:
        w = max(d.spec.mult, 1)
        mode, comm = _effective_comm(d, rules)
        total_comm += comm * w
        if d.compute_cycles > 0 and (FUSIBLE_MODES.get(mode, False) or
                                     (d.streamed and mode is d.mode)):
            serial = comm + d.compute_cycles
            fused = overlapped_cycles(comm, d.compute_cycles, d.ramp_cycles)
            hidden += (serial - fused) * w
    return hidden / total_comm if total_comm else 0.0


def mode_mix(decisions: Sequence[PlanDecision]) -> Dict[str, int]:
    """Count of per-transfer (per-layer) decisions by chosen mode; a
    capped dominant spec counts as the layers it stands for."""
    mix = {m.name: 0 for m in CommMode}
    for d in decisions:
        mix[d.mode.name] += max(d.spec.mult, 1)
    return mix


def dominant_decisions(decisions: Sequence[PlanDecision]
                       ) -> List[PlanDecision]:
    """One representative decision per base archetype (largest payload) —
    compact CLI reporting for per-layer plans (a 40-layer model prints 5
    archetype lines, not 200 layer lines)."""
    best: Dict[str, PlanDecision] = {}
    for d in decisions:
        b = base_transfer_name(d.spec.name)
        if b not in best or d.spec.nbytes > best[b].spec.nbytes:
            best[b] = d
    return [best[b] for b in sorted(best)]


def plan_summary_lines(decisions: Sequence[PlanDecision]) -> List[str]:
    """The train/serve CLIs' comm-plan report: the per-layer mode mix plus
    one line per archetype (dominant layer)."""
    if not decisions:
        return []
    mix = mode_mix(decisions)
    fused = sum(max(d.spec.mult, 1) for d in decisions if d.fused)
    lines = ["comm-plan mix: " +
             ", ".join(f"{k}:{v}" for k, v in mix.items()) +
             (f" (overlap-fused: {fused})" if fused else "")]
    for d in dominant_decisions(decisions):
        lines.append(f"comm-plan: {d.spec.name} -> {d.mode.name} "
                     f"({d.reason})")
    return lines


# --------------------------------------------------------------- step specs

def kv_prefix_transfer_spec(cfg, prompt_len: int, consumers: int,
                            cache_bytes: int = 2) -> TransferSpec:
    """The serving engine's prefill->decode hand-off, priced from the cache
    shape x the active consumer count: one admitted request's whole decode
    cache (every attention layer's (S, K, hd) k/v prefix at ``cache_bytes``
    per element, plus the f32 recurrent state of mamba/rglru blocks)
    multicast to the ``consumers`` registered decode stages — the paper's
    Fig. 1(c) one-burst-to-N dataflow at the ``engine.kv_prefix`` site."""
    S = max(int(prompt_len), 1)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    nbytes = 0
    for kind in cfg.block_kinds():
        if kind in ("attn", "swa"):
            # prefill emits the full-S prefix regardless of window
            nbytes += 2 * S * K * hd * cache_bytes
        elif kind == "mamba":
            di = cfg.ssm.expand * cfg.d_model
            nbytes += (di * cfg.ssm.state_dim +
                       (cfg.ssm.conv_dim - 1) * di) * 4
        elif kind == "rglru":
            w = cfg.rglru.lru_width or cfg.d_model
            nbytes += (w + (cfg.rglru.conv_dim - 1) * w) * 4
    return TransferSpec(name="kv_prefix", nbytes=max(nbytes, 1),
                        fan_out=max(int(consumers), 1),
                        word_bytes=cache_bytes)


def step_transfer_specs(cfg, shape, mesh_axes: Dict[str, int],
                        activation_bytes: int = 2,
                        kv_consumers: int = 0,
                        with_compute: bool = False) -> List[TransferSpec]:
    """Derive the named transfers of one train/serve step from an arch
    config + input shape + mesh, for ``CommPlanner.plan``:

    * ``moe_dispatch`` — each source shard's token buffers multicast to the
      ``top_k`` expert-owning shards (push; top-1 = unicast degeneracy);
    * ``stage_activation`` — the next pipeline stage pulls the previous
      layer's activations (the paper's NN example; read-channel P2P);
    * ``weights`` — weight broadcast to every data-parallel replica; at
      high replica counts this exceeds the destination-set limit and the
      planner degrades it to MEM (FSDP-style gather through memory);
    * ``grad_reduce_compressed`` — the error-feedback int8 gradient
      all-reduce over the cross-pod axis (``optim.compression``): a
      *reduce* spec whose on-wire payload is one byte per gradient
      element — 4x fewer bytes than the f32 reduction, which is exactly
      what can flip a pod-axis MEM verdict back toward a direct mode on
      capacity-limited meshes.  Emitted only when the mesh has a pod
      axis (> 1); without one the compressor is inactive and gradients
      ride the plain reduction.
    * ``kv_prefix`` — only with ``kv_consumers > 0`` (the serving
      engine's admission path): the prefill cache prefix of one request
      multicast to the registered decode consumers, priced from the
      cache shape (:func:`kv_prefix_transfer_spec`).  Default 0 keeps
      train/dryrun spec tuples (and the plan cache keyed on them)
      byte-identical to before.

    ``with_compute=True`` additionally emits the plain f32 ``grad_reduce``
    over the data axis and attaches a roofline compute estimate
    (6 x params x tokens per device) apportioned bytes-weighted across
    the emitted specs — the same attribution
    ``launch.hlo_analysis.transfer_specs_from_hlo`` derives from a real
    module, so the overlap objective has compute to hide transfers
    behind even without an HLO in hand (the ``step_overlap`` bench row).
    Default ``False`` keeps the config-level spec tuples (and the plan
    cache keyed on them) byte-identical to before.
    """
    model_shards = max(mesh_axes.get("model", 1), 1)
    data_shards = max(mesh_axes.get("pod", 1) * mesh_axes.get("data", 1), 1)
    B, S = shape.global_batch, shape.seq_len
    d_model = cfg.d_model
    specs = []
    if cfg.moe is not None:
        tokens_per_shard = max((B * S) // model_shards, 1)
        specs.append(TransferSpec(
            name="moe_dispatch",
            nbytes=tokens_per_shard * d_model * activation_bytes,
            fan_out=cfg.moe.top_k))
    specs.append(TransferSpec(
        name="stage_activation",
        nbytes=max((B * S) // max(data_shards, 1), 1) * d_model *
        activation_bytes,
        fan_out=1, pull=True))
    per_shard_params = cfg.param_count() // max(model_shards, 1)
    specs.append(TransferSpec(
        name="weights",
        nbytes=max(per_shard_params * activation_bytes, 1),
        fan_out=data_shards))
    pod_shards = max(mesh_axes.get("pod", 1), 1)
    if pod_shards > 1:
        # int8 on the wire: one byte per gradient element (word_bytes=1)
        specs.append(TransferSpec(
            name="grad_reduce_compressed",
            nbytes=max(per_shard_params, 1),
            fan_out=pod_shards, reduce=True, word_bytes=1))
    if kv_consumers > 0:
        specs.append(kv_prefix_transfer_spec(cfg, S, kv_consumers))
    if with_compute:
        if data_shards > 1:
            # the plain f32 data-parallel gradient reduction (what the
            # compiled step's all-reduce census prices per layer)
            specs.append(TransferSpec(
                name="grad_reduce",
                nbytes=max(per_shard_params * 4, 1),
                fan_out=data_shards, reduce=True, word_bytes=4))
        # roofline step compute per device: fwd + bwd ~ 6 flops per param
        # per token, over this device's token slice
        tokens_per_dev = max((B * S) // max(model_shards * data_shards, 1), 1)
        step_flops = 6.0 * float(per_shard_params) * tokens_per_dev
        total_bytes = sum(max(s.nbytes, 1) for s in specs)
        specs = [dataclasses.replace(
            s, compute_flops=step_flops * max(s.nbytes, 1) / total_bytes)
            for s in specs]
    return specs


# ---------------------------------------------------------------- caching
# ``--comm-plan=auto`` prices once per launch: resolved plans are cached by
# (policy, NoC profile, rule overlay, derived transfer-spec tuple) — the
# spec tuple is the exact pricing input, so distinct configs/shapes/meshes
# (and distinct compiled HLO modules via ``transfer_specs_from_hlo``) never
# collide while repeated step-factory calls hit the cache.  The rule
# overlay (core.sharding.resolve_rules) is part of the key because the same
# HLO priced under rewritten rules is a different plan context: a relowered
# step must not alias the static-rules entry.
_PLAN_CACHE: Dict[Tuple, Tuple[CommPlan, List[PlanDecision]]] = {}
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _PLAN_CACHE_STATS["hits"] = _PLAN_CACHE_STATS["misses"] = 0


def plan_cache_stats() -> Dict[str, int]:
    return dict(_PLAN_CACHE_STATS, size=len(_PLAN_CACHE))


def _overlay_key(rules_overlay: Optional[Dict]) -> Tuple:
    return tuple(sorted((rules_overlay or {}).items(),
                        key=lambda kv: kv[0]))


def _plan_cached(policy: str, profile: Optional[str],
                 specs: Sequence[TransferSpec],
                 model=None, rules_overlay: Optional[Dict] = None,
                 precomputed=None, mesh_axes: Optional[Dict[str, int]] = None
                 ) -> Tuple[CommPlan, List[PlanDecision]]:
    # the mesh shape is part of the key: an elastic re-mesh (shrink_mesh
    # after a host loss) re-plans on the survivor topology, and its entry
    # must never alias the pre-fault plan even when the HLO-derived spec
    # tuple happens to coincide
    key = (policy, profile, _overlay_key(rules_overlay),
           tuple(sorted((mesh_axes or {}).items())), tuple(specs))
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        _PLAN_CACHE_STATS["hits"] += 1
        return hit
    _PLAN_CACHE_STATS["misses"] += 1
    # ``precomputed`` re-keys an already-priced (plan, decisions) under a
    # new overlay without re-running the pricing sweep (it is deterministic)
    plan, decisions = (precomputed if precomputed is not None
                       else CommPlanner(model).plan_with_decisions(specs))
    _PLAN_CACHE[key] = (plan, decisions)
    return plan, decisions


def resolve_policy(policy: str, cfg, shape, mesh_axes: Dict[str, int],
                   hlo_text: Optional[str] = None, model=None,
                   rules_overlay: Optional[Dict] = None,
                   precomputed=None
                   ) -> Tuple[Optional[CommPlan], Optional[List[PlanDecision]]]:
    """Resolve a ``--comm-plan`` policy string into a plan.

    ``manual`` -> (None, None): legacy flag-driven behaviour.  ``auto`` ->
    cost-model plan + its decisions, cached per launch.  ``mem`` /
    ``mcast`` -> constant plans (the benchmark baselines; mcast still
    honours nothing — it is the deliberately naive "always direct" policy).

    With ``hlo_text`` (the compiled step's post-partitioning HLO), the
    ``auto`` transfers are derived from the lowered collective ops —
    fan-out and bytes read from the all-gather/all-to-all/psum lowerings
    themselves, one spec per layer (see ``transfer_specs_from_hlo``) —
    with the config-level ``step_transfer_specs`` estimates retained only
    for logical transfers the HLO does not exhibit.  ``model`` optionally
    substitutes a pod-scale :class:`SoCPerfModel`.  ``rules_overlay`` is
    the sharding-rule overlay the step was (re)built under; it keys the
    plan cache alongside policy/profile/specs.  ``precomputed`` (a
    ``(plan, decisions)`` pair from an earlier resolution of the same
    specs) re-keys that result under the overlay without re-pricing.
    """
    if policy == "manual":
        return None, None
    specs = step_transfer_specs(cfg, shape, mesh_axes)
    if policy == "auto":
        if hlo_text is not None:
            from repro.launch.hlo_analysis import transfer_specs_from_hlo
            specs = transfer_specs_from_hlo(hlo_text, fallback=specs)
        # key by the full parameter tuple of the *effective* model — never
        # the profile name, and never ``None`` for the default model: two
        # models sharing a name but differing in (say) link latency must
        # not collide, and a calibrated params install
        # (``perfmodel.set_default_params``) must invalidate the plans
        # priced under the previous defaults instead of aliasing them
        # (the calibration loop's "a calibration is a re-plan").
        profile = dataclasses.astuple(model.p if model is not None
                                      else default_params())
        return _plan_cached(policy, profile, specs, model, rules_overlay,
                            precomputed, mesh_axes=mesh_axes)
    if policy not in ("mem", "mcast"):
        raise ValueError(f"unknown comm-plan policy: {policy!r}")
    mode = CommMode.MEM if policy == "mem" else CommMode.MCAST
    plan = CommPlan(default=mode)
    for s in specs:
        plan = plan.with_mode(s.name, mode)
    return plan, None


def refine_plan_from_hlo(plan: CommPlan, cfg, shape, mesh_axes: Dict[str, int],
                         hlo_text: str, resolve, model=None
                         ) -> Tuple[CommPlan, List[PlanDecision], Dict,
                                    Dict, bool]:
    """The ``--comm-plan=auto`` feedback step shared by the dryrun/train/
    serve launchers: re-price the estimate-based ``plan`` from the compiled
    module's own collectives (per-layer specs), feed the refined plan
    through ``resolve`` — a callable ``CommPlan -> (resolved_rules,
    overlay)`` such as ``runtime.train.resolved_train_rules`` — and, when
    the overlay applies, re-key the cached plan under it.

    Returns ``(plan2, decisions2, resolved_rules, overlay, rebuild)``;
    ``rebuild`` is True iff the caller must relower/rebuild the step ONCE
    (the rule overlay applied, or a mode the step consults changed).
    Callers adopt ``plan2``/``decisions2`` either way — the HLO-derived
    pricing is ground truth for reporting.
    """
    plan2, decisions2 = resolve_policy("auto", cfg, shape, mesh_axes,
                                       hlo_text=hlo_text, model=model)
    rules, overlay = resolve(plan2)
    changed = plan2 is not None and any(plan2.mode(k) is not plan.mode(k)
                                        for k in plan.modes)
    if overlay:
        # the final step is built under the overlay: re-key the cached
        # plan (already priced — pricing is deterministic) so it cannot
        # alias the static-rules entry
        plan2, decisions2 = resolve_policy("auto", cfg, shape, mesh_axes,
                                           hlo_text=hlo_text, model=model,
                                           rules_overlay=overlay,
                                           precomputed=(plan2, decisions2))
    return plan2, decisions2, rules, overlay, bool(overlay) or changed


# ------------------------------------------- measurement-driven re-planning

def _obs_field(obs, key, default=None):
    """Duck-typed observation access: the calib package passes typed
    ``repro.calib.measure.Observation`` records, the socket exports plain
    dicts (core must not import calib) — both read the same way."""
    if isinstance(obs, dict):
        return obs.get(key, default)
    return getattr(obs, key, default)


# Issued-mode strings that are dispatch refinements of a plan mode, not
# plan modes themselves: a FUSED_RING issue is the fused dispatch of a
# P2P plan entry (socket DEGRADATION_LADDER), so "trusting the socket"
# re-prices the tensor to P2P, never to a mode the plan cannot express.
_ISSUED_TO_PLAN_MODE = {"FUSED_RING": "P2P"}


def refine_plan_from_measurements(plan: Optional[CommPlan], observations,
                                  *, decisions: Optional[
                                      Sequence[PlanDecision]] = None,
                                  divergence_threshold: float = 0.25
                                  ) -> Tuple[Optional[CommPlan],
                                             List[Dict[str, str]]]:
    """Close the measurement loop: re-price plan entries against what the
    system *observed* — a calibration is a re-plan, symmetric with the
    elastic re-mesh path.

    Two observation families flip decisions:

    * **issued != planned** (``kind == "issue"``, from
      ``socket.issue_observations()``): a site that *silently* dispatched a
      different mode than planned (no machine-readable ``degraded_reason``
      — explicit degradations conform by definition, exactly the
      ``mismatched_sites`` convention) re-prices the tensor to the issued
      mode: the fabric already voted with its feet.
    * **measured vs modeled divergence** (timing observations carrying
      ``measured_cycles`` + ``mode``): when the measured cycles of a
      tensor's *chosen* path diverge from the modeled prediction by more
      than ``divergence_threshold`` (relative), the decision is re-decided
      with the measurement substituted for the model on that path; if an
      alternative path is now cheaper, the plan flips.  Modeled cycles come
      from the matching :class:`PlanDecision` (``decisions``) or from the
      observation's own ``modeled_cycles``.

    Returns ``(new_plan, flips)``; each flip is the same machine-readable
    ``{"tensor", "old", "new"}`` schema as :func:`plan_decision_flips`,
    plus a ``"cause"`` (``"issued_mismatch"`` | ``"measured_divergence"``)
    — append them to ``comm_replan_events`` exactly as the re-mesh hook
    and the dryrun's ``hlo_refine`` events are.
    """
    if plan is None:
        return None, []
    by_name: Dict[str, PlanDecision] = {}
    for d in (decisions or []):
        by_name[d.spec.name] = d
        base = base_transfer_name(d.spec.name)
        # dominant decision per archetype: largest payload represents it
        if base not in by_name or d.spec.nbytes > by_name[base].spec.nbytes:
            by_name[base] = d
    new_plan, flips = plan, []

    def flip(tensor: str, new_mode: CommMode, cause: str, **extra) -> None:
        nonlocal new_plan
        old = new_plan.mode(tensor)
        if old is new_mode:
            return
        new_plan = new_plan.with_mode(tensor, new_mode)
        flips.append({"tensor": tensor, "old": old.name,
                      "new": new_mode.name, "cause": cause, **extra})

    for obs in observations:
        name = _obs_field(obs, "name")
        if not name:
            continue
        tensor = base_transfer_name(name)
        issued = _obs_field(obs, "issued")
        planned = _obs_field(obs, "planned")
        if issued and planned:
            if _obs_field(obs, "degraded_reason") is not None:
                continue   # explicit degradation conforms; not a mis-model
            issued = _ISSUED_TO_PLAN_MODE.get(issued, issued)
            if issued != planned and issued in CommMode.__members__:
                flip(tensor, CommMode[issued], "issued_mismatch",
                     site=_obs_field(obs, "site") or name)
            continue
        measured = _obs_field(obs, "measured_cycles")
        mode = _obs_field(obs, "mode")
        if not measured or mode not in ("mem", "p2p", "mcast"):
            continue
        d = by_name.get(name) or by_name.get(tensor)
        modeled = (d.cycles.get(mode) if d is not None
                   else _obs_field(obs, "modeled_cycles"))
        if modeled is None or not np.isfinite(modeled) or modeled <= 0:
            continue
        chosen = new_plan.mode(tensor)
        if mode != chosen.name.lower():
            continue   # only the chosen path's divergence re-opens a call
        divergence = abs(measured - modeled) / modeled
        if divergence <= divergence_threshold:
            continue
        # re-decide with the measurement substituted on the observed path;
        # only plan-expressible paths compete (the "ring" column is the
        # fused dispatch of P2P, not a plan mode)
        candidates = ({m: d.cycles.get(m) for m in ("mem", "p2p", "mcast")}
                      if d is not None else {mode: modeled})
        candidates[mode] = float(measured)
        feasible = {m: c for m, c in candidates.items()
                    if c is not None and np.isfinite(c)}
        if not feasible:
            continue
        winner = min(feasible, key=feasible.get)
        flip(tensor, CommMode[winner.upper()], "measured_divergence",
             divergence=round(float(divergence), 3))
    return new_plan, flips


def plan_decision_flips(old_plan: Optional[CommPlan],
                        new_plan: Optional[CommPlan]) -> List[Dict[str, str]]:
    """The per-tensor mode flips between two plans, as machine-readable
    ``{"tensor", "old", "new"}`` entries — the dryrun artifact's
    ``comm_replan_events`` payload and the re-mesh hook's record of what
    the survivor topology changed (e.g. a weights fan-out that no longer
    exceeds the multicast capacity flips MEM -> MCAST).  Keys are the
    union of both plans' explicit entries; a tensor only one plan names
    still flips if the other's default disagrees."""
    if old_plan is None or new_plan is None:
        return []
    flips: List[Dict[str, str]] = []
    for name in sorted(set(old_plan.modes) | set(new_plan.modes)):
        old, new = old_plan.mode(name), new_plan.mode(name)
        if old is not new:
            flips.append({"tensor": name, "old": old.name, "new": new.name})
    return flips
