"""Cost-model-driven communication-mode planner (the paper's C4, automated).

The paper's central claim is that *per-transfer* control over the
communication mode — memory DMA vs. P2P vs. multicast — is what unlocks the
Fig. 6 speedups; its evaluation hand-picks the mode per experiment.  This
module closes the loop: :class:`CommPlanner` queries the calibrated NoC
performance model (:class:`~repro.core.noc.perfmodel.SoCPerfModel`, batched
sweep API) for every named transfer of a step and emits the
:class:`~repro.core.comm.CommPlan` that hand-written configs used to
hard-code.  Selection follows the paper's constraints:

* fan-out above the multicast capacity (header-flit bound
  ``max_multicast_dests`` / ESP's ``ESP_MAX_DESTS`` cap) degrades to MEM —
  past the destination-set limit the transfer must round-trip through
  memory;
* a pull-type unicast (consumer fetches a known producer's output — the
  paper's "a previous layer's outputs from another accelerator") is
  labelled ``P2P`` and rides the read channel (``user = k``);
* push-type transfers take the write channel: ``MCAST`` with the
  destination list in the header flit (fan-out 1 encodes as ``user = 1``,
  the unicast degeneracy — a 1-destination multicast *is* a P2P write);
* when the direct path is not predicted faster than the memory baseline,
  MEM wins (it is the safe default the rest of the stack understands).

``plan()`` is batched: one vectorized model sweep prices every transfer,
so planning stays off the step's critical path even for many tensors.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.comm import CommMode, CommPlan, CommRequest
from repro.core.noc.perfmodel import SoCPerfModel


@dataclasses.dataclass(frozen=True)
class TransferSpec:
    """One named transfer the planner prices: ``name`` is the logical
    tensor key the :class:`CommPlan` is indexed by (e.g. "moe_dispatch",
    "stage_activation", "weights"); ``nbytes`` the payload per transfer;
    ``fan_out`` the consumer count; ``pull`` marks consumer-initiated
    unicasts (read channel -> P2P label); ``reduce`` marks transfers that
    combine data from the fan-in set (all-reduce/reduce-scatter lowerings)
    — the NoC forks multicast flits but cannot combine them in flight, so
    reductions always round-trip through the memory tile."""
    name: str
    nbytes: int
    fan_out: int
    pull: bool = False
    source: int = 1               # producer index for request encoding
    dests: Tuple[int, ...] = ()   # explicit consumer indices (else 1..fan_out)
    word_bytes: int = 4
    reduce: bool = False


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """Why a transfer got its mode: predicted cycles per candidate path and
    the chosen mode's predicted speedup over the always-MEM baseline."""
    spec: TransferSpec
    mode: CommMode
    cycles: Dict[str, float]
    speedup_vs_mem: float
    reason: str


class CommPlanner:
    """Builds :class:`CommPlan`s from the NoC cost model.

    ``max_dests`` defaults to the model's multicast capacity (header-flit
    bound, ESP cap, tile budget); pass a smaller value to emulate a
    narrower NoC.
    """

    def __init__(self, model: Optional[SoCPerfModel] = None, *,
                 max_dests: Optional[int] = None):
        self.model = model or SoCPerfModel()
        cap = self.model.max_dests
        self.capacity = cap if max_dests is None else min(cap, max_dests)

    # ------------------------------------------------------------ pricing
    def price(self, specs: Sequence[TransferSpec]) -> List[PlanDecision]:
        """Batched pricing: one vectorized model sweep for all transfers."""
        if not specs:
            return []
        fan = np.array([max(s.fan_out, 1) for s in specs])
        nbytes = np.array([max(s.nbytes, 1) for s in specs])
        cycles = self.model.batch_cycles(fan, nbytes)
        out: List[PlanDecision] = []
        for i, spec in enumerate(specs):
            mem = float(cycles["mem"][i])
            direct = float(cycles["mcast"][i])   # fan-out 1: == p2p path
            point = {"mem": mem, "p2p": float(cycles["p2p"][i]),
                     "mcast": direct}
            if spec.fan_out < 1:
                out.append(PlanDecision(spec, CommMode.MEM, point, 1.0,
                                        "no consumers: plain store to memory"))
            elif spec.reduce:
                out.append(PlanDecision(
                    spec, CommMode.MEM, point, 1.0,
                    "reduction: the NoC forks multicasts but cannot combine "
                    "in flight — round-trip through memory"))
            elif spec.fan_out > self.capacity:
                out.append(PlanDecision(
                    spec, CommMode.MEM, point, 1.0,
                    f"fan-out {spec.fan_out} exceeds multicast capacity "
                    f"{self.capacity}: degrade to memory round-trip"))
            elif not np.isfinite(direct) or direct >= mem:
                out.append(PlanDecision(
                    spec, CommMode.MEM, point, 1.0,
                    "memory path predicted no slower than direct path"))
            else:
                mode = (CommMode.P2P if spec.pull and spec.fan_out == 1
                        else CommMode.MCAST)
                out.append(PlanDecision(
                    spec, mode, point, mem / direct,
                    f"direct path {mem / direct:.2f}x faster than memory "
                    f"({'read-channel pull' if mode is CommMode.P2P else 'write-channel push'})"))
        return out

    # ----------------------------------------------------------- planning
    def plan(self, specs: Sequence[TransferSpec]) -> CommPlan:
        """The drop-in replacement for a hand-written CommPlan dict."""
        plan = CommPlan()
        for d in self.price(specs):
            plan = plan.with_mode(d.spec.name, d.mode)
        return plan

    def plan_with_decisions(self, specs: Sequence[TransferSpec]
                            ) -> Tuple[CommPlan, List[PlanDecision]]:
        decisions = self.price(specs)
        plan = CommPlan()
        for d in decisions:
            plan = plan.with_mode(d.spec.name, d.mode)
        return plan, decisions

    # ----------------------------------------------------------- requests
    def requests(self, specs: Sequence[TransferSpec]) -> List[CommRequest]:
        """Control-channel beats for the planned transfers — the user-field
        encoding the accelerator interface consumes (paper Fig. 3)."""
        reqs = []
        for d in self.price(specs):
            s = d.spec
            dests = s.dests or tuple(range(1, max(s.fan_out, 0) + 1))
            if d.mode is CommMode.MEM:
                dests = ()
            reqs.append(CommRequest(
                length=max(1, s.nbytes // s.word_bytes),
                word_bytes=s.word_bytes, mode=d.mode,
                source=s.source if d.mode is not CommMode.MEM else None,
                dests=dests))
        return reqs


# --------------------------------------------------------------- step specs

def step_transfer_specs(cfg, shape, mesh_axes: Dict[str, int],
                        activation_bytes: int = 2) -> List[TransferSpec]:
    """Derive the named transfers of one train/serve step from an arch
    config + input shape + mesh, for ``CommPlanner.plan``:

    * ``moe_dispatch`` — each source shard's token buffers multicast to the
      ``top_k`` expert-owning shards (push; top-1 = unicast degeneracy);
    * ``stage_activation`` — the next pipeline stage pulls the previous
      layer's activations (the paper's NN example; read-channel P2P);
    * ``weights`` — weight broadcast to every data-parallel replica; at
      high replica counts this exceeds the destination-set limit and the
      planner degrades it to MEM (FSDP-style gather through memory).
    """
    model_shards = max(mesh_axes.get("model", 1), 1)
    data_shards = max(mesh_axes.get("pod", 1) * mesh_axes.get("data", 1), 1)
    B, S = shape.global_batch, shape.seq_len
    d_model = cfg.d_model
    specs = []
    if cfg.moe is not None:
        tokens_per_shard = max((B * S) // model_shards, 1)
        specs.append(TransferSpec(
            name="moe_dispatch",
            nbytes=tokens_per_shard * d_model * activation_bytes,
            fan_out=cfg.moe.top_k))
    specs.append(TransferSpec(
        name="stage_activation",
        nbytes=max((B * S) // max(data_shards, 1), 1) * d_model *
        activation_bytes,
        fan_out=1, pull=True))
    per_shard_params = cfg.param_count() // max(model_shards, 1)
    specs.append(TransferSpec(
        name="weights",
        nbytes=max(per_shard_params * activation_bytes, 1),
        fan_out=data_shards))
    return specs


# ---------------------------------------------------------------- caching
# ``--comm-plan=auto`` prices once per launch: resolved plans are cached by
# (policy, NoC profile, derived transfer-spec tuple) — the spec tuple is the
# exact pricing input, so distinct configs/shapes/meshes (and distinct
# compiled HLO modules via ``transfer_specs_from_hlo``) never collide while
# repeated step-factory calls hit the cache.
_PLAN_CACHE: Dict[Tuple, Tuple[CommPlan, List[PlanDecision]]] = {}
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _PLAN_CACHE_STATS["hits"] = _PLAN_CACHE_STATS["misses"] = 0


def plan_cache_stats() -> Dict[str, int]:
    return dict(_PLAN_CACHE_STATS, size=len(_PLAN_CACHE))


def _plan_cached(policy: str, profile: Optional[str],
                 specs: Sequence[TransferSpec],
                 model=None) -> Tuple[CommPlan, List[PlanDecision]]:
    key = (policy, profile, tuple(specs))
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        _PLAN_CACHE_STATS["hits"] += 1
        return hit
    _PLAN_CACHE_STATS["misses"] += 1
    plan, decisions = CommPlanner(model).plan_with_decisions(specs)
    _PLAN_CACHE[key] = (plan, decisions)
    return plan, decisions


def resolve_policy(policy: str, cfg, shape, mesh_axes: Dict[str, int],
                   hlo_text: Optional[str] = None, model=None
                   ) -> Tuple[Optional[CommPlan], Optional[List[PlanDecision]]]:
    """Resolve a ``--comm-plan`` policy string into a plan.

    ``manual`` -> (None, None): legacy flag-driven behaviour.  ``auto`` ->
    cost-model plan + its decisions, cached per launch.  ``mem`` /
    ``mcast`` -> constant plans (the benchmark baselines; mcast still
    honours nothing — it is the deliberately naive "always direct" policy).

    With ``hlo_text`` (the compiled step's post-partitioning HLO), the
    ``auto`` transfers are derived from the lowered collective ops —
    fan-out and bytes read from the all-gather/all-to-all/psum lowerings
    themselves — with the config-level ``step_transfer_specs`` estimates
    retained only for logical transfers the HLO does not exhibit.  ``model``
    optionally substitutes a pod-scale :class:`SoCPerfModel`.
    """
    if policy == "manual":
        return None, None
    specs = step_transfer_specs(cfg, shape, mesh_axes)
    if policy == "auto":
        if hlo_text is not None:
            from repro.launch.hlo_analysis import transfer_specs_from_hlo
            specs = transfer_specs_from_hlo(hlo_text, fallback=specs)
        # key by the full parameter tuple, not the profile name: two models
        # sharing a name but differing in (say) link latency must not
        # collide in the cache
        profile = (dataclasses.astuple(model.p) if model is not None
                   else None)
        return _plan_cached(policy, profile, specs, model)
    if policy not in ("mem", "mcast"):
        raise ValueError(f"unknown comm-plan policy: {policy!r}")
    mode = CommMode.MEM if policy == "mem" else CommMode.MCAST
    plan = CommPlan(default=mode)
    for s in specs:
        plan = plan.with_mode(s.name, mode)
    return plan, None
