"""Mesh-agnostic sharded checkpointing with async save and elastic restore.

Design (what 1000-node runs need):

* **Mesh-agnostic layout** — every leaf is stored with its *global* shape
  under a stable tree path; restore reshards onto whatever mesh/sharding the
  new job uses (elastic up/down-scaling, TP/DP regrouping).
* **Atomic commit** — writes go to ``step_XXXX.tmp/`` and are renamed into
  place after the manifest (with per-leaf checksums) is fsync'd; a crashed
  save can never shadow the last good checkpoint.
* **Async save** — ``AsyncCheckpointer`` snapshots device arrays to host
  (the only blocking part) and writes on a background thread, double-
  buffered: training continues during serialization (C5's IDMA/CDMA
  issue/poll pattern at the checkpoint layer).
* **Keep-last-k GC** and crash-consistent ``latest_step`` discovery.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree, keep: int = 3) -> str:
    """Blocking sharded save.  Returns the committed directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in _leaf_paths(tree).items():
        arr = np.asarray(leaf)  # note: tobytes() serializes C-order
        fname = key.replace("/", "__") + ".npy"
        # raw-byte serialization: ml_dtypes types (bfloat16, fp8) do not
        # survive np.save/np.load, so every leaf is stored as uint8 with
        # its logical dtype in the manifest.
        np.save(os.path.join(tmp, fname),
                np.frombuffer(arr.tobytes(), dtype=np.uint8))
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, _MANIFEST)):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None, verify: bool = True):
    """Restore into the structure of ``target_tree`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — leaves are device_put with them (elastic re-mesh)."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, _MANIFEST)) as f:
        manifest = json.load(f)
    paths = _leaf_paths(target_tree)
    shard_paths = _leaf_paths(shardings) if shardings is not None else {}
    out = {}
    for key, tgt in paths.items():
        meta = manifest["leaves"][key]
        raw = np.load(os.path.join(src, meta["file"]))
        if verify and zlib.crc32(raw.tobytes()) != meta["crc32"]:
            raise IOError(f"checksum mismatch restoring {key}")
        arr = raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(
                f"{key}: stored {arr.shape} != target {tgt.shape}")
        if key in shard_paths and shard_paths[key] is not None:
            out[key] = jax.device_put(arr.astype(tgt.dtype), shard_paths[key])
        else:
            out[key] = jax.numpy.asarray(arr.astype(tgt.dtype))
    # rebuild the tree
    flat, treedef = jax.tree_util.tree_flatten(target_tree)
    keys = list(_leaf_paths(target_tree).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])


class AsyncCheckpointer:
    """Double-buffered background saver (at most one save in flight)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _run():
            try:
                save_checkpoint(self.directory, step, host_tree, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
