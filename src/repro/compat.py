"""JAX version-compatibility layer.

The repo targets the Pallas/TPU API surface of recent JAX, but must run
(tier-1 tests included) on the pinned container JAX.  Supported range:
**0.4.37 .. 0.7.x**.  Everything version-dependent funnels through here so
the rest of the codebase is written once against a single surface:

* ``interpret_params()`` — ``pltpu.InterpretParams(...)`` where it exists
  (per-device TPU interpret machinery with real DMA semantics); plain
  ``interpret=True`` (state-discharge interpreter) on 0.4.x.
* ``AxisType`` / ``make_mesh`` — ``jax.sharding.AxisType`` appeared after
  0.4.37; older ``jax.make_mesh`` takes no ``axis_types``.
* ``shard_map`` — ``jax.shard_map(..., check_vma=...)`` vs
  ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
* ``axis_size`` — ``jax.lax.axis_size`` is missing on 0.4.37; the static
  size is read from the axis env instead (kernels need a Python int to
  build output shapes).
* ``compiler_params`` — ``pltpu.CompilerParams`` vs the older
  ``pltpu.TPUCompilerParams`` (whose field set is smaller; unknown fields
  are dropped).
* ``remote_device_id`` — the 0.4.37 interpret discharge rule wants a
  scalar mesh device id; newer interpret/TPU lowering takes a tuple.

See ``docs/compat.md`` for the behavioural differences that do NOT shim
cleanly (uniform-DMA requirement of the discharge interpreter).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Sequence

import jax
from jax.experimental.pallas import tpu as pltpu

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_INTERPRET_PARAMS = hasattr(pltpu, "InterpretParams")
HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")

# The 0.4.x interpret path lowers every remote DMA to a lockstep collective
# (state discharge): all devices must issue the same DMA sequence, and each
# dma_start moves data exactly one hop.  Kernels that branch their remote
# copies on the device index must use a uniform schedule under this flag.
UNIFORM_DMA_INTERPRET = not HAS_INTERPRET_PARAMS


if HAS_AXIS_TYPE:
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` (absent on 0.4.x, where
        every mesh axis behaves like ``Auto``)."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Optional[Sequence[Any]] = None, devices=None):
    """``jax.make_mesh`` that tolerates ``axis_types`` on old JAX (dropped:
    0.4.x meshes are implicitly all-Auto, which is what every caller here
    requests anyway)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPE and axis_types is not None:
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Dispatch to ``jax.shard_map`` (new) or
    ``jax.experimental.shard_map.shard_map`` (old, where the replication
    check is spelled ``check_rep``)."""
    if HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, inside shard_map/pmap context."""
    if HAS_AXIS_SIZE:
        return jax.lax.axis_size(axis_name)
    from jax._src.core import get_axis_env
    return get_axis_env().axis_size(axis_name)


def interpret_params():
    """Interpret-mode selector for ``pallas_call`` on CPU test runs.

    New JAX: ``InterpretParams`` with on_wait DMA execution (robust for
    multi-kernel processes; eager mode can deadlock intermittently).  Old
    JAX: ``True`` — the state-discharge interpreter, which imposes the
    uniform-DMA constraint described in ``UNIFORM_DMA_INTERPRET``.
    """
    if HAS_INTERPRET_PARAMS:
        return pltpu.InterpretParams(dma_execution_mode="on_wait")
    return True


def compiler_params(**kwargs):
    """TPU compiler params across the CompilerParams/TPUCompilerParams
    rename; fields the old dataclass lacks (e.g. ``has_side_effects``) are
    dropped rather than crashing the call."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    fields = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in kwargs.items() if k in fields})


def peak_memory_in_bytes(memory_stats) -> int:
    """``CompiledMemoryStats.peak_memory_in_bytes`` appeared after 0.4.37;
    older stats objects expose only the per-category sizes, whose sum
    (arguments + outputs + temps) is the standard stand-in."""
    peak = getattr(memory_stats, "peak_memory_in_bytes", None)
    if peak is not None:
        return peak
    return (memory_stats.argument_size_in_bytes +
            memory_stats.output_size_in_bytes +
            memory_stats.temp_size_in_bytes)


def remote_device_id(idx):
    """Device-id operand for ``pltpu.make_async_remote_copy`` over a 1-D
    mesh axis: a 1-tuple on new JAX, a scalar on 0.4.x (whose interpret
    discharge rule all-gathers the id and cannot handle the tuple form)."""
    if HAS_INTERPRET_PARAMS:
        return (idx,)
    return idx
