"""Continuous-batching serving engine with plan-driven KV movement.

One :class:`ServeEngine` owns a paged block cache
(:mod:`repro.runtime.kv_blocks`) and runs a *single* continuously
batched decode step over every in-flight request: admissions land in
free slots mid-stream, completions free their blocks for the next
arrival, and the step itself never retraces — per-request depth rides
the ``(n_slots,)`` position vector and the ``(n_slots, max_blocks)``
block table, both plain device arrays.

The prefill -> decode hand-off is a *communication spine* transfer, not
an implementation detail: each admitted request's cache prefix issues
through :class:`~repro.core.socket.AcceleratorSocket` from the
``engine.kv_prefix`` :class:`~repro.core.comm.TransferDescriptor` — a
one-burst multicast from the ``prefill`` stage to every registered
decode consumer (the paper's Fig. 1(c) dataflow), priced by
:func:`~repro.core.planner.kv_prefix_transfer_spec` against the cache
shape x the consumer count.  On a topology with no live stage axis the
socket degrades the write to the MEM path and records the degradation
reason — delivery and accounting both stay audit-visible in the issue
log, scoped per engine phase by :func:`~repro.core.socket.issue_epoch`
(``engine.kv_prefix@prefill`` vs ``decode.weights_gather@decode``), so
``issued_modes()`` distinguishes the admission burst from the steady
decode even though both trace once.

Consumers are *virtualized*: :meth:`ServeEngine.remap_consumer` is a
:class:`~repro.core.socket.StageRegistry` LUT update, and the live-axis
writer from :meth:`ServeEngine.make_stage_kv_writer` takes the consumer
ranks as traced values — retargeting a decode stage mid-serve never
retraces (``trace_counts`` stays flat; tier-1 asserted).  A mesh change
is a *re-plan*: :meth:`ServeEngine.replan_for_mesh` re-prices the
serve-step specs (including ``kv_prefix``) on the survivor topology via
:func:`repro.runtime.fault.replan_for_mesh` and rebinds the step
factories to the new plan.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.comm import TransferDescriptor
from repro.core.planner import CommPlanner, step_transfer_specs
from repro.core.socket import AcceleratorSocket, StageRegistry, issue_epoch
from repro.models import transformer as T
from repro.runtime import kv_blocks as KB
from repro.runtime import serve as RS

KV_PREFIX_SITE = "engine.kv_prefix"


# ---------------------------------------------------------------- requests ----

@dataclasses.dataclass
class Request:
    """One serving request and its engine-side lifecycle state."""
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_step: int = 0              # engine step index it becomes visible
    # --- engine-managed state ---
    slot: int = -1
    blocks: List[int] = dataclasses.field(default_factory=list)
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_visible: float = 0.0             # wall clock when arrival_step opened
    t_admitted: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_visible


@dataclasses.dataclass(frozen=True)
class ServeMetrics:
    """What the ``serve_load`` benchmark row reports."""
    n_requests: int
    total_new_tokens: int
    steps: int
    wall_s: float
    tokens_per_s: float
    p50_latency_s: float
    p99_latency_s: float
    latencies_s: Tuple[float, ...] = ()

    def summary(self) -> Dict[str, float]:
        return {"n_requests": self.n_requests,
                "total_new_tokens": self.total_new_tokens,
                "steps": self.steps, "wall_s": round(self.wall_s, 6),
                "tokens_per_s": round(self.tokens_per_s, 3),
                "p50_latency_s": round(self.p50_latency_s, 6),
                "p99_latency_s": round(self.p99_latency_s, 6)}


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return float(sorted_vals[idx])


def poisson_trace(n_requests: int, rate: float, prompt_len: int, vocab: int,
                  max_new_tokens: int, *, seed: int = 0) -> List[Request]:
    """Deterministic Poisson arrival trace: inter-arrival gaps drawn from
    ``random.Random(seed).expovariate(rate)`` in units of *decode steps*
    (the engine's scheduling clock), prompts uniform over the vocab.  The
    same seed always yields the same trace — the serve_load benchmark and
    CI gate depend on that."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.expovariate(rate)
        prompt = np.asarray([rng.randrange(vocab) for _ in range(prompt_len)],
                            np.int32)
        out.append(Request(rid=i, prompt=prompt,
                           max_new_tokens=max_new_tokens,
                           arrival_step=int(t)))
    return out


# ------------------------------------------------------------------ engine ----

class ServeEngine:
    """Continuous-batching serving over a paged KV cache.

    ``submit`` enqueues requests; ``step`` admits as many as fit (free
    slot + free blocks for the full depth), runs one batched decode over
    every active slot, and evicts completions; ``run`` drives a whole
    arrival trace and returns :class:`ServeMetrics`.

    Tracing contract: exactly one trace per jitted function for the
    engine's lifetime (``trace_counts`` is tier-1 asserted) — admission,
    block growth, eviction and consumer remaps are all host-side table /
    LUT updates.
    """

    def __init__(self, cfg: ArchConfig, *, prompt_len: int,
                 max_new_tokens: int, n_slots: int = 4, block_size: int = 16,
                 consumers: Sequence[str] = ("decode1", "decode2"),
                 flags: Optional[T.RunFlags] = None, mesh=None, rules=None,
                 plan=None, params=None, seed: int = 0,
                 param_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
                 mesh_axes: Optional[Dict[str, int]] = None,
                 planner: Optional[CommPlanner] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules
        self.flags = flags or T.RunFlags(param_dtype=param_dtype,
                                         cache_dtype=cache_dtype,
                                         remat="none")
        self.layout = KB.paged_layout(cfg, n_slots=n_slots,
                                      prompt_len=prompt_len,
                                      max_new_tokens=max_new_tokens,
                                      block_size=block_size,
                                      dtype=self.flags.cache_dtype)
        self.allocator = KB.BlockAllocator(1 + self.layout.capacity_blocks)
        self.pools = KB.make_pools(self.layout)
        self.tables = KB.null_table(self.layout)

        # --- the communication spine: registry, plan, socket, descriptor ---
        self.registry = StageRegistry("stage")
        self.registry.register("prefill", 0)
        for i, name in enumerate(consumers):
            self.registry.register(name, i + 1)
        self.consumers = tuple(consumers)
        self._mesh_axes = dict(mesh_axes or {})
        self.shape = ShapeConfig(f"serve_{prompt_len}", prompt_len, n_slots,
                                 "decode")
        if plan is None:
            planner = planner or CommPlanner()
            plan, self.plan_decisions = planner.plan_with_decisions(
                step_transfer_specs(cfg, self.shape, self._mesh_axes,
                                    kv_consumers=len(self.consumers)))
        else:
            self.plan_decisions = []
        self.plan = plan
        self.socket = AcceleratorSocket(self.registry, plan)
        # the engine's own jit domain has no live stage axis (the GSPMD
        # mesh, if any, is not a pipeline): null the axis the constructor
        # inherited from the registry so every kv_prefix write takes the
        # recorded MEM degradation instead of tracing a dead collective.
        # make_stage_kv_writer rebinds the axis for shard_map callers.
        self.socket.axis_name = None
        # literal site label: commcheck's extractor admits it into the
        # --against-artifact coverage universe (KV_PREFIX_SITE mirrors it)
        self.kv_desc = TransferDescriptor(
            "kv_prefix", source="prefill", dests=self.consumers, sync=True,
            site="engine.kv_prefix")

        # --- model state + jitted step functions (one trace each) ---
        if params is None:
            params = T.init_params(jax.random.key(seed), cfg,
                                   self.flags.param_dtype)
        self.params = params
        self.trace_counts: Dict[str, int] = {"prefill": 0, "decode": 0,
                                             "admit": 0}
        self._prefill = jax.jit(self._counted(
            "prefill", RS.make_prefill_step(cfg, self.flags, mesh, rules,
                                            self.plan)))
        self._bind_decode()
        self._admit = jax.jit(self._counted("admit", self._admit_fn))

        # --- scheduler state ---
        self._slot_req: List[Optional[Request]] = [None] * n_slots
        self._free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self._tokens = np.zeros((n_slots, 1), np.int32)
        self._pos = np.zeros((n_slots,), np.int32)
        self.pending: List[Request] = []
        self.completed: List[Request] = []
        self.step_idx = 0

    # ------------------------------------------------------------ plumbing ----
    def _counted(self, key: str, fn: Callable) -> Callable:
        def wrapped(*a):
            # runs at trace time only: jit caches the traced computation,
            # so this counter measures retraces, not calls
            self.trace_counts[key] += 1
            return fn(*a)
        return wrapped

    def _bind_decode(self):
        # bind under the decode epoch: the step factory's downgrade
        # records (the MoE dispatch chain's decode_no_seq_dim demotion)
        # key as "moe.dispatch@decode" in the artifact's issue summary
        with issue_epoch("decode"):
            self._decode = jax.jit(self._counted(
                "decode", RS.make_paged_decode_step(self.cfg, self.flags,
                                                    self.layout, self.mesh,
                                                    self.rules, self.plan)))

    def _admit_fn(self, pools, prefix_caches, slot, block_ids):
        """Traced once: multicast one request's prefill caches through the
        socket (the plan's kv_prefix verdict; degraded to recorded MEM
        with no stage axis), then land them in the block pools."""
        prefix_caches = jax.tree.map(
            lambda c: self.socket.write(c, self.kv_desc), prefix_caches)
        return KB.write_prefix(self.layout, pools, prefix_caches, slot,
                               block_ids)

    def make_stage_kv_writer(self, axis_name: str) -> Callable:
        """A kv_prefix writer for callers with a *live* stage axis (use
        inside ``shard_map`` over ``axis_name``): ``writer(leaf, ranks)``
        multicasts ``leaf`` from the prefill rank to the traced consumer
        ``ranks`` vector under the same plan + descriptor the engine
        accounts with.  Traced ranks come from :meth:`consumer_ranks` —
        a later :meth:`remap_consumer` retargets without retracing."""
        sock = AcceleratorSocket(self.registry, self.plan,
                                 axis_name=axis_name)

        def writer(leaf, ranks):
            return sock.write(leaf, self.kv_desc,
                              producer=0, dests=list(ranks))
        return writer

    def consumer_ranks(self) -> jnp.ndarray:
        """The consumers' current LUT ranks as a traced (n,) int32 vector."""
        return jnp.asarray([self.registry.rank_of(n) for n in self.consumers],
                           jnp.int32)

    def remap_consumer(self, name: str, new_rank: int) -> None:
        """Retarget a decode consumer: a LUT update, never a retrace."""
        self.registry.remap(name, new_rank)

    def replan_for_mesh(self, new_mesh_axes: Dict[str, int], *,
                        hlo_text=None, model=None):
        """Re-mesh is a re-plan: re-price the serve-step specs (kv_prefix
        included) on the survivor topology and rebind the decode step to
        the new plan.  Returns the ``plan_decision_flips`` record."""
        from repro.core.planner import plan_decision_flips, resolve_policy
        specs = step_transfer_specs(self.cfg, self.shape, new_mesh_axes,
                                    kv_consumers=len(self.consumers))
        planner = CommPlanner(model=model)
        new_plan, decisions = planner.plan_with_decisions(specs)
        flips = plan_decision_flips(self.plan, new_plan)
        self.plan, self.plan_decisions = new_plan, decisions
        self._mesh_axes = dict(new_mesh_axes)
        self.socket = AcceleratorSocket(self.registry, new_plan)
        self.socket.axis_name = None
        self._bind_decode()
        self._admit = jax.jit(self._counted("admit", self._admit_fn))
        return flips

    # ----------------------------------------------------------- scheduling ----
    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               rid: Optional[int] = None, arrival_step: int = 0) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] != self.layout.prompt_len:
            raise ValueError(f"prompt length {prompt.shape[0]} != engine "
                             f"prompt_len {self.layout.prompt_len}")
        req = Request(rid=len(self.pending) + len(self.completed) +
                      self.n_active if rid is None else rid,
                      prompt=prompt,
                      max_new_tokens=max_new_tokens or
                      self.layout.max_new_tokens,
                      arrival_step=arrival_step)
        if req.max_new_tokens > self.layout.max_new_tokens:
            raise ValueError("max_new_tokens exceeds layout provisioning")
        self.pending.append(req)
        return req

    def _admissible(self, req: Request) -> bool:
        return (req.arrival_step <= self.step_idx and
                bool(self._free_slots) and
                # conservative gate: a slot only enters if the pool can
                # carry it to full depth — admitted requests never starve
                self.allocator.n_free >= self.layout.max_blocks)

    def _admit_one(self, req: Request) -> None:
        S, bs = self.layout.prompt_len, self.layout.block_size
        n_prefix = -(-S // bs)
        n0 = max(n_prefix, self.layout.blocks_needed(S))
        req.blocks = self.allocator.alloc(n0)
        req.slot = self._free_slots.pop()
        with issue_epoch("prefill"):
            logits, caches = self._prefill(self.params, req.prompt[None, :])
            self.pools = self._admit(
                self.pools, caches, jnp.int32(req.slot),
                jnp.asarray(req.blocks[:n_prefix], jnp.int32))
        first = int(np.asarray(jnp.argmax(logits[0, -1])))
        self._slot_req[req.slot] = req
        self.tables[req.slot, :] = KB.NULL_BLOCK
        self.tables[req.slot, :len(req.blocks)] = req.blocks
        self._tokens[req.slot, 0] = first
        self._pos[req.slot] = S
        req.generated.append(first)
        req.t_admitted = time.perf_counter()

    def _evict(self, req: Request) -> None:
        self.allocator.free(req.blocks)
        self.tables[req.slot, :] = KB.NULL_BLOCK
        self._slot_req[req.slot] = None
        self._free_slots.append(req.slot)
        req.done = True
        req.t_done = time.perf_counter()
        req.blocks = []
        self.completed.append(req)

    def step(self) -> Dict[str, int]:
        """Admit what fits, decode one token for every active slot, evict
        completions.  Returns ``{"admitted", "active", "evicted"}``."""
        now = time.perf_counter()
        for r in self.pending:
            if r.arrival_step <= self.step_idx and not r.t_visible:
                r.t_visible = now
        admitted = 0
        while self.pending and self._admissible(self.pending[0]):
            self._admit_one(self.pending.pop(0))
            admitted += 1
        evicted = 0
        for req in [r for r in self._slot_req if r is not None]:
            # covers max_new_tokens == 1: the prefill token was the output
            if len(req.generated) >= req.max_new_tokens:
                self._evict(req)
                evicted += 1
        active = [r for r in self._slot_req if r is not None]
        if active:
            with issue_epoch("decode"):
                logits, self.pools = self._decode(
                    self.params, jnp.asarray(self._tokens),
                    jnp.asarray(self._pos), self.pools,
                    jnp.asarray(self.tables))
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for req in active:
                s = req.slot
                req.generated.append(int(nxt[s]))
                self._tokens[s, 0] = int(nxt[s])
                self._pos[s] += 1
                if len(req.generated) >= req.max_new_tokens:
                    self._evict(req)
                    evicted += 1
                    continue
                need = self.layout.blocks_needed(int(self._pos[s]))
                if need > len(req.blocks):
                    new = self.allocator.alloc(need - len(req.blocks))
                    self.tables[s, len(req.blocks):need] = new
                    req.blocks.extend(new)
        self.step_idx += 1
        return {"admitted": admitted, "active": len(active),
                "evicted": evicted}

    def run(self, trace: Sequence[Request]) -> ServeMetrics:
        """Drive a whole arrival trace to completion."""
        for req in sorted(trace, key=lambda r: (r.arrival_step, r.rid)):
            self.submit(req.prompt, req.max_new_tokens, rid=req.rid,
                        arrival_step=req.arrival_step)
        t0 = time.perf_counter()
        steps = 0
        while self.pending or self.n_active:
            if not self.n_active and self.pending and \
                    self.pending[0].arrival_step > self.step_idx:
                # idle gap before the next arrival: fast-forward the clock
                self.step_idx = self.pending[0].arrival_step
                continue
            self.step()
            steps += 1
        wall = time.perf_counter() - t0
        lats = sorted(r.latency_s for r in self.completed)
        total = sum(len(r.generated) for r in self.completed)
        return ServeMetrics(
            n_requests=len(self.completed), total_new_tokens=total,
            steps=steps, wall_s=wall,
            tokens_per_s=total / wall if wall > 0 else 0.0,
            p50_latency_s=_percentile(lats, 0.50),
            p99_latency_s=_percentile(lats, 0.99),
            latencies_s=tuple(lats))
