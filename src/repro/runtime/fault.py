"""Fault tolerance: failure detection, checkpoint-restart, straggler
watchdog, and elastic re-meshing.

What a 1000-node run needs and what this module provides:

* **Failure detection** — NaN/Inf losses, raised exceptions, and a wall-time
  watchdog per step (a hung collective on a dead node surfaces as a stall).
* **Checkpoint-restart** — on failure, restore the last committed checkpoint
  (``checkpoint.store`` commits atomically) and replay.  The synthetic data
  pipeline is counter-mode, so replayed steps see identical batches.
* **Straggler mitigation** — per-step timing EMA; steps slower than
  ``straggler_factor`` x the EMA are logged and counted; callers can trigger
  re-mesh (drop the slow host) after ``max_strag`` consecutive events.  This
  is the *software* analogue of the paper's observation that invocation-
  granularity synchronization magnifies tail latency: we detect at step
  granularity and keep sync off the critical path.
* **Elastic re-mesh** — ``shrink_mesh`` rebuilds the largest usable
  (data, model) mesh from a surviving device list; checkpoints are
  mesh-agnostic so restore works onto the new topology.
* **Re-mesh => re-plan** — a re-mesh is a *communication* event, not just a
  placement event: fan-outs shrink, multicast capacity verdicts flip, the
  rule overlay may resolve differently.  ``replan_for_mesh`` re-prices the
  comm plan on the survivor topology (the plan cache keys on the mesh
  shape, so the pre-fault entry is never aliased), and
  :class:`FaultTolerantRunner`'s ``remesh_hook`` folds the whole recovery
  — shrink, re-plan, step rebuild, LUT remap — into the restart path,
  recording every old->new decision flip in ``comm_replan_events``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
# FaultError lives in core.comm so the socket's degradation ladder and
# fence watchdog can raise it without importing runtime code; this module
# re-exports it as the historical spelling.
from repro.core.comm import FaultError


@dataclasses.dataclass
class StragglerStats:
    ema: float = 0.0
    count: int = 0
    events: int = 0

    def update(self, dt: float, factor: float = 3.0) -> bool:
        """Returns True if this step is a straggler."""
        if self.count == 0:
            self.ema = dt
        slow = self.count > 2 and dt > factor * self.ema
        # EMA excludes straggler samples so one stall doesn't mask the next
        if not slow:
            self.ema = 0.9 * self.ema + 0.1 * dt
        self.count += 1
        if slow:
            self.events += 1
        return slow

    def reset(self) -> None:
        """Drop the timing state (EMA and warmup count) but keep the
        cumulative ``events`` tally.  Called after a re-mesh: the survivor
        topology has a different step time, and judging it against the
        pre-fault EMA would flag every post-recovery step a straggler."""
        self.ema = 0.0
        self.count = 0


def shrink_mesh(devices: Sequence, model_parallel: int,
                axis_names=("data", "model")):
    """Largest (data, model) mesh from the surviving devices.  Keeps the
    model axis intact (TP groups must be whole) and drops remainder hosts."""
    n = len(devices)
    data = n // model_parallel
    if data < 1:
        raise FaultError(
            f"{n} devices cannot host model_parallel={model_parallel}")
    use = np.asarray(devices[: data * model_parallel]).reshape(
        data, model_parallel)
    return jax.sharding.Mesh(use, axis_names)


def remap_registry_for_mesh(registry, n_survivors: int):
    """Fold LUT peers that lived on dropped ranks back onto survivors.

    After ``shrink_mesh`` the stage axis has ``n_survivors`` ranks; any
    :class:`~repro.core.socket.StageRegistry` entry pointing past it is
    retargeted (``rank % n_survivors``) through the registry's own
    ``remap`` — the no-retrace path: virtual indices (what the encoded
    user field carries) never change, so the relowered step is not even
    required for the transfers to follow the survivors.  Returns the
    ``(name, old_rank, new_rank)`` moves for the recovery log."""
    moved = []
    for name, rank in list(registry.table.items()):
        if rank >= n_survivors:
            new_rank = rank % n_survivors
            registry.remap(name, new_rank)
            moved.append((name, rank, new_rank))
    return moved


def replan_for_mesh(plan, cfg, shape, new_mesh_axes, *, hlo_text=None,
                    resolve=None, model=None):
    """Re-price the comm plan for a survivor topology (re-mesh => re-plan).

    ``plan`` is the plan the failed step ran under; ``new_mesh_axes`` the
    shrunken mesh's axis sizes (e.g. ``dict(mesh.shape)``).  Re-resolves
    the ``auto`` policy on the new topology — with ``hlo_text`` the
    pricing reads the relowered module's own collectives, else the config
    estimates — and re-resolves the rule overlay via ``resolve`` (a
    ``CommPlan -> (rules, overlay)`` callable such as
    ``runtime.train.resolved_train_rules``) exactly like the launch-time
    refine step.  The plan cache keys on the mesh shape, so this never
    aliases the pre-fault entry.

    Returns ``(new_plan, decisions, rules, overlay, flips)`` where
    ``flips`` is the machine-readable list of per-tensor mode changes
    (``core.planner.plan_decision_flips``) the dryrun artifact and the
    runner's ``comm_replan_events`` record."""
    from repro.core.planner import plan_decision_flips, resolve_policy
    new_plan, decisions = resolve_policy("auto", cfg, shape, new_mesh_axes,
                                         hlo_text=hlo_text, model=model)
    rules = overlay = None
    if resolve is not None:
        rules, overlay = resolve(new_plan)
        if overlay:
            new_plan, decisions = resolve_policy(
                "auto", cfg, shape, new_mesh_axes, hlo_text=hlo_text,
                model=model, rules_overlay=overlay,
                precomputed=(new_plan, decisions))
    return (new_plan, decisions, rules, overlay,
            plan_decision_flips(plan, new_plan))


class FaultTolerantRunner:
    """Wraps a step function with detection, checkpointing, and restart.

    ``remesh_hook`` makes the restart *elastic*: called as ``hook(step,
    err)`` after the fault is caught (checkpoint writer quiesced, before
    restore).  Returning ``None`` keeps the old topology (plain
    checkpoint-restart).  Returning a dict re-meshes the run: the runner
    swaps in ``"step_fn"`` / ``"shardings"`` / ``"state_template"`` (each
    optional — the hook typically shrank the mesh, re-planned via
    :func:`replan_for_mesh`, rebuilt the step, and remapped its
    ``StageRegistry`` consumers through the no-retrace ``remap`` path),
    resets the straggler EMA (:meth:`StragglerStats.reset` — survivor
    steps have a new baseline), and appends ``{"step", "error", "flips",
    ...}`` to ``comm_replan_events`` — ``"flips"`` (and any other keys
    the hook returns, e.g. ``"mesh_axes"``) record what the re-plan
    actually changed."""

    def __init__(self, step_fn: Callable, ckpt_dir: str, *,
                 ckpt_every: int = 50, step_timeout_s: float = 0.0,
                 straggler_factor: float = 3.0, keep: int = 3,
                 remesh_hook: Optional[Callable[[int, Exception],
                                               Optional[Dict]]] = None):
        self.step_fn = step_fn
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.step_timeout_s = step_timeout_s
        self.straggler = StragglerStats()
        self.straggler_factor = straggler_factor
        self.restarts = 0
        self.remesh_hook = remesh_hook
        self.comm_replan_events: List[Dict[str, Any]] = []
        self._failure_injector: Optional[Callable[[int], None]] = None

    def inject_failures(self, fn: Callable[[int], None]):
        """Testing hook: called with the step number before each step; raise
        to simulate a node failure."""
        self._failure_injector = fn

    def _check_finite(self, metrics: Dict[str, Any], step: int):
        loss = metrics.get("loss")
        if loss is not None and not bool(jax.numpy.isfinite(loss)):
            raise FaultError(f"non-finite loss at step {step}: {loss}")

    def run(self, state, batches: Callable[[int], Any], num_steps: int,
            start_step: int = 0, state_template=None, shardings=None):
        """Drive ``num_steps`` steps with restart-on-failure.  ``batches`` is
        step -> batch (deterministic replay).  Returns (state, history)."""
        history: List[Dict[str, Any]] = []
        step = start_step
        while step < num_steps:
            try:
                if self._failure_injector is not None:
                    self._failure_injector(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batches(step))
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                if self.step_timeout_s and dt > self.step_timeout_s:
                    raise FaultError(f"step {step} exceeded {self.step_timeout_s}s")
                self._check_finite(metrics, step)
                slow = self.straggler.update(dt, self.straggler_factor)
                history.append({"step": step, "dt": dt, "straggler": slow,
                                "loss": float(metrics["loss"])})
                if (step + 1) % self.ckpt_every == 0:
                    self.ckpt.save(step + 1, state)
                step += 1
            except FaultError as err:
                self.restarts += 1
                self.ckpt.wait()
                if self.remesh_hook is not None:
                    swap = self.remesh_hook(step, err)
                    if swap is not None:
                        # elastic recovery: the hook shrank the mesh and
                        # re-planned — adopt the rebuilt step/shardings
                        # before restoring onto the survivor topology
                        self.step_fn = swap.get("step_fn", self.step_fn)
                        shardings = swap.get("shardings", shardings)
                        state_template = swap.get("state_template",
                                                  state_template)
                        self.straggler.reset()
                        event = {k: v for k, v in swap.items()
                                 if k not in ("step_fn", "shardings",
                                              "state_template")}
                        event.setdefault("flips", [])
                        event.update(step=step, error=str(err))
                        self.comm_replan_events.append(event)
                last = latest_step(self.ckpt_dir)
                if last is None:
                    raise
                tmpl = state_template if state_template is not None else state
                state = restore_checkpoint(self.ckpt_dir, last, tmpl,
                                           shardings=shardings)
                step = last
        self.ckpt.wait()
        return state, history
