"""Fault tolerance: failure detection, checkpoint-restart, straggler
watchdog, and elastic re-meshing.

What a 1000-node run needs and what this module provides:

* **Failure detection** — NaN/Inf losses, raised exceptions, and a wall-time
  watchdog per step (a hung collective on a dead node surfaces as a stall).
* **Checkpoint-restart** — on failure, restore the last committed checkpoint
  (``checkpoint.store`` commits atomically) and replay.  The synthetic data
  pipeline is counter-mode, so replayed steps see identical batches.
* **Straggler mitigation** — per-step timing EMA; steps slower than
  ``straggler_factor`` x the EMA are logged and counted; callers can trigger
  re-mesh (drop the slow host) after ``max_strag`` consecutive events.  This
  is the *software* analogue of the paper's observation that invocation-
  granularity synchronization magnifies tail latency: we detect at step
  granularity and keep sync off the critical path.
* **Elastic re-mesh** — ``shrink_mesh`` rebuilds the largest usable
  (data, model) mesh from a surviving device list; checkpoints are
  mesh-agnostic so restore works onto the new topology.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


class FaultError(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerStats:
    ema: float = 0.0
    count: int = 0
    events: int = 0

    def update(self, dt: float, factor: float = 3.0) -> bool:
        """Returns True if this step is a straggler."""
        if self.count == 0:
            self.ema = dt
        slow = self.count > 2 and dt > factor * self.ema
        # EMA excludes straggler samples so one stall doesn't mask the next
        if not slow:
            self.ema = 0.9 * self.ema + 0.1 * dt
        self.count += 1
        if slow:
            self.events += 1
        return slow


def shrink_mesh(devices: Sequence, model_parallel: int,
                axis_names=("data", "model")):
    """Largest (data, model) mesh from the surviving devices.  Keeps the
    model axis intact (TP groups must be whole) and drops remainder hosts."""
    n = len(devices)
    data = n // model_parallel
    if data < 1:
        raise FaultError(
            f"{n} devices cannot host model_parallel={model_parallel}")
    use = np.asarray(devices[: data * model_parallel]).reshape(
        data, model_parallel)
    return jax.sharding.Mesh(use, axis_names)


class FaultTolerantRunner:
    """Wraps a step function with detection, checkpointing, and restart."""

    def __init__(self, step_fn: Callable, ckpt_dir: str, *,
                 ckpt_every: int = 50, step_timeout_s: float = 0.0,
                 straggler_factor: float = 3.0, keep: int = 3):
        self.step_fn = step_fn
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.step_timeout_s = step_timeout_s
        self.straggler = StragglerStats()
        self.straggler_factor = straggler_factor
        self.restarts = 0
        self._failure_injector: Optional[Callable[[int], None]] = None

    def inject_failures(self, fn: Callable[[int], None]):
        """Testing hook: called with the step number before each step; raise
        to simulate a node failure."""
        self._failure_injector = fn

    def _check_finite(self, metrics: Dict[str, Any], step: int):
        loss = metrics.get("loss")
        if loss is not None and not bool(jax.numpy.isfinite(loss)):
            raise FaultError(f"non-finite loss at step {step}: {loss}")

    def run(self, state, batches: Callable[[int], Any], num_steps: int,
            start_step: int = 0, state_template=None, shardings=None):
        """Drive ``num_steps`` steps with restart-on-failure.  ``batches`` is
        step -> batch (deterministic replay).  Returns (state, history)."""
        history: List[Dict[str, Any]] = []
        step = start_step
        while step < num_steps:
            try:
                if self._failure_injector is not None:
                    self._failure_injector(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batches(step))
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                if self.step_timeout_s and dt > self.step_timeout_s:
                    raise FaultError(f"step {step} exceeded {self.step_timeout_s}s")
                self._check_finite(metrics, step)
                slow = self.straggler.update(dt, self.straggler_factor)
                history.append({"step": step, "dt": dt, "straggler": slow,
                                "loss": float(metrics["loss"])})
                if (step + 1) % self.ckpt_every == 0:
                    self.ckpt.save(step + 1, state)
                step += 1
            except FaultError:
                self.restarts += 1
                self.ckpt.wait()
                last = latest_step(self.ckpt_dir)
                if last is None:
                    raise
                tmpl = state_template if state_template is not None else state
                state = restore_checkpoint(self.ckpt_dir, last, tmpl,
                                           shardings=shardings)
                step = last
        self.ckpt.wait()
        return state, history
