"""Paged (block) KV cache for the continuous-batching serving engine.

The contiguous serve path gives every request a private, monolithic
cache; under continuous batching that wastes a full ``S_max`` allocation
on every slot regardless of depth, and growing it is an O(S^2) repad.
This module stores the *full-sequence* attention leaves as pools of
fixed-size blocks instead:

* each paged leaf keeps a **pool** shaped ``(..., num_blocks, block_size,
  ...)`` — the per-request batch axis is replaced by a physical-block
  axis, the ``kv_seq`` axis by the block's slot count;
* a host-side :class:`BlockAllocator` hands out physical blocks from a
  free list (block 0 is the reserved *null block* backing inactive
  table entries);
* a per-slot **block table** ``(n_slots, max_blocks) int32`` maps each
  active request's logical block j to its physical block, and is passed
  to the decode step as a device array — growing a request is a host
  table write, never a retrace;
* :func:`gather_caches` materializes the contiguous per-slot view the
  unchanged model decode consumes (``jnp.take`` over the block axis);
  :func:`scatter_caches` writes back only the single block containing
  each slot's write position.

Leaves that are *not* full-sequence attention history are *slot state*:
mamba/rglru recurrent state (fixed O(1) shape per request) and windowed
ring caches whose ring is no larger than the prompt (the contiguous
serve contract keeps those at ``S_prompt`` and wraps — a fixed-size
recurrent buffer in all but name).  Slot-state leaves live as dense
``(n_slots, ...)`` arrays: gather is identity, scatter is replacement.

Leaf classification keys on the **logical axis names** from
``transformer.cache_axes`` ("batch"/"kv_seq"), never on shape
coincidences — matching ``leaf.shape[-3] == S`` false-positives whenever
an unrelated cache dim equals the prompt length.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T


# ----------------------------------------------------------- block allocator ----

NULL_BLOCK = 0


class OutOfBlocksError(RuntimeError):
    """The pool has fewer free blocks than the allocation asked for."""


class BlockAllocator:
    """Host-side free-list allocator over ``num_blocks`` physical blocks.

    Block ``NULL_BLOCK`` (0) is reserved: it backs every inactive block-
    table entry and is never handed out.  Invariants (tier-1 tested):
    a block is never allocated twice without an intervening free; freeing
    a block not currently allocated (or the null block) raises."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (one is the reserved null)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._used: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocksError(
                f"asked for {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the reserved null block")
            if b not in self._used:
                raise ValueError(f"double free of block {b}")
            self._used.remove(b)
            self._free.append(b)


# ------------------------------------------------------------- cache layout ----

@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Geometry of one decode-cache leaf under the engine.

    ``names`` are the leaf's logical axes (group-scanned leaves carry a
    leading ``None``); ``contig_shape`` is the contiguous per-step view
    with ``n_slots`` at the batch axis; ``paged`` leaves additionally
    carry the block-pool geometry."""
    names: Tuple[Optional[str], ...]
    dtype: Any
    contig_shape: Tuple[int, ...]
    paged: bool
    skv: Optional[int] = None      # kv length of the contiguous view

    @property
    def batch_ax(self) -> int:
        return self.names.index("batch")

    @property
    def kv_ax(self) -> int:
        return self.names.index("kv_seq")


def _spec_is_leaf(x) -> bool:
    return isinstance(x, LeafSpec)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of the engine's cache: which leaves are paged,
    block size, per-slot capacity.  ``specs`` mirrors the model's cache
    pytree structure with :class:`LeafSpec` leaves."""
    cfg: ArchConfig
    n_slots: int
    prompt_len: int
    max_new_tokens: int
    block_size: int
    specs: Any = dataclasses.field(hash=False, compare=False)

    @property
    def s_max(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def max_blocks(self) -> int:
        return self.s_max // self.block_size

    @property
    def capacity_blocks(self) -> int:
        """Physical blocks needed to run every slot at full depth (the
        default pool provisioning), excluding the null block."""
        return self.n_slots * self.max_blocks

    def blocks_needed(self, pos: int) -> int:
        """Blocks a request must own before writing position ``pos``."""
        return min(pos // self.block_size + 1, self.max_blocks)


def _leaf_specs_for_kind(cfg: ArchConfig, kind: str, n_slots: int,
                         prompt_len: int, s_max: int, dtype):
    """Per-leaf specs for one block kind, mirroring the *contiguous serve
    contract*: prefill emits full-``S_prompt`` attention prefixes; the
    serve driver grows them to ``S_prompt + GEN`` unless the leaf is a
    ring no larger than the prompt (``window <= S_prompt``), which stays
    at ``S_prompt`` and wraps.  Full-sequence leaves page; rings and
    recurrent state are slot state."""
    hd = cfg.resolved_head_dim
    K = cfg.n_kv_heads
    if kind in ("attn", "swa"):
        window = cfg.local_window if kind == "swa" else cfg.sliding_window
        ring = bool(window) and prompt_len >= window
        skv = prompt_len if ring else s_max
        names = ("batch", "kv_seq", "kv_heads", "head_dim")
        spec = LeafSpec(names=names, dtype=dtype,
                        contig_shape=(n_slots, skv, K, hd),
                        paged=not ring, skv=skv)
        return {"k": spec, "v": spec}
    if kind == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        return {"h": LeafSpec(("batch", "state", None), jnp.float32,
                              (n_slots, di, cfg.ssm.state_dim), False),
                "conv": LeafSpec(("batch", None, "state"), jnp.float32,
                                 (n_slots, cfg.ssm.conv_dim - 1, di), False)}
    if kind == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        return {"h": LeafSpec(("batch", "state"), jnp.float32,
                              (n_slots, w), False),
                "conv": LeafSpec(("batch", None, "state"), jnp.float32,
                                 (n_slots, cfg.rglru.conv_dim - 1, w), False)}
    raise ValueError(kind)


def paged_layout(cfg: ArchConfig, *, n_slots: int, prompt_len: int,
                 max_new_tokens: int, block_size: int,
                 dtype=jnp.bfloat16) -> PagedLayout:
    s_max = prompt_len + max_new_tokens
    if s_max % block_size:
        raise ValueError(f"block_size {block_size} must divide "
                         f"prompt_len + max_new_tokens = {s_max}")
    pattern, n_groups, rem = T._grouping(cfg)
    specs: Dict[str, Any] = {}
    if n_groups:
        group = {f"b{i}": _leaf_specs_for_kind(cfg, kind, n_slots,
                                               prompt_len, s_max, dtype)
                 for i, kind in enumerate(pattern)}
        specs["groups"] = jax.tree.map(
            lambda sp: dataclasses.replace(
                sp, names=(None,) + sp.names,
                contig_shape=(n_groups,) + sp.contig_shape),
            group, is_leaf=_spec_is_leaf)
    if rem:
        specs["rem"] = {f"r{i}": _leaf_specs_for_kind(cfg, kind, n_slots,
                                                      prompt_len, s_max,
                                                      dtype)
                        for i, kind in enumerate(rem)}
    return PagedLayout(cfg=cfg, n_slots=n_slots, prompt_len=prompt_len,
                       max_new_tokens=max_new_tokens, block_size=block_size,
                       specs=specs)


# ------------------------------------------------------------ pool storage ----

def _pool_shape(layout: PagedLayout, spec: LeafSpec) -> Tuple[int, ...]:
    if not spec.paged:
        return spec.contig_shape
    sh = list(spec.contig_shape)
    sh[spec.batch_ax] = 1 + layout.capacity_blocks   # + the null block
    sh[spec.kv_ax] = layout.block_size
    return tuple(sh)


def make_pools(layout: PagedLayout):
    """Zero-initialized device storage: block pools for paged leaves,
    dense slot-state arrays for the rest."""
    return jax.tree.map(
        lambda sp: jnp.zeros(_pool_shape(layout, sp), sp.dtype),
        layout.specs, is_leaf=_spec_is_leaf)


def pool_specs(layout: PagedLayout):
    """ShapeDtypeStructs of :func:`make_pools` (for eval_shape / jit)."""
    return jax.tree.map(
        lambda sp: jax.ShapeDtypeStruct(_pool_shape(layout, sp), sp.dtype),
        layout.specs, is_leaf=_spec_is_leaf)


# --------------------------------------------------------- gather / scatter ----

def _gather_leaf(layout: PagedLayout, spec: LeafSpec, pool, tables):
    """Pool -> contiguous per-slot view.  tables: (n_slots, max_blocks)
    int32 physical-block ids (null entries gather the zero block — the
    decode validity mask keeps them out of the softmax)."""
    if not spec.paged:
        return pool
    b, s = spec.batch_ax, spec.kv_ax
    pm = jnp.moveaxis(pool, (b, s), (0, 1))          # (blocks, bs, rest)
    flat = jnp.take(pm, tables.reshape(-1), axis=0)  # (slots*mb, bs, rest)
    n_slots, mb = tables.shape
    contig = flat.reshape((n_slots, mb * layout.block_size) + pm.shape[2:])
    return jnp.moveaxis(contig, (0, 1), (b, s))


def gather_caches(layout: PagedLayout, pools, tables):
    return jax.tree.map(
        lambda sp, pool: _gather_leaf(layout, sp, pool, tables),
        layout.specs, pools, is_leaf=_spec_is_leaf)


def _scatter_leaf(layout: PagedLayout, spec: LeafSpec, pool, new_contig,
                  tables, pos):
    """Write back the one block per slot containing the slot's write
    position.  Inactive slots (all-null tables) land on the null block —
    harmless garbage no active table references."""
    if not spec.paged:
        # keep the pool dtype stable: a decode step may hand back slot
        # state in its compute dtype, and a dtype flip would retrace
        return new_contig.astype(pool.dtype)
    b, s = spec.batch_ax, spec.kv_ax
    bs = layout.block_size
    pm = jnp.moveaxis(pool, (b, s), (0, 1))              # (blocks, bs, rest)
    cm = jnp.moveaxis(new_contig, (b, s), (0, 1))        # (slots, S, rest)
    n_slots, mb = tables.shape
    cm = cm.reshape((n_slots, mb, bs) + cm.shape[2:])
    # the decode write slot mirrors decode_attn_apply: pos mod capacity
    # (no-op below capacity; rings never page)
    j = jnp.mod(pos.astype(jnp.int32), mb * bs) // bs    # (n_slots,)
    blk = jax.vmap(lambda row, jj: jax.lax.dynamic_index_in_dim(
        row, jj, 0, keepdims=False))(cm, j)              # (slots, bs, rest)
    phys = jnp.take_along_axis(tables, j[:, None], axis=1)[:, 0]
    pm = pm.at[phys].set(blk.astype(pm.dtype))
    return jnp.moveaxis(pm, (0, 1), (b, s))


def scatter_caches(layout: PagedLayout, pools, new_caches, tables, pos):
    return jax.tree.map(
        lambda sp, pool, nc: _scatter_leaf(layout, sp, pool, nc, tables, pos),
        layout.specs, pools, new_caches, is_leaf=_spec_is_leaf)


def _write_prefix_leaf(layout: PagedLayout, spec: LeafSpec, pool,
                       prefix_leaf, slot, block_ids):
    """Admission: land one request's prefill cache.  ``prefix_leaf`` has
    batch 1 and (for attention) ``kv_seq == prompt_len``; paged leaves
    scatter it block-by-block into ``block_ids``, slot-state leaves write
    their row.  ``slot``/``block_ids`` are traced values — one trace
    serves every admission."""
    b = spec.batch_ax
    if not spec.paged:
        pm = jnp.moveaxis(pool, b, 0)
        row = jnp.moveaxis(prefix_leaf, b, 0)[0]
        return jnp.moveaxis(pm.at[slot].set(row.astype(pm.dtype)), 0, b)
    s = spec.kv_ax
    bs = layout.block_size
    n_pb = -(-layout.prompt_len // bs)                  # ceil
    pm = jnp.moveaxis(pool, (b, s), (0, 1))             # (blocks, bs, rest)
    cm = jnp.moveaxis(prefix_leaf, (b, s), (0, 1))[0]   # (S_prompt, rest)
    pad = n_pb * bs - layout.prompt_len
    if pad:
        cm = jnp.pad(cm, [(0, pad)] + [(0, 0)] * (cm.ndim - 1))
    cm = cm.reshape((n_pb, bs) + cm.shape[1:])
    pm = pm.at[block_ids[:n_pb]].set(cm.astype(pm.dtype))
    return jnp.moveaxis(pm, (0, 1), (b, s))


def write_prefix(layout: PagedLayout, pools, prefix_caches, slot, block_ids):
    """Write one admitted request's prefill caches into the pools.
    ``block_ids``: (>= ceil(prompt_len / block_size),) int32 physical
    blocks owned by the request, in logical order."""
    return jax.tree.map(
        lambda sp, pool, pre: _write_prefix_leaf(layout, sp, pool, pre,
                                                 slot, block_ids),
        layout.specs, pools, prefix_caches, is_leaf=_spec_is_leaf)


def null_table(layout: PagedLayout) -> np.ndarray:
    """Host block table with every entry on the null block."""
    return np.full((layout.n_slots, layout.max_blocks), NULL_BLOCK,
                   dtype=np.int32)
