"""Train-step factory: value_and_grad + AdamW, with logical-rule shardings.

``make_train_step(cfg, flags, mesh)`` returns a jit-able step whose in/out
shardings come from the params' logical axes — the same rule table the
models annotate with.  Donation of (params, opt_state) keeps the working set
at 1x.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.comm import CommMode
from repro.core.sharding import (DEFAULT_RULES, logical_to_pspec,
                                 resolve_rules, rule_gated_issued_mode,
                                 tree_pspecs, use_rules)
from repro.core.socket import record_implicit_issue
from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, cosine_schedule, opt_state_axes
from repro.optim.adamw import AdamWState

jax.tree_util.register_dataclass(
    AdamWState, data_fields=["step", "mu", "nu"], meta_fields=[])

TRAIN_RULES = dict(DEFAULT_RULES)

SERVE_RULES = dict(DEFAULT_RULES)
# Weights stay sharded over BOTH axes at inference (2-D weight sharding):
# a 400B-param MoE at bf16 is 800 GB — it only fits a 256-chip pod at
# ~3 GB/device; the per-layer gather rides the same fast axis the TP
# collectives use and is fully overlappable (prefetched one layer ahead).
SERVE_RULES["w_fsdp"] = "data"
SERVE_RULES["batch"] = ("pod", "data")


def resolved_train_rules(comm_plan, rules=None):
    """Planner -> sharding feedback for the train rules: rewrite the rule
    table from a :class:`~repro.core.comm.CommPlan`'s decisions (e.g.
    ``w_fsdp`` off when the weight all-gather plans to MCAST; FSDP kept
    when MEM wins).  Returns ``(resolved_rules, overlay)``; pass the
    resolved rules to :func:`make_train_step` and the overlay to
    ``core.planner.resolve_policy`` so the plan cache keys on it."""
    return resolve_rules(comm_plan, dict(rules or TRAIN_RULES))


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt"], meta_fields=[])


def _axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def train_shardings(cfg: ArchConfig, mesh, rules=None, flags=None,
                    batch_shape=None):
    """Returns (state_shardings, batch_sharding) as NamedSharding pytrees.
    Shape-aware: logical axes that do not divide a leaf dimension fall back
    to replication (e.g. 3 kv-heads on a 16-way model axis)."""
    rules = rules or TRAIN_RULES
    flags = flags or T.RunFlags()
    p_axes = T.param_axes(cfg)
    o_axes = opt_state_axes(p_axes)
    state_axes = TrainState(params=p_axes, opt=o_axes)
    state_specs = jax.eval_shape(
        lambda: TrainState(
            params=(p := T.init_params(jax.random.key(0), cfg,
                                       flags.param_dtype)),
            opt=adamw_init(p, flags.opt_dtype)))

    def to_sh(names, spec):
        return NamedSharding(mesh, logical_to_pspec(names, rules, mesh,
                                                    shape=spec.shape))

    state_sh = jax.tree.map(to_sh, state_axes, state_specs, is_leaf=_axes_leaf)
    batch_sh = {
        k: NamedSharding(mesh, logical_to_pspec(("batch", "seq"), rules, mesh,
                                                shape=batch_shape))
        for k in ("tokens", "labels")
    }
    return state_sh, batch_sh


def make_train_step(cfg: ArchConfig, flags: T.RunFlags, mesh=None, rules=None,
                    lr=None, total_steps: int = 10000, batch_shape=None,
                    comm_plan=None):
    """Returns (step_fn, state_shardings, batch_shardings).  step_fn:
    (TrainState, batch) -> (TrainState, metrics).

    ``comm_plan`` (a :class:`~repro.core.comm.CommPlan`, typically built by
    ``core.planner.CommPlanner``) is installed for the step's trace: every
    collective site that consults ``current_comm_plan()`` (MoE dispatch
    today) takes the planned mode instead of ``flags.moe_mode``."""
    rules = rules or TRAIN_RULES
    lr = lr or cosine_schedule(3e-4, 200, total_steps)

    def loss_fn(params, batch):
        return T.forward_train(params, batch, cfg, flags)

    def step(state: TrainState, batch):
        with use_rules(rules, mesh, comm_plan=comm_plan):
            if comm_plan is not None:
                # transfers the *compiler* issues for this step, logged at
                # trace time so dryrun artifacts report them per site: the
                # rule-gated weight gather (direct only once the w_fsdp
                # rewrite is real) and the gradient reduction (pinned MEM)
                record_implicit_issue(
                    "weights", planned=comm_plan.mode("weights"),
                    issued=rule_gated_issued_mode("weights", comm_plan,
                                                  rules),
                    impl="dma_double_buffer"
                    if comm_plan.streamed("weights") else "xla_all_gather",
                    site="train.weights_gather",
                    reason="streamed gather: block i+1's IDMA behind block "
                    "i's consumer matmul (kernels.dma_double_buffer)"
                    if comm_plan.streamed("weights") else
                    "w_fsdp gate not cleared: gather rides memory")
                record_implicit_issue(
                    "grad_reduce", planned=comm_plan.mode("grad_reduce"),
                    issued=CommMode.MEM,
                    impl="dma_double_buffer"
                    if comm_plan.streamed("grad_reduce") else
                    "xla_all_reduce",
                    site="train.grad_reduce",
                    reason="streamed reduction: bucket i's DMA behind "
                    "bucket i+1's producer compute"
                    if comm_plan.streamed("grad_reduce") else
                    "reduction: cannot combine in flight")
                # the cross-pod int8 gradient transport
                # (optim.compression): recorded whether or not this mesh
                # activates it, so every auto artifact carries the site —
                # the ci.sh --against-artifact gate asserts it is covered
                pod = (dict(mesh.shape).get("pod", 1)
                       if mesh is not None else 1)
                record_implicit_issue(
                    "grad_reduce_compressed",
                    planned=comm_plan.mode("grad_reduce_compressed"),
                    issued=CommMode.MEM,
                    impl="int8_psum" if pod > 1 else "inactive",
                    site="train.grad_reduce_compressed",
                    reason="reduction: cannot combine in flight"
                    if pod > 1 else
                    "no pod axis: compression inactive — gradients ride "
                    "the plain reduction")
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            new_params, new_opt, metrics = adamw_update(
                state.params, grads, state.opt, lr)
            metrics["loss"] = loss
            return TrainState(new_params, new_opt), metrics

    if mesh is None:
        return step, None, None
    state_sh, batch_sh = train_shardings(cfg, mesh, rules, flags, batch_shape)
    return step, state_sh, batch_sh


def init_state(key, cfg: ArchConfig, flags: T.RunFlags) -> TrainState:
    params = T.init_params(key, cfg, flags.param_dtype)
    return TrainState(params=params,
                      opt=adamw_init(params, flags.opt_dtype))
