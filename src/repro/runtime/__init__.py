from repro.runtime.train import (TrainState, make_train_step, train_shardings,
                                 TRAIN_RULES, SERVE_RULES)
from repro.runtime.serve import make_prefill_step, make_decode_step

__all__ = ["TrainState", "make_train_step", "train_shardings",
           "TRAIN_RULES", "SERVE_RULES",
           "make_prefill_step", "make_decode_step"]
