"""Serving-step factories: prefill and single-token decode.

Decode shardings follow SERVE_RULES: KV caches are *sequence-sharded* over
the model axis (all 16 TP ranks hold a slice of every head's history) with
partial-softmax statistics combined by small all-reduces — the bulk payload
(the cache) never moves; only the tiny (m, l) statistics cross the fabric.
This is the paper's C3 split (sync region vs. bulk) applied to attention.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.comm import CommMode
from repro.core.sharding import (logical_to_pspec, resolve_rules,
                                 rule_gated_issued_mode, use_rules)
from repro.core.socket import record_implicit_issue
from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.runtime.train import SERVE_RULES, _axes_leaf


def resolved_serve_rules(comm_plan, rules=None):
    """Planner -> sharding feedback for the serve rules (see
    ``runtime.train.resolved_train_rules``): e.g. the 2-D weight sharding's
    ``w_fsdp = "data"`` gather is dropped when the weight transfer plans to
    MCAST.  Returns ``(resolved_rules, overlay)``."""
    return resolve_rules(comm_plan, dict(rules or SERVE_RULES))


def serve_shardings(cfg: ArchConfig, mesh, B: int, skv: int, rules=None,
                    param_dtype=jnp.bfloat16):
    """Shape-aware shardings for (params, cache, tokens)."""
    rules = rules or SERVE_RULES
    p_axes = T.param_axes(cfg)
    p_specs = jax.eval_shape(
        lambda: T.init_params(jax.random.key(0), cfg, param_dtype))

    def to_sh(names, spec=None):
        shape = spec.shape if spec is not None else None
        return NamedSharding(mesh, logical_to_pspec(names, rules, mesh,
                                                    shape=shape))

    param_sh = jax.tree.map(to_sh, p_axes, p_specs, is_leaf=_axes_leaf)
    cache_specs = T.make_cache(cfg, B, skv, as_specs=True)
    cache_sh = jax.tree.map(to_sh, T.cache_axes(cfg, B, skv), cache_specs,
                            is_leaf=_axes_leaf)
    tok_sh = NamedSharding(mesh, logical_to_pspec(("batch", None), rules, mesh,
                                                  shape=(B, 1)))
    return param_sh, cache_sh, tok_sh


def _record_serve_weights(comm_plan, rules, site):
    """Log the compiler-issued weight gather for a serve step (trace time):
    the 2-D sharding's per-layer gather goes direct only once the plan's
    verdict cleared the ``w_fsdp`` rule gate."""
    if comm_plan is None:
        return
    record_implicit_issue(
        "weights", planned=comm_plan.mode("weights"),
        issued=rule_gated_issued_mode("weights", comm_plan, rules),
        impl="xla_all_gather", site=site,
        reason="w_fsdp gate not cleared: gather rides memory")


def make_prefill_step(cfg: ArchConfig, flags: T.RunFlags, mesh=None,
                      rules=None, comm_plan=None):
    rules = rules or SERVE_RULES

    def step(params, tokens):
        with use_rules(rules, mesh, comm_plan=comm_plan):
            _record_serve_weights(comm_plan, rules, "prefill.weights_gather")
            return T.prefill(params, tokens, cfg, flags)

    return step


def make_decode_step(cfg: ArchConfig, flags: T.RunFlags, mesh=None,
                     rules=None, comm_plan=None):
    rules = rules or SERVE_RULES
    # MoE mcast dispatch needs a sequence dimension to shard; a single decode
    # position has none, so decode always uses the MEM path (C4: mode choice
    # is per-transfer, and this transfer's best mode differs from prefill's).
    if flags.moe_mode != "mem":
        flags = T.RunFlags(**{**flags.__dict__, "moe_mode": "mem"})
    if comm_plan is not None:
        # same per-transfer reasoning applies to a planner-built plan: the
        # decode-time dispatch transfer is not the prefill one
        comm_plan = comm_plan.with_mode("moe_dispatch", CommMode.MEM)

    def step(params, token, pos, caches):
        with use_rules(rules, mesh, comm_plan=comm_plan):
            _record_serve_weights(comm_plan, rules, "decode.weights_gather")
            return T.decode_step(params, token, pos, caches, cfg, flags)

    return step
