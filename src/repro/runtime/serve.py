"""Serving-step factories: prefill and single-token decode.

Decode shardings follow SERVE_RULES: KV caches are *sequence-sharded* over
the model axis (all 16 TP ranks hold a slice of every head's history) with
partial-softmax statistics combined by small all-reduces — the bulk payload
(the cache) never moves; only the tiny (m, l) statistics cross the fabric.
This is the paper's C3 split (sync region vs. bulk) applied to attention.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.comm import CommMode
from repro.core.sharding import (logical_to_pspec, resolve_rules,
                                 rule_gated_issued_mode, use_rules)
from repro.core.socket import record_implicit_issue
from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.runtime import kv_blocks as KB
from repro.runtime.train import SERVE_RULES, _axes_leaf


def resolved_serve_rules(comm_plan, rules=None):
    """Planner -> sharding feedback for the serve rules (see
    ``runtime.train.resolved_train_rules``): e.g. the 2-D weight sharding's
    ``w_fsdp = "data"`` gather is dropped when the weight transfer plans to
    MCAST.  Returns ``(resolved_rules, overlay)``."""
    return resolve_rules(comm_plan, dict(rules or SERVE_RULES))


def serve_shardings(cfg: ArchConfig, mesh, B: int, skv: int, rules=None,
                    param_dtype=jnp.bfloat16):
    """Shape-aware shardings for (params, cache, tokens)."""
    rules = rules or SERVE_RULES
    p_axes = T.param_axes(cfg)
    p_specs = jax.eval_shape(
        lambda: T.init_params(jax.random.key(0), cfg, param_dtype))

    def to_sh(names, spec=None):
        shape = spec.shape if spec is not None else None
        return NamedSharding(mesh, logical_to_pspec(names, rules, mesh,
                                                    shape=shape))

    param_sh = jax.tree.map(to_sh, p_axes, p_specs, is_leaf=_axes_leaf)
    cache_specs = T.make_cache(cfg, B, skv, as_specs=True)
    cache_sh = jax.tree.map(to_sh, T.cache_axes(cfg, B, skv), cache_specs,
                            is_leaf=_axes_leaf)
    tok_sh = NamedSharding(mesh, logical_to_pspec(("batch", None), rules, mesh,
                                                  shape=(B, 1)))
    return param_sh, cache_sh, tok_sh


def grow_caches(cfg: ArchConfig, caches, prompt_len: int, gen: int):
    """Grow contiguous prefill caches to hold ``gen`` decoded tokens.

    Only full-sequence attention history grows, and it is classified by
    the *logical axis names* of ``transformer.cache_axes`` (via the
    paged-layout leaf specs) — never by a shape test like
    ``leaf.shape[-3] == prompt_len``, which false-positives whenever an
    unrelated cache dim (e.g. a conv-state depth) happens to equal the
    prompt length.  Ring leaves (``window <= prompt_len``) stay at
    ``prompt_len`` and wrap (the decode contract); recurrent slot state
    never grows.  The pad happens once — callers must not re-pad per
    decode step (an O(S^2) copy)."""
    layout = KB.paged_layout(cfg, n_slots=1, prompt_len=prompt_len,
                             max_new_tokens=gen, block_size=1)

    def grow(sp, leaf):
        if not sp.paged:
            return leaf
        ax = sp.kv_ax
        pad = [(0, 0)] * leaf.ndim
        pad[ax] = (0, gen)
        return jnp.pad(leaf, pad)

    return jax.tree.map(grow, layout.specs, caches,
                        is_leaf=KB._spec_is_leaf)


# The compiler-issued weight gather is logged inline at each step factory
# (trace time, literal site= and reason= so commcheck's extractor admits
# the sites into the coverage universe): the 2-D sharding's per-layer
# gather goes direct only once the plan's verdict cleared the ``w_fsdp``
# rule gate.


def make_prefill_step(cfg: ArchConfig, flags: T.RunFlags, mesh=None,
                      rules=None, comm_plan=None):
    rules = rules or SERVE_RULES

    def step(params, tokens):
        with use_rules(rules, mesh, comm_plan=comm_plan):
            if comm_plan is not None:
                record_implicit_issue(
                    "weights", planned=comm_plan.mode("weights"),
                    issued=rule_gated_issued_mode("weights", comm_plan,
                                                  rules),
                    impl="xla_all_gather", site="prefill.weights_gather",
                    reason="w_fsdp gate not cleared: gather rides memory")
            return T.prefill(params, tokens, cfg, flags)

    return step


def _decode_downgrades(cfg: ArchConfig, flags: T.RunFlags, comm_plan):
    """MoE mcast dispatch needs a sequence dimension to shard; a single
    decode position has none, so decode always uses the MEM path (C4: mode
    choice is per-transfer, and this transfer's best mode differs from
    prefill's).  The downgrade is *recorded*, not silent: a
    machine-readable ``decode_no_seq_dim`` reason lands in the issue log
    under the descriptor's canonical ``moe.dispatch`` site — epoch-scoped
    when the caller builds the step inside an ``issue_epoch`` (the engine
    binds decode under ``issue_epoch("decode")``, keying the record as
    ``moe.dispatch@decode``) — so ``mismatched_sites()`` and the
    ``--against-artifact`` coverage gate resolve it through the same
    descriptor the fused dispatch chain declares."""
    if flags.moe_mode != "mem":
        # dataclasses.replace, never RunFlags(**{**flags.__dict__, ...}):
        # the frozen dataclass's __dict__ round-trip breaks under slots
        # and silently copies stale derived state
        flags = dataclasses.replace(flags, moe_mode="mem")
    if comm_plan is not None and cfg.moe is not None:
        planned = comm_plan.mode("moe_dispatch")
        comm_plan = comm_plan.with_mode("moe_dispatch", CommMode.MEM)
        record_implicit_issue(
            "moe_dispatch", planned=planned, issued=CommMode.MEM,
            impl="decode_downgrade", reason="decode_no_seq_dim",
            site="moe.dispatch")
    elif comm_plan is not None:
        comm_plan = comm_plan.with_mode("moe_dispatch", CommMode.MEM)
    return flags, comm_plan


def make_decode_step(cfg: ArchConfig, flags: T.RunFlags, mesh=None,
                     rules=None, comm_plan=None):
    rules = rules or SERVE_RULES
    flags, comm_plan = _decode_downgrades(cfg, flags, comm_plan)

    def step(params, token, pos, caches):
        with use_rules(rules, mesh, comm_plan=comm_plan):
            if comm_plan is not None:
                record_implicit_issue(
                    "weights", planned=comm_plan.mode("weights"),
                    issued=rule_gated_issued_mode("weights", comm_plan,
                                                  rules),
                    impl="xla_all_gather", site="decode.weights_gather",
                    reason="w_fsdp gate not cleared: gather rides memory")
            return T.decode_step(params, token, pos, caches, cfg, flags)

    return step


def make_batched_decode_step(cfg: ArchConfig, flags: T.RunFlags, mesh=None,
                             rules=None, comm_plan=None):
    """Continuously batched decode over contiguous caches: ``pos`` is a
    (B,) int32 vector — every batch row is its own request at its own
    depth, with cache slots past a row's position masked out of the
    softmax (see ``attention.decode_attn_apply``)."""
    rules = rules or SERVE_RULES
    flags, comm_plan = _decode_downgrades(cfg, flags, comm_plan)

    def step(params, tokens, pos, caches):
        with use_rules(rules, mesh, comm_plan=comm_plan):
            if comm_plan is not None:
                record_implicit_issue(
                    "weights", planned=comm_plan.mode("weights"),
                    issued=rule_gated_issued_mode("weights", comm_plan,
                                                  rules),
                    impl="xla_all_gather", site="decode.weights_gather",
                    reason="w_fsdp gate not cleared: gather rides memory")
            return T.decode_step(params, tokens, pos, caches, cfg, flags)

    return step


def make_paged_decode_step(cfg: ArchConfig, flags: T.RunFlags,
                           layout: "KB.PagedLayout", mesh=None, rules=None,
                           comm_plan=None):
    """Block-table decode for the serving engine: gather the paged pools
    into the contiguous per-slot view, run one batched decode step, and
    scatter back only the block containing each slot's write position.

    ``step(params, tokens, pos, pools, tables)``: tokens ``(n_slots, 1)``,
    pos ``(n_slots,)``, ``tables`` the ``(n_slots, max_blocks)`` int32
    block table.  Growing a request's cache is a host-side table update —
    the step never retraces."""
    rules = rules or SERVE_RULES
    flags, comm_plan = _decode_downgrades(cfg, flags, comm_plan)

    def step(params, tokens, pos, pools, tables):
        with use_rules(rules, mesh, comm_plan=comm_plan):
            if comm_plan is not None:
                record_implicit_issue(
                    "weights", planned=comm_plan.mode("weights"),
                    issued=rule_gated_issued_mode("weights", comm_plan,
                                                  rules),
                    impl="xla_all_gather", site="decode.weights_gather",
                    reason="w_fsdp gate not cleared: gather rides memory")
            caches = KB.gather_caches(layout, pools, tables)
            logits, new_caches = T.decode_step(params, tokens, pos, caches,
                                               cfg, flags)
            pools = KB.scatter_caches(layout, pools, new_caches, tables, pos)
            return logits, pools

    return step
