"""Config registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``get_reduced(name)``
returns a tiny same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, RGLRUConfig, SHAPES, ShapeConfig, shape_applicable

from repro.configs import (
    musicgen_medium,
    dbrx_132b,
    llama4_maverick_400b_a17b,
    smollm_135m,
    qwen3_4b,
    h2o_danube_3_4b,
    olmo_1b,
    recurrentgemma_9b,
    falcon_mamba_7b,
    qwen2_vl_72b,
)

_MODULES = {
    "musicgen-medium": musicgen_medium,
    "dbrx-132b": dbrx_132b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "smollm-135m": smollm_135m,
    "qwen3-4b": qwen3_4b,
    "h2o-danube-3-4b": h2o_danube_3_4b,
    "olmo-1b": olmo_1b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "qwen2-vl-72b": qwen2_vl_72b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    return _MODULES[name].CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _MODULES[name].reduced()


def applicable_shapes(name: str):
    arch = get_config(name)
    return [s for s in SHAPES.values() if shape_applicable(arch, s)]


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "RGLRUConfig",
    "SHAPES", "ShapeConfig", "shape_applicable",
    "ARCH_NAMES", "get_config", "get_reduced", "applicable_shapes",
]
