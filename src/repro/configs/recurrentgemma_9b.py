"""RecurrentGemma-9B: RG-LRU + local attention, 1:2 pattern (Griffin).

[arXiv:2402.19427; unverified]  38L, d_model=4096, 16 heads (MQA kv=1),
d_ff=12288, vocab=256000.  Pattern: (rglru, rglru, local-attn) repeating;
local attention window 2048.  Bounded state => ``long_500k`` RUNS.
"""

import dataclasses

from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    pattern=("rglru", "rglru", "swa"),
    local_window=2048,
    rglru=RGLRUConfig(lru_width=4096, conv_dim=4),
    subquadratic=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-reduced",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=128, local_window=32,
        rglru=RGLRUConfig(lru_width=64, conv_dim=4),
    )
