"""Llama-4 Maverick 400B-A17B: 128-expert top-1 MoE.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L, d_model=5120,
40 heads (GQA kv=8), d_ff=8192 per expert, vocab=202048, MoE 128 experts
top-1.  Early-fusion multimodality is frontend-stubbed (text tokens only).

Top-1 routing is the paper's UNICAST P2P mode (one producer -> one
consumer); contrast with dbrx's top-4 multicast.
"""

import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(n_experts=128, top_k=1),
    # Maverick interleaves dense and MoE layers 1:1 (that is how 48 layers
    # of 128 experts lands at ~400B total / 17B active)
    pattern=("attn", "attn"),
    moe_pattern=(False, True),
    dense_ff=16384,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="llama4-maverick-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=128, moe=MoEConfig(n_experts=8, top_k=1),
        dense_ff=128,
    )
