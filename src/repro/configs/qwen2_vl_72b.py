"""Qwen2-VL-72B: VLM backbone with M-RoPE.

[arXiv:2409.12191; hf]  80L, d_model=8192, 64 heads (GQA kv=8),
d_ff=29568, vocab=152064, M-RoPE (t/h/w sections), dynamic resolution.

The vision frontend (ViT + dynamic-resolution patching) is a STUB:
``input_specs`` provides precomputed patch-embedding token ids interleaved
with text tokens; the 72B transformer BACKBONE is the deliverable.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
    frontend="vision_patches",
    subquadratic=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, mrope_sections=(4, 2, 2),
    )
