"""H2O-Danube3-4B: llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L, d_model=3840, 32 heads (GQA kv=8),
d_ff=10240, vocab=32000, SWA.  The sliding window makes both prefill (banded
attention) and decode (ring-buffer KV cache) O(seq * window) =>
``long_500k`` RUNS for this arch.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    pattern=("swa",),
    local_window=4096,
    subquadratic=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="h2o-danube-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=128, local_window=32,
    )
