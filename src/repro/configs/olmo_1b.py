"""OLMo-1B: dense LM with non-parametric LayerNorm.

[arXiv:2402.00838; hf]  16L, d_model=2048, 16 heads (kv=16 => MHA),
d_ff=8192, vocab=50304, LayerNorm without learned affine.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    non_parametric_ln=True,
    tie_embeddings=True,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="olmo-1b-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=128,
    )
