"""Falcon-Mamba-7B: pure Mamba-1 SSM, attention-free.

[arXiv:2410.05355; unverified]  64L, d_model=4096, attention-free,
vocab=65024, ssm_state=16, expand=2 (d_inner=8192), conv=4.

Attention-sharding aspects of the paper's technique are inapplicable (no
attention); the communication modes instead govern scan-state / channel
sharding (see DESIGN.md §Arch-applicability).  O(1) decode state =>
``long_500k`` RUNS.
"""

import dataclasses

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    pattern=("mamba",),
    ssm=SSMConfig(state_dim=16, expand=2, conv_dim=4, dt_rank=256),
    tie_embeddings=True,
    subquadratic=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="falcon-mamba-reduced",
        n_layers=2, d_model=64, vocab_size=128,
        ssm=SSMConfig(state_dim=4, expand=2, conv_dim=4, dt_rank=8),
    )
