"""The paper's own evaluation platform (Fig. 5): a 3x4-tile SoC with 1 CPU
tile (CVA6), 1 memory tile, 1 I/O tile, and 17 traffic-generator
accelerators on a 256-bit NoC at 78 MHz, prototyped on a Xilinx VCU128.

Consumed by the NoC benchmarks (`benchmarks/run.py`) and the NoC property
tests — this is the reproduction config for Fig. 4 / Fig. 6.  Alongside the
calibrated FPGA profile, ``PROFILES`` carries pod-scale ``SoCParams``
variants (one generator per free tile, 2-cycle links) for pricing
transfers on meshes beyond the paper's prototype; those are NOT calibrated
against the Fig. 6 milestones — relative MEM/P2P/MCAST comparisons only
(docs/perfmodel.md §Pod-scale profiles).
"""

from repro.core.noc.perfmodel import SoCParams

CONFIG = SoCParams()

# Named NoC profiles selectable via --noc-profile on the launch CLIs.
PROFILES = {
    "espsoc-3x4": CONFIG,
    "pod-8x8": SoCParams.pod(8, 8),
    "pod-16x16": SoCParams.pod(16, 16),
}


def noc_model(profile: str = "espsoc-3x4"):
    """--noc-profile value -> optional planner model override.  Returns
    None for the default calibrated 3x4 profile (the planner builds its
    own SoCPerfModel lazily), else the pod-scale model — the single
    mapping all three launch CLIs share."""
    from repro.core.noc.perfmodel import SoCPerfModel
    return (None if profile == "espsoc-3x4"
            else SoCPerfModel(PROFILES[profile]))

# Fig. 6 sweep axes
CONSUMER_SWEEP = (1, 2, 4, 8, 16)
SIZE_SWEEP = (4096, 16384, 65536, 262144, 1048576, 4194304)

# Fig. 4 sweep axes
BITWIDTH_SWEEP = (64, 128, 256)
DEST_SWEEP = tuple(range(0, 17))

# noc_mesh_scale benchmark axes (vectorized flit simulator)
MESH_SCALE_SWEEP = ((4, 3), (8, 8), (16, 16))
