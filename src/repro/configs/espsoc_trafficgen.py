"""The paper's own evaluation platform (Fig. 5): a 3x4-tile SoC with 1 CPU
tile (CVA6), 1 memory tile, 1 I/O tile, and 17 traffic-generator
accelerators on a 256-bit NoC at 78 MHz, prototyped on a Xilinx VCU128.

Consumed by the NoC benchmarks (`benchmarks/multicast_speedup.py`) and the
NoC property tests — this is the reproduction config for Fig. 4 / Fig. 6.
"""

from repro.core.noc.perfmodel import SoCParams

CONFIG = SoCParams()

# Fig. 6 sweep axes
CONSUMER_SWEEP = (1, 2, 4, 8, 16)
SIZE_SWEEP = (4096, 16384, 65536, 262144, 1048576, 4194304)

# Fig. 4 sweep axes
BITWIDTH_SWEEP = (64, 128, 256)
DEST_SWEEP = tuple(range(0, 17))
