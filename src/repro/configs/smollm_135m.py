"""SmolLM-135M: llama-architecture small dense LM.

[hf:HuggingFaceTB/SmolLM-135M; hf]  30L, d_model=576, 9 heads (GQA kv=3),
d_ff=1536, vocab=49152.  Used by examples/ as the ~100M end-to-end training
model.  9 heads on a 16-way model axis exercises GSPMD padded sharding; the
hill-climb log shows the rule change that removes the waste.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-135m-reduced",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_ff=128,
        vocab_size=128,
    )
