"""Qwen3-4B: dense with qk-norm and GQA.

[hf:Qwen/Qwen3-8B; hf]  36L, d_model=2560, 32 heads (GQA kv=8),
d_ff=9728, vocab=151936, explicit head_dim=128, per-head RMS qk-norm.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    subquadratic=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-4b-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128,
    )
