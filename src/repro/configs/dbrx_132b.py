"""DBRX-132B: fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]  40L, d_model=6144, 48 heads (GQA
kv=8), d_ff=10752 per expert, vocab=100352, MoE 16 experts top-4.

Top-4 routing is the paper's MULTICAST mode: each token's activations are
forwarded to 4 expert tiles in a single dispatch (CommMode.MCAST).
"""

import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe=MoEConfig(n_experts=16, top_k=4),
    subquadratic=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="dbrx-132b-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=128, moe=MoEConfig(n_experts=4, top_k=2),
    )
