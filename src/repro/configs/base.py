"""Architecture configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`.  Configs
are plain dataclasses (no framework deps) consumed by ``models.transformer``
to build the parameter pytree and the forward functions, and by
``launch.dryrun`` to build ``input_specs``.

Block kinds
-----------
A model is a sequence of *blocks*.  Most architectures are homogeneous
(``pattern`` of length 1); RecurrentGemma uses a 1:2 local-attention /
RG-LRU pattern.  Supported kinds:

* ``"attn"``    — self-attention (GQA / MHA / MQA, optional qk-norm, M-RoPE)
* ``"swa"``     — sliding-window self-attention (banded; sub-quadratic)
* ``"rglru"``   — RG-LRU recurrent block (RecurrentGemma)
* ``"mamba"``   — Mamba-1 selective-scan block (attention free)

The feed-forward part of a block is dense or MoE depending on ``moe``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # Token capacity per expert = capacity_factor * tokens / n_experts.
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16       # N: per-channel state size
    expand: int = 2           # d_inner = expand * d_model
    conv_dim: int = 4         # depthwise causal conv width
    dt_rank: int = 0          # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0        # 0 -> d_model
    conv_dim: int = 4
    block_width: int = 0      # RG-LRU diagonal block size (unused placeholder)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int              # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int                 # per-expert d_ff when MoE
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    pattern: Tuple[str, ...] = ("attn",)   # repeating block-kind pattern
    moe: Optional[MoEConfig] = None
    # which pattern positions carry the MoE FFN (None -> all, when moe set);
    # llama4 interleaves dense and MoE layers 1:1
    moe_pattern: Optional[Tuple[bool, ...]] = None
    dense_ff: int = 0          # d_ff of non-MoE positions (0 -> d_ff)
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # Attention flavour flags.
    qk_norm: bool = False
    sliding_window: int = 0   # 0 -> full attention for "attn" kind
    local_window: int = 2048  # window for "swa" blocks / RG local attention
    mrope: bool = False       # M-RoPE (sections over head_dim; Qwen2-VL)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t, h, w half-dim splits
    non_parametric_ln: bool = False   # OLMo-style LN without learned scale
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # Modality frontend stub: "none" | "audio_tokens" | "vision_patches".
    frontend: str = "none"
    # Does the arch support O(seq) decode state (=> long_500k runnable)?
    subquadratic: bool = False

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return all(k == "mamba" for k in self.pattern)

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, pattern repeated/truncated to n_layers."""
        reps = (self.n_layers + len(self.pattern) - 1) // len(self.pattern)
        return tuple((self.pattern * reps)[: self.n_layers])

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), exact enough
        for MODEL_FLOPS bookkeeping."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # unembedding
        hd = self.resolved_head_dim
        for kind in self.block_kinds():
            if kind in ("attn", "swa"):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                # in/out proj + gates + conv
                total += 2 * d * w + 2 * w + w * self.rglru.conv_dim + 2 * w * w // 1
            elif kind == "mamba":
                di = self.ssm.expand * d
                dtr = self.ssm.dt_rank or -(-d // 16)
                total += (d * 2 * di            # in_proj (x and z)
                          + di * self.ssm.conv_dim
                          + di * (dtr + 2 * self.ssm.state_dim)  # x -> dt,B,C
                          + dtr * di            # dt_proj
                          + di * self.ssm.state_dim  # A
                          + di                  # D
                          + di * d)             # out_proj
            total += 2 * d  # norms
        # FFN params (kind- and position-aware)
        kinds = self.block_kinds()
        plen = len(self.pattern)
        for i, kind in enumerate(kinds):
            if kind == "mamba":
                continue
            is_moe = self.moe is not None and (
                self.moe_pattern is None or self.moe_pattern[i % plen])
            if is_moe:
                total += self.moe.n_experts * 3 * d * self.d_ff \
                    + d * self.moe.n_experts
            else:
                ff = self.dense_ff or self.d_ff
                if ff:
                    total += 3 * d * ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        plen = len(self.pattern)
        n_moe = sum(1 for i, k in enumerate(self.block_kinds())
                    if k in ("attn", "swa", "rglru") and (
                        self.moe_pattern is None or self.moe_pattern[i % plen]))
        all_experts = n_moe * self.moe.n_experts * 3 * d * self.d_ff
        active = n_moe * self.moe.top_k * 3 * d * self.d_ff
        return total - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention / bounded decode state."""
    if shape.name == "long_500k":
        return arch.subquadratic
    return True
