"""MusicGen-medium: decoder-only LM over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L, d_model=1536, 24 heads (kv=24 => MHA),
d_ff=6144, vocab=2048 (EnCodec codebook).  The audio frontend (EnCodec) is a
STUB: ``input_specs`` provides precomputed token ids; the backbone is the
deliverable.  MusicGen uses plain LayerNorm + learned positions in the
original; we keep the repo-standard pre-norm decoder (RMSNorm + RoPE) and
note the substitution — the communication substrate under test is identical.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio_tokens",
    subquadratic=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="musicgen-medium-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=128,
    )
