"""Matmul fused with ring reduce-scatter (producer-side P2P pipelining).

Computes ``Y = reduce_scatter(X @ W, axis)`` where every rank holds X (m, k_p)
— a column shard of the contraction — and W (k_p, n).  The ring walks the m
dimension in P chunks: at step i each rank multiplies the chunk that is
still (P-1-i) hops from its final owner, adds the partial sum received from
the left, and forwards — matmul and DMA overlap exactly as the paper's
burst-pipelined P2P (the partial-sum packet is the "burst", the add is the
consumer).  After P steps each rank holds its own fully-reduced (m/P, n).

Per-step receive regions and semaphores make the pipeline overrun-safe (a
rank ahead of its right neighbour never clobbers an unconsumed partial).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _rs_mm_kernel(axis_name, x_ref, w_ref, y_ref, send_buf, recv_buf,
                  send_sems, recv_sems):
    p = jax.lax.axis_index(axis_name)
    P = compat.axis_size(axis_name)
    right = jax.lax.rem(p + 1, P)
    mloc = y_ref.shape[0]

    def step(i, _):
        # chunk whose owner is (P-1-i) hops to the right of me
        chunk = jax.lax.rem(p + P - 1 - i + P, P)
        part = jnp.dot(x_ref[pl.ds(chunk * mloc, mloc), :], w_ref[...],
                       preferred_element_type=jnp.float32)

        @pl.when(i > 0)
        def _():
            # partial sum forwarded by the left neighbour for step i
            pltpu.make_async_copy(recv_buf.at[i], recv_buf.at[i],
                                  recv_sems.at[i]).wait()

        total = jax.lax.cond(
            i > 0, lambda: part + recv_buf[i], lambda: part)

        @pl.when(i < P - 1)
        def _():
            send_buf[jax.lax.rem(i, 2)] = total     # stage for sending
            rc = pltpu.make_async_remote_copy(
                src_ref=send_buf.at[jax.lax.rem(i, 2)],
                dst_ref=recv_buf.at[i + 1],
                send_sem=send_sems.at[jax.lax.rem(i, 2)],
                recv_sem=recv_sems.at[i + 1],
                device_id=compat.remote_device_id(right),
                device_id_type=pltpu.DeviceIdType.MESH)
            rc.start()
            rc.wait_send()

        @pl.when(i == P - 1)
        def _():
            y_ref[...] = total.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, P, step, 0)


def ring_reducescatter_matmul_local(x_local, w_local, *, axis_name: str,
                                    interpret=None):
    """Per-shard body (call inside shard_map).  x_local: (m, k_p), w_local:
    (k_p, n).  Returns (m/P, n): this rank's reduced output shard."""
    P = compat.axis_size(axis_name)
    m, kp = x_local.shape
    n = w_local.shape[1]
    assert m % P == 0
    mloc = m // P
    kernel = functools.partial(_rs_mm_kernel, axis_name)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((mloc, n), jnp.float32),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((2, mloc, n), jnp.float32),   # send staging
            pltpu.VMEM((P, mloc, n), jnp.float32),   # per-step recv regions
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((P,)),
        ],
        compiler_params=compat.compiler_params(
            collective_id=1, has_side_effects=True),
        interpret=interpret if interpret is not None else False,
    )(x_local, w_local)
