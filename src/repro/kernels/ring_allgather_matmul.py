"""Ring all-gather fused with matmul (P2P burst pipelining on the MXU).

Computes ``Y = X @ W`` where X is row-sharded over a ring axis: every step
multiplies the chunk already in VMEM while the same chunk streams onward to
the right neighbour via an async remote DMA — the paper's Fig. 6 mechanism
(consumer starts on burst k while burst k+1 is in flight) applied to the
tensor-parallel all-gather.  The pull-based handshake is the receive
semaphore: a chunk is consumed (dot-producted / forwarded) only after its
recv semaphore fires (consumption assumption, C1).

Race-freedom by construction: every chunk owns a distinct gather-buffer
region (written exactly once) and a distinct per-step semaphore — no slot
reuse, so a fast sender can run ahead without overrunning a slow receiver
(the deadlock-freedom argument the paper inherits from [18]).

VMEM budget: P*m*k (gather buffer) + k*n (W) + P*m*n (Y); callers pick
chunk sizes so this fits ~16 MB VMEM with 128-aligned matmul dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _ag_mm_kernel(axis_name, x_hbm, w_ref, y_ref, gbuf, send_sems, recv_sems,
                  local_sem):
    p = jax.lax.axis_index(axis_name)
    P = compat.axis_size(axis_name)
    right = jax.lax.rem(p + 1, P)
    m = x_hbm.shape[0]

    # stage my shard into my gather slot (IDMA/CDMA pair)
    local = pltpu.make_async_copy(x_hbm, gbuf.at[p], local_sem)
    local.start()
    local.wait()

    def step(i, _):
        cur = jax.lax.rem(p - i + P, P)      # chunk consumed this step

        @pl.when(i > 0)
        def _():
            # pull-side handshake: chunk `cur` arrived from the left
            pltpu.make_async_copy(gbuf.at[cur], gbuf.at[cur],
                                  recv_sems.at[i - 1]).wait()

        rc = pltpu.make_async_remote_copy(
            src_ref=gbuf.at[cur], dst_ref=gbuf.at[cur],
            send_sem=send_sems.at[i], recv_sem=recv_sems.at[i],
            device_id=compat.remote_device_id(right),
            device_id_type=pltpu.DeviceIdType.MESH)

        @pl.when(i < P - 1)
        def _():
            rc.start()          # overlap: forward in flight during the dot

        acc = jnp.dot(gbuf[cur], w_ref[...],
                      preferred_element_type=jnp.float32)
        y_ref[pl.ds(cur * m, m), :] = acc.astype(y_ref.dtype)

        @pl.when(i < P - 1)
        def _():
            rc.wait_send()
        return 0

    jax.lax.fori_loop(0, P, step, 0)


def ring_allgather_matmul_local(x_local, w, *, axis_name: str,
                                interpret=None):
    """Per-shard body (call inside shard_map).  x_local: (m, k) this rank's
    row shard; w: (k, n) replicated.  Returns (P*m, n) = full X @ W."""
    P = compat.axis_size(axis_name)
    m, k = x_local.shape
    n = w.shape[1]
    out_dtype = jnp.promote_types(x_local.dtype, w.dtype)
    kernel = functools.partial(_ag_mm_kernel, axis_name)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((P * m, n), out_dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),    # x stays in HBM; DMA'd
            pl.BlockSpec(memory_space=pltpu.VMEM),   # w resident in VMEM
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((P, m, k), x_local.dtype),    # gather buffer
            pltpu.SemaphoreType.DMA((P,)),           # per-step send
            pltpu.SemaphoreType.DMA((P,)),           # per-step recv
            pltpu.SemaphoreType.DMA,                 # local staging
        ],
        compiler_params=compat.compiler_params(
            collective_id=0, has_side_effects=True),
        interpret=interpret if interpret is not None else False,
    )(x_local, w)
