"""Pallas TPU kernels for the communication hot spots.

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py``; all are validated on CPU with ``compat.interpret_params()``
(``pltpu.InterpretParams`` where available — simulating VMEM, DMA, remote
copies, and semaphores — else the state-discharge interpreter; see
``docs/compat.md`` for the uniform-DMA constraint the latter imposes).

Paper mapping:
  ring_allgather_matmul  — pull-based P2P forwarding (C1) fused with the MXU
                           consumer: burst-granularity pipelining (Fig. 6's
                           mechanism) applied to the TP all-gather.
  ring_reducescatter_matmul — the mirrored producer side: partial-sum
                           forwarding overlapped with matmul.
  multicast_stream       — the multicast NoC (C2): one source, chunked
                           store-and-forward to every ring member (wormhole
                           burst pipelining across the ICI).
  dma_double_buffer      — the IDMA/CDMA ISA pair (C5): tag-based async DMA
                           with double-buffered load/compute overlap.
"""
