"""The paper's IDMA/CDMA ISA extension as a Pallas programming model (C5).

IDMA "specifies the necessary information for the read/write control
interfaces ... and returns a tag, which uniquely identifies the DMA
transaction"; CDMA "can use the tag ... to query the status".  On TPU the
exact analogue is an async copy whose *semaphore* is the tag:

    tag = idma(src_ref, dst_ref, sem)     # launch, returns the tag
    ... compute on other data ...
    cdma(tag)                             # wait for completion

``idma_remote`` is the P2P flavour (write channel with user field >= 1):
the destination lives on another chip and the send/recv semaphore pair
implements the pull-based consumption guarantee.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def idma(src_ref, dst_ref, sem):
    """Initiate DMA: start an async copy, return its tag."""
    tag = pltpu.make_async_copy(src_ref, dst_ref, sem)
    tag.start()
    return tag


def idma_remote(src_ref, dst_ref, send_sem, recv_sem, device_id,
                device_id_type=None):
    """Initiate a remote (P2P) DMA to ``device_id``; returns the tag."""
    if device_id_type is None:
        device_id_type = pltpu.DeviceIdType.MESH
    tag = pltpu.make_async_remote_copy(
        src_ref=src_ref, dst_ref=dst_ref, send_sem=send_sem,
        recv_sem=recv_sem, device_id=device_id,
        device_id_type=device_id_type)
    tag.start()
    return tag


def cdma(tag):
    """Check/complete DMA: block until the tagged transaction finishes.
    (Pallas semaphores expose blocking waits, not polling; the control-flow
    use in the paper — issue, compute, then check — maps to issuing the
    wait exactly where the data is first consumed.)"""
    tag.wait()
    return tag


def execute(instr, src_ref, dst_ref, sem=None, *, send_sem=None,
            recv_sem=None, device_id=None, device_id_type=None):
    """Kernel-side consumer of a :class:`repro.core.isa.DmaInstruction`:
    the user field selects the DMA flavour exactly as the paper's ISA
    extension specifies — ``user == 0`` is a local DMA to/from memory
    (``idma``); ``user >= 1`` is a remote transfer to the LUT-resolved
    peer (``idma_remote``).  ``device_id`` is the *physical* target the
    socket's registry resolved the instruction's virtual index to.
    Returns the transaction tag for ``cdma``.

    ``instr.user`` is static at kernel-build time (the instruction is
    encoded at the issue site, before lowering), so the dispatch is a
    plain Python branch, not traced control flow."""
    if instr.user == 0:
        assert sem is not None, "local IDMA needs a completion semaphore"
        return idma(src_ref, dst_ref, sem)
    assert send_sem is not None and recv_sem is not None and \
        device_id is not None, "remote IDMA needs send/recv sems + target"
    return idma_remote(src_ref, dst_ref, send_sem, recv_sem, device_id,
                       device_id_type)
