"""Pure-jnp oracles for every kernel (the correctness contracts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def allgather_matmul_ref(x_shards, w):
    """x_shards: (P, m, k) stacked row shards; w: (k, n).
    Every rank's expected output: concat(shards) @ w -> (P*m, n)."""
    P, m, k = x_shards.shape
    full = x_shards.reshape(P * m, k)
    return jnp.dot(full, w, preferred_element_type=jnp.float32)


def reducescatter_matmul_ref(x_shards, w_shards):
    """x_shards: (P, m, k_p); w_shards: (P, k_p, n).  Rank r's expected
    output: rows [r*m/P, (r+1)*m/P) of sum_p(x_p @ w_p) -> (P, m/P, n)."""
    P, m, kp = x_shards.shape
    full = jnp.einsum("pmk,pkn->mn", x_shards.astype(jnp.float32),
                      w_shards.astype(jnp.float32))
    return full.reshape(P, m // P, -1)


def multicast_ref(x_src, P):
    """Every rank receives the source payload."""
    return jnp.broadcast_to(x_src[None], (P,) + x_src.shape)


def dma_stream_ref(x, scale):
    xf = x.astype(jnp.float32) * scale
    return (xf * jax.nn.sigmoid(xf)).astype(x.dtype)
