"""Jit'd public wrappers: shard_map plumbing + interpret-mode selection.

On CPU (tests) pass ``interpret=interpret_params()``; on TPU leave the
default (compiled).  The collective wrappers build the shard_map over the
given mesh axis so callers hand in global arrays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import interpret_params  # re-export: tests use ops.interpret_params()
from repro.kernels.ring_allgather_matmul import ring_allgather_matmul_local
from repro.kernels.ring_reducescatter_matmul import ring_reducescatter_matmul_local
from repro.kernels.multicast_stream import multicast_stream_local
from repro.kernels.dma_double_buffer import dma_double_buffer_stream


def allgather_matmul(x, w, mesh, axis_name="x", *, interpret=None):
    """x: (M, k) row-sharded over ``axis_name``; w: (k, n) replicated.
    Returns (M, n) = x @ w, gathered on every rank."""
    fn = functools.partial(ring_allgather_matmul_local, axis_name=axis_name,
                           interpret=interpret)
    return jax.jit(compat.shard_map(
        lambda xs, ws: fn(xs, ws), mesh=mesh,
        in_specs=(P(axis_name, None), P(None, None)),
        out_specs=P(None, None), check_vma=False))(x, w)


def reducescatter_matmul(x, w, mesh, axis_name="x", *, interpret=None):
    """x: (m, K) column-sharded on K; w: (K, n) row-sharded on K.
    Returns (m, n) = x @ w with rows scattered over ranks."""
    fn = functools.partial(ring_reducescatter_matmul_local,
                           axis_name=axis_name, interpret=interpret)
    return jax.jit(compat.shard_map(
        lambda xs, ws: fn(xs, ws), mesh=mesh,
        in_specs=(P(None, axis_name), P(axis_name, None)),
        out_specs=P(axis_name, None), check_vma=False))(x, w)


def multicast(x, mesh, axis_name="x", src=0, n_chunks=4, *, interpret=None):
    """x: (m, n) source payload (replicated input; only rank ``src``'s value
    matters).  Returns (P*m, n): every rank's received copy, stacked."""
    fn = functools.partial(multicast_stream_local, axis_name=axis_name,
                           src=src, n_chunks=n_chunks, interpret=interpret)
    return jax.jit(compat.shard_map(
        lambda xs: fn(xs), mesh=mesh,
        in_specs=(P(None, None),),
        out_specs=P(axis_name, None), check_vma=False))(x)


def dma_stream(x, scale, n_blocks=4, *, interpret=None):
    """Single-device streaming op: y = silu(x * scale)."""
    return jax.jit(functools.partial(
        dma_double_buffer_stream, n_blocks=n_blocks, interpret=interpret))(
        x, jnp.asarray([scale], jnp.float32))
