"""Multicast stream: one source, chunked store-and-forward on a ring (C2).

The multicast NoC forks a message at routers so one injection serves all
destinations; on the ICI ring the analogue is store-and-forward pipelining:
the source streams the payload in chunks and every member forwards each
chunk to its right neighbour — after a P-hop latency fill, all links carry
payload concurrently (the wormhole/burst pipelining of Fig. 6).  Total time
~ (chunks + P) * chunk_time instead of P * message_time for repeated
unicasts.

The schedule is *uniform*: the ring runs R = P + n_chunks - 1 rounds and
every device issues exactly one remote DMA per round.  At round r the
device ``dist`` hops from the source forwards chunk ``c = r - dist``; when
that chunk index is out of range (pipeline fill/drain) or the device is the
last ring member, it still sends — into the receiver's scratch slot, so the
payload is untouched.  Uniformity buys two things: per-round semaphores
make the pipeline overrun-safe without per-device branching of the DMA
sequence (the deadlock-freedom argument the paper inherits from [18]), and
the kernel stays valid under the lockstep state-discharge interpreter of
older JAX (``compat.UNIFORM_DMA_INTERPRET``), where every remote DMA is a
collective all devices must issue and data advances one hop per round —
exactly this schedule.

Chunk granularity doubles as flow control: a member holds at most the one
chunk it has not yet forwarded (the consumption assumption, C1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _mcast_kernel(axis_name, src, n_chunks, x_hbm, y_ref, buf, send_sems,
                  recv_sems, stage_sem):
    p = jax.lax.axis_index(axis_name)
    P = compat.axis_size(axis_name)
    right = jax.lax.rem(p + 1, P)
    dist = jax.lax.rem(p - src + P, P)      # hops from the source
    rows = y_ref.shape[0] // n_chunks
    trash = n_chunks                        # scratch slot for fill/drain sends
    R = P + n_chunks - 1                    # total forwarding rounds

    @pl.when(dist == 0)
    def _():
        # source: stage payload chunks into the ring buffer (local IDMA)
        def stage(c, _):
            cp = pltpu.make_async_copy(
                x_hbm.at[pl.ds(c * rows, rows), :], buf.at[c], stage_sem)
            cp.start()
            cp.wait()
            return 0
        jax.lax.fori_loop(0, n_chunks, stage, 0)

    def step(r, _):
        @pl.when(r > 0)
        def _():
            # exactly one slot-sized message lands per device per round
            pltpu.make_async_copy(buf.at[trash], buf.at[trash],
                                  recv_sems.at[r - 1]).wait()

        c = r - dist                        # chunk scheduled for this round
        real = (c >= 0) & (c < n_chunks) & (dist < P - 1)
        c_src = jnp.clip(c, 0, n_chunks - 1)
        dst_slot = jnp.where(real, c_src, trash)
        rc = pltpu.make_async_remote_copy(
            src_ref=buf.at[c_src], dst_ref=buf.at[dst_slot],
            send_sem=send_sems.at[r], recv_sem=recv_sems.at[r],
            device_id=compat.remote_device_id(right),
            device_id_type=pltpu.DeviceIdType.MESH)
        rc.start()
        rc.wait_send()
        return 0

    jax.lax.fori_loop(0, R, step, 0)
    # drain the final round's arrival, then publish the assembled payload
    pltpu.make_async_copy(buf.at[trash], buf.at[trash],
                          recv_sems.at[R - 1]).wait()

    def publish(c, _):
        y_ref[pl.ds(c * rows, rows), :] = buf[c]
        return 0

    jax.lax.fori_loop(0, n_chunks, publish, 0)


def multicast_stream_local(x, *, axis_name: str, src: int = 0,
                           n_chunks: int = 4, interpret=None):
    """Per-shard body (call inside shard_map).  ``x``: (m, n) payload (only
    the source rank's value is used).  Returns (m, n) on every rank."""
    m, n = x.shape
    assert m % n_chunks == 0, f"rows {m} % chunks {n_chunks} != 0"
    P = compat.axis_size(axis_name)
    n_rounds = P + n_chunks - 1
    kernel = functools.partial(_mcast_kernel, axis_name, src, n_chunks)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((n_chunks + 1, m // n_chunks, n), x.dtype),
            pltpu.SemaphoreType.DMA((n_rounds,)),
            pltpu.SemaphoreType.DMA((n_rounds,)),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=compat.compiler_params(
            collective_id=2, has_side_effects=True),
        interpret=interpret if interpret is not None else False,
    )(x)
