"""Multicast stream: one source, chunked store-and-forward to a ring (C2).

The multicast NoC forks a message at routers so one injection serves all
destinations; on the ICI ring the analogue is store-and-forward pipelining:
the source streams the payload in chunks, every member forwards chunk c to
its right neighbour as soon as it arrives — after a P-hop latency fill, all
links carry payload concurrently (the wormhole/burst pipelining of Fig. 6).
Total time ~ (chunks + P) * chunk_time instead of P * message_time for
repeated unicasts.

Chunk granularity doubles as flow control: a member holds at most one chunk
it has not yet forwarded (the consumption assumption, C1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mcast_kernel(axis_name, src, n_chunks, x_ref, y_ref, send_sems,
                  recv_sems, local_sem):
    p = jax.lax.axis_index(axis_name)
    P = jax.lax.axis_size(axis_name)
    right = jax.lax.rem(p + 1, P)
    dist = jax.lax.rem(p - src + P, P)      # hops from the source
    rows = y_ref.shape[0] // n_chunks

    @pl.when(dist == 0)
    def _():
        # source: stage payload into the output buffer (local IDMA)
        cp = pltpu.make_async_copy(x_ref, y_ref, local_sem)
        cp.start()
        cp.wait()

    def step(c, _):
        chunk = y_ref.at[pl.ds(c * rows, rows), :]

        @pl.when(dist > 0)
        def _():
            # wait for chunk c from the left neighbour (per-chunk semaphore:
            # a fast upstream cannot alias credits onto a later chunk)
            pltpu.make_async_copy(chunk, chunk, recv_sems.at[c]).wait()

        @pl.when(dist < P - 1)
        def _():
            # forward chunk c onward (the router fork, serialized on a ring)
            rc = pltpu.make_async_remote_copy(
                src_ref=chunk, dst_ref=chunk,
                send_sem=send_sems.at[c], recv_sem=recv_sems.at[c],
                device_id=(right,), device_id_type=pltpu.DeviceIdType.MESH)
            rc.start()
            rc.wait_send()
        return 0

    jax.lax.fori_loop(0, n_chunks, step, 0)


def multicast_stream_local(x, *, axis_name: str, src: int = 0,
                           n_chunks: int = 4, interpret=None):
    """Per-shard body (call inside shard_map).  ``x``: (m, n) payload (only
    the source rank's value is used).  Returns (m, n) on every rank."""
    m, n = x.shape
    assert m % n_chunks == 0, f"rows {m} % chunks {n_chunks} != 0"
    kernel = functools.partial(_mcast_kernel, axis_name, src, n_chunks)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.SemaphoreType.DMA((n_chunks,)),
            pltpu.SemaphoreType.DMA((n_chunks,)),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=pltpu.CompilerParams(
            collective_id=2, has_side_effects=True),
        interpret=interpret if interpret is not None else False,
    )(x)
