"""Double-buffered streamed weights-gather matmul (IDMA/CDMA, C5).

The FSDP weight all-gather rides the memory path — a MEM verdict is the
round trip through HBM, and that round trip *is* the gather.  But a MEM
verdict need not be serial: the gathered operand streams VMEM-ward in
row blocks with block i+1's IDMA issued behind block i's consumer
matmul — the paper's C5 decoupling ("initiate a DMA to load data, do
some computation, and then query whether the DMA load is complete")
applied to the weight stream.  The planner prices this schedule as the
*streamed* MEM verdict (``PlanDecision.streamed``); the socket
dispatches it from :meth:`AcceleratorSocket.gather_matmul` when the
active plan streams the transfer.

Row-blocking the streamed operand keeps every output element's
contraction intact (each output row is one row-block's product), so the
streamed result is bit-identical to the unfused ``all_gather`` +
``jnp.dot`` reference — the fallback the socket's ladder degrades to.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dma_isa import idma, cdma


def _stream_matmul_kernel(n_blocks, rows, x_hbm, w_ref, y_ref, buf, sems):
    m = x_hbm.shape[0]

    def start(i):
        # clamp the fixed-size DMA window into bounds: an uneven final
        # block re-reads a few trailing rows of its predecessor and
        # rewrites their products with identical values
        return jnp.minimum(i * rows, m - rows)

    def dma(i, slot):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(start(i), rows), :], buf.at[slot], sems.at[slot])

    # prime the pipeline: IDMA block 0
    idma(x_hbm.at[pl.ds(0, rows), :], buf.at[0], sems.at[0])

    def step(i, _):
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_blocks)
        def _():
            # IDMA block i+1 while block i feeds the MXU
            idma(x_hbm.at[pl.ds(start(i + 1), rows), :], buf.at[nxt],
                 sems.at[nxt])

        # CDMA: block i must have landed before the matmul consumes it
        cdma(dma(i, slot))
        y_ref[pl.ds(start(i), rows), :] = jnp.dot(
            buf[slot], w_ref[...],
            preferred_element_type=jnp.float32).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, n_blocks, step, 0)


def streamed_gather_matmul(x_full, w, *, n_blocks: int = 4, interpret=None):
    """``x_full @ w`` with ``x_full`` (m, k) streamed from HBM in
    ``n_blocks`` double-buffered row blocks; ``w`` (k, n) resident in
    VMEM.  ``m`` need not divide evenly — the final block clamps its
    window (see the kernel).  Output dtype follows the promotion rule of
    the unfused reference (``jnp.dot`` at f32 accumulate)."""
    m, k = x_full.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x_full.shape} @ {w.shape}"
    rows = -(-m // n_blocks)          # ceil: the streamed block height
    out_dtype = jnp.promote_types(x_full.dtype, w.dtype)
    kernel = functools.partial(_stream_matmul_kernel, n_blocks, rows)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),        # stays in HBM
            pl.BlockSpec(memory_space=pltpu.VMEM),    # resident operand
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((2, rows, k), x_full.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret if interpret is not None else False,
    )(x_full, w)


def streamed_gather_matmul_local(x, w, *, axis_name: str,
                                 n_blocks: int = 4, interpret=None):
    """The socket's streamed-MEM gather site: gather the row shards over
    ``axis_name`` (the memory path — this hop is what the MEM verdict
    charges), then consume the gathered operand through the
    double-buffered stream so the HBM reads hide behind the matmul."""
    full = jax.lax.all_gather(x, axis_name, axis=0, tiled=True)
    return streamed_gather_matmul(full, w, n_blocks=n_blocks,
                                  interpret=interpret)
