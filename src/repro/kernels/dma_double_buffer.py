"""Double-buffered HBM->VMEM streaming kernel (IDMA/CDMA, C5).

The paper's example use: "the accelerator can initiate a DMA to load data,
do some computation, and then query whether the DMA load is complete".
Here: block i+1's IDMA is issued before block i's compute; CDMA (the tag
wait) happens only when block i+1 is first consumed — the classic
double-buffer schedule, written with the idma/cdma pair from
``kernels.dma_isa``.

The op computes y = silu(x * scale) row-block-wise — a stand-in for any
streaming elementwise consumer; the point is the explicit BlockSpec-free
manual DMA pipeline over VMEM slots.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dma_isa import idma, cdma


def _stream_kernel(n_blocks, rows, x_hbm, scale_ref, y_ref, buf, sems):
    m = x_hbm.shape[0]

    def start(i):
        # clamp the fixed-size window into bounds: when m is not divisible
        # by n_blocks the final (short) block re-reads a few trailing rows
        # of its predecessor and rewrites them with identical values — the
        # DMA window stays one static shape, the stream stays uneven-safe
        return jnp.minimum(i * rows, m - rows)

    def dma(i, slot):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(start(i), rows), :], buf.at[slot], sems.at[slot])

    # prime the pipeline: IDMA block 0
    idma(x_hbm.at[pl.ds(0, rows), :], buf.at[0], sems.at[0])

    def step(i, _):
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_blocks)
        def _():
            # IDMA the next block while this one computes
            idma(x_hbm.at[pl.ds(start(i + 1), rows), :], buf.at[nxt],
                 sems.at[nxt])

        # CDMA: block i must have landed before it is consumed
        cdma(dma(i, slot))
        xb = buf[slot].astype(jnp.float32) * scale_ref[0]
        y_ref[pl.ds(start(i), rows), :] = (
            xb * jax.nn.sigmoid(xb)).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, n_blocks, step, 0)


def dma_double_buffer_stream(x, scale, *, n_blocks: int = 4, interpret=None):
    """y = silu(x * scale), streamed in ``n_blocks`` double-buffered blocks.
    x: (m, n); scale: scalar array (1,).  ``m`` need not divide evenly:
    the final block is short — the stream clamps its window and rewrites
    the overlap with identical values (each output row is a function of
    its own input row only)."""
    m, n = x.shape
    rows = -(-m // n_blocks)          # ceil: the streamed block height
    kernel = functools.partial(_stream_kernel, n_blocks, rows)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),     # stays in HBM
            pl.BlockSpec(memory_space=pltpu.SMEM),    # scalar
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((2, rows, n), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret if interpret is not None else False,
    )(x, scale)
