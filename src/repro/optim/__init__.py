from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               cosine_schedule, clip_by_global_norm,
                               opt_state_axes)
from repro.optim.compression import (ef_int8_compress, ef_int8_decompress,
                                     compressed_psum)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
    "clip_by_global_norm", "opt_state_axes",
    "ef_int8_compress", "ef_int8_decompress", "compressed_psum",
]
