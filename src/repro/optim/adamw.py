"""AdamW with ZeRO-1-style sharded optimizer state.

The first/second-moment trees reuse each parameter's logical axes, so with
the FSDP rule active ("w_fsdp" -> data) the optimizer state is sharded over
*both* mesh axes — the ZeRO-1 partitioning — with zero extra code: the
sharding rules table (paper C4: route selection belongs to the platform, not
the model) decides.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    mu: Any       # first moment, same tree as params
    nu: Any       # second moment, same tree as params


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    """``moment_dtype=bf16`` halves optimizer memory — the difference
    between fitting and not fitting a 400B MoE's training state on a
    256-chip pod (12 B/param f32 vs 8 B/param mixed)."""
    zeros = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def opt_state_axes(axes_tree):
    """Logical axes for AdamWState given the params' axes tree."""
    return AdamWState(step=(), mu=axes_tree, nu=axes_tree)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, state: AdamWState, lr, *,
                 b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 max_grad_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mdt = m.dtype
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm,
                                                   "lr": lr_t}
