"""Error-feedback int8 gradient compression for the cross-pod axis.

The multi-pod mesh's "pod" axis crosses the slow inter-pod links; the
all-reduce there is the collective-term bottleneck for data parallelism at
512+ chips.  We compress pod-axis gradients to int8 with per-tensor scales
and keep the quantization residual locally (error feedback), which preserves
convergence (the residual is re-injected next step, making the compressor
unbiased in the long run).

This is a *beyond-paper* distributed-optimization feature; it composes with
the paper's mode system: the pod-axis int8 transfer is a real, priced
transfer — :data:`GRAD_REDUCE_COMPRESSED` below is its typed descriptor,
``compressed_psum`` issues the int32 combine through the socket's reduce
channel with the *on-wire* byte count (one byte per element: 4x fewer
bytes than f32, which is what can flip the planner's MEM<->MCAST verdict
for the pod axis), and the planner emits the matching
``grad_reduce_compressed`` :class:`~repro.core.planner.TransferSpec`
whenever the mesh has a pod axis.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.comm import TransferDescriptor
from repro.core.socket import socket_for_axis

# the pod-axis gradient combine: a fan-in reduction (the socket pins it to
# the memory path — the NoC cannot combine in flight) whose wire payload
# is int8 — word_bytes=1 is the whole point of the compressor
GRAD_REDUCE_COMPRESSED = TransferDescriptor(
    "grad_reduce_compressed", word_bytes=1,
    site="compression.grad_reduce_compressed")


def ef_int8_compress(g: jax.Array, residual: Optional[jax.Array] = None):
    """Returns (q int8, scale f32 scalar, new_residual)."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def ef_int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis_name: str,
                    residual: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8 all-reduce over ``axis_name`` with error feedback.

    The int8 payloads are summed in int32 (no overflow for pod counts < 2^24)
    and the scales max-reduced; 4x fewer bytes on the slow links than f32.
    The combine is issued through the socket's reduce channel under the
    :data:`GRAD_REDUCE_COMPRESSED` descriptor with ``wire_bytes`` set to
    the int8 payload, so the issue log (and commcheck's descriptor
    universe) prices what actually moves, not the widened accumulator.
    Returns (mean gradient f32, new residual to carry)."""
    g_ef = g.astype(jnp.float32)
    if residual is not None:
        g_ef = g_ef + residual
    local_scale = jnp.maximum(jnp.max(jnp.abs(g_ef)), 1e-30) / 127.0
    # shared scale (pmax) so all pods' int8 payloads are commensurate
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(g_ef / scale), -127, 127).astype(jnp.int8)
    s = socket_for_axis(axis_name).reduce(
        q.astype(jnp.int32), GRAD_REDUCE_COMPRESSED,
        wire_bytes=int(q.size))   # one byte per int8 element on the wire
    n = compat.axis_size(axis_name)
    mean = s.astype(jnp.float32) * scale / n
    new_res = g_ef - q.astype(jnp.float32) * scale
    return mean, new_res
