"""Mixture-of-Experts with communication-mode-selectable dispatch.

This is the framework-level reproduction of the paper's multicast NoC (C2)
and per-transfer mode control (C4):

* ``mode="mem"`` — the *shared-memory baseline* (paper Fig. 6 baseline):
  token activations are replicated across the model axis (the "round trip
  through memory"); every expert-owning shard locally selects the tokens
  routed to its experts and the partial outputs are combined with a full
  ``psum`` over the model axis.

* ``mode="mcast"`` — the *multicast/P2P path*: token activations live
  sequence-sharded on the model axis (SP); each source shard packs, per
  expert, a capacity-bounded buffer of routed tokens and a single
  ``all_to_all`` forwards every buffer to its expert's owner — one producer
  burst fanned out to k consumers, exactly the paper's multicast transfer
  (top-1 = unicast P2P, top-k = multicast).  Results return by the mirrored
  ``all_to_all``; no psum is needed.

Both paths share routing and expert compute, so tests assert they agree.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.comm import (CommMode, TransferDescriptor,
                             register_fusion_target)
from repro.core.socket import socket_for_axis
from repro.models.layers import _he

# the two transfers of the multicast dispatch path, as issued through the
# socket: the plan key is "moe_dispatch" for both (the combine all_to_all
# is the mirrored dispatch — the HLO analysis prices them under the same
# archetype); distinct site labels keep them apart in the issue log.  The
# dispatch declares the expert FFN as its consumer matmul (fused_with):
# the overlap objective prices its transfer hidden behind the expert
# einsums, and ``AcceleratorSocket.dispatch_expert_ffn`` dispatches the
# whole chain (dispatch -> FFN -> combine) as the ring pipeline when
# kernels are enabled — hop s+1 streams while slab s feeds the expert
# matmuls.  With kernels off the chain lowers the serial all_to_all pair
# (bit-identical; ``fused=False`` in the issue log).  The combine feeds
# the token scatter-add — no matmul of its own — so it stays undeclared
# and rides the chain's mirrored hop.
register_fusion_target("moe.expert_ffn")   # the expert gate/up/down einsums
DISPATCH_DESC = TransferDescriptor("moe_dispatch", site="moe.dispatch",
                                   fused_with="moe.expert_ffn")
COMBINE_DESC = TransferDescriptor("moe_dispatch", site="moe.combine")
COMBINE_REDUCE_DESC = TransferDescriptor("grad_reduce", site="moe.combine_psum")


def moe_init(key, cfg, dtype=jnp.float32):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _he(ks[0], (d, E), dtype),
        "w_gate": _he(ks[1], (E, d, ff), dtype, fan_in=d),
        "w_up": _he(ks[2], (E, d, ff), dtype, fan_in=d),
        "w_down": _he(ks[3], (E, ff, d), dtype, fan_in=ff),
    }


def moe_axes(cfg):
    return {
        "router": (None, None),
        "w_gate": ("experts", "w_fsdp", None),
        "w_up": ("experts", "w_fsdp", None),
        "w_down": ("experts", None, "w_fsdp"),
    }


def _route(router_w, x_flat, k):
    """Returns (gates (N, k), idx (N, k), aux_loss scalar)."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # GShard aux loss: E * mean_e(frac_tokens_e * mean_prob_e)
    E = probs.shape[-1]
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(onehot, axis=0) * jnp.mean(probs, axis=0))
    return gates, idx, aux


def _expert_ffn(wg, wu, wd, toks, compute_dtype):
    """toks (E_loc, C, d) through per-expert gated MLP."""
    t = toks.astype(compute_dtype)
    g = jnp.einsum("ecd,edf->ecf", t, wg.astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", t, wu.astype(compute_dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(compute_dtype))


def _select_for_experts(x_flat, gates, idx, experts, capacity):
    """For each expert id in `experts` (static int array), pick its top-
    `capacity` routed tokens by gate weight.

    Returns toks (E_sel, C, d), src (E_sel, C) token indices, w (E_sel, C)
    gate weights (0 where slot unused)."""
    N = x_flat.shape[0]

    def one_expert(e):
        # gate of token n for expert e (0 if not routed there)
        match = (idx == e)                           # (N, k)
        g = jnp.sum(jnp.where(match, gates, 0.0), axis=-1)   # (N,)
        w, src = jax.lax.top_k(g, capacity)          # capacity <= N enforced by caller
        valid = w > 0
        toks = jnp.take(x_flat, src, axis=0) * valid[:, None].astype(x_flat.dtype)
        return toks, src, jnp.where(valid, w, 0.0)

    return jax.vmap(one_expert)(experts)


def moe_apply(params, x, cfg, *, mode: str = "mem",
              model_axis: Optional[str] = "model",
              compute_dtype=jnp.bfloat16,
              use_kernels: bool = False, interpret=None):
    """x: (B, S_local_or_global, d) *inside* shard_map when model_axis is an
    active axis name, or a plain array when model_axis is None (single-device
    smoke-test path).  Returns (y, aux_loss).

    ``use_kernels``/``interpret`` forward to the socket: with kernels on,
    the mcast path's dispatch->FFN->combine chain dispatches as the ring
    pipeline (``AcceleratorSocket.dispatch_expert_ffn``); off, the same
    chain lowers the serial all_to_all pair — identical numbers."""
    B, S, d = x.shape
    k = cfg.moe.top_k
    E = cfg.moe.n_experts
    x_flat = x.reshape(B * S, d)
    N = B * S

    gates, idx, aux = _route(params["router"], x_flat, k)

    if model_axis is None:
        M, rank, E_loc = 1, 0, E
    else:
        M = compat.axis_size(model_axis)
        rank = jax.lax.axis_index(model_axis)
        assert E % M == 0, f"{E} experts not divisible by model axis {M}"
        E_loc = E // M

    capacity = max(1, min(N, int(cfg.moe.capacity_factor * N * k / E)))

    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]

    if mode == "mem" or model_axis is None:
        # shared-memory baseline: x is replicated over the model axis; each
        # shard computes its local experts' tokens and psums the combine.
        local_ids = jnp.arange(E_loc) + rank * E_loc
        toks, src, w = _select_for_experts(x_flat, gates, idx, local_ids, capacity)
        out_toks = _expert_ffn(wg, wu, wd, toks, compute_dtype)
        out_toks = out_toks * w[..., None].astype(out_toks.dtype)
        y = jnp.zeros((N, d), jnp.float32).at[src.reshape(-1)].add(
            out_toks.reshape(-1, d).astype(jnp.float32))
        if model_axis is not None:
            # bf16 combine: each token has at most top_k contributions, so
            # the psum is a short sum — half the wire/buffer of f32 (§Perf A3);
            # a reduction cannot combine in flight, so the socket pins it
            # to the memory path regardless of the plan
            sock = socket_for_axis(model_axis)
            y = sock.reduce(y.astype(jnp.bfloat16), COMBINE_REDUCE_DESC)
        return y.reshape(B, S, d).astype(x.dtype), aux

    if mode == "mcast":
        # multicast dispatch: pack per-expert capacity buffers for ALL
        # experts from the local (sequence-sharded) tokens, then forward
        # each buffer to the shard owning that expert.  The whole
        # dispatch -> expert FFN -> combine chain is ONE socket dispatch
        # (``dispatch_expert_ffn``): each source's per-expert buffers fan
        # out to the expert owners — the paper's multicast transfer
        # (top-1 = unicast degeneracy) — run as the overlapped ring
        # pipeline when kernels are on, the serial all_to_all pair
        # otherwise; the caller's mode choice rides in as the hint when
        # no plan is active.
        all_ids = jnp.arange(E)
        toks, src, w = _select_for_experts(x_flat, gates, idx, all_ids, capacity)
        sock = socket_for_axis(model_axis, use_kernels=use_kernels,
                               interpret=interpret)
        back = sock.dispatch_expert_ffn(
            toks.reshape(M, E_loc, capacity, d),
            lambda t: _expert_ffn(wg, wu, wd, t, compute_dtype),
            DISPATCH_DESC, COMBINE_DESC, hint=CommMode.MCAST)
        # back: (M, E_loc, C, d) == outputs for MY tokens, expert-major.
        back = back.reshape(E, capacity, d)
        back = back * w[..., None].astype(back.dtype)
        y = jnp.zeros((N, d), jnp.float32).at[src.reshape(-1)].add(
            back.reshape(-1, d).astype(jnp.float32))
        return y.reshape(B, S, d).astype(x.dtype), aux

    raise ValueError(f"unknown moe mode: {mode}")
