"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel):
    r_t = sigmoid(W_r u_t + b_r)           (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)           (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

where u is the depthwise-conv'd input branch.  The block output merges a
gelu-gated linear branch with h (Griffin's gated output).  Sequence handled
by the shared chunked linear scan; decode is an O(1) state update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding import logical_constraint
from repro.core.socket import mem_write
from repro.models.layers import _he
from repro.models.ssm import causal_conv1d, chunked_linear_scan

_C = 8.0


def rglru_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    Kw = cfg.rglru.conv_dim
    ks = jax.random.split(key, 6)
    return {
        "w_y": _he(ks[0], (d, w), dtype),
        "w_x": _he(ks[1], (d, w), dtype),
        "conv_w": (jax.random.normal(ks[2], (w, Kw)) * (Kw ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": _he(ks[3], (w, w), dtype, fan_in=w),
        "b_r": jnp.zeros((w,), dtype),
        "w_i": _he(ks[4], (w, w), dtype, fan_in=w),
        "b_i": jnp.zeros((w,), dtype),
        # softplus(Lambda) in (0.1, 1): a^c in a useful decay range
        "lam": jnp.full((w,), 0.54, dtype),  # softplus(0.54) ~ 1.0
        "w_o": _he(ks[5], (w, d), dtype, fan_in=w),
    }


def rglru_axes(cfg):
    return {
        "w_y": ("w_fsdp", "state"),
        "w_x": ("w_fsdp", "state"),
        "conv_w": ("state", None),
        "conv_b": ("state",),
        "w_r": (None, "state"),
        "b_r": ("state",),
        "w_i": (None, "state"),
        "b_i": ("state",),
        "lam": ("state",),
        "w_o": ("state", "w_fsdp"),
    }


def _gates_and_decay(params, u, compute_dtype):
    """u (B,S,w) -> (a (B,S,w) f32, gated_in (B,S,w) f32)."""
    uc = u.astype(compute_dtype)
    r = jax.nn.sigmoid(jnp.einsum(
        "bsw,wv->bsv", uc, params["w_r"].astype(compute_dtype),
        preferred_element_type=jnp.float32) + params["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum(
        "bsw,wv->bsv", uc, params["w_i"].astype(compute_dtype),
        preferred_element_type=jnp.float32) + params["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gated


def rglru_apply(params, x, cfg, state=None, *, chunk=256,
                compute_dtype=jnp.bfloat16):
    """x: (B, S, d).  state: {"h": (B,w), "conv": (B,K-1,w)}.
    Returns (y (B,S,d), new_state)."""
    B, S, d = x.shape
    w = cfg.rglru.lru_width or d
    Kw = cfg.rglru.conv_dim
    if state is None:
        state = {"h": jnp.zeros((B, w), jnp.float32),
                 "conv": jnp.zeros((B, Kw - 1, w), jnp.float32)}
    xc = x.astype(compute_dtype)
    y_branch = jax.nn.gelu(jnp.einsum(
        "bsd,dw->bsw", xc, params["w_y"].astype(compute_dtype),
        preferred_element_type=jnp.float32))
    u = jnp.einsum("bsd,dw->bsw", xc, params["w_x"].astype(compute_dtype),
                   preferred_element_type=jnp.float32)
    u = logical_constraint(u, ("batch", "seq", "state"))
    u, conv_state = causal_conv1d(u, params["conv_w"], params["conv_b"],
                                  state["conv"])
    a, gated = _gates_and_decay(params, u, compute_dtype)
    h_all, h_last = chunked_linear_scan(a, gated, state["h"], chunk)
    merged = (y_branch * h_all).astype(compute_dtype)
    merged = logical_constraint(merged, ("batch", "seq", "state"))
    out = jnp.einsum("bsw,wd->bsd", merged, params["w_o"].astype(compute_dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = mem_write(out, "rglru_output", ("batch", "seq", "embed"))
    return out, {"h": h_last, "conv": conv_state}


def rglru_decode_step(params, x, cfg, state, *, compute_dtype=jnp.bfloat16):
    """Single-token decode, O(1) state."""
    y, new_state = rglru_apply(params, x, cfg, state, chunk=1,
                               compute_dtype=compute_dtype)
    return y, new_state
