"""Attention: GQA/MHA/MQA, qk-norm, RoPE + M-RoPE, blockwise prefill/train
attention (pair-scan online softmax), banded sliding-window attention, and
single-token decode attention over a (possibly sequence-sharded) KV cache.

The pair-scan attention linearizes the (q-chunk, kv-chunk) iteration space to
*only the blocks that contain at least one unmasked element* (lower triangle
for causal; a diagonal band for SWA).  The pair list is computed statically
with numpy, so causal attention costs S(S+1)/2 block matmuls instead of S^2 —
this keeps HLO_FLOPs honest relative to MODEL_FLOPS.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.core.comm import (CommMode, TransferDescriptor,
                             register_fusion_target)
from repro.core.sharding import logical_constraint
from repro.core.socket import mem_write
from repro.models.layers import _he, rmsnorm

# Fused-transfer descriptor of the tensor-parallel o-projection: the
# partial head products combine on the ring as a matmul+reduce-scatter
# (FUSED_RING under ``use_kernels=True`` with a P2P verdict) instead of a
# serial all-reduce after the matmul.  Archetype "grad_scatter" matches
# the reduce-scatter the compiled HLO exhibits for this lowering.  The
# consumer matmul is registered explicitly even though the descriptor's
# own site label would resolve the self-loop at runtime — commcheck's
# ``fused-target-unregistered`` rule requires every fusion target to
# appear in a register_fusion_target() call, so the chain contract stays
# greppable.
register_fusion_target("attn.o_proj")      # the o-projection matmul
O_PROJ_DESC = TransferDescriptor("grad_scatter", site="attn.o_proj",
                                 fused_with="attn.o_proj")


def o_proj_tp(ctx_local, w_o_local, *, socket, out_dtype=None):
    """Tensor-parallel o-projection inside shard_map over the socket's
    stage axis: ``ctx_local`` (T, H_loc*hd) is this rank's head shard of
    the flattened attention context, ``w_o_local`` (H_loc*hd, d) the
    matching row shard of the output projection.  The per-rank partial
    products are combined hop-by-hop by the fused ring reduce-scatter —
    the transfer the overlap planner prices with the o-matmul's FLOPs —
    returning the (T/P, d) output sequence shard (f32 unless
    ``out_dtype``)."""
    y = socket.matmul_reduce_scatter(ctx_local, w_o_local, O_PROJ_DESC,
                                     hint=CommMode.P2P)
    return y if out_dtype is None else y.astype(out_dtype)


# ------------------------------------------------------------------ RoPE ----

def rope_inv_freq(head_dim: int, theta: float):
    return theta ** (-np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)


def apply_rope(x, pos, theta: float, mrope_sections=None):
    """x: (B, S, N, hd) — N heads or kv-heads.  pos: (B, S) int positions, or
    (B, S, 3) for M-RoPE (t/h/w components; sections are half-dim splits)."""
    B, S, N, hd = x.shape
    inv = jnp.asarray(rope_inv_freq(hd, theta))          # (hd/2,)
    if mrope_sections is not None:
        if pos.ndim == 2:  # text-only stub: t = h = w
            pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
        comps = []
        for idx, sec in enumerate(mrope_sections):
            comps.append(jnp.broadcast_to(pos[..., idx:idx + 1], (B, S, sec)))
        pos_f = jnp.concatenate(comps, axis=-1).astype(jnp.float32)  # (B,S,hd/2)
        ang = pos_f * inv[None, None, :]
    else:
        ang = pos.astype(jnp.float32)[..., None] * inv[None, None, :]  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- parameters ----

def attn_init(key, cfg, dtype=jnp.float32):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "w_q": _he(ks[0], (d, H, hd), dtype, fan_in=d),
        "w_k": _he(ks[1], (d, K, hd), dtype, fan_in=d),
        "w_v": _he(ks[2], (d, K, hd), dtype, fan_in=d),
        "w_o": _he(ks[3], (H, hd, d), dtype, fan_in=H * hd),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), dtype)
        p["k_scale"] = jnp.ones((hd,), dtype)
    return p


def attn_axes(cfg):
    a = {
        "w_q": ("w_fsdp", "heads", "head_dim"),
        "w_k": ("w_fsdp", "kv_heads", "head_dim"),
        "w_v": ("w_fsdp", "kv_heads", "head_dim"),
        "w_o": ("heads", "head_dim", "w_fsdp"),
    }
    if cfg.qk_norm:
        a["q_scale"] = ("head_dim",)
        a["k_scale"] = ("head_dim",)
    return a


def _project_qkv(params, x, cfg, pos, compute_dtype):
    """x (B,S,d) -> q (B,S,K,G,hd), k/v (B,S,K,hd), rope applied."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // K
    xc = x.astype(compute_dtype)
    q = jnp.einsum("bsd,dnh->bsnh", xc, params["w_q"].astype(compute_dtype))
    k = jnp.einsum("bsd,dnh->bsnh", xc, params["w_k"].astype(compute_dtype))
    v = jnp.einsum("bsd,dnh->bsnh", xc, params["w_v"].astype(compute_dtype))
    if cfg.qk_norm:
        q = rmsnorm({"scale": params["q_scale"]}, q)
        k = rmsnorm({"scale": params["k_scale"]}, k)
    sections = cfg.mrope_sections if cfg.mrope else None
    q = apply_rope(q, pos, cfg.rope_theta, sections)
    k = apply_rope(k, pos, cfg.rope_theta, sections)
    q = logical_constraint(q, ("batch", "seq", "heads", "head_dim"))
    k = logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = logical_constraint(v, ("batch", "seq", "kv_heads", "head_dim"))
    B, S = x.shape[:2]
    q = q.reshape(B, S, K, G, hd)
    return q, k, v


# ------------------------------------------------- pair-scan block attention

def _block_pairs(n_chunks: int, chunk: int, window: int):
    """Static (i, j) block pairs with >=1 unmasked element, plus a per-pair
    mask id into a SMALL constant mask table.

    Only O(window/chunk) distinct masks exist: off-diagonal interior blocks
    are fully unmasked (id 0), the diagonal is causal (id 1), and band-edge
    blocks share one mask per (i - j) offset.  Using a constant table +
    gather keeps XLA from precomputing a per-pair broadcast mask stack
    (observed: a (n_pairs, B, K, G, c, c) pred tensor carried through the
    scan — gigabytes at 32k prefill).

    window == 0 -> plain causal; else kv in (q - window, q]."""
    pairs = []
    offs_needing_mask = {}
    for i in range(n_chunks):
        for j in range(i + 1):
            if window and (i - j - 1) * chunk >= window:
                continue
            if i == j:
                mask_id = 1
            elif window and (i - j + 1) * chunk - 1 >= window:
                # band edge: some (q, kv) in the block violate the window
                off = i - j
                if off not in offs_needing_mask:
                    offs_needing_mask[off] = 2 + len(offs_needing_mask)
                mask_id = offs_needing_mask[off]
            else:
                mask_id = 0
            pairs.append((i, j, mask_id))
    idx = np.asarray(pairs, dtype=np.int32)

    n_masks = 2 + len(offs_needing_mask)
    pos = np.arange(chunk)
    table = np.ones((n_masks, chunk, chunk), dtype=bool)
    diag = pos[None, :] <= pos[:, None]
    if window:
        diag &= (pos[:, None] - pos[None, :]) < window
    table[1] = diag
    for off, mid in offs_needing_mask.items():
        q_pos = off * chunk + pos[:, None]
        table[mid] = (q_pos - pos[None, :]) < window
    return idx[:, 0], idx[:, 1], idx[:, 2], table


def blockwise_attention(q, k, v, *, chunk=512, window=0):
    out, _ = _blockwise_fwd_impl(q, k, v, chunk=chunk, window=window)
    return out


def _flat_heads(q, k, v):
    """(B,S,K,G,hd) q + (B,S,K,hd) kv -> flat-head (B,S,H,hd) bf16 triples
    with KV repeated.  Flat heads shard over the model axis (unevenly padded
    when H doesn't divide it — 1.8x waste for 9 heads on 16 ranks instead of
    16x replication); the repeat is cheap (KV is the small GQA operand)."""
    B, S, K, G, hd = q.shape
    qf = q.reshape(B, S, K * G, hd).astype(jnp.bfloat16)
    kf = jnp.repeat(k.astype(jnp.bfloat16), G, axis=2)
    vf = jnp.repeat(v.astype(jnp.bfloat16), G, axis=2)
    names = ("batch", "seq", "heads", "head_dim")
    return (logical_constraint(qf, names), logical_constraint(kf, names),
            logical_constraint(vf, names))


def _blockwise_fwd_impl(q, k, v, *, chunk=512, window=0):
    """Causal (optionally banded) attention via online softmax over static
    block pairs, flat-head layout.  q: (B,S,K,G,hd); k, v: (B,S,K,hd).
    Returns (out (B,S,K,G,hd), lse (n,B,H,chunk))."""
    B, S, K, G, hd = q.shape
    H = K * G
    chunk = min(chunk, S)
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    n = S // chunk
    i_arr, j_arr, mask_ids, mask_table = _block_pairs(n, chunk, window)
    scale = hd ** -0.5

    qf, kf, vf = _flat_heads(q, k, v)
    masks = jnp.asarray(mask_table)                  # (n_masks, c, c), tiny

    buf_names = (None, "batch", "heads", None)
    m0 = logical_constraint(
        jnp.full((n, B, H, chunk), -jnp.inf, jnp.float32), buf_names)
    l0 = logical_constraint(
        jnp.zeros((n, B, H, chunk), jnp.float32), buf_names)
    o0 = logical_constraint(
        jnp.zeros((n, B, H, chunk, hd), jnp.float32), buf_names + (None,))

    def body(carry, ij):
        m_buf, l_buf, o_buf = carry
        qi, kj, mid = ij
        qc = jax.lax.dynamic_slice_in_dim(qf, qi * chunk, chunk, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(kf, kj * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vf, kj * chunk, chunk, axis=1)
        s = jnp.einsum("bqnh,bsnh->bnqs", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = jax.lax.dynamic_index_in_dim(masks, mid, axis=0,
                                            keepdims=False)  # (c, c)
        s = jnp.where(mask[None, None], s, -jnp.inf)

        m_old = m_buf[qi]                                # (B,H,c)
        l_old = l_buf[qi]
        o_old = o_buf[qi]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        alpha = jnp.where(jnp.isneginf(m_old), 0.0, jnp.exp(m_old - m_safe))
        l_new = alpha * l_old + jnp.sum(p, axis=-1)
        o_new = alpha[..., None] * o_old + jnp.einsum(
            "bnqs,bsnh->bnqh", p.astype(jnp.bfloat16), vc,
            preferred_element_type=jnp.float32)
        return (m_buf.at[qi].set(m_new), l_buf.at[qi].set(l_new),
                o_buf.at[qi].set(o_new)), None

    (m_buf, l_buf, o_buf), _ = jax.lax.scan(
        body, (m0, l0, o0),
        (jnp.asarray(i_arr), jnp.asarray(j_arr), jnp.asarray(mask_ids)))
    lse = m_buf + jnp.log(jnp.maximum(l_buf, 1e-37))     # (n,B,H,chunk)
    out = o_buf / jnp.maximum(l_buf[..., None], 1e-37)   # (n,B,H,chunk,hd)
    out = jnp.moveaxis(out, 0, 1)                        # (B,n,H,chunk,hd)
    out = jnp.moveaxis(out, 3, 2)                        # (B,n,chunk,H,hd)
    return out.reshape(B, S, K, G, hd).astype(q.dtype), lse


# ------------------------------------------------------- flash custom_vjp ----

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, chunk=512, window=0):
    """Blockwise attention with a hand-written backward pass (flash).

    Differentiating the pair-*scan* forward would stack O(S^2) residuals per
    layer; the custom VJP saves only (q, k, v, out, lse) and recomputes each
    block's probabilities in the backward sweep — the flash-attention
    recipe, expressed as the same static block-pair scan."""
    return blockwise_attention(q, k, v, chunk=chunk, window=window)


def _flash_fwd(q, k, v, chunk, window):
    out, lse = _blockwise_fwd_impl(q, k, v, chunk=chunk, window=window)
    return out, (q, k, v, out, lse)


def _flash_bwd(chunk, window, res, g):
    q, k, v, out, lse = res
    B, S, K, G, hd = q.shape
    H = K * G
    chunk = min(chunk, S)
    n = S // chunk
    i_arr, j_arr, mask_ids, mask_table = _block_pairs(n, chunk, window)
    scale = hd ** -0.5
    masks = jnp.asarray(mask_table)

    qf, kf, vf = _flat_heads(q, k, v)
    gf = logical_constraint(
        g.reshape(B, S, H, hd).astype(jnp.bfloat16),
        ("batch", "seq", "heads", "head_dim"))
    # delta = rowsum(g * out): (B,S,H) -> chunked (n,B,H,c)
    delta = jnp.sum(g.astype(jnp.float32).reshape(B, S, H, hd) *
                    out.astype(jnp.float32).reshape(B, S, H, hd), axis=-1)
    delta = jnp.moveaxis(jnp.moveaxis(delta.reshape(B, n, chunk, H), 1, 0),
                         2, 3)                           # (n,B,H,c)

    buf_names = (None, "batch", "heads", None, None)
    dq0 = logical_constraint(
        jnp.zeros((n, B, H, chunk, hd), jnp.float32), buf_names)
    dk0 = logical_constraint(
        jnp.zeros((n, B, H, chunk, hd), jnp.float32), buf_names)
    dv0 = logical_constraint(
        jnp.zeros((n, B, H, chunk, hd), jnp.float32), buf_names)

    def body(carry, ij):
        dq_buf, dk_buf, dv_buf = carry
        qi, kj, mid = ij
        qc = jax.lax.dynamic_slice_in_dim(qf, qi * chunk, chunk, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(kf, kj * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vf, kj * chunk, chunk, axis=1)
        gc = jax.lax.dynamic_slice_in_dim(gf, qi * chunk, chunk, axis=1)
        lse_c = lse[qi]                                   # (B,H,c)
        delta_c = delta[qi]                               # (B,H,c)
        s = jnp.einsum("bqnh,bsnh->bnqs", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = jax.lax.dynamic_index_in_dim(masks, mid, axis=0,
                                            keepdims=False)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        p = jnp.exp(s - lse_c[..., None])                 # (B,H,c,c2)
        pb = p.astype(jnp.bfloat16)
        dv_c = jnp.einsum("bnqs,bqnh->bnsh", pb, gc).astype(jnp.float32)
        dp = jnp.einsum("bqnh,bsnh->bnqs", gc, vc).astype(jnp.float32)
        ds = p * (dp - delta_c[..., None]) * scale        # (B,H,c,c2) f32
        dsb = ds.astype(jnp.bfloat16)
        dq_c = jnp.einsum("bnqs,bsnh->bnqh", dsb, kc).astype(jnp.float32)
        dk_c = jnp.einsum("bnqs,bqnh->bnsh", dsb, qc).astype(jnp.float32)
        return (dq_buf.at[qi].add(dq_c), dk_buf.at[kj].add(dk_c),
                dv_buf.at[kj].add(dv_c)), None

    (dq_buf, dk_buf, dv_buf), _ = jax.lax.scan(
        body, (dq0, dk0, dv0),
        (jnp.asarray(i_arr), jnp.asarray(j_arr), jnp.asarray(mask_ids)))

    def unchunk(buf):  # (n,B,H,c,hd) -> (B,S,H,hd)
        return jnp.moveaxis(jnp.moveaxis(buf, 0, 1), 2, 3).reshape(
            B, S, H, hd)

    dq = unchunk(dq_buf).reshape(B, S, K, G, hd)
    dk = jnp.sum(unchunk(dk_buf).reshape(B, S, K, G, hd), axis=3)
    dv = jnp.sum(unchunk(dv_buf).reshape(B, S, K, G, hd), axis=3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def full_attention(q, k, v, *, window=0):
    """Unchunked causal attention (training path).  The (S, S) score matrix
    is transient under the per-layer remat policy; differentiating it is
    cheap recompute, whereas differentiating the pair-*scan* would stack
    O(S^2) residuals per iteration (observed: 5.4 GB x 1080 loop bodies).

    Sharding: KV is repeated to the full head count so the score tensor can
    shard cleanly over flat heads (classic GQA tensor parallelism).  When
    the head count does not divide the model axis (smollm: 9 heads,
    musicgen: 24, llama4: 40 on a 16-way axis) we fall back to *sequence*
    parallelism over the q dimension — S is divisible for every assigned
    shape, so the score matrix always shards instead of replicating
    (observed otherwise: 9.7 GB/device f32 scores for smollm).

    q: (B,S,K,G,hd); k, v: (B,S,K,hd)."""
    from repro.core.sharding import current_mesh
    B, S, K, G, hd = q.shape
    H = K * G
    qf = q.reshape(B, S, H, hd).astype(jnp.bfloat16)
    kf = jnp.repeat(k.astype(jnp.bfloat16), G, axis=2)
    vf = jnp.repeat(v.astype(jnp.bfloat16), G, axis=2)

    mesh = current_mesh()
    msize = mesh.shape.get("model") if mesh is not None and \
        "model" in mesh.axis_names else 0
    if msize and H % msize == 0:
        s_names = ("batch", "heads", None, None)      # (B, H, Sq, Skv)
        ctx_names = ("batch", None, "heads", "head_dim")
    elif msize and S % msize == 0:
        s_names = ("batch", None, "seq_sp", None)     # shard q rows
        ctx_names = ("batch", "seq_sp", "heads", "head_dim")
    else:
        s_names = ("batch", None, None, None)
        ctx_names = ("batch", None, None, None)

    s = jnp.einsum("bqnh,bsnh->bnqs", qf, kf,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    s = logical_constraint(s, s_names)
    pos = np.arange(S)
    mask = pos[None, :] <= pos[:, None]
    if window:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(jnp.asarray(mask)[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bnqs,bsnh->bqnh", p.astype(jnp.bfloat16), vf,
                     preferred_element_type=jnp.float32)
    ctx = logical_constraint(ctx, ctx_names)
    return ctx.reshape(B, S, K, G, hd).astype(q.dtype)


def attn_apply(params, x, cfg, pos, *, chunk=512, compute_dtype=jnp.bfloat16,
               window=0, impl="blockwise"):
    """Full train/prefill attention for one block.  Returns (y, (k, v))."""
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, x, cfg, pos, compute_dtype)
    if impl == "full" or S <= chunk:
        ctx = full_attention(q, k, v, window=window)
    elif impl == "flash":
        ctx = flash_attention(q, k, v, chunk, window)
    else:
        ctx = blockwise_attention(q, k, v, chunk=chunk, window=window)
    # bf16-out o-projection: its model-axis all-reduce moves half the bytes
    # of the f32 version (observed 3.7 TB/device/step of f32 all-reduce
    # wire on qwen2-vl train before this change) — §Perf iteration C1.
    y = jnp.einsum("bskgh,kghd->bsd",
                   ctx.astype(compute_dtype),
                   params["w_o"].astype(compute_dtype).reshape(K, H // K, hd, d)
                   ).astype(x.dtype)
    y = mem_write(y, "attn_output", ("batch", "seq", "embed"))
    # tagged for the save_collectives remat policy (§Perf C2)
    y = checkpoint_name(y, "post_collective")
    return y, (k, v)


# ------------------------------------------------------- decode attention ----

def decode_attn_apply(params, x, cfg, cache, pos_scalar, *,
                      compute_dtype=jnp.bfloat16, window=0):
    """One-token decode.  x: (B, 1, d).  cache: {"k","v"}: (B, Skv, K, hd)
    (ring buffer of size `window` when window>0, else full seq).  pos_scalar:
    scalar int32 absolute position of the new token — or a (B,) int32 vector
    of per-row positions (continuous batching: each slot in the batch is a
    different request at a different depth).  Returns (y, new_cache).

    Slots past a row's position are masked out of the softmax: a freshly
    allocated (zero) cache tail must not contribute exp(0-m) mass to the
    denominator.  For a full ring (pos + 1 >= Skv) every slot is valid and
    the mask is the identity, so the pre-filled single-request contract is
    unchanged.

    The KV cache's Skv dim carries the "kv_seq" logical axis (sequence-sharded
    over the model axis by the serve rules); softmax reductions over it lower
    to small all-reduces — the paper's sync-region pattern: tiny control
    payloads (m, l statistics) on the fast path, bulk (cache) stays put.
    """
    B, _, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // K
    pos_b = jnp.reshape(
        jnp.broadcast_to(jnp.asarray(pos_scalar, jnp.int32), (B,)), (B, 1))
    q, k_new, v_new = _project_qkv(params, x, cfg, pos_b, compute_dtype)

    Skv = cache["k"].shape[1]
    slot = jnp.mod(pos_b, Skv) if window else jnp.minimum(pos_b, Skv - 1)
    # One-hot update instead of dynamic-update-slice: a DUS at a dynamic
    # index on the sequence-SHARDED cache dim forces GSPMD into full-cache
    # gather/select patterns; the where(iota == slot) form shards cleanly
    # (each shard compares its local iota against the global slot).
    iota_s = jax.lax.broadcasted_iota(jnp.int32, (1, Skv, 1, 1), 1)
    sel = iota_s == slot[:, :, None, None]
    k_cache = jnp.where(sel, k_new.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(sel, v_new.astype(cache["v"].dtype), cache["v"])
    k_cache = logical_constraint(k_cache, ("batch", "kv_seq", "kv_heads", "head_dim"))
    v_cache = logical_constraint(v_cache, ("batch", "kv_seq", "kv_heads", "head_dim"))

    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(compute_dtype),
                   k_cache.astype(compute_dtype),
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    # slots written so far: min(pos + 1, Skv) — the whole ring once full
    # (the pre-filled serve_step contract), a prefix while a paged/slot
    # cache is still growing.  s: (B, K, G, 1, Skv).
    n_valid = jnp.minimum(pos_b[:, :1] + 1, Skv)          # (B, 1)
    valid = (jax.lax.broadcasted_iota(jnp.int32, (1, Skv), 1)
             < n_valid)[:, None, None, None, :]           # (B,1,1,1,Skv)
    s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    ctx = jnp.einsum("bkgqs,bskh->bqkgh", (p / l).astype(compute_dtype),
                     v_cache.astype(compute_dtype),
                     preferred_element_type=jnp.float32)
    y = jnp.einsum("bqkgh,kghd->bqd", ctx.astype(compute_dtype),
                   params["w_o"].astype(compute_dtype).reshape(K, G, hd, d),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = logical_constraint(y, ("batch", None, "embed"))
    return y, {"k": k_cache, "v": v_cache}
