"""Shared neural-net substrate: norms, gated MLP, embeddings, chunked CE.

Functional style: ``*_init(key, ...) -> params`` (dict pytree) with a twin
``*_axes(...) -> logical-axis pytree`` of identical structure, used by the
distribution layer to build PartitionSpecs.  Compute dtype is bf16 by
default with f32 accumulation; params are stored in the dtype chosen by the
runtime (f32 train / bf16 serve).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.core.comm import (CommMode, TransferDescriptor,
                             register_fusion_target)
from repro.core.sharding import logical_constraint
from repro.core.socket import mem_write


def _he(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


# ---------------------------------------------------------------- norms ----

def rmsnorm_init(d, dtype=jnp.float32, parametric=True):
    return {"scale": jnp.ones((d,), dtype)} if parametric else {}


def rmsnorm_axes(parametric=True):
    return {"scale": ("embed",)} if parametric else {}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    if "scale" in params:
        x32 = x32 * params["scale"].astype(jnp.float32)
    return x32.astype(dt)


def layernorm_np(x, eps=1e-5):
    """Non-parametric LayerNorm (OLMo)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def make_norm(cfg):
    """Returns (init, axes, apply) for the arch's norm flavour."""
    if cfg.non_parametric_ln:
        return (lambda d, dtype: {}), (lambda: {}), (lambda p, x: layernorm_np(x))
    return (lambda d, dtype: rmsnorm_init(d, dtype),
            lambda: rmsnorm_axes(),
            lambda p, x: rmsnorm(p, x))


# ------------------------------------------------------------ gated MLP ----

def mlp_init(key, d, ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _he(k1, (d, ff), dtype),
        "w_up": _he(k2, (d, ff), dtype),
        "w_down": _he(k3, (ff, d), dtype, fan_in=ff),
    }


def mlp_axes():
    return {
        "w_gate": ("w_fsdp", "mlp"),
        "w_up": ("w_fsdp", "mlp"),
        "w_down": ("mlp", "w_fsdp"),
    }


# Fused-transfer descriptors of the tensor-parallel MLP (the FUSED_RING
# call sites): the sequence-gather feeding the up/gate matmuls and the
# down projection's matmul+reduce-scatter.  Archetype names match what
# the compiled HLO exhibits (all-gather -> "weights", reduce-scatter ->
# "grad_scatter" — see launch/hlo_analysis) so planned and issued modes
# line up in artifacts; ``fused_with`` declares the consumer matmul the
# overlap objective hides each transfer behind.
register_fusion_target("mlp.up_proj")     # the up/gate matmul pair
register_fusion_target("mlp.down_proj")   # the down-projection matmul
MLP_GATHER_DESC = TransferDescriptor("weights", site="mlp.up_gather",
                                     fused_with="mlp.up_proj")
MLP_DOWN_DESC = TransferDescriptor("grad_scatter", site="mlp.down_proj",
                                   fused_with="mlp.down_proj")


def mlp_apply_tp(params, x_local, *, socket, compute_dtype=jnp.bfloat16):
    """Tensor-parallel gated MLP inside shard_map over the socket's stage
    axis (Megatron sequence-parallel): ``x_local`` (t_loc, d) is this
    rank's sequence shard, ``w_gate``/``w_up`` arrive column-sharded
    (d, ff_loc) and ``w_down`` row-sharded (ff_loc, d).

    Both collective sites issue through the socket as *fused* transfers:
    one ring all-gather feeds the up AND gate matmuls (the two column
    shards concatenate into a single (d, 2*ff_loc) operand), and the down
    projection is a matmul+reduce-scatter — under ``use_kernels=True``
    with a P2P verdict each dispatches the FUSED_RING kernel (comm
    overlapped with the MXU); otherwise the unfused lax path runs with
    identical numbers.  Returns the (t_loc, d) output sequence shard."""
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    ff = wg.shape[1]
    gu = socket.gather_matmul(x_local.astype(compute_dtype),
                              jnp.concatenate([wg, wu], axis=1),
                              MLP_GATHER_DESC, hint=CommMode.P2P)
    g, u = gu[:, :ff], gu[:, ff:]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * \
        u.astype(compute_dtype)
    y = socket.matmul_reduce_scatter(h, wd, MLP_DOWN_DESC,
                                     hint=CommMode.P2P)
    return checkpoint_name(y.astype(x_local.dtype), "post_collective")


def mlp_apply(params, x, compute_dtype=jnp.bfloat16):
    xc = x.astype(compute_dtype)
    g = xc @ params["w_gate"].astype(compute_dtype)
    u = xc @ params["w_up"].astype(compute_dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    h = logical_constraint(h, ("batch", "seq", "mlp"))
    # bf16-out row-parallel matmul (§Perf C1: refuted — XLA already sank
    # the convert below the all-reduce; kept for clarity).  The
    # checkpoint_name tag enables the "save_collectives" remat policy
    # (§Perf C2): recompute inside the backward does NOT re-run the
    # all-reduce that this output carries.
    y = (h @ params["w_down"].astype(compute_dtype)).astype(x.dtype)
    return checkpoint_name(y, "post_collective")


# ------------------------------------------------------------ embeddings ----

def embedding_init(key, vocab, d, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * (d ** -0.5)).astype(dtype)}


def embedding_axes():
    return {"table": ("vocab", "w_embed")}


def embed_tokens(params, ids, compute_dtype=jnp.bfloat16):
    out = jnp.take(params["table"].astype(compute_dtype), ids, axis=0)
    return mem_write(out, "embed_output", ("batch", "seq", "embed"))


# ------------------------------------------- chunked cross-entropy loss ----

def chunked_ce_loss(unembed, h, labels, chunk=512, compute_dtype=jnp.bfloat16):
    """Cross-entropy over a model-axis-sharded vocabulary, scanned over the
    sequence in ``chunk``-sized slices so the full (B, S, V) logits tensor is
    never materialized.  Returns mean loss over all positions.

    unembed: (V, d) table (vocab sharded).  h: (B, S, d).  labels: (B, S).
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0, f"seq {S} not divisible by CE chunk {chunk}"
    wt = unembed.astype(compute_dtype).T  # (d, V)

    def body(acc, idx):
        hs = jax.lax.dynamic_slice_in_dim(h, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = jnp.einsum("bcd,dv->bcv", hs.astype(compute_dtype), wt,
                            preferred_element_type=jnp.float32)
        logits = logical_constraint(logits, ("batch", "seq", "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - ll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(n))
    return total / (B * S)


def decode_logits(unembed, h, compute_dtype=jnp.bfloat16):
    """(B, 1, d) -> (B, 1, V) logits for a single decode position."""
    logits = jnp.einsum("btd,vd->btv", h.astype(compute_dtype),
                        unembed.astype(compute_dtype),
                        preferred_element_type=jnp.float32)
    return logical_constraint(logits, ("batch", None, "vocab"))
