"""Mamba-1 selective-scan block (Falcon-Mamba), TPU-adapted.

The CUDA selective-scan kernel fuses the recurrence in SRAM.  The TPU-native
adaptation chunks the sequence (``chunk`` tokens at a time) and runs a
log-depth ``associative_scan`` *within* each chunk while carrying the SSM
state across chunks with ``lax.scan`` — the (B, S, d_inner, N) discretized
tensors only ever exist one chunk at a time (VMEM-sized working set), which
is the same blocking insight rethought for the HBM->VMEM hierarchy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding import logical_constraint
from repro.core.socket import mem_write
from repro.models.layers import _he


def chunked_linear_scan(a, b, h0, chunk):
    """h_t = a_t * h_{t-1} + b_t  along axis=1 of (B, S, ...) tensors.
    Returns (h_all (B, S, ...), h_last (B, ...))."""
    B, S = a.shape[:2]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    rest = a.shape[2:]
    a_r = jnp.moveaxis(a.reshape(B, n, chunk, *rest), 1, 0)
    b_r = jnp.moveaxis(b.reshape(B, n, chunk, *rest), 1, 0)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    def body(h, xs):
        ac, bc = xs
        acum, bcum = jax.lax.associative_scan(op, (ac, bc), axis=1)
        h_t = acum * h[:, None] + bcum
        return h_t[:, -1], h_t

    h_last, ys = jax.lax.scan(body, h0, (a_r, b_r))
    h_all = jnp.moveaxis(ys, 0, 1).reshape(B, S, *rest)
    return h_all, h_last


def causal_conv1d(x, w, b, state):
    """Depthwise causal conv.  x: (B, S, C), w: (C, K), state: (B, K-1, C)
    carry-in.  Returns (y (B, S, C), new_state (B, K-1, C))."""
    Kw = w.shape[1]
    xpad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xpad[:, j:j + S] * w[:, j].astype(x.dtype) for j in range(Kw))
    if b is not None:
        y = y + b.astype(x.dtype)
    new_state = xpad[:, -(Kw - 1):] if Kw > 1 else state
    return y, new_state


def mamba_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    N = cfg.ssm.state_dim
    dtr = cfg.ssm.dt_rank or -(-d // 16)
    Kw = cfg.ssm.conv_dim
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": _he(ks[0], (d, 2 * di), dtype),
        "conv_w": (jax.random.normal(ks[1], (di, Kw)) * (Kw ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _he(ks[2], (di, dtr + 2 * N), dtype, fan_in=di),
        "dt_proj": _he(ks[3], (dtr, di), dtype, fan_in=dtr),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": _he(ks[4], (di, d), dtype, fan_in=di),
    }


def mamba_axes(cfg):
    return {
        "in_proj": ("w_fsdp", "state"),
        "conv_w": ("state", None),
        "conv_b": ("state",),
        "x_proj": ("state", None),
        "dt_proj": (None, "state"),
        "dt_bias": ("state",),
        "A_log": ("state", None),
        "D": ("state",),
        "out_proj": ("state", "w_fsdp"),
    }


def _discretize(params, x_conv, cfg, compute_dtype):
    """x_conv (B, C, di) -> (dt (B,C,di), B_ssm (B,C,N), C_ssm (B,C,N)) f32."""
    N = cfg.ssm.state_dim
    dtr = cfg.ssm.dt_rank or -(-cfg.d_model // 16)
    dbc = jnp.einsum("bsd,dk->bsk", x_conv.astype(compute_dtype),
                     params["x_proj"].astype(compute_dtype),
                     preferred_element_type=jnp.float32)
    dt_lr, B_ssm, C_ssm = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_lr.astype(compute_dtype),
                    params["dt_proj"].astype(compute_dtype),
                    preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))
    return dt, B_ssm, C_ssm


def mamba_apply(params, x, cfg, state=None, *, chunk=128,
                compute_dtype=jnp.bfloat16):
    """Full-sequence Mamba block.  x: (B, S, d).  state: optional carry-in
    {"h": (B, di, N), "conv": (B, K-1, di)}.  Returns (y, new_state)."""
    B, S, d = x.shape
    di = cfg.ssm.expand * d
    N = cfg.ssm.state_dim
    Kw = cfg.ssm.conv_dim
    if state is None:
        state = {"h": jnp.zeros((B, di, N), jnp.float32),
                 "conv": jnp.zeros((B, Kw - 1, di), jnp.float32)}

    xz = jnp.einsum("bsd,de->bse", x.astype(compute_dtype),
                    params["in_proj"].astype(compute_dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = logical_constraint(x_in, ("batch", "seq", "state"))
    x_conv, conv_state = causal_conv1d(x_in, params["conv_w"], params["conv_b"],
                                       state["conv"])
    x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(compute_dtype)

    dt, B_ssm, C_ssm = _discretize(params, x_conv, cfg, compute_dtype)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))   # (di, N)
    xf = x_conv.astype(jnp.float32)

    n = S // min(chunk, S)
    c = S // n

    def body(h, xs):
        dt_c, B_c, C_c, x_c = xs    # (B,c,di), (B,c,N), (B,c,N), (B,c,di)
        dA = jnp.exp(dt_c[..., None] * A[None, None])            # (B,c,di,N)
        dBx = dt_c[..., None] * B_c[:, :, None, :] * x_c[..., None]

        def op(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        acum, bcum = jax.lax.associative_scan(op, (dA, dBx), axis=1)
        h_t = acum * h[:, None] + bcum                            # (B,c,di,N)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_t, C_c)
        return h_t[:, -1], y_c

    def split_chunks(t):
        return jnp.moveaxis(t.reshape(B, n, c, *t.shape[2:]), 1, 0)

    h_last, ys = jax.lax.scan(
        body, state["h"],
        (split_chunks(dt), split_chunks(B_ssm), split_chunks(C_ssm),
         split_chunks(xf)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = y + params["D"].astype(jnp.float32) * xf
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = logical_constraint(y.astype(compute_dtype), ("batch", "seq", "state"))
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(compute_dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = mem_write(out, "ssm_output", ("batch", "seq", "embed"))
    return out, {"h": h_last, "conv": conv_state}


def mamba_decode_step(params, x, cfg, state, *, compute_dtype=jnp.bfloat16):
    """Single-token decode.  x: (B, 1, d).  O(1) state update."""
    B, _, d = x.shape
    di = cfg.ssm.expand * d
    Kw = cfg.ssm.conv_dim
    xz = jnp.einsum("bsd,de->bse", x.astype(compute_dtype),
                    params["in_proj"].astype(compute_dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = causal_conv1d(x_in, params["conv_w"], params["conv_b"],
                                       state["conv"])
    x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(compute_dtype)
    dt, B_ssm, C_ssm = _discretize(params, x_conv, cfg, compute_dtype)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xf = x_conv.astype(jnp.float32)
    dA = jnp.exp(dt[:, 0, :, None] * A[None])                    # (B,di,N)
    dBx = dt[:, 0, :, None] * B_ssm[:, 0, None, :] * xf[:, 0, :, None]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_ssm[:, 0])[:, None]
    y = y + params["D"].astype(jnp.float32) * xf
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(compute_dtype),
                     params["out_proj"].astype(compute_dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"h": h, "conv": conv_state}
