"""Arch-config-driven decoder stack.

Blocks are grouped by the config's repeating ``pattern`` and scanned with
``jax.lax.scan`` over stacked parameters (keeps HLO size O(1) in depth, which
matters for 64-80 layer dry-runs).  Remainder layers (pattern not dividing
n_layers, e.g. RecurrentGemma's 38 = 12*3 + 2) run unscanned.

Three entry points: ``forward_train`` (loss), ``prefill`` (cache build +
last-position logits), ``decode_step`` (one token through the cache).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.comm import CommMode
from repro.core.sharding import (current_comm_plan, current_mesh,
                                 logical_to_pspec)
from repro.core.socket import mem_write, socket_for_axis
from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as SSM
from repro.models import griffin as G


@dataclasses.dataclass(frozen=True)
class RunFlags:
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    opt_dtype: Any = jnp.float32     # bf16 halves optimizer memory (400B fit)
    cache_dtype: Any = jnp.bfloat16
    remat: str = "full"          # "none" | "full"
    moe_mode: str = "mem"        # "mem" | "mcast"  (paper comm modes)
    distributed: bool = False    # use shard_map for MoE dispatch
    attn_impl: str = "flash"     # "flash" (custom-vjp blockwise) | "full" | "blockwise"
    attn_chunk: int = 512
    ssm_chunk: int = 128
    ce_chunk: int = 512
    aux_loss_coef: float = 0.01
    # route the dense-MLP blocks through the socket's fused-matmul issue
    # sites (shard_map over the model axis; see models.layers.mlp_apply_tp)
    ffn_tp: bool = False
    # dispatch the Pallas comm kernels (multicast stream, FUSED_RING) at
    # socket sites that qualify; kernel_interpret forwards interpret-mode
    # params on CPU (tests pass compat.interpret_params())
    use_comm_kernels: bool = False
    kernel_interpret: Any = None


# ------------------------------------------------------------- block defs ----

def _ffn_kind(cfg: ArchConfig, kind: str, pos: int = 0) -> Optional[str]:
    """pos = position within the repeating pattern (llama4 interleaves
    dense and MoE FFNs via cfg.moe_pattern)."""
    if kind == "mamba" or (cfg.d_ff == 0 and cfg.dense_ff == 0):
        return None
    if cfg.moe is not None and (cfg.moe_pattern is None or
                                cfg.moe_pattern[pos % len(cfg.pattern)]):
        return "moe"
    return "mlp"


def block_init(key, cfg: ArchConfig, kind: str, dtype, pos: int = 0):
    norm_init, _, _ = L.make_norm(cfg)
    ks = jax.random.split(key, 2)
    p: Dict[str, Any] = {"ln1": norm_init(cfg.d_model, dtype)}
    if kind in ("attn", "swa"):
        p["mixer"] = A.attn_init(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = SSM.mamba_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = G.rglru_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    fk = _ffn_kind(cfg, kind, pos)
    if fk:
        p["ln2"] = norm_init(cfg.d_model, dtype)
        ff = cfg.dense_ff or cfg.d_ff
        p["ffn"] = (M.moe_init(ks[1], cfg, dtype) if fk == "moe"
                    else L.mlp_init(ks[1], cfg.d_model, ff, dtype))
    return p


def block_axes(cfg: ArchConfig, kind: str, pos: int = 0):
    _, norm_axes, _ = L.make_norm(cfg)
    a: Dict[str, Any] = {"ln1": norm_axes()}
    if kind in ("attn", "swa"):
        a["mixer"] = A.attn_axes(cfg)
    elif kind == "mamba":
        a["mixer"] = SSM.mamba_axes(cfg)
    elif kind == "rglru":
        a["mixer"] = G.rglru_axes(cfg)
    fk = _ffn_kind(cfg, kind, pos)
    if fk:
        a["ln2"] = norm_axes()
        a["ffn"] = M.moe_axes(cfg) if fk == "moe" else L.mlp_axes()
    return a


def _bd_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _moe_ffn(params, h, cfg, flags: RunFlags):
    """MoE dispatch honouring the configured communication mode (C2/C4).

    An active :class:`CommPlan` (installed by ``use_rules(...,
    comm_plan=...)``, typically planner-built) overrides ``flags.moe_mode``:
    ``MEM`` keeps the shared-memory baseline; ``P2P``/``MCAST`` take the
    direct dispatch path (top-1 = unicast, the paper's degeneracy)."""
    mesh = current_mesh()
    if not flags.distributed or mesh is None or "model" not in mesh.axis_names:
        return M.moe_apply(params, h, cfg, mode="mem", model_axis=None,
                           compute_dtype=flags.compute_dtype)
    bd = _bd_axes(mesh)
    mode = flags.moe_mode
    plan = current_comm_plan()
    if plan is not None:
        mode = "mem" if plan.mode("moe_dispatch") is CommMode.MEM else "mcast"
    # the dispatch's sequence axis follows the ``seq_sp`` rule (the
    # ``moe_dispatch`` overlay in RULE_OVERLAYS rewrites it when the plan
    # picks the shared-memory baseline), not a hard-coded mesh axis
    seq_ax = logical_to_pspec(("seq_sp",), mesh=mesh)[0] \
        if mode == "mcast" else None
    x_spec = P(bd, seq_ax, None)
    param_specs = jax.tree.map(
        lambda names: logical_to_pspec(tuple(
            n if n == "experts" else None for n in names), mesh=mesh),
        M.moe_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    def body(p, x):
        y, aux = M.moe_apply(p, x, cfg, mode=mode, model_axis="model",
                             compute_dtype=flags.compute_dtype,
                             use_kernels=flags.use_comm_kernels,
                             interpret=flags.kernel_interpret)
        for ax in mesh.axis_names:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    fn = compat.shard_map(body, mesh=mesh, in_specs=(param_specs, x_spec),
                          out_specs=(x_spec, P()), check_vma=False)
    y, aux = fn(params, h)
    y = mem_write(y, "moe_output", ("batch", "seq", "embed"))
    return y, aux


def _mlp_ffn_tp(params, h, flags: RunFlags):
    """Dense-MLP block routed through the socket's fused-matmul issue
    sites: shard_map over the model axis, sequence-parallel activations,
    weights column/row-sharded — the up/gate gather and the down
    projection's matmul+reduce-scatter issue as fused transfers (the
    FUSED_RING kernels under ``use_comm_kernels``, the lax paths
    otherwise; identical numbers either way).  Falls back to the GSPMD
    ``mlp_apply`` when no model axis is live or the shapes do not divide
    the ring."""
    mesh = current_mesh()
    if not flags.distributed or mesh is None or \
            "model" not in mesh.axis_names:
        return L.mlp_apply(params, h, compute_dtype=flags.compute_dtype)
    M = mesh.shape["model"]
    B, S, _ = h.shape
    ff = params["w_gate"].shape[-1]
    bd = _bd_axes(mesh)
    bd_size = 1
    for a in bd:
        bd_size *= mesh.shape[a]
    if M < 2 or ff % M or B % max(bd_size, 1) or S % M:
        # sequence-parallel activations and column/row weight shards must
        # divide the mesh axes evenly for the shard_map specs
        return L.mlp_apply(params, h, compute_dtype=flags.compute_dtype)
    x_spec = P(bd, "model", None)
    param_specs = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
                   "w_down": P("model", None)}

    def body(p, x):
        Bl, Sl, d = x.shape
        sock = socket_for_axis("model",
                               use_kernels=flags.use_comm_kernels,
                               interpret=flags.kernel_interpret)
        y = L.mlp_apply_tp(p, x.reshape(Bl * Sl, d), socket=sock,
                           compute_dtype=flags.compute_dtype)
        return y.reshape(Bl, Sl, d)

    fn = compat.shard_map(body, mesh=mesh, in_specs=(param_specs, x_spec),
                          out_specs=x_spec, check_vma=False)
    y = fn({k: params[k] for k in ("w_gate", "w_up", "w_down")}, h)
    return mem_write(y, "mlp_output", ("batch", "seq", "embed"))


def block_apply(params, x, cfg: ArchConfig, kind: str, flags: RunFlags,
                pos, cache=None, decode: bool = False, pat_pos: int = 0):
    """Returns (x_out, new_cache, aux_loss)."""
    _, _, norm = L.make_norm(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = norm(params["ln1"], x)

    window = cfg.local_window if kind == "swa" else cfg.sliding_window
    if kind in ("attn", "swa"):
        if decode:
            y, new_cache = A.decode_attn_apply(
                params["mixer"], h, cfg, cache, pos,
                compute_dtype=flags.compute_dtype, window=window)
        else:
            y, kv = A.attn_apply(params["mixer"], h, cfg, pos,
                                 chunk=flags.attn_chunk,
                                 compute_dtype=flags.compute_dtype,
                                 window=window, impl=flags.attn_impl)
            new_cache = {"k": kv[0].astype(flags.cache_dtype),
                         "v": kv[1].astype(flags.cache_dtype)}
    elif kind == "mamba":
        if decode:
            y, new_cache = SSM.mamba_decode_step(
                params["mixer"], h, cfg, cache, compute_dtype=flags.compute_dtype)
        else:
            y, new_cache = SSM.mamba_apply(
                params["mixer"], h, cfg, cache, chunk=flags.ssm_chunk,
                compute_dtype=flags.compute_dtype)
    elif kind == "rglru":
        if decode:
            y, new_cache = G.rglru_decode_step(
                params["mixer"], h, cfg, cache, compute_dtype=flags.compute_dtype)
        else:
            y, new_cache = G.rglru_apply(
                params["mixer"], h, cfg, cache, chunk=flags.ssm_chunk,
                compute_dtype=flags.compute_dtype)
    else:
        raise ValueError(kind)
    x = x + y

    fk = _ffn_kind(cfg, kind, pat_pos)
    if fk:
        h = norm(params["ln2"], x)
        if fk == "moe":
            y, aux = _moe_ffn(params["ffn"], h, cfg, flags)
        elif flags.ffn_tp:
            y = _mlp_ffn_tp(params["ffn"], h, flags)
        else:
            y = L.mlp_apply(params["ffn"], h, compute_dtype=flags.compute_dtype)
        x = x + y
    x = mem_write(x, "block_activation", ("batch", "seq", "embed"))
    return x, new_cache, aux


# --------------------------------------------------------------- full model ----

def _grouping(cfg: ArchConfig):
    kinds = cfg.block_kinds()
    plen = len(cfg.pattern)
    n_groups = len(kinds) // plen
    rem = kinds[n_groups * plen:]
    return cfg.pattern, n_groups, rem


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    pattern, n_groups, rem = _grouping(cfg)
    norm_init, _, _ = L.make_norm(cfg)
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": L.embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.embedding_init(keys[1], cfg.vocab_size,
                                             cfg.d_model, dtype)

    def group_init(k):
        ks = jax.random.split(k, len(pattern))
        return {f"b{i}": block_init(ks[i], cfg, kind, dtype, pos=i)
                for i, kind in enumerate(pattern)}

    if n_groups:
        gkeys = jax.random.split(keys[2], n_groups)
        params["groups"] = jax.vmap(group_init)(gkeys)
    if rem:
        rkeys = jax.random.split(keys[3], len(rem))
        params["rem"] = {f"r{i}": block_init(rkeys[i], cfg, kind, dtype,
                                             pos=i)
                         for i, kind in enumerate(rem)}
    return params


def param_axes(cfg: ArchConfig):
    pattern, n_groups, rem = _grouping(cfg)
    _, norm_axes, _ = L.make_norm(cfg)
    axes: Dict[str, Any] = {
        "embed": L.embedding_axes(),
        "final_norm": norm_axes(),
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = L.embedding_axes()
    group = {f"b{i}": block_axes(cfg, kind, pos=i)
             for i, kind in enumerate(pattern)}
    if n_groups:
        axes["groups"] = jax.tree.map(
            lambda names: (None,) + names, group,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    if rem:
        axes["rem"] = {f"r{i}": block_axes(cfg, kind, pos=i)
                       for i, kind in enumerate(rem)}
    return axes


def _apply_stack(params, x, cfg, flags, pos, caches, decode, collect_cache):
    """Runs grouped-scan + remainder blocks.  caches/new_caches mirror params
    structure under "groups"/"rem"."""
    pattern, n_groups, rem = _grouping(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    keep_cache = decode or collect_cache

    def group_body(x, gp, gc):
        aux_g = jnp.zeros((), jnp.float32)
        ncs = {}
        for i, kind in enumerate(pattern):
            c = gc[f"b{i}"] if gc is not None else None
            x, nc, aux = block_apply(gp[f"b{i}"], x, cfg, kind, flags, pos,
                                     cache=c, decode=decode, pat_pos=i)
            if keep_cache:
                ncs[f"b{i}"] = nc
            aux_g = aux_g + aux
        return x, ncs, aux_g

    body = group_body
    remat_on = flags.remat in ("full", "save_collectives") and not decode \
        and not collect_cache
    policy = None
    if flags.remat == "save_collectives":
        # keep post-all-reduce activations: the backward's recompute stays
        # local (no second pass over the ICI for the same partial sums)
        policy = jax.checkpoint_policies.save_only_these_names(
            "post_collective")
    if remat_on:
        body = (jax.checkpoint(group_body, policy=policy)
                if policy else jax.checkpoint(group_body))

    if n_groups:
        gcaches = caches.get("groups") if caches else None

        if gcaches is None and remat_on and n_groups >= 4:
            # sqrt(L) nested remat ("remat_scan"): an outer scan over
            # segments (checkpointed) of an inner scan over layers (each
            # layer checkpointed).  Saved residuals drop from O(L) full
            # activation stacks to O(sqrt(L)) + O(sqrt(L)) — the difference
            # between a 24 GB and a ~4 GB per-device remat stack at 36
            # layers x (256, 4096, 2560).
            g2 = max(1, int(round(n_groups ** 0.5)))
            while n_groups % g2:
                g2 -= 1
            g1 = n_groups // g2
            seg_params = jax.tree.map(
                lambda a: a.reshape((g1, g2) + a.shape[1:]), params["groups"])

            def layer_body(x, gp):
                x, ncs, aux_g = body(x, gp, None)
                return x, aux_g

            def seg_body(x, sp):
                return jax.lax.scan(layer_body, x, sp)

            seg_body = (jax.checkpoint(seg_body, policy=policy)
                        if policy else jax.checkpoint(seg_body))
            x, g_aux = jax.lax.scan(seg_body, x, seg_params)
            g_new = {}
        elif gcaches is None:
            def scan_body(x, gp):
                x, ncs, aux_g = body(x, gp, None)
                return x, (ncs, aux_g)
            x, (g_new, g_aux) = jax.lax.scan(scan_body, x, params["groups"])
        else:
            def scan_body(x, inp):
                gp, gc = inp
                x, ncs, aux_g = body(x, gp, gc)
                return x, (ncs, aux_g)
            x, (g_new, g_aux) = jax.lax.scan(scan_body, x,
                                             (params["groups"], gcaches))
        aux_total = aux_total + jnp.sum(g_aux)
        if keep_cache:
            new_caches["groups"] = g_new

    for i, kind in enumerate(rem):
        rp = params["rem"][f"r{i}"]
        rc = caches["rem"][f"r{i}"] if caches else None
        x, nc, aux = block_apply(rp, x, cfg, kind, flags, pos, cache=rc,
                                 decode=decode, pat_pos=i)
        aux_total = aux_total + aux
        if keep_cache:
            new_caches.setdefault("rem", {})[f"r{i}"] = nc
    return x, new_caches, aux_total


def _unembed_table(params, cfg):
    return params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]["table"]


def forward_train(params, batch, cfg: ArchConfig, flags: RunFlags):
    """batch: {"tokens": (B,S) int32, "labels": (B,S) int32} -> scalar loss."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params["embed"], tokens, flags.compute_dtype)
    x, _, aux = _apply_stack(params, x, cfg, flags, pos, None, decode=False,
                             collect_cache=False)
    _, _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    loss = L.chunked_ce_loss(_unembed_table(params, cfg), x, labels,
                             chunk=flags.ce_chunk,
                             compute_dtype=flags.compute_dtype)
    if cfg.moe is not None:
        loss = loss + flags.aux_loss_coef * aux
    return loss


def prefill(params, tokens, cfg: ArchConfig, flags: RunFlags):
    """tokens (B,S) -> (last-position logits (B,1,V), caches)."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params["embed"], tokens, flags.compute_dtype)
    x, caches, _ = _apply_stack(params, x, cfg, flags, pos, None, decode=False,
                                collect_cache=True)
    _, _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x[:, -1:])
    logits = L.decode_logits(_unembed_table(params, cfg), x,
                             flags.compute_dtype)
    return logits, caches


def decode_step(params, token, pos_scalar, caches, cfg: ArchConfig,
                flags: RunFlags):
    """token (B,1) int32, pos_scalar scalar int32 — or a (B,) int32 vector
    of per-row positions (continuous batching) -> (logits (B,1,V), caches)."""
    B = token.shape[0]
    x = L.embed_tokens(params["embed"], token, flags.compute_dtype)
    x, new_caches, _ = _apply_stack(params, x, cfg, flags, pos_scalar, caches,
                                    decode=True, collect_cache=True)
    _, _, norm = L.make_norm(cfg)
    x = norm(params["final_norm"], x)
    logits = L.decode_logits(_unembed_table(params, cfg), x,
                             flags.compute_dtype)
    return logits, new_caches


# ------------------------------------------------------------ cache layout ----

def _block_cache_spec(cfg: ArchConfig, kind: str, B: int, skv: int, dtype):
    """(shape/dtype, logical-axes) spec tree for one block's decode cache."""
    hd = cfg.resolved_head_dim
    K = cfg.n_kv_heads
    if kind in ("attn", "swa"):
        window = cfg.local_window if kind == "swa" else cfg.sliding_window
        s = min(skv, window) if window else skv
        sh = (B, s, K, hd)
        names = ("batch", "kv_seq", "kv_heads", "head_dim")
        return {"k": (sh, dtype, names), "v": (sh, dtype, names)}
    if kind == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        return {"h": ((B, di, cfg.ssm.state_dim), jnp.float32,
                      ("batch", "state", None)),
                "conv": ((B, cfg.ssm.conv_dim - 1, di), jnp.float32,
                         ("batch", None, "state"))}
    if kind == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        return {"h": ((B, w), jnp.float32, ("batch", "state")),
                "conv": ((B, cfg.rglru.conv_dim - 1, w), jnp.float32,
                         ("batch", None, "state"))}
    raise ValueError(kind)


def cache_spec(cfg: ArchConfig, B: int, skv: int, dtype=jnp.bfloat16):
    """Returns a pytree of (shape, dtype, logical_names) leaves mirroring the
    decode-cache structure (leaves are 3-tuples, treated as leaves)."""
    pattern, n_groups, rem = _grouping(cfg)
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)
    out: Dict[str, Any] = {}
    if n_groups:
        group = {f"b{i}": _block_cache_spec(cfg, kind, B, skv, dtype)
                 for i, kind in enumerate(pattern)}
        out["groups"] = jax.tree.map(
            lambda sp: ((n_groups,) + sp[0], sp[1], (None,) + sp[2]),
            group, is_leaf=is_leaf)
    if rem:
        out["rem"] = {f"r{i}": _block_cache_spec(cfg, kind, B, skv, dtype)
                      for i, kind in enumerate(rem)}
    return out


def make_cache(cfg: ArchConfig, B: int, skv: int, dtype=jnp.bfloat16,
               as_specs: bool = False):
    spec = cache_spec(cfg, B, skv, dtype)
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)
    if as_specs:
        return jax.tree.map(lambda sp: jax.ShapeDtypeStruct(sp[0], sp[1]),
                            spec, is_leaf=is_leaf)
    return jax.tree.map(lambda sp: jnp.zeros(sp[0], sp[1]), spec,
                        is_leaf=is_leaf)


def cache_axes(cfg: ArchConfig, B: int = 1, skv: int = 1):
    spec = cache_spec(cfg, B, skv)
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)
    return jax.tree.map(lambda sp: sp[2], spec, is_leaf=is_leaf)
