"""AST fact extraction for commcheck (no jax import: the CLI stays cheap).

One parse per file produces a :class:`ModuleFacts` — everything the rules
consume: the resolved module-reference list (imports, ``from``-imports,
aliased attribute chains, literal ``importlib`` loads), every
``TransferDescriptor(...)`` construction site, ``register_fusion_target``
registrations, the implicit issue sites (``mem_write`` /
``record_implicit_issue`` literals), and the straight-line socket call
sequence per function body for the happens-before pass.

Extraction is *resolution-based*, not textual: ``import repro.core.p2p as
_x``, ``from repro.core import p2p``, ``from repro import core`` followed
by ``core.p2p.send(...)``, and ``importlib.import_module("repro.core.p2p")``
all surface as a module use of ``repro.core.p2p`` — the aliasing holes the
old grep gates could not see.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

# sync/pull keyword values that are not literal booleans surface as this
# sentinel so the literal-flags rule can tell "absent" from "dynamic"
NON_LITERAL = "<non-literal>"

# ------------------------------------------------------------------ zones ----

ZONE_CORE = "core"        # src/repro/core/ — owns the collective helpers
ZONE_KERNELS = "kernels"  # src/repro/kernels/ — ring kernels live here
ZONE_TESTS = "tests"      # test code may reach anything directly
ZONE_USER = "user"        # everything else: must go through the socket

_FIXTURE_MARK = "fixtures/commcheck"


def zone_of(path: str) -> str:
    """Boundary zone of a file, from its (repo-relative or absolute) path.
    The analyzer's own fixture corpus under ``tests/fixtures/commcheck/``
    is deliberately scanned as user code — it exists to trip the rules."""
    p = path.replace(os.sep, "/")
    if _FIXTURE_MARK in p:
        return ZONE_USER
    if "repro/core/" in p:
        return ZONE_CORE
    if "repro/kernels/" in p:
        return ZONE_KERNELS
    if "tests" in p.split("/"):
        return ZONE_TESTS
    return ZONE_USER


# ------------------------------------------------------------ fact records ----

@dataclasses.dataclass(frozen=True)
class ModuleUse:
    """One resolved reference to a module path (dotted name)."""
    module: str               # e.g. "repro.core.p2p"
    line: int
    via: str                  # "import" | "from" | "attribute" | "importlib"


@dataclasses.dataclass(frozen=True)
class DescriptorSite:
    """One ``TransferDescriptor(...)`` construction site."""
    path: str
    line: int
    name: Optional[str]           # first arg when a string literal
    site: Optional[str]           # site= keyword when a string literal
    fused_with: Optional[str]     # fused_with= keyword when a literal
    sync: Optional[object]        # True/False, NON_LITERAL, or None (absent)
    pull: Optional[object]
    var: Optional[str] = None     # module-level variable it was bound to

    @property
    def site_label(self) -> Optional[str]:
        """Issue-log label (``site or name``), None when neither is a
        literal the extractor could read."""
        return self.site if self.site is not None else self.name


@dataclasses.dataclass(frozen=True)
class DegradeSite:
    """One call that can mint a downgrade record: a
    ``record_implicit_issue(...)`` or a direct ``IssueRecord(...)``.
    ``reason`` is the literal string (a plain literal, or a conditional
    whose branches are both literals), ``NON_LITERAL`` for anything
    dynamic, or ``None`` when the keyword is absent."""
    path: str
    line: int
    kind: str                     # "record_implicit_issue" | "IssueRecord"
    site: Optional[str]
    reason: Optional[object]


@dataclasses.dataclass(frozen=True)
class SocketCall:
    """One socket-ish call inside a function body, in statement order."""
    kind: str                     # "write" | "fence" | "other"
    label: Optional[str]          # descriptor site label when resolvable
    sync: bool                    # the descriptor folds in the C3 fence
    line: int


@dataclasses.dataclass
class ModuleFacts:
    path: str
    zone: str
    uses: List[ModuleUse] = dataclasses.field(default_factory=list)
    descriptors: List[DescriptorSite] = dataclasses.field(default_factory=list)
    fusion_registrations: List[Tuple[str, int]] = \
        dataclasses.field(default_factory=list)
    implicit_sites: List[str] = dataclasses.field(default_factory=list)
    degrade_sites: List[DegradeSite] = dataclasses.field(default_factory=list)
    sequences: List[Tuple[str, List[SocketCall]]] = \
        dataclasses.field(default_factory=list)
    suppressions: Dict[int, set] = dataclasses.field(default_factory=dict)
    parse_error: Optional[str] = None


# ----------------------------------------------------------- suppressions ----

_SUPPRESS_RE = re.compile(r"#\s*commcheck:\s*allow\(\s*([^)]*?)\s*\)")


def format_suppression(rule_ids: Sequence[str]) -> str:
    """The canonical inline-suppression comment for ``rule_ids``."""
    return f"# commcheck: allow({', '.join(rule_ids)})"


def parse_suppression_comment(text: str) -> Optional[List[str]]:
    """Rule ids named by a suppression comment in ``text`` (None when the
    text carries no suppression).  Inverse of :func:`format_suppression`."""
    m = _SUPPRESS_RE.search(text)
    if m is None:
        return None
    return [r.strip() for r in m.group(1).split(",") if r.strip()]


def parse_suppressions(source: str) -> Dict[int, set]:
    """Per-line suppressed rule ids: a suppression on a code line covers
    that line; a comment-only line covers the next non-blank line (so a
    long statement can carry the comment above it)."""
    out: Dict[int, set] = {}
    pending: set = set()
    pending_from = None
    for lineno, text in enumerate(source.splitlines(), start=1):
        stripped = text.strip()
        rules = parse_suppression_comment(text)
        if rules is not None and stripped.startswith("#"):
            pending |= set(rules)
            pending_from = lineno
            out.setdefault(lineno, set()).update(rules)
            continue
        if not stripped:
            continue
        here = set(rules or ())
        if pending:
            here |= pending
            pending = set()
            pending_from = None
        if here:
            out.setdefault(lineno, set()).update(here)
    if pending and pending_from is not None:
        out.setdefault(pending_from, set()).update(pending)
    return out


# -------------------------------------------------------------- extraction ----

def _dotted(node: ast.AST) -> Optional[List[str]]:
    """Flatten a Name/Attribute chain into its dotted parts, or None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def _call_name(node: ast.Call) -> Optional[str]:
    """Last path segment of the called object ("write" for sock.write)."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _literal_str(node: Optional[ast.AST]) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _kw(node: ast.Call, name: str) -> Optional[ast.AST]:
    for k in node.keywords:
        if k.arg == name:
            return k.value
    return None


def _flag_value(node: Optional[ast.AST]):
    """True/False for a literal boolean keyword, NON_LITERAL for anything
    else, None when the keyword is absent."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, bool):
        return node.value
    return NON_LITERAL


def _reason_value(node: Optional[ast.AST]):
    """Statically readable reason string: a literal, or a conditional
    expression both of whose branches are literals (the idiom
    ``reason="active" if pod > 1 else "inactive"``).  Implicit string
    concatenation parses as one Constant, so multi-line literals pass.
    NON_LITERAL for anything dynamic, None when absent."""
    if node is None:
        return None
    lit = _literal_str(node)
    if lit is not None:
        return lit
    if isinstance(node, ast.IfExp):
        body, orelse = _literal_str(node.body), _literal_str(node.orelse)
        if body is not None and orelse is not None:
            return body
    return NON_LITERAL


class _Extractor(ast.NodeVisitor):
    def __init__(self, facts: ModuleFacts):
        self.facts = facts
        # name -> dotted module path it is bound to (import aliasing)
        self.aliases: Dict[str, str] = {}
        # module-level variable -> DescriptorSite (for fence resolution)
        self.desc_vars: Dict[str, DescriptorSite] = {}
        self._attr_owned: set = set()

    # ----- imports build the alias map AND count as module uses -----
    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            self.facts.uses.append(ModuleUse(alias.name, node.lineno,
                                             "import"))
            if alias.asname:
                self.aliases[alias.asname] = alias.name
            else:
                top = alias.name.split(".")[0]
                self.aliases[top] = top
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module is None or node.level:
            # relative imports stay unresolved (nothing in this tree uses
            # them for the guarded modules)
            self.generic_visit(node)
            return
        for alias in node.names:
            full = f"{node.module}.{alias.name}"
            self.facts.uses.append(ModuleUse(full, node.lineno, "from"))
            self.aliases[alias.asname or alias.name] = full
        self.generic_visit(node)

    # ----- attribute chains resolve through the alias map -----
    def visit_Attribute(self, node: ast.Attribute):
        if id(node) not in self._attr_owned:
            parts = _dotted(node)
            if parts and parts[0] in self.aliases:
                full = ".".join([self.aliases[parts[0]]] + parts[1:])
                self.facts.uses.append(ModuleUse(full, node.lineno,
                                                 "attribute"))
                # inner Attribute nodes are part of this chain: don't
                # re-report each prefix as its own use
                inner = node.value
                while isinstance(inner, ast.Attribute):
                    self._attr_owned.add(id(inner))
                    inner = inner.value
        self.generic_visit(node)

    # ----- calls: importlib loads, descriptors, registrations -----
    def visit_Call(self, node: ast.Call):
        callee = _call_name(node)
        target = self._resolved_callee(node)
        if ((target in ("importlib.import_module",
                        "importlib.machinery.SourceFileLoader")
             or callee == "__import__") and node.args):
            lit = _literal_str(node.args[0])
            if lit is not None:
                self.facts.uses.append(ModuleUse(lit, node.lineno,
                                                 "importlib"))
        if callee == "TransferDescriptor":
            self._extract_descriptor(node)
        elif callee == "register_fusion_target" and node.args:
            lit = _literal_str(node.args[0])
            if lit is not None:
                self.facts.fusion_registrations.append((lit, node.lineno))
        elif callee == "mem_write":
            label = self._mem_write_label(node)
            if label is not None:
                self.facts.implicit_sites.append(label)
        elif callee == "record_implicit_issue":
            site = _literal_str(_kw(node, "site"))
            if site is None and node.args:
                site = _literal_str(node.args[0])
            if site is not None:
                self.facts.implicit_sites.append(site)
            self.facts.degrade_sites.append(DegradeSite(
                path=self.facts.path, line=node.lineno,
                kind="record_implicit_issue", site=site,
                reason=_reason_value(_kw(node, "reason"))))
        elif callee == "IssueRecord":
            self.facts.degrade_sites.append(DegradeSite(
                path=self.facts.path, line=node.lineno, kind="IssueRecord",
                site=_literal_str(_kw(node, "site")),
                reason=_reason_value(_kw(node, "degraded_reason"))))
        self.generic_visit(node)

    def _resolved_callee(self, node: ast.Call) -> Optional[str]:
        parts = _dotted(node.func)
        if not parts:
            return None
        if parts[0] in self.aliases:
            return ".".join([self.aliases[parts[0]]] + parts[1:])
        return ".".join(parts)

    def _mem_write_label(self, node: ast.Call) -> Optional[str]:
        site = _literal_str(_kw(node, "site"))
        if site is not None:
            return site
        if len(node.args) >= 2:
            return _literal_str(node.args[1])
        return _literal_str(_kw(node, "name"))

    def _extract_descriptor(self, node: ast.Call,
                            var: Optional[str] = None) -> DescriptorSite:
        name = (_literal_str(node.args[0]) if node.args
                else _literal_str(_kw(node, "name")))
        d = DescriptorSite(
            path=self.facts.path, line=node.lineno, name=name,
            site=_literal_str(_kw(node, "site")),
            fused_with=_literal_str(_kw(node, "fused_with")),
            sync=_flag_value(_kw(node, "sync")),
            pull=_flag_value(_kw(node, "pull")), var=var)
        self.facts.descriptors.append(d)
        return d

    # ----- module-level descriptor bindings -----
    def visit_Assign(self, node: ast.Assign):
        if (isinstance(node.value, ast.Call)
                and _call_name(node.value) == "TransferDescriptor"
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            var = node.targets[0].id
            d = self._extract_descriptor(node.value, var=var)
            self.desc_vars[var] = d
            # the call was handled here; still walk args for nested uses
            for arg in list(node.value.args) + \
                    [k.value for k in node.value.keywords]:
                self.visit(arg)
            return
        self.generic_visit(node)


# write-like socket methods and the fences that clear pending writes
_WRITE_METHODS = {"write", "mem_write"}
_FENCE_METHODS = {"reduce", "barrier"}


def _walk_pruned(node: ast.AST):
    """Like ``ast.walk`` but does not descend into nested function
    definitions — those run at call time, not in this body's order, and
    get their own sequence.  Lambdas stay in: they execute as part of the
    statement that builds and passes them (``tree.map(lambda c: ...)``)."""
    yield node
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield from _walk_pruned(child)


def _socket_calls(stmts, extractor: _Extractor) -> List[SocketCall]:
    """Socket-ish calls across ``stmts`` in source order (straight-line:
    branches and loops are walked but not path-split — conservative in the
    no-false-positive direction, since both arms merge into one order)."""
    calls: List[SocketCall] = []
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in _walk_pruned(stmt):
            if not isinstance(node, ast.Call):
                continue
            callee = _call_name(node)
            if callee in _WRITE_METHODS:
                label, sync = _resolve_desc_arg(node, callee, extractor)
                calls.append(SocketCall("write", label, bool(sync is True),
                                        node.lineno))
            elif callee in _FENCE_METHODS:
                if callee == "reduce" and _is_module_attr(node, extractor):
                    continue      # functools.reduce & friends
                calls.append(SocketCall("fence", None, True, node.lineno))
    calls.sort(key=lambda c: c.line)
    return calls


def _is_module_attr(node: ast.Call, extractor: _Extractor) -> bool:
    """True when ``X.reduce(...)``'s base resolves to an imported module
    (functools.reduce is not a socket fence)."""
    parts = _dotted(node.func)
    return bool(parts and len(parts) > 1 and parts[0] in extractor.aliases)


def _resolve_desc_arg(node: ast.Call, callee: str, extractor: _Extractor):
    """(site label, sync flag) of the descriptor a write-like call issues
    from; (None, None) when unresolvable."""
    if callee == "mem_write":
        return extractor._mem_write_label(node), False
    desc_node = node.args[1] if len(node.args) >= 2 else _kw(node, "desc")
    if isinstance(desc_node, ast.Call) and \
            _call_name(desc_node) == "TransferDescriptor":
        name = (_literal_str(desc_node.args[0]) if desc_node.args
                else _literal_str(_kw(desc_node, "name")))
        site = _literal_str(_kw(desc_node, "site"))
        sync = _flag_value(_kw(desc_node, "sync"))
        return (site if site is not None else name), sync
    if isinstance(desc_node, ast.Name):
        d = extractor.desc_vars.get(desc_node.id)
        if d is not None:
            return d.site_label, d.sync
    return None, None


def extract_module(path: str, source: Optional[str] = None) -> ModuleFacts:
    """Parse one file into its :class:`ModuleFacts`; a syntax error is a
    fact too (the engine reports it as a finding, not a crash)."""
    facts = ModuleFacts(path=path, zone=zone_of(path))
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    facts.suppressions = parse_suppressions(source)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        facts.parse_error = f"{e.msg} (line {e.lineno})"
        return facts
    ex = _Extractor(facts)
    ex.visit(tree)
    # straight-line socket sequences: module body + each function body
    facts.sequences.append(("<module>", _socket_calls(tree.body, ex)))
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            facts.sequences.append((node.name, _socket_calls(node.body, ex)))
    return facts
