"""commcheck: static analysis of the communication spine.

The ROADMAP invariant — *new communication goes through the socket spine,
not around it* — enforced as a real analysis pass instead of grep:
AST-resolved boundary lint, descriptor integrity (unique site labels,
resolvable ``fused_with``, literal ``sync``/``pull``), a conservative
sync-fence happens-before pass, and the ``--against-artifact`` coverage
cross-check of dryrun ``comm_issued`` sites.

CLI: ``python -m repro.analysis [paths ...]`` — see docs/analysis.md for
the rule catalog, the ``# commcheck: allow(<rule-id>)`` suppression
syntax, and the allowlist format.  This package imports no jax: scans
stay sub-second (the ``commcheck_scan`` benchmark row gates that).
"""

from repro.analysis.engine import (Finding, Report, Rule, analyze,
                                   check_rule_ids, iter_python_files,
                                   load_allowlist, parse_allowlist,
                                   format_allowlist, DEFAULT_ALLOWLIST)
from repro.analysis.extract import (ModuleFacts, extract_module,
                                    format_suppression,
                                    parse_suppression_comment,
                                    parse_suppressions, zone_of)
from repro.analysis.rules import default_rules

__all__ = [
    "Finding", "Report", "Rule", "analyze", "check_rule_ids",
    "iter_python_files", "load_allowlist", "parse_allowlist",
    "format_allowlist", "DEFAULT_ALLOWLIST", "ModuleFacts",
    "extract_module", "format_suppression", "parse_suppression_comment",
    "parse_suppressions", "zone_of", "default_rules",
]
