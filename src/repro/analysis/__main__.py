"""The commcheck CLI: ``python -m repro.analysis [paths ...]``.

Default scan roots mirror the old grep gates (src/repro, examples,
benchmarks, scripts — tests may reach anything directly and are not
scanned).  Exit status: 0 clean, 1 findings, 2 usage/environment error.

  --against-artifact F   cross-check F's comm_issued sites against the
                         extracted descriptor universe (plan coverage)
  --changed              scan only files from ``git diff --name-only HEAD``
                         (fast local pre-commit loop)
  --allowlist F          committed exemptions (default
                         scripts/commcheck_allowlist.txt when present)
  --list-rules           print the rule catalog and exit
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

from repro.analysis import (DEFAULT_ALLOWLIST, analyze, default_rules,
                            iter_python_files)

DEFAULT_ROOTS = ("src/repro", "examples", "benchmarks", "scripts")


def changed_files(roots) -> list:
    """Tracked .py files with uncommitted changes, limited to the scan
    roots — the --changed pre-commit fast path."""
    try:
        proc = subprocess.run(
            ["git", "diff", "--name-only", "HEAD"],
            capture_output=True, text=True, check=True)
    except (OSError, subprocess.CalledProcessError) as e:
        raise SystemExit(f"commcheck: --changed needs a git checkout "
                         f"({e})")
    scanned = set(os.path.normpath(f) for f in iter_python_files(roots))
    out = []
    for line in proc.stdout.splitlines():
        path = os.path.normpath(line.strip())
        if path.endswith(".py") and os.path.exists(path) and \
                (path in scanned or not scanned):
            out.append(path)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="commcheck: static analysis of the communication spine")
    ap.add_argument("paths", nargs="*",
                    help=f"files or directories (default: "
                         f"{' '.join(DEFAULT_ROOTS)})")
    ap.add_argument("--against-artifact", metavar="DRYRUN_JSON",
                    help="cross-check descriptor coverage against a dryrun "
                         "artifact's comm_issued sites")
    ap.add_argument("--allowlist", default=None,
                    help=f"allowlist file (default {DEFAULT_ALLOWLIST} "
                         f"when present)")
    ap.add_argument("--changed", action="store_true",
                    help="scan only files changed vs HEAD (git)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print findings only, no summary")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in default_rules():
            print(f"{rule.id:26s} {rule.summary}")
        print(f"{'plan-uncovered-site':26s} (with --against-artifact) "
              f"every artifact comm_issued site must map to an extracted "
              f"site")
        return 0

    roots = args.paths or [r for r in DEFAULT_ROOTS if os.path.exists(r)]
    if not roots:
        raise SystemExit("commcheck: nothing to scan (no paths given and "
                         "no default roots exist here)")
    if args.changed:
        roots = changed_files(roots)
        if not roots:
            if not args.quiet:
                print("commcheck: no changed .py files — nothing to scan")
            return 0

    allowlist = args.allowlist
    if allowlist is None and os.path.exists(DEFAULT_ALLOWLIST):
        allowlist = DEFAULT_ALLOWLIST

    report = analyze(roots, artifact_path=args.against_artifact,
                     allowlist_path=allowlist)
    for f in report.findings:
        print(f.render())
    if not args.quiet:
        extras = []
        if report.suppressed:
            extras.append(f"{len(report.suppressed)} suppressed inline")
        if report.allowlisted:
            extras.append(f"{len(report.allowlisted)} allowlisted")
        if args.against_artifact:
            uncovered = sum(f.rule == "plan-uncovered-site"
                            for f in report.findings)
            extras.append(f"{uncovered} uncovered artifact sites")
        tail = f" ({', '.join(extras)})" if extras else ""
        print(f"commcheck: {len(report.findings)} finding(s) across "
              f"{len(report.files)} files{tail}")
    return 1 if report.findings else 0


if __name__ == "__main__":
    sys.exit(main())
