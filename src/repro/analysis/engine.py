"""The commcheck rule engine: scan -> extract -> rules -> report.

A :class:`Rule` contributes per-module findings (``check_module``) and/or
whole-tree findings (``check_tree`` — cross-file resolution like the
``fused_with`` universe).  The engine applies the two suppression layers
before anything reaches the report:

* inline: ``# commcheck: allow(<rule-id>[, ...])`` on the offending line
  (or as a comment-only line directly above it);
* the committed allowlist file — ``<rule-id> <path-glob>`` lines — for
  exemptions that should be visible in review rather than scattered
  through the tree.

``scripts/ci.sh`` fails the build on any finding that survives both.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.extract import ModuleFacts, extract_module

DEFAULT_ALLOWLIST = os.path.join("scripts", "commcheck_allowlist.txt")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Rule:
    """Base rule: subclasses set ``id`` + ``summary`` and override one or
    both check hooks."""
    id: str = "<abstract>"
    summary: str = ""

    def check_module(self, facts: ModuleFacts) -> List[Finding]:
        return []

    def check_tree(self, modules: List[ModuleFacts]) -> List[Finding]:
        return []


# ---------------------------------------------------------------- allowlist ----

@dataclasses.dataclass(frozen=True)
class AllowEntry:
    rule: str
    glob: str

    def covers(self, finding: Finding) -> bool:
        if self.rule not in ("*", finding.rule):
            return False
        path = finding.path.replace(os.sep, "/")
        return (fnmatch.fnmatch(path, self.glob)
                or fnmatch.fnmatch(path, "*/" + self.glob))


def parse_allowlist(text: str) -> List[AllowEntry]:
    """``<rule-id> <path-glob>`` per line; ``#`` comments and blanks
    skipped.  A malformed line is an error — a silently ignored exemption
    is worse than a loud one."""
    entries = []
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        if len(parts) != 2:
            raise ValueError(
                f"allowlist line {lineno}: expected '<rule-id> <path-glob>', "
                f"got {raw!r}")
        entries.append(AllowEntry(parts[0], parts[1]))
    return entries


def format_allowlist(entries: Sequence[AllowEntry]) -> str:
    """Inverse of :func:`parse_allowlist` (round-trips exactly)."""
    return "\n".join(f"{e.rule} {e.glob}" for e in entries)


def load_allowlist(path: Optional[str]) -> List[AllowEntry]:
    if path is None or not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        return parse_allowlist(f.read())


# ------------------------------------------------------------------- report ----

@dataclasses.dataclass
class Report:
    findings: List[Finding]              # survive suppression + allowlist
    suppressed: List[Finding]            # killed by an inline comment
    allowlisted: List[Finding]           # killed by the committed allowlist
    files: List[str]

    @property
    def ok(self) -> bool:
        return not self.findings


def iter_python_files(paths: Iterable[str]) -> List[str]:
    """Expand files/directories into a sorted, de-duplicated .py list."""
    out = []
    seen = set()
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
        elif p.endswith(".py"):
            out.append(p)
    uniq = []
    for f in out:
        key = os.path.normpath(f)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq


def check_rule_ids(rules: Sequence[Rule]) -> None:
    """Rule ids are the suppression/allowlist vocabulary — a duplicate id
    would make ``allow(...)`` ambiguous."""
    seen: Dict[str, Rule] = {}
    for r in rules:
        if r.id in seen:
            raise ValueError(f"duplicate rule id {r.id!r} "
                             f"({type(seen[r.id]).__name__} vs "
                             f"{type(r).__name__})")
        seen[r.id] = r


def analyze(paths: Sequence[str], *,
            artifact_path: Optional[str] = None,
            allowlist_path: Optional[str] = None,
            rules: Optional[Sequence[Rule]] = None) -> Report:
    """Scan ``paths`` (files or directories) under the full rule set; an
    artifact path appends the plan-coverage cross-check."""
    from repro.analysis.rules import PlanCoverageRule, default_rules
    active: List[Rule] = list(rules) if rules is not None else default_rules()
    if artifact_path is not None:
        active.append(PlanCoverageRule(artifact_path))
    check_rule_ids(active)

    files = iter_python_files(paths)
    modules: List[ModuleFacts] = []
    raw: List[Tuple[ModuleFacts, Finding]] = []
    for path in files:
        facts = extract_module(path)
        modules.append(facts)
        if facts.parse_error is not None:
            raw.append((facts, Finding("parse-error", path, 0,
                                       facts.parse_error)))

    by_path = {m.path: m for m in modules}
    for rule in active:
        for facts in modules:
            for f in rule.check_module(facts):
                raw.append((by_path.get(f.path, facts), f))
        for f in rule.check_tree(modules):
            raw.append((by_path.get(f.path, modules[0] if modules else None),
                        f))

    allow = load_allowlist(allowlist_path)
    report = Report([], [], [], files)
    for facts, finding in sorted(
            raw, key=lambda t: (t[1].path, t[1].line, t[1].rule)):
        suppressed_here = (facts is not None and facts.path == finding.path
                           and finding.rule in
                           facts.suppressions.get(finding.line, set()))
        if suppressed_here:
            report.suppressed.append(finding)
        elif any(e.covers(finding) for e in allow):
            report.allowlisted.append(finding)
        else:
            report.findings.append(finding)
    return report
