"""Degradation-record integrity: every downgrade carries a readable why.

The fault-aware socket's contract is that a transfer never silently runs
in a mode other than the planned one — ``IssueRecord.degraded_reason``
is the machine-readable audit trail the chaos stage asserts on.  User
code that mints its own records (``record_implicit_issue`` at a
compiler-issued collective site, or a raw ``IssueRecord``) can break
that contract in two ways this rule catches statically:

* a ``record_implicit_issue`` with **no** ``reason=`` at all — if the
  planned and issued modes ever diverge there, the downgrade is
  undocumented;
* a ``reason=`` / ``degraded_reason=`` the extractor cannot read (not a
  literal, nor a conditional of two literals) — the artifact would carry
  whatever a runtime expression happened to produce, which the analyzer
  (and a post-mortem) cannot audit.

``core`` is exempt: the socket's degradation ladder *accumulates* its
reasons dynamically ("ladder FUSED_RING->P2P: ..."), which is the one
place dynamic strings are the mechanism, not a bypass.  Tests and
kernels are exempt with it — the rule polices user-zone spine clients.
"""

from __future__ import annotations

from typing import List

from repro.analysis.engine import Finding, Rule
from repro.analysis.extract import NON_LITERAL, ZONE_USER, ModuleFacts


class DegradedWithoutReasonRule(Rule):
    id = "degraded-without-reason"
    summary = ("downgrade records minted outside core must carry a "
               "statically readable reason= (literal, or a conditional "
               "of literals)")

    def check_module(self, facts: ModuleFacts) -> List[Finding]:
        if facts.zone != ZONE_USER:
            return []
        out = []
        for d in facts.degrade_sites:
            label = d.site or "<dynamic site>"
            if d.kind == "record_implicit_issue" and d.reason is None:
                out.append(Finding(
                    self.id, facts.path, d.line,
                    f"record_implicit_issue at {label} carries no reason= "
                    f"— if the planned and issued modes ever diverge here "
                    f"the downgrade is undocumented (degraded_reason "
                    f"empty); state why the issued mode is what it is"))
            elif d.reason == NON_LITERAL:
                kw = ("reason" if d.kind == "record_implicit_issue"
                      else "degraded_reason")
                out.append(Finding(
                    self.id, facts.path, d.line,
                    f"{kw}= on the {d.kind} at {label} is not statically "
                    f"readable — use a literal string (or a conditional "
                    f"of two literals) so the downgrade audit trail can "
                    f"be checked without running the step"))
        return out
