"""The commcheck rule catalog (docs/analysis.md lists it with examples).

``default_rules()`` returns the tree-scan set; the plan-coverage rule is
appended by the engine only when ``--against-artifact`` names an
artifact (it needs one to check against).
"""

from __future__ import annotations

from typing import List

from repro.analysis.rules.boundary import BoundaryP2PRule, BoundaryRingRule
from repro.analysis.rules.degrade import DegradedWithoutReasonRule
from repro.analysis.rules.descriptors import (DanglingFusedRule,
                                              DuplicateSiteRule,
                                              FusedTargetUnregisteredRule,
                                              LiteralFlagsRule)
from repro.analysis.rules.fences import (FusedCycleRule,
                                         UnfencedDoubleWriteRule)
from repro.analysis.rules.coverage import PlanCoverageRule


def default_rules() -> List:
    return [BoundaryP2PRule(), BoundaryRingRule(), DuplicateSiteRule(),
            LiteralFlagsRule(), DanglingFusedRule(),
            FusedTargetUnregisteredRule(),
            UnfencedDoubleWriteRule(), FusedCycleRule(),
            DegradedWithoutReasonRule()]


__all__ = ["default_rules", "BoundaryP2PRule", "BoundaryRingRule",
           "DuplicateSiteRule", "LiteralFlagsRule", "DanglingFusedRule",
           "FusedTargetUnregisteredRule",
           "UnfencedDoubleWriteRule", "FusedCycleRule",
           "DegradedWithoutReasonRule", "PlanCoverageRule"]
