"""Descriptor-integrity rules over every extracted ``TransferDescriptor``.

The issue log and dryrun artifacts key per-site records by
``desc.site_label`` (``site or name``) — two descriptors sharing a label
in one module silently overwrite each other's ``comm_issued`` entries.
``fused_with`` must name a real consumer site: a dangling target (a typo
like ``"moe.expert_ffn "``) used to silently never fuse; now it is both a
lint finding here and a typed runtime error at the socket
(``core.comm.UnregisteredFusionTargetError`` — runtime and lint agree).
``sync``/``pull`` must be literal booleans so the planner (and this
analyzer's happens-before pass) can reason about fencing statically.
"""

from __future__ import annotations

from typing import Dict, List

from repro.analysis.engine import Finding, Rule
from repro.analysis.extract import NON_LITERAL, ModuleFacts


class DuplicateSiteRule(Rule):
    id = "descriptor-dup-site"
    summary = ("TransferDescriptor site labels must be unique within a "
               "module (duplicate labels collide in the issue log)")

    def check_module(self, facts: ModuleFacts) -> List[Finding]:
        seen: Dict[str, int] = {}
        out = []
        for d in facts.descriptors:
            label = d.site_label
            if label is None:
                continue
            if label in seen:
                out.append(Finding(
                    self.id, facts.path, d.line,
                    f"descriptor site label {label!r} already used at line "
                    f"{seen[label]} — per-site issue-log entries would "
                    f"silently overwrite each other; give one of them a "
                    f"distinct site="))
            else:
                seen[label] = d.line
        return out


class LiteralFlagsRule(Rule):
    id = "descriptor-literal-flags"
    summary = ("sync= / pull= on a TransferDescriptor must be literal "
               "booleans the planner can reason about")

    def check_module(self, facts: ModuleFacts) -> List[Finding]:
        out = []
        for d in facts.descriptors:
            for field, value in (("sync", d.sync), ("pull", d.pull)):
                if value == NON_LITERAL:
                    out.append(Finding(
                        self.id, facts.path, d.line,
                        f"{field}= on descriptor "
                        f"{d.site_label or '<dynamic>'} is not a literal "
                        f"boolean — the planner and the fence pass cannot "
                        f"reason about a dynamic {field} flag"))
        return out


class DanglingFusedRule(Rule):
    id = "descriptor-dangling-fused"
    summary = ("fused_with targets must resolve to an extracted descriptor "
               "site or a register_fusion_target() registration")

    def check_tree(self, modules: List[ModuleFacts]) -> List[Finding]:
        universe = set()
        for facts in modules:
            universe.update(label for label, _ in facts.fusion_registrations)
            universe.update(d.site_label for d in facts.descriptors
                            if d.site_label is not None)
        out = []
        for facts in modules:
            for d in facts.descriptors:
                if d.fused_with is None or d.fused_with in universe:
                    continue
                out.append(Finding(
                    self.id, facts.path, d.line,
                    f"fused_with={d.fused_with!r} on descriptor "
                    f"{d.site_label or '<dynamic>'} resolves to no "
                    f"extracted descriptor site and no registered fusion "
                    f"target — the transfer would silently never fuse "
                    f"(register the consumer matmul with "
                    f"core.comm.register_fusion_target)"))
        return out


class FusedTargetUnregisteredRule(Rule):
    id = "fused-target-unregistered"
    summary = ("fused_with targets must appear in a register_fusion_target() "
               "call — implicit resolution through a descriptor's own site "
               "label hides the chain contract")

    def check_tree(self, modules: List[ModuleFacts]) -> List[Finding]:
        registered, sites = set(), set()
        for facts in modules:
            registered.update(label for label, _
                              in facts.fusion_registrations)
            sites.update(d.site_label for d in facts.descriptors
                         if d.site_label is not None)
        out = []
        for facts in modules:
            for d in facts.descriptors:
                if d.fused_with is None or d.fused_with in registered:
                    continue
                if d.fused_with not in sites:
                    # in NEITHER universe: the runtime would raise
                    # UnregisteredFusionTargetError — that is
                    # descriptor-dangling-fused's finding, not ours
                    continue
                out.append(Finding(
                    self.id, facts.path, d.line,
                    f"fused_with={d.fused_with!r} on descriptor "
                    f"{d.site_label or '<dynamic>'} resolves only through "
                    f"a descriptor site label, never through a "
                    f"register_fusion_target() call — the consumer of a "
                    f"chain fusion must be registered explicitly so the "
                    f"contract survives a site rename (add "
                    f"register_fusion_target({d.fused_with!r}) next to "
                    f"the consumer)"))
        return out
