"""Sync-fence race rules: a conservative happens-before pass.

Per function body (straight-line statement order), two ``write`` /
``mem_write`` issues to the same descriptor label with no intervening
fence race on the consumer: the second burst can overtake the first's
consumption on the NoC (the paper's C3 sync region exists exactly to
order this).  A fence is a ``sync=True`` descriptor issue (the socket
folds the C3 barrier in), a ``reduce`` (psum is its own ordering point),
or an explicit ``barrier``.

The second rule closes the ``fused_with`` graph: descriptors whose
``fused_with`` edges form a cycle of length >= 2 declare a circular
producer/consumer adjacency no schedule can realize (A hides behind B's
matmul while B hides behind A's).  A self-edge is legal and common — a
descriptor named after its own consumer matmul (``attn.o_proj``) feeds
exactly that matmul.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.analysis.engine import Finding, Rule
from repro.analysis.extract import ModuleFacts


class UnfencedDoubleWriteRule(Rule):
    id = "fence-double-write"
    summary = ("two writes to the same descriptor label in one body need "
               "an intervening sync=True fence / reduce / barrier")

    def check_module(self, facts: ModuleFacts) -> List[Finding]:
        out = []
        for func, calls in facts.sequences:
            pending: Dict[str, int] = {}
            for c in calls:
                if c.kind == "fence" or (c.kind == "write" and c.sync):
                    # the C3 barrier orders everything issued before it
                    pending.clear()
                if c.kind != "write" or c.label is None:
                    continue
                if c.label in pending:
                    out.append(Finding(
                        self.id, facts.path, c.line,
                        f"unfenced double write to {c.label!r} in {func} "
                        f"(previous write at line {pending[c.label]}): the "
                        f"second burst can overtake the first's consumption "
                        f"— fold a fence in (sync=True on the descriptor) "
                        f"or reduce between them"))
                pending[c.label] = c.line
        return out


class FusedCycleRule(Rule):
    id = "fence-fused-cycle"
    summary = ("fused_with edges between descriptor sites must not form a "
               "cycle (length >= 2): no schedule can overlap both ways")

    def check_tree(self, modules: List[ModuleFacts]) -> List[Finding]:
        nodes: Dict[str, Tuple[str, int]] = {}     # label -> (path, line)
        edges: Dict[str, str] = {}                 # label -> fused target
        for facts in modules:
            for d in facts.descriptors:
                label = d.site_label
                if label is None:
                    continue
                nodes.setdefault(label, (facts.path, d.line))
                if d.fused_with is not None and d.fused_with != label:
                    edges[label] = d.fused_with
        out = []
        reported = set()
        for start in edges:
            seen: Dict[str, int] = {}
            cur, i = start, 0
            while cur in edges and cur not in seen:
                seen[cur] = i
                cur, i = edges[cur], i + 1
            if cur not in seen:          # walked off the graph: no cycle
                continue
            cycle = sorted(label for label, idx in seen.items()
                           if idx >= seen[cur])
            key = tuple(cycle)
            if key in reported:
                continue
            reported.add(key)
            anchor = min(cycle, key=lambda m: nodes.get(m, ("", 1 << 30)))
            path, line = nodes.get(anchor, (modules[0].path, 0))
            out.append(Finding(
                self.id, path, line,
                f"fused_with cycle between descriptor sites "
                f"{' -> '.join(cycle + [cycle[0]])}: each transfer claims "
                f"to hide behind the other's consumer matmul — break the "
                f"cycle (one of them is not matmul-adjacent)"))
        return out
