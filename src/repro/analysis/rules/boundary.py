"""Socket-boundary rules: the spine invariant, enforced on the AST.

Every transfer outside ``core/`` issues through ``AcceleratorSocket``
from a ``TransferDescriptor`` (docs/interface.md).  The old CI grep gates
only saw the literal strings ``repro.core.p2p`` / ``ring_`` — an aliased
import, an ``importlib`` load, or ``from repro import core; core.p2p...``
sailed straight through.  These rules match the *resolved* module
reference instead.
"""

from __future__ import annotations

from typing import List

from repro.analysis.engine import Finding, Rule
from repro.analysis.extract import (ZONE_CORE, ZONE_KERNELS, ZONE_TESTS,
                                    ModuleFacts)

_COLLECTIVE_MODULES = ("repro.core.p2p", "repro.core.multicast")
_RING_PREFIX = "repro.kernels.ring_"


def _matches(module: str, root: str) -> bool:
    return module == root or module.startswith(root + ".")


class BoundaryP2PRule(Rule):
    id = "boundary-p2p"
    summary = ("no repro.core.p2p / repro.core.multicast use outside core/ "
               "— route transfers through AcceleratorSocket")

    def check_module(self, facts: ModuleFacts) -> List[Finding]:
        if facts.zone in (ZONE_CORE, ZONE_TESTS):
            return []
        out = []
        for use in facts.uses:
            if any(_matches(use.module, m) for m in _COLLECTIVE_MODULES):
                out.append(Finding(
                    self.id, facts.path, use.line,
                    f"direct {use.module} reference (via {use.via}) outside "
                    f"core/ — issue the transfer through AcceleratorSocket "
                    f"with a TransferDescriptor (docs/interface.md)"))
        return out


class BoundaryRingRule(Rule):
    id = "boundary-ring"
    summary = ("no repro.kernels.ring_* use outside core/ and kernels/ — "
               "dispatch through the socket's FUSED_RING path")

    def check_module(self, facts: ModuleFacts) -> List[Finding]:
        if facts.zone in (ZONE_CORE, ZONE_KERNELS, ZONE_TESTS):
            return []
        out = []
        for use in facts.uses:
            if use.module.startswith(_RING_PREFIX):
                out.append(Finding(
                    self.id, facts.path, use.line,
                    f"direct ring kernel reference {use.module} (via "
                    f"{use.via}) outside core//kernels/ — dispatch through "
                    f"AcceleratorSocket.gather_matmul / "
                    f"matmul_reduce_scatter (docs/interface.md)"))
        return out
