"""Plan-coverage rule: ``mismatched_sites()`` made static, shifted left.

``--against-artifact <dryrun.json>`` cross-checks the artifact's
``comm_issued`` sites (what the traced step actually dispatched, per site
label) against the descriptor/implicit sites this scan extracted from the
tree.  A site the artifact reports but the tree no longer declares means
the artifact is stale or a site was renamed without re-running the
dryrun — the descriptor/plan drift CI should catch before it ships.
"""

from __future__ import annotations

import json
from typing import List

from repro.analysis.engine import Finding, Rule
from repro.analysis.extract import ModuleFacts


def static_site_universe(modules: List[ModuleFacts]) -> set:
    """Every site label the tree can issue under: descriptor site labels
    plus the implicit sites (``mem_write`` names, ``record_implicit_issue``
    site literals)."""
    universe = set()
    for facts in modules:
        universe.update(d.site_label for d in facts.descriptors
                        if d.site_label is not None)
        universe.update(facts.implicit_sites)
    return universe


class PlanCoverageRule(Rule):
    id = "plan-uncovered-site"
    summary = ("every comm_issued site in the dryrun artifact must map to "
               "an extracted descriptor/implicit site in the tree")

    def __init__(self, artifact_path: str):
        self.artifact_path = artifact_path

    def check_tree(self, modules: List[ModuleFacts]) -> List[Finding]:
        try:
            with open(self.artifact_path, encoding="utf-8") as f:
                artifact = json.load(f)
        except (OSError, ValueError) as e:
            return [Finding(self.id, self.artifact_path, 0,
                            f"cannot read dryrun artifact: {e}")]
        issued = artifact.get("comm_issued") or {}
        if not issued:
            return [Finding(
                self.id, self.artifact_path, 0,
                "artifact carries no comm_issued sites — re-run the dryrun "
                "with --comm-plan=auto so the issue log is populated")]
        universe = static_site_universe(modules)
        out = []
        for site in sorted(issued):
            # continuous-batching artifacts scope keys by issue epoch
            # ("engine.kv_prefix@prefill"); the static universe knows the
            # bare site label — the epoch is a runtime scope, not a site
            bare = site.split("@", 1)[0]
            if bare not in universe:
                out.append(Finding(
                    self.id, self.artifact_path, 0,
                    f"artifact site {site!r} (tensor "
                    f"{issued[site].get('tensor')!r}) matches no extracted "
                    f"descriptor or implicit issue site in the scanned tree "
                    f"— stale artifact or renamed site"))
        return out
