"""Benchmark driver: one function per paper table/figure + framework tables.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention, plus
human-readable tables to stderr-like sections.  Sources:

  fig4_router_area      — paper Fig. 4 (area model vs published numbers)
  fig6_multicast        — paper Fig. 6 (NoC perf model vs milestones)
  noc_flit_microbench   — flit simulator throughput (cycles/flit)
  comm_mode_bytes       — MoE mem vs mcast collective bytes (C2/C4, from
                          compiled HLO of the production step)
  roofline_table        — per (arch x shape x mesh) roofline terms from the
                          dry-run artifacts in experiments/dryrun/
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

import numpy as np

from repro.core.comm import CommMode
from repro.core.noc.router import base_router_area, router_area
from repro.core.noc.perfmodel import SoCPerfModel, PAPER_MILESTONES
from repro.core.noc.simulator import MeshNoC, Message
from repro.core.planner import CommPlanner, TransferSpec
from repro.configs.espsoc_trafficgen import (CONSUMER_SWEEP, SIZE_SWEEP,
                                             BITWIDTH_SWEEP, DEST_SWEEP)

_ROWS = []


def _row(name: str, us: float, derived: str = ""):
    _ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}")


# ------------------------------------------------------------- Fig. 4 ----

def fig4_router_area():
    print("# Fig4: post-synthesis router area (um^2), 12nm model")
    print("# bitwidth,dests,area_um2,overhead_vs_baseline")
    t0 = time.perf_counter()
    for w in BITWIDTH_SWEEP:
        base = base_router_area(w)
        for d in DEST_SWEEP:
            a = router_area(w, d)
            print(f"# {w},{d},{a:.0f},{a / base - 1:.3f}")
    us = (time.perf_counter() - t0) * 1e6 / (len(BITWIDTH_SWEEP) *
                                             len(DEST_SWEEP))
    checks = [
        abs(base_router_area(64) - 3620) < 1,
        abs(base_router_area(128) - 6230) < 1,
        abs(base_router_area(256) - 11520) < 1,
        router_area(64, 4) / base_router_area(64) < 1.30,
        router_area(128, 8) / base_router_area(128) < 1.30,
        router_area(256, 16) / base_router_area(256) < 1.30,
    ]
    _row("fig4_router_area", us,
         f"paper_checks={sum(checks)}/{len(checks)}")


# ------------------------------------------------------------- Fig. 6 ----

def fig6_multicast() -> float:
    """Prints the Fig. 6 grid; returns the max relative milestone error
    (the --fig6-check gate consumes it)."""
    print("# Fig6: multicast vs shared-memory speedup "
          "(burst-level DES of the 3x4 SoC)")
    print("# consumers," + ",".join(f"{s//1024}KB" for s in SIZE_SWEEP))
    model = SoCPerfModel()
    t0 = time.perf_counter()
    sweep = model.sweep(CONSUMER_SWEEP, SIZE_SWEEP)
    dt = time.perf_counter() - t0
    for n in CONSUMER_SWEEP:
        print(f"# {n}," + ",".join(f"{sweep[(n, s)]:.2f}" for s in SIZE_SWEEP))
    errs = []
    for (n, s), target in PAPER_MILESTONES.items():
        got = sweep.get((n, s)) or model.speedup(n, s)
        errs.append(abs(got - target) / target)
        print(f"# milestone ({n} consumers, {s//1024}KB): model {got:.2f} "
              f"vs paper {target:.2f} ({(got-target)/target:+.1%})")
    _row("fig6_multicast_speedup", dt * 1e6 / len(sweep),
         f"max_milestone_err={max(errs):.3f}")
    return max(errs)


def comm_plan_fig6() -> bool:
    """Planner policy comparison over the Fig. 6 grid: the cost-model-driven
    ``auto`` plan vs the two constant policies (always-MEM = the paper's
    shared-memory baseline; always-MCAST = always take the direct path).

    Returns True when the acceptance checks hold: the planner selects MCAST
    at all three paper milestones, its predicted speedup over always-MEM is
    within +-10% of the quoted 1.72x / 2.20x / 3.03x, and the auto plan is
    never slower than either constant policy at any grid point.
    """
    print("# CommPlanner policies over the Fig. 6 grid (cycles per point)")
    print("# consumers,bytes,mem,mcast,auto_mode,auto,auto_vs_mem")
    planner = CommPlanner()
    grid = [(n, s) for n in CONSUMER_SWEEP for s in SIZE_SWEEP]
    specs = [TransferSpec(f"xfer_{n}x{s}", nbytes=s, fan_out=n)
             for n, s in grid]
    t0 = time.perf_counter()
    decisions = planner.price(specs)       # one batched model sweep
    dt = time.perf_counter() - t0
    tot = {"mem": 0.0, "mcast": 0.0, "auto": 0.0}
    never_slower = True
    for (n, s), d in zip(grid, decisions):
        mem, mcast = d.cycles["mem"], d.cycles["mcast"]
        auto = d.cycles["mem"] if d.mode is CommMode.MEM else d.cycles["mcast"]
        tot["mem"] += mem
        tot["mcast"] += mcast if np.isfinite(mcast) else mem
        tot["auto"] += auto
        never_slower &= auto <= mem + 1e-9 and (
            not np.isfinite(mcast) or auto <= mcast + 1e-9)
        print(f"# {n},{s},{mem:.0f},{mcast:.0f},{d.mode.name},{auto:.0f},"
              f"{mem / auto:.2f}x")
    milestones_ok = 0
    for (n, s), target in PAPER_MILESTONES.items():
        d = decisions[grid.index((n, s))]
        ok = (d.mode is CommMode.MCAST and
              abs(d.speedup_vs_mem - target) / target <= 0.10)
        milestones_ok += ok
        print(f"# milestone ({n} consumers, {s//1024}KB): mode={d.mode.name} "
              f"planner {d.speedup_vs_mem:.2f}x vs paper {target:.2f}x "
              f"-> {'OK' if ok else 'FAIL'}")
    passed = milestones_ok == len(PAPER_MILESTONES) and never_slower
    _row("comm_plan_fig6", dt * 1e6 / len(grid),
         f"auto_vs_mem={tot['mem'] / tot['auto']:.2f}x;"
         f"auto_vs_mcast={tot['mcast'] / tot['auto']:.2f}x;"
         f"milestones_ok={milestones_ok}/{len(PAPER_MILESTONES)};"
         f"never_slower={never_slower}")
    return passed


def noc_flit_microbench():
    t0 = time.perf_counter()
    noc = MeshNoC(4, 3, bitwidth=256)
    mid = noc.inject(Message((1, 0), ((3, 2), (0, 2), (2, 1)), 64))
    cycles = noc.drain()
    dt = time.perf_counter() - t0
    delivered = sum(len(noc.received(d, mid))
                    for d in ((3, 2), (0, 2), (2, 1)))
    _row("noc_flit_sim_3dest_64flit", dt * 1e6,
         f"cycles={cycles};flits_delivered={delivered}")


# ---------------------------------------------- comm modes (C2/C4, HLO) ----

def comm_mode_bytes():
    """Collective wire bytes of the dbrx MoE layer under the two modes —
    the production-framework analogue of Fig. 6 (multicast vs memory)."""
    import jax
    if len(jax.devices()) < 2:
        # measured from the persisted dry-run artifacts instead (the
        # matrix runs in a 512-device process)
        mem = _load_cell("dbrx-132b", "train_4k", "16x16", "mem")
        mc = _load_cell("dbrx-132b", "train_4k", "16x16", "mcast")
        if mem is None or mc is None:
            _row("comm_mode_bytes", 0.0, "needs dryrun artifacts (mem+mcast)")
            return
        b_mem = mem["roofline"]["wire_bytes_per_dev"]
        b_mc = mc["roofline"]["wire_bytes_per_dev"]
        _row("comm_mode_bytes", 0.0,
             f"mem_GB={b_mem/1e9:.2f};mcast_GB={b_mc/1e9:.2f};"
             f"saving={1 - b_mc / b_mem:.1%}")
        return


def _load_cell(arch, shape, mesh, mode=None, tag=""):
    suffix = (f"_{mode}" if mode else "") + (f"_{tag}" if tag else "")
    path = f"experiments/dryrun/{arch}_{shape}_{mesh}{suffix}.json"
    if not os.path.exists(path):
        return None
    return json.load(open(path))


# -------------------------------------------------------- roofline table ----

def roofline_table():
    print("# Roofline per (arch x shape x mesh) from dry-run artifacts")
    print("# arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_flops_ratio,roofline_fraction,peak_GiB,fits16GB")
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    n = 0
    worst = (1.0, None)
    for f in files:
        d = json.load(open(f))
        if d.get("skipped") or d.get("moe_mode") == "mcast":
            continue
        if "_hc" in os.path.basename(f):
            continue
        r, m = d["roofline"], d["memory"]
        print(f"# {d['arch']},{d['shape']},{d['mesh']},"
              f"{r['compute_s']*1e3:.1f},{r['memory_s']*1e3:.1f},"
              f"{r['collective_s']*1e3:.1f},{r['dominant']},"
              f"{r['useful_flops_ratio']:.2f},{r['roofline_fraction']:.4f},"
              f"{m['peak_bytes_est_per_dev']/2**30:.1f},"
              f"{m['fits_16gb']}")
        n += 1
        if r["roofline_fraction"] < worst[0]:
            worst = (r["roofline_fraction"], f"{d['arch']}x{d['shape']}")
    _row("roofline_table", 0.0, f"cells={n};worst={worst[1]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig6-check", action="store_true",
                    help="run only the Fig. 6 model + planner milestone "
                         "checks and exit nonzero on failure (CI gate)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.fig6_check:
        max_err = fig6_multicast()
        ok = comm_plan_fig6()
        if max_err > 0.10:
            print(f"# FAIL: Fig. 6 milestone error {max_err:.1%} > 10%")
            raise SystemExit(1)
        if not ok:
            print("# FAIL: planner policy checks failed")
            raise SystemExit(1)
        print("# fig6-check passed")
        return
    fig4_router_area()
    fig6_multicast()
    comm_plan_fig6()
    noc_flit_microbench()
    comm_mode_bytes()
    roofline_table()


if __name__ == "__main__":
    main()
