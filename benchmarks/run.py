"""Benchmark driver: one function per paper table/figure + framework tables.

Prints ``name,us_per_call,derived,spread`` CSV rows per the repo
convention (``spread`` = best-of-N max-min gap in the same microsecond
units, 0 for single-sample rows; see docs/perfmodel.md "Noise
convention"), plus human-readable tables to stderr-like sections.
Sources:

  fig4_router_area      — paper Fig. 4 (area model vs published numbers)
  fig6_multicast        — paper Fig. 6 (closed-form batch path of the NoC
                          perf model vs milestones)
  comm_plan_fig6        — planner policy comparison over the Fig. 6 grid,
                          with the closed-form vs scalar-DES pricing ratio
  ring_fused_matmul     — overlap objective (FUSED_RING pricing): serial
                          vs max(comm, compute)+ramp over the Fig. 6 grid
  step_overlap          — comm_overlap_fraction of the modeled dbrx-132b
                          train_4k step through the resolved rules; fails
                          below the 0.50 floor, and check_baseline fails
                          any exact decrease vs the committed fraction
  pod_allreduce_compressed — int8 vs raw f32 pod gradient all-reduce
                          (the priced compressed_psum transfer); fails if
                          int8 stops beating raw on modeled cycles
  noc_flit_microbench   — vectorized flit simulator vs the object-based
                          reference on one congested multicast workload
  noc_mesh_scale        — vectorized simulator drain throughput per mesh
                          size (4x3 ... 16x16), bursty waves with
                          fast-forwarded quiescent gaps; all NoC rows are
                          timed best-of-3 (minima, not noisy samples)
  commcheck_scan        — wall time of the full commcheck static gate
                          (best-of-3); fails outright if the tree carries
                          findings, so the row doubles as the lint invariant
  calib_fit             — wall time of one calib.fit_soc_params round trip
                          (best-of-3); fails outright if the fit stops
                          recovering the ground truth it synthesized from
  comm_mode_bytes       — MoE mem vs mcast collective bytes (C2/C4, from
                          compiled HLO of the production step)
  roofline_table        — per (arch x shape x mesh) roofline terms from the
                          dry-run artifacts in experiments/dryrun/

``--bench-noc`` runs the NoC/planner/serve/calibration battery, writes it
to a JSON file (default BENCH_noc.json) and, with ``--baseline``, fails
when a row's us_per_call regresses past ``CI_BENCH_TOL`` (default 5x —
wall-clock noise on shared CI boxes is large) — the scripts/ci.sh
regression gate.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import random
import time

import numpy as np

from repro.core.comm import CommMode
from repro.core.noc.router import base_router_area, router_area
from repro.core.noc.perfmodel import SoCPerfModel, PAPER_MILESTONES
from repro.core.noc.simulator import MeshNoC, Message
from repro.core.noc.reference_sim import ReferenceMeshNoC
from repro.core.planner import (CommPlanner, TransferSpec,
                                comm_overlap_fraction, mode_mix,
                                modeled_step_cycles)
from repro.configs.espsoc_trafficgen import (CONSUMER_SWEEP, SIZE_SWEEP,
                                             BITWIDTH_SWEEP, DEST_SWEEP,
                                             MESH_SCALE_SWEEP)

_ROWS = []


def _row(name: str, us: float, derived: str = "", spread: float = 0.0):
    """One CSV row.  ``spread`` is the best-of-N max-min gap in the same
    microsecond units as ``us`` (0 for single-sample rows) — the run-to-run
    noise band that rides next to the minimum; see docs/perfmodel.md
    ("Noise convention")."""
    _ROWS.append((name, us, derived, spread))
    print(f"{name},{us:.3f},{derived},{spread:.3f}")


# ------------------------------------------------------------- Fig. 4 ----

def fig4_router_area():
    print("# Fig4: post-synthesis router area (um^2), 12nm model")
    print("# bitwidth,dests,area_um2,overhead_vs_baseline")
    t0 = time.perf_counter()
    for w in BITWIDTH_SWEEP:
        base = base_router_area(w)
        for d in DEST_SWEEP:
            a = router_area(w, d)
            print(f"# {w},{d},{a:.0f},{a / base - 1:.3f}")
    us = (time.perf_counter() - t0) * 1e6 / (len(BITWIDTH_SWEEP) *
                                             len(DEST_SWEEP))
    checks = [
        abs(base_router_area(64) - 3620) < 1,
        abs(base_router_area(128) - 6230) < 1,
        abs(base_router_area(256) - 11520) < 1,
        router_area(64, 4) / base_router_area(64) < 1.30,
        router_area(128, 8) / base_router_area(128) < 1.30,
        router_area(256, 16) / base_router_area(256) < 1.30,
    ]
    _row("fig4_router_area", us,
         f"paper_checks={sum(checks)}/{len(checks)}")


# ------------------------------------------------------------- Fig. 6 ----

def fig6_multicast() -> float:
    """Prints the Fig. 6 grid; returns the max relative milestone error
    (the --fig6-check gate consumes it)."""
    print("# Fig6: multicast vs shared-memory speedup "
          "(closed-form batch path, bit-exact vs the scalar DES)")
    print("# consumers," + ",".join(f"{s//1024}KB" for s in SIZE_SWEEP))
    model = SoCPerfModel()
    t0 = time.perf_counter()
    sweep = model.sweep(CONSUMER_SWEEP, SIZE_SWEEP)
    dt = time.perf_counter() - t0
    for n in CONSUMER_SWEEP:
        print(f"# {n}," + ",".join(f"{sweep[(n, s)]:.2f}" for s in SIZE_SWEEP))
    errs = []
    for (n, s), target in PAPER_MILESTONES.items():
        got = sweep.get((n, s))
        if got is None:
            # a falsy-zero `or`-fallback here used to silently re-run the
            # scalar DES; a missing milestone point is a sweep-grid bug
            raise SystemExit(
                f"# FAIL: milestone point ({n} consumers, {s} bytes) absent "
                f"from the Fig. 6 sweep grid")
        errs.append(abs(got - target) / target)
        print(f"# milestone ({n} consumers, {s//1024}KB): model {got:.2f} "
              f"vs paper {target:.2f} ({(got-target)/target:+.1%})")
    _row("fig6_multicast_speedup", dt * 1e6 / len(sweep),
         f"max_milestone_err={max(errs):.3f}")
    return max(errs)


def comm_plan_fig6() -> bool:
    """Planner policy comparison over the Fig. 6 grid: the cost-model-driven
    ``auto`` plan vs the two constant policies (always-MEM = the paper's
    shared-memory baseline; always-MCAST = always take the direct path).

    Returns True when the acceptance checks hold: the planner selects MCAST
    at all three paper milestones, its predicted speedup over always-MEM is
    within +-10% of the quoted 1.72x / 2.20x / 3.03x, and the auto plan is
    never slower than either constant policy at any grid point.
    """
    print("# CommPlanner policies over the Fig. 6 grid (cycles per point)")
    print("# consumers,bytes,mem,mcast,auto_mode,auto,auto_vs_mem")
    planner = CommPlanner()
    grid = [(n, s) for n in CONSUMER_SWEEP for s in SIZE_SWEEP]
    specs = [TransferSpec(f"xfer_{n}x{s}.L{i}", nbytes=s, fan_out=n, layer=i)
             for i, (n, s) in enumerate(grid)]
    t0 = time.perf_counter()
    decisions = planner.price(specs)       # one closed-form model sweep
    dt = time.perf_counter() - t0
    # per-layer mode mix: the planner's verdicts are per transfer (layer),
    # not one step-level mode — an empty mix means the pricing produced no
    # decisions at all, which is a planner bug, not a benchmark result
    mix = mode_mix(decisions)
    if sum(mix.values()) == 0:
        raise SystemExit("# FAIL: comm_plan_fig6 produced an empty per-layer "
                         "mode mix — the planner returned no decisions")
    # the same pricing through the scalar DES, for the speedup report
    model = planner.model
    t0 = time.perf_counter()
    for n, s in grid:
        model.shared_memory_cycles(n, s)
        model.multicast_cycles(n, s)
    dt_scalar = time.perf_counter() - t0
    tot = {"mem": 0.0, "mcast": 0.0, "auto": 0.0}
    never_slower = True
    for (n, s), d in zip(grid, decisions):
        mem, mcast = d.cycles["mem"], d.cycles["mcast"]
        auto = d.cycles["mem"] if d.mode is CommMode.MEM else d.cycles["mcast"]
        tot["mem"] += mem
        tot["mcast"] += mcast if np.isfinite(mcast) else mem
        tot["auto"] += auto
        never_slower &= auto <= mem + 1e-9 and (
            not np.isfinite(mcast) or auto <= mcast + 1e-9)
        print(f"# {n},{s},{mem:.0f},{mcast:.0f},{d.mode.name},{auto:.0f},"
              f"{mem / auto:.2f}x")
    milestones_ok = 0
    for (n, s), target in PAPER_MILESTONES.items():
        d = decisions[grid.index((n, s))]
        ok = (d.mode is CommMode.MCAST and
              abs(d.speedup_vs_mem - target) / target <= 0.10)
        milestones_ok += ok
        print(f"# milestone ({n} consumers, {s//1024}KB): mode={d.mode.name} "
              f"planner {d.speedup_vs_mem:.2f}x vs paper {target:.2f}x "
              f"-> {'OK' if ok else 'FAIL'}")
    passed = milestones_ok == len(PAPER_MILESTONES) and never_slower
    _row("comm_plan_fig6", dt * 1e6 / len(grid),
         f"mix=MEM:{mix['MEM']}/P2P:{mix['P2P']}/MCAST:{mix['MCAST']};"
         f"auto_vs_mem={tot['mem'] / tot['auto']:.2f}x;"
         f"auto_vs_mcast={tot['mcast'] / tot['auto']:.2f}x;"
         f"milestones_ok={milestones_ok}/{len(PAPER_MILESTONES)};"
         f"never_slower={never_slower};"
         f"vs_scalar_des={dt_scalar / max(dt, 1e-9):.1f}x")
    return passed


# ------------------------------------------------- flit simulator rows ----

def _scale_traffic(w, h, n_msgs, fan, n_flits, seed=2, waves=1, wave_gap=0):
    """Randomized multicast traffic; with ``waves > 1`` messages inject in
    bursty waves ``wave_gap`` cycles apart — the quiescent gaps between
    waves are what the vectorized stepper's fast-forward skips."""
    rng = random.Random(seed)
    nodes = [(x, y) for x in range(w) for y in range(h)]
    fan = min(fan, len(nodes))
    per_wave = max(1, n_msgs // waves)
    return [(rng.choice(nodes), tuple(rng.sample(nodes, fan)), n_flits,
             (i // per_wave) * wave_gap)
            for i in range(n_msgs)]


def _drain(noc_cls, w, h, msgs):
    noc = noc_cls(w, h)
    t0 = time.perf_counter()
    for src, dests, n, at in msgs:
        noc.inject(Message(src, dests, n, inject_cycle=at))
    cycles = noc.drain()
    dt = time.perf_counter() - t0
    return dt, cycles, noc


def _best_of(n, fn):
    """Best-of-N wall clock (compares minima, like
    ``socket_dispatch_overhead``): shared benchmark boxes jitter by tens
    of percent, and the CI_BENCH_TOL gate should see the machine's floor,
    not one noisy sample.  Returns ``(best_result, spread_seconds)`` where
    the spread is the max-min gap of the timed element ``r[0]`` across the
    N samples — the noise band the ``spread`` CSV column reports."""
    results = [fn() for _ in range(n)]
    times = [r[0] for r in results]
    return min(results, key=lambda r: r[0]), max(times) - min(times)


def noc_flit_microbench():
    """Vectorized stepper vs the object-based reference on one congested
    16x16 multicast workload (identical traffic; the property tests prove
    the two deliver identical flit sequences).  Best-of-3 on both sides."""
    w, h = 16, 16
    msgs = _scale_traffic(w, h, n_msgs=384, fan=16, n_flits=16)
    (dt_vec, cycles, noc), sp = _best_of(
        3, lambda: _drain(MeshNoC, w, h, msgs))
    (dt_ref, cycles_ref, _), _ = _best_of(
        3, lambda: _drain(ReferenceMeshNoC, w, h, msgs))
    assert cycles == cycles_ref, (cycles, cycles_ref)
    delivered = sum(len(v) for v in noc._dlog().values())
    _row("noc_flit_microbench", dt_vec * 1e6,
         f"mesh=16x16;msgs=384;fan=16;cycles={cycles};"
         f"flits_delivered={delivered};hops={noc.total_hops};"
         f"ref_us={dt_ref * 1e6:.0f};vs_reference={dt_ref / dt_vec:.1f}x",
         spread=sp * 1e6)


def noc_mesh_scale():
    """Drain throughput of the vectorized simulator across mesh sizes up
    to 16x16 (the pod-scale envelope the property tests validate),
    best-of-3.  Traffic arrives in four bursty waves with quiescent gaps
    between them — the fast-forward jumps each gap straight to the next
    injection cycle instead of stepping it (``ffwd`` in the derived
    column counts the skipped cycles)."""
    for (w, h) in MESH_SCALE_SWEEP:
        n_nodes = w * h
        msgs = _scale_traffic(w, h, n_msgs=6 * n_nodes,
                              fan=min(8, n_nodes), n_flits=8, seed=1,
                              waves=4, wave_gap=4096)
        (dt, cycles, noc), sp = _best_of(
            3, lambda: _drain(MeshNoC, w, h, msgs))
        delivered = sum(len(v) for v in noc._dlog().values())
        _row(f"noc_mesh_scale_{w}x{h}", dt * 1e6,
             f"msgs={len(msgs)};cycles={cycles};ffwd={noc.ffwd_cycles};"
             f"flits_delivered={delivered};hops={noc.total_hops};"
             f"khops_per_s={noc.total_hops / dt / 1e3:.0f}",
             spread=sp * 1e6)


# ----------------------------------------------- overlap objective row ----

def ring_fused_matmul():
    """Overlap-aware pricing of matmul-adjacent transfers (the FUSED_RING
    dispatch's cost-model side): the Fig. 6 grid re-priced with each
    transfer feeding a consumer matmul of moderate arithmetic intensity,
    compared serial (compute waits for comm) vs overlapped
    (``max(comm, compute) + ramp`` for fusible modes).  Fails loudly if
    the overlap objective ever prices WORSE than serial (the planner's
    property-tested invariant) or nothing fuses."""
    planner = CommPlanner()
    grid = [(n, s) for n in CONSUMER_SWEEP for s in SIZE_SWEEP]
    # ~64 FLOPs per transferred byte: a matmul consumer whose compute is
    # on the order of the transfer itself — the regime overlap targets
    specs = [TransferSpec(f"fused_{n}x{s}.L{i}", nbytes=s, fan_out=n,
                          layer=i, compute_flops=64.0 * s)
             for i, (n, s) in enumerate(grid)]
    t0 = time.perf_counter()
    decisions = planner.price(specs)
    dt = time.perf_counter() - t0
    serial = modeled_step_cycles(decisions, objective="serial")
    overlap = modeled_step_cycles(decisions)
    frac = comm_overlap_fraction(decisions)
    fused = sum(d.fused for d in decisions)
    if overlap > serial + 1e-9:
        raise SystemExit("# FAIL: overlap objective priced worse than "
                         f"serial ({overlap} > {serial})")
    if fused == 0:
        raise SystemExit("# FAIL: ring_fused_matmul fused no transfers — "
                         "the overlap objective is dead")
    mix = mode_mix(decisions)
    _row("ring_fused_matmul", dt * 1e6 / len(specs),
         f"fused={fused}/{len(specs)};"
         f"mix=MEM:{mix['MEM']}/P2P:{mix['P2P']}/MCAST:{mix['MCAST']};"
         f"overlap_vs_serial={serial / overlap:.2f}x;"
         f"comm_hidden={frac:.1%}")


# ------------------------------------------------ whole-step overlap ----

def step_overlap():
    """Comm-overlap fraction of the full dbrx-132b train_4k step on the
    16x16 mesh — the headline the fused MoE dispatch chain and the
    double-buffered FSDP weight stream buy.  The specs are the modeled
    step (``step_transfer_specs`` with the roofline compute pool
    attached), priced by the planner and gated through the RESOLVED
    sharding rules (``resolve_rules`` applied to the plan, exactly the
    dryrun's relower-once path).  Fails outright below the 0.50 floor;
    ``check_baseline`` additionally fails any regression of the fraction
    against the committed baseline (it is closed-form and deterministic,
    so the gate is exact)."""
    from repro.configs import SHAPES, get_config
    from repro.core.planner import step_transfer_specs
    from repro.core.sharding import resolve_rules
    from repro.runtime.train import TRAIN_RULES

    cfg = get_config("dbrx-132b")
    shape = SHAPES["train_4k"]
    mesh_axes = {"data": 16, "model": 16}
    specs = step_transfer_specs(cfg, shape, mesh_axes, with_compute=True)
    planner = CommPlanner()
    t0 = time.perf_counter()
    plan, decisions = planner.plan_with_decisions(specs)
    resolved, overlay = resolve_rules(plan, dict(TRAIN_RULES))
    frac = comm_overlap_fraction(decisions, resolved)
    dt = time.perf_counter() - t0
    serial = modeled_step_cycles(decisions, resolved, objective="serial")
    overlap = modeled_step_cycles(decisions, resolved)
    fused = sum(1 for d in decisions if d.fused or d.streamed)
    if frac < 0.50:
        raise SystemExit(f"# FAIL: step_overlap comm_overlap_fraction "
                         f"{frac:.4f} < 0.50 — the fused step regressed")
    if overlap > serial + 1e-9:
        raise SystemExit("# FAIL: step_overlap priced overlap worse than "
                         f"serial ({overlap} > {serial})")
    _row("step_overlap", dt * 1e6 / max(len(specs), 1),
         f"arch=dbrx-132b;shape=train_4k;mesh=16x16;"
         f"overlap_frac={frac:.4f};fused={fused}/{len(decisions)};"
         f"overlay={','.join(sorted(overlay)) or 'none'};"
         f"serial_vs_overlap={serial / overlap:.2f}x")


# ------------------------------------------ compressed pod all-reduce ----

def pod_allreduce_compressed():
    """Priced pod-axis gradient all-reduce: raw f32 vs the int8 transfer
    ``optim.compression.compressed_psum`` issues through its
    ``TransferDescriptor`` site (word_bytes=1 — one wire byte per
    gradient element, the ``grad_reduce_compressed`` spec the planner
    prices).  Both sides best-of-3 (minima); the row fails loudly if the
    compressed transfer ever stops beating raw on modeled cycles — the
    whole point of quantizing the inter-pod hop."""
    pods = 8
    raw = [TransferSpec(f"grad_raw_{s}.L{i}", nbytes=4 * s, fan_out=pods,
                        layer=i, reduce=True, word_bytes=4)
           for i, s in enumerate(SIZE_SWEEP)]
    comp = [TransferSpec(f"grad_int8_{s}.L{i}", nbytes=s, fan_out=pods,
                         layer=i, reduce=True, word_bytes=1)
            for i, s in enumerate(SIZE_SWEEP)]

    def _price(specs):
        t0 = time.perf_counter()
        decisions = CommPlanner().price(specs)
        return time.perf_counter() - t0, decisions

    (dt_raw, dec_raw), _ = _best_of(3, lambda: _price(raw))
    (dt_c, dec_c), sp = _best_of(3, lambda: _price(comp))
    if any(d.mode is not CommMode.MEM for d in dec_raw + dec_c):
        raise SystemExit("# FAIL: pod_allreduce_compressed — a reduction "
                         "priced off the memory tile (NoC cannot combine "
                         "in flight)")
    cyc_raw = modeled_step_cycles(dec_raw)
    cyc_c = modeled_step_cycles(dec_c)
    if cyc_c >= cyc_raw:
        raise SystemExit("# FAIL: pod_allreduce_compressed — int8 pod "
                         f"all-reduce stopped beating raw ({cyc_c:.0f} >= "
                         f"{cyc_raw:.0f} modeled cycles)")
    _row("pod_allreduce_compressed", dt_c * 1e6 / len(comp),
         f"pods={pods};bytes_raw={sum(s.nbytes for s in raw)};"
         f"bytes_int8={sum(s.nbytes for s in comp)};"
         f"cycles_raw={cyc_raw:.0f};cycles_int8={cyc_c:.0f};"
         f"cycles_saved={(cyc_raw - cyc_c) / cyc_raw:.1%};"
         f"raw_price_us={dt_raw * 1e6 / len(raw):.3f}",
         spread=sp * 1e6 / len(comp))


# -------------------------------------------- socket dispatch overhead ----

def socket_dispatch_overhead():
    """Per-issue cost of the descriptor-based socket path (plan lookup +
    control-beat build + ISA user-field encode — everything
    ``AcceleratorSocket.resolve`` does at trace time) vs the direct-call
    baseline (the bare plan-dict lookup a hardcoded collective site pays).
    Both sides best-of-3; the overhead is per *trace*, never per step."""
    from repro.core.comm import CommPlan, TransferDescriptor
    from repro.core.socket import AcceleratorSocket, StageRegistry

    reg = StageRegistry("stage")
    reg.register("prefill", 0)
    for i in (1, 2, 3):
        reg.register(f"decode{i}", i)
    plan = CommPlan({"kv_prefix": CommMode.MCAST,
                     "stage_activation": CommMode.P2P})
    sock = AcceleratorSocket(reg, plan)
    desc = TransferDescriptor("kv_prefix", source="prefill",
                              dests=("decode1", "decode2", "decode3"))
    n = 20000

    def best(fn, reps=3):
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times), max(times) - min(times)

    def socket_side():
        for _ in range(n):
            sock.resolve(desc, 1 << 16, "write")

    def direct_side():
        for _ in range(n):
            plan.mode(desc.name)

    dt_sock, sp = best(socket_side)
    dt_direct, _ = best(direct_side)
    _row("socket_dispatch_overhead", dt_sock * 1e6 / n,
         f"direct_us={dt_direct * 1e6 / n:.3f};"
         f"vs_direct={dt_sock / max(dt_direct, 1e-12):.1f}x;"
         f"per_trace_not_per_step=True",
         spread=sp * 1e6 / n)


# ---------------------------------------------------------- serve load ----

def serve_load():
    """Continuous-batching serving throughput/latency under a
    deterministic Poisson arrival trace (``runtime.engine``), one dense
    and one MoE reduced config.  The row's ``us_per_call`` is
    microseconds per generated token (1e6 / tokens-per-second), best-of-3
    full trace runs on a fresh engine each time (trace + compile cost is
    amortized inside the run, exactly as a serving process would pay it);
    p50/p99 request latency ride the derived column.  Gated against
    BENCH_noc_baseline.json by CI_BENCH_TOL like every other row."""
    from repro.configs import get_reduced
    from repro.core import socket as socket_mod
    from repro.runtime.engine import ServeEngine, poisson_trace

    S, GEN, SLOTS, BS, NREQ = 16, 8, 3, 8, 6
    for arch in ("qwen3-4b", "dbrx-132b"):
        cfg = get_reduced(arch)

        def run():
            socket_mod.reset_issue_log()
            eng = ServeEngine(cfg, prompt_len=S, max_new_tokens=GEN,
                              n_slots=SLOTS, block_size=BS)
            trace = poisson_trace(NREQ, rate=0.8, prompt_len=S,
                                  vocab=cfg.vocab_size,
                                  max_new_tokens=GEN, seed=3)
            t0 = time.perf_counter()
            m = eng.run(trace)
            return time.perf_counter() - t0, m

        (dt, m), sp = _best_of(3, run)
        us = 1e6 / max(m.tokens_per_s, 1e-9)
        # spread in the row's own per-token units: the relative wall-clock
        # band applied to the reported us_per_call
        _row(f"serve_load_{arch}", us,
             f"tok_s={m.tokens_per_s:.1f};"
             f"p50_ms={m.p50_latency_s * 1e3:.1f};"
             f"p99_ms={m.p99_latency_s * 1e3:.1f};"
             f"requests={m.n_requests};steps={m.steps};"
             f"poisson_seed=3",
             spread=us * sp / max(dt, 1e-12))


# ------------------------------------------------------- commcheck scan ----

def commcheck_scan():
    """Wall time of the full commcheck static gate (the same scan
    scripts/ci.sh runs), best-of-3.  The row keeps the analyzer honest on
    two axes: it must stay fast enough to run on every commit (no jax
    import, one AST parse per file), and the tree it scans must stay
    clean — a finding here fails the bench like a regression."""
    from repro.analysis import DEFAULT_ALLOWLIST, analyze

    roots = [p for p in ("src/repro", "examples", "benchmarks", "scripts")
             if os.path.exists(p)]
    allow = DEFAULT_ALLOWLIST if os.path.exists(DEFAULT_ALLOWLIST) else None
    times, report = [], None
    for _ in range(3):
        t0 = time.perf_counter()
        report = analyze(roots, allowlist_path=allow)
        times.append(time.perf_counter() - t0)
    if not report.ok:
        for f in report.findings:
            print(f"# COMMCHECK FAIL: {f.render()}")
        raise SystemExit(1)
    _row("commcheck_scan", min(times) * 1e6,
         f"files={len(report.files)};findings=0;"
         f"suppressed={len(report.suppressed)};"
         f"allowlisted={len(report.allowlisted)}",
         spread=(max(times) - min(times)) * 1e6)


# ------------------------------------------------------- calibration fit ----

def calib_fit_bench():
    """Wall time of one full ``calib.fit_soc_params`` round trip (grid
    search over burst x link + the closed-form flops fit) on the standard
    flit-sim observation grid, best-of-3.  Like ``commcheck_scan`` the row
    doubles as an invariant: it fails outright if the fit stops recovering
    the ground-truth ``SoCParams`` it synthesized from — the calibration
    loop's end-to-end correctness, timed."""
    import dataclasses as _dc

    from repro.calib import fit as calib_fit, measure
    from repro.core.noc.perfmodel import SoCParams

    truth = SoCParams(link_latency=2, burst_bytes=8192,
                      flops_per_cycle=4096.0)
    obs = (measure.flit_sim_observations(truth) +
           measure.compute_observations(truth))
    base = _dc.replace(truth, link_latency=1, burst_bytes=4096,
                       flops_per_cycle=8192.0)

    def run():
        t0 = time.perf_counter()
        cp = calib_fit.fit_soc_params(obs, base=base)
        return time.perf_counter() - t0, cp

    run()   # warm the flit-sim cache: time the fit, not the simulations
    (dt, cp), sp = _best_of(3, run)
    ok = (cp.params.link_latency == truth.link_latency and
          cp.params.burst_bytes == truth.burst_bytes and
          abs(cp.params.flops_per_cycle - truth.flops_per_cycle)
          / truth.flops_per_cycle < 1e-6)
    if not ok:
        raise SystemExit("# FAIL: calib_fit stopped recovering the "
                         f"ground truth ({cp.params.link_latency}, "
                         f"{cp.params.burst_bytes}, "
                         f"{cp.params.flops_per_cycle:g})")
    _row("calib_fit", dt * 1e6,
         f"n_obs={cp.n_obs};residual={cp.residual:.5f};"
         f"recovered=link:{cp.params.link_latency}/"
         f"burst:{cp.params.burst_bytes}/"
         f"fpc:{cp.params.flops_per_cycle:g}",
         spread=sp * 1e6)


# ---------------------------------------------- comm modes (C2/C4, HLO) ----

def comm_mode_bytes():
    """Collective wire bytes of the dbrx MoE layer under the two modes —
    the production-framework analogue of Fig. 6 (multicast vs memory)."""
    import jax
    if len(jax.devices()) < 2:
        # measured from the persisted dry-run artifacts instead (the
        # matrix runs in a 512-device process)
        mem = _load_cell("dbrx-132b", "train_4k", "16x16", "mem")
        mc = _load_cell("dbrx-132b", "train_4k", "16x16", "mcast")
        if mem is None or mc is None:
            _row("comm_mode_bytes", 0.0, "needs dryrun artifacts (mem+mcast)")
            return
        b_mem = mem["roofline"]["wire_bytes_per_dev"]
        b_mc = mc["roofline"]["wire_bytes_per_dev"]
        _row("comm_mode_bytes", 0.0,
             f"mem_GB={b_mem/1e9:.2f};mcast_GB={b_mc/1e9:.2f};"
             f"saving={1 - b_mc / b_mem:.1%}")
        return
    # multi-device host: lower the reduced MoE step under both modes and
    # count collective wire bytes from the compiled HLO directly.  (The
    # dryrun import sets XLA_FLAGS, but jax is already initialized here, so
    # the device count cannot change.)
    try:
        from repro import compat
        from repro.configs import get_reduced
        from repro.configs.base import ShapeConfig
        from repro.launch.dryrun import build_comm_plan, lower_cell, make_flags
        from repro.launch.hlo_analysis import parse_collectives

        n = len(jax.devices())
        grid = (n // 2, 2) if n >= 4 else (1, n)
        mesh = compat.make_mesh(grid, ("data", "model"),
                                axis_types=(compat.AxisType.Auto,) * 2)
        cfg = get_reduced("dbrx-132b")
        shape = ShapeConfig("bench", 128, 4 * grid[0], "train")
        t0 = time.perf_counter()
        wire = {}
        for policy in ("mem", "mcast"):
            plan, _ = build_comm_plan(policy, cfg, shape, mesh)
            flags = make_flags(cfg, shape, moe_mode=policy)
            lowered, _ = lower_cell(cfg, shape, mesh, flags, comm_plan=plan)
            colls = parse_collectives(lowered.compile().as_text())
            wire[policy] = sum(c.wire_bytes for c in colls.values())
        dt = time.perf_counter() - t0
        saving = (1 - wire["mcast"] / wire["mem"]) if wire["mem"] else 0.0
        _row("comm_mode_bytes", dt * 1e6 / 2,
             f"devices={n};mem_MB={wire['mem']/1e6:.2f};"
             f"mcast_MB={wire['mcast']/1e6:.2f};saving={saving:.1%}")
    except Exception as e:   # noqa: BLE001 - report, don't hide, the skip
        _row("comm_mode_bytes", 0.0,
             f"skipped={type(e).__name__}: {str(e)[:80]}")


def _load_cell(arch, shape, mesh, mode=None, tag=""):
    suffix = (f"_{mode}" if mode else "") + (f"_{tag}" if tag else "")
    path = f"experiments/dryrun/{arch}_{shape}_{mesh}{suffix}.json"
    if not os.path.exists(path):
        return None
    return json.load(open(path))


# -------------------------------------------------------- roofline table ----

def roofline_table():
    print("# Roofline per (arch x shape x mesh) from dry-run artifacts")
    print("# arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_flops_ratio,roofline_fraction,peak_GiB,fits16GB")
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    n = 0
    worst = (1.0, None)
    for f in files:
        d = json.load(open(f))
        if d.get("skipped") or d.get("moe_mode") == "mcast":
            continue
        if "_hc" in os.path.basename(f):
            continue
        r, m = d["roofline"], d["memory"]
        print(f"# {d['arch']},{d['shape']},{d['mesh']},"
              f"{r['compute_s']*1e3:.1f},{r['memory_s']*1e3:.1f},"
              f"{r['collective_s']*1e3:.1f},{r['dominant']},"
              f"{r['useful_flops_ratio']:.2f},{r['roofline_fraction']:.4f},"
              f"{m['peak_bytes_est_per_dev']/2**30:.1f},"
              f"{m['fits_16gb']}")
        n += 1
        if r["roofline_fraction"] < worst[0]:
            worst = (r["roofline_fraction"], f"{d['arch']}x{d['shape']}")
    _row("roofline_table", 0.0, f"cells={n};worst={worst[1]}")


# ------------------------------------------------------------ NoC gate ----

def write_bench_json(path: str) -> None:
    rows = {name: {"us_per_call": us, "derived": derived, "spread": spread}
            for name, us, derived, spread in _ROWS}
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, sort_keys=True)
    print(f"# wrote {path} ({len(rows)} rows)")


def _derived_overlap_frac(derived: str):
    """Parse ``overlap_frac=0.xxxx`` out of a derived column (None when
    the row carries no fraction)."""
    for part in derived.split(";"):
        if part.startswith("overlap_frac="):
            return float(part.split("=", 1)[1])
    return None


def check_baseline(baseline_path: str) -> bool:
    """Compare the collected rows against a committed baseline: fail when a
    row's us_per_call regressed past CI_BENCH_TOL (wall-clock multiplier,
    default 5 — shared CI boxes are noisy) or a baseline row went missing.
    Rows carrying ``overlap_frac=`` in their derived column (step_overlap)
    are additionally gated EXACTLY: the fraction is closed-form model
    output, not wall clock, so any decrease is a planner regression."""
    tol = float(os.environ.get("CI_BENCH_TOL", "5"))
    with open(baseline_path) as f:
        base = json.load(f)
    rows = {name: (us, derived) for name, us, derived, _ in _ROWS}
    ok = True
    for name, entry in base.items():
        if name not in rows:
            print(f"# BENCH FAIL: row {name} missing from this run")
            ok = False
            continue
        b = entry["us_per_call"]
        got, derived = rows[name]
        if b > 0 and got > b * tol:
            print(f"# BENCH FAIL: {name} {got:.0f}us vs baseline {b:.0f}us "
                  f"(> {tol:.0f}x)")
            ok = False
        else:
            print(f"# bench ok: {name} {got:.0f}us (baseline {b:.0f}us)")
        base_frac = _derived_overlap_frac(entry.get("derived", ""))
        if base_frac is not None:
            frac = _derived_overlap_frac(derived)
            if frac is None or frac + 1e-9 < base_frac:
                print(f"# BENCH FAIL: {name} overlap_frac "
                      f"{'missing' if frac is None else f'{frac:.4f}'} vs "
                      f"baseline {base_frac:.4f} — overlap regressed")
                ok = False
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig6-check", action="store_true",
                    help="run only the Fig. 6 model + planner milestone "
                         "checks and exit nonzero on failure (CI gate)")
    ap.add_argument("--bench-noc", action="store_true",
                    help="run the NoC benchmark rows, write them to --out "
                         "and compare against --baseline (CI gate)")
    ap.add_argument("--out", default="BENCH_noc.json")
    ap.add_argument("--baseline", default="")
    args = ap.parse_args()

    print("name,us_per_call,derived,spread")
    if args.fig6_check:
        max_err = fig6_multicast()
        ok = comm_plan_fig6()
        if max_err > 0.10:
            print(f"# FAIL: Fig. 6 milestone error {max_err:.1%} > 10%")
            raise SystemExit(1)
        if not ok:
            print("# FAIL: planner policy checks failed")
            raise SystemExit(1)
        print("# fig6-check passed")
        return
    if args.bench_noc:
        fig6_multicast()
        comm_plan_fig6()
        ring_fused_matmul()
        step_overlap()
        pod_allreduce_compressed()
        noc_flit_microbench()
        noc_mesh_scale()
        socket_dispatch_overhead()
        commcheck_scan()
        serve_load()
        calib_fit_bench()
        write_bench_json(args.out)
        if args.baseline:
            if not check_baseline(args.baseline):
                raise SystemExit(1)
            print("# bench-noc baseline check passed")
        return
    fig4_router_area()
    fig6_multicast()
    comm_plan_fig6()
    ring_fused_matmul()
    step_overlap()
    pod_allreduce_compressed()
    noc_flit_microbench()
    noc_mesh_scale()
    socket_dispatch_overhead()
    commcheck_scan()
    serve_load()
    calib_fit_bench()
    comm_mode_bytes()
    roofline_table()
    write_bench_json(args.out)


if __name__ == "__main__":
    main()
