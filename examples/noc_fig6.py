"""Reproduce the paper's Fig. 6 on the NoC performance model.

  PYTHONPATH=src python examples/noc_fig6.py
"""

from repro.core.noc.perfmodel import SoCPerfModel, PAPER_MILESTONES
from repro.configs.espsoc_trafficgen import CONSUMER_SWEEP, SIZE_SWEEP


def main():
    model = SoCPerfModel()
    print("speedup of multicast over shared memory "
          "(rows: consumers, cols: data size)")
    print(f"{'N':>4} " + " ".join(f"{s//1024:>7d}KB" for s in SIZE_SWEEP))
    for n in CONSUMER_SWEEP:
        row = " ".join(f"{model.speedup(n, s):9.2f}" for s in SIZE_SWEEP)
        print(f"{n:>4} {row}")
    print("\npaper milestones:")
    for (n, s), target in sorted(PAPER_MILESTONES.items()):
        got = model.speedup(n, s)
        print(f"  {n:>2} consumers @ {s//1024:>5}KB: model {got:.2f}x "
              f"vs paper {target:.2f}x  ({(got-target)/target:+.1%})")


if __name__ == "__main__":
    main()
