"""Quickstart: train a reduced SmolLM on CPU in under a minute.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import SyntheticTokenStream
from repro.models.transformer import RunFlags
from repro.runtime.train import make_train_step, init_state


def main():
    cfg = get_reduced("smollm-135m")
    flags = RunFlags(remat="none")
    step_fn, _, _ = make_train_step(cfg, flags, lr=1e-3)
    jstep = jax.jit(step_fn, donate_argnums=0)
    state = init_state(jax.random.key(0), cfg, flags)
    stream = SyntheticTokenStream(cfg.vocab_size, global_batch=8, seq_len=128)

    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(step).items()}
        state, metrics = jstep(state, batch)
        if step % 5 == 0:
            print(f"step {step:3d}  loss {float(metrics['loss']):.4f}  "
                  f"grad-norm {float(metrics['grad_norm']):.3f}")
    print("quickstart done.")


if __name__ == "__main__":
    main()
