"""End-to-end driver: train SmolLM-135M for a few hundred steps, with an
injected node failure, asynchronous checkpoints, and a restart — the full
fault-tolerance loop on one box.

  PYTHONPATH=src python examples/train_end2end.py                # reduced, fast
  PYTHONPATH=src python examples/train_end2end.py --preset full  # real 135M

This simply drives the production launcher (repro.launch.train); see it for
the mesh-enabled variants.
"""

import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    argv = ["--arch", "smollm-135m", "--steps", "200",
            "--global-batch", "8", "--seq", "256",
            "--ckpt", "/tmp/repro_e2e_ckpt", "--ckpt-every", "40",
            "--inject-failure-at", "90"]
    sys.argv = [sys.argv[0]] + argv + sys.argv[1:]
    train_main()
