"""Continuous-batching serving on the engine, with the KV prefix moving
over a live stage axis — the paper's dataflow (1 producer, N consumers)
as a model-serving topology.

Part 1 drives :class:`repro.runtime.engine.ServeEngine` end to end: a
deterministic Poisson arrival trace is admitted into a single
continuously batched decode step over a paged block cache.  Every
admission's prefill->decode hand-off issues through the socket from the
``engine.kv_prefix`` descriptor; with no live stage axis inside the
engine's jit domain the write degrades to the MEM path *with a recorded
reason* — the issue log shows the transfer either way.  (The paged pools
are preallocated once by the engine's block layout: there is no per-call
cache repad, and leaf classification keys on the logical ``cache_axes``
names, never on shape coincidences.)

Part 2 replays the same descriptor on real tiles: 8 forced host devices
form the "stage" axis, rank 0 is the PREFILL producer and the engine's
registered decode consumers receive one admitted request's KV prefix by
MULTICAST (Fig. 1(c): one producer burst forked to N consumers, instead
of N reads from host memory).  Consumer ranks ride the LUT as *traced*
values, so retargeting a consumer mid-serve (``remap_consumer``) changes
where the burst lands without retracing.

  PYTHONPATH=src python examples/serve_pipeline.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_reduced
from repro.core import socket as socket_mod
from repro.runtime.engine import ServeEngine, poisson_trace


def main():
    cfg = get_reduced("qwen3-4b")
    S, GEN = 16, 8

    # ---- part 1: continuous batching over the paged cache ----------------
    eng = ServeEngine(cfg, prompt_len=S, max_new_tokens=GEN, n_slots=4,
                      block_size=8,
                      consumers=("decode1", "decode2", "decode3"))
    trace = poisson_trace(6, rate=0.8, prompt_len=S, vocab=cfg.vocab_size,
                          max_new_tokens=GEN, seed=7)
    t0 = time.monotonic()
    metrics = eng.run(trace)
    dt = time.monotonic() - t0

    print(f"engine: {metrics.n_requests} requests, "
          f"{metrics.total_new_tokens} tokens in {metrics.steps} batched "
          f"steps ({dt*1e3:.0f} ms wall)")
    print(f"  tokens/s={metrics.tokens_per_s:.1f}  "
          f"p50={metrics.p50_latency_s*1e3:.1f} ms  "
          f"p99={metrics.p99_latency_s*1e3:.1f} ms")
    for site, rec in socket_mod.issued_modes().items():
        print(f"  issued {site}: {rec['issued']} (user={rec['user_field']}, "
              f"impl={rec['impl']})")
    assert eng.trace_counts == {"prefill": 1, "decode": 1, "admit": 1}, \
        eng.trace_counts
    assert eng.allocator.n_used == 0, "eviction must return every block"
    kv = socket_mod.issued_modes()["engine.kv_prefix@prefill"]
    assert kv["degraded_reason"], "no stage axis -> recorded degradation"
    gens = {r.rid: r.generated for r in eng.completed}
    assert len({tuple(g) for g in gens.values()}) > 1, \
        "distinct prompts should decode distinct continuations"

    # ---- part 2: the same descriptor on a live 8-tile stage axis ---------
    mesh = compat.make_mesh((8,), ("stage",),
                            axis_types=(compat.AxisType.Auto,))
    writer = eng.make_stage_kv_writer("stage")
    # one admitted request's first-layer K prefix, as the burst payload
    leaf = jax.tree.leaves(eng.pools)[0]
    payload = np.zeros((8, leaf.size), np.float32)
    payload[0] = np.asarray(leaf, np.float32).reshape(-1)

    traces = []

    def burst(rows, ranks):
        traces.append(1)            # trace-time only: counts retraces
        return writer(rows, ranks)

    fn = jax.jit(compat.shard_map(
        burst, mesh=mesh, in_specs=(P("stage", None), P()),
        out_specs=P("stage", None), check_vma=False))

    out = np.asarray(fn(payload, eng.consumer_ranks()))
    for r in (1, 2, 3):
        np.testing.assert_allclose(out[r], payload[0])
    assert not out[6].any(), "rank 6 is not yet a consumer"

    eng.remap_consumer("decode3", 6)     # LUT update: retarget mid-serve
    out2 = np.asarray(fn(payload, eng.consumer_ranks()))
    for r in (1, 2, 6):
        np.testing.assert_allclose(out2[r], payload[0])
    assert not out2[3].any(), "rank 3 was remapped away"
    assert len(traces) == 1, f"stage burst retraced {len(traces)}x"

    rec = [r for r in socket_mod.issued_records()
           if r.site == "engine.kv_prefix"][-1]
    print(f"stage burst: issued {rec.issued} (user={rec.user}, "
          f"impl={rec.impl}) — remap retargeted rank 3 -> 6 with "
          f"{len(traces)} trace")
    print("ok: one multicast prefix burst, continuously batched decode, "
          "no retrace across remap.")


if __name__ == "__main__":
    main()
