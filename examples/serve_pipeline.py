"""Producer/consumer serving pipeline with P2P and multicast transfers —
the paper's dataflow (1 producer, N consumers) as a model-serving topology.

Stage layout on an 8-way "stage" axis (think: 8 accelerator tiles):
  rank 0      = PREFILL producer: runs the prompt, produces the KV prefix
  ranks 1..3  = DECODE consumers: each receives the prefix by MULTICAST and
                decodes its own continuation batch (e.g. different sampling)
The prefix transfer is exactly Fig. 1(c): one producer burst forked to N
consumers, instead of N reads from host memory.

Must run with >= 8 devices, so this script forces 8 host CPU devices.

  PYTHONPATH=src python examples/serve_pipeline.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.comm import TransferDescriptor
from repro.core.socket import AcceleratorSocket, StageRegistry, issued_modes
from repro.configs import get_reduced
from repro.models import transformer as T


def main():
    mesh = compat.make_mesh((8,), ("stage",),
                            axis_types=(compat.AxisType.Auto,))
    cfg = get_reduced("qwen3-4b")
    flags = T.RunFlags(param_dtype=jnp.bfloat16, remat="none",
                       cache_dtype=jnp.bfloat16)
    params = T.init_params(jax.random.key(0), cfg, flags.param_dtype)

    registry = StageRegistry("stage")
    registry.register("prefill", 0)
    consumers = [1, 2, 3]
    consumer_names = tuple(f"decode{i}" for i in consumers)
    for n, i in zip(consumer_names, consumers):
        registry.register(n, i)
    sock = AcceleratorSocket(registry)

    # the KV-prefix hand-off, as a typed descriptor: one producer burst
    # forked to the three decode consumers (write channel, user=3), with
    # the C3 sync fence folded in by the socket — the producer aggregates
    # the consumers' pull requests on the sync region before the bulk moves
    kv_desc = TransferDescriptor("kv_prefix", source="prefill",
                                 dests=consumer_names, sync=True,
                                 site="pipeline.kv_prefix")
    logits_desc = TransferDescriptor("prefill_logits", source="prefill",
                                     dests=consumer_names,
                                     site="pipeline.logits")

    B, S, GEN = 2, 32, 8
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0,
                                 cfg.vocab_size)

    def pipeline(params, prompts):
        me = jax.lax.axis_index("stage")

        # producer: prefill; consumers contribute zeros (pull-based: they
        # issue the same collective and wait on it — consumption assumption)
        logits, caches = T.prefill(params, prompts, cfg, flags)
        caches = jax.tree.map(
            lambda c: jnp.where(me == 0, c, jnp.zeros_like(c)), caches)

        # MULTICAST the KV prefix through the socket: one producer burst
        # forked to the consumer list (Fig. 1(c)); the producer rank keeps
        # its copy, non-consumers receive zeros they never read
        caches = jax.tree.map(lambda c: sock.write(c, kv_desc), caches)
        logits = sock.write(logits, logits_desc)

        # grow cache for generation
        def grow(leaf):
            if leaf.ndim >= 4 and leaf.shape[-3] == S:
                pad = [(0, 0)] * leaf.ndim
                pad[-3] = (0, GEN)
                return jnp.pad(leaf, pad)
            return leaf
        caches = jax.tree.map(grow, caches)

        # each consumer decodes its own continuation (greedy + rank offset
        # stands in for per-consumer sampling temperature)
        tok = ((jnp.argmax(logits[:, -1], axis=-1) + me) %
               cfg.vocab_size)[:, None].astype(jnp.int32)
        outs = [tok]
        for i in range(GEN - 1):
            lg, caches = T.decode_step(params, tok, jnp.int32(S + i),
                                       caches, cfg, flags)
            tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)

    fn = jax.jit(compat.shard_map(
        functools.partial(pipeline),
        mesh=mesh, in_specs=(P(), P()), out_specs=P("stage", None),
        check_vma=False))

    t0 = time.monotonic()
    gen = fn(params, prompts)          # (8*B, GEN), stage-major
    gen = np.asarray(jax.block_until_ready(gen)).reshape(8, B, GEN)
    dt = time.monotonic() - t0

    print(f"pipeline: 1 prefill producer -> {len(consumers)} multicast "
          f"decode consumers")
    for site, rec in issued_modes().items():
        print(f"  issued {site}: {rec['issued']} (user={rec['user_field']}, "
              f"impl={rec['impl']})")
    print(f"batch={B} prompt={S} gen={GEN}  wall={dt*1e3:.0f} ms")
    for c in consumers:
        print(f"  consumer {c}: tokens {gen[c, 0, :8].tolist()}")
    # consumers with the same seed+offset=0 logic would match the producer;
    # different offsets -> diverging continuations, but all from ONE prefix
    assert not np.array_equal(gen[1], gen[2])
    print("ok: consumers decoded distinct continuations from one multicast "
          "prefix.")


if __name__ == "__main__":
    main()
